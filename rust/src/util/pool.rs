//! A small persistent worker pool for the Solve stage.
//!
//! The staged planner used to spawn a fresh `std::thread::scope` for every
//! re-plan's parallel per-region solves — at adaptive cadence that is
//! thread spawn/teardown on the hot path, paid even when only two small
//! components actually need solving. A [`WorkerPool`] keeps its threads
//! parked on a condvar between re-plans, so a warm re-plan's solve cost is
//! the solves themselves.
//!
//! Jobs are `'static` closures (the Solve stage moves each subproblem into
//! its job and shares the graph cache behind an `Arc`); results travel back
//! over the caller's channel. A panicking job is contained to that job —
//! the worker survives and keeps serving the queue.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    /// (queue, shutdown flag) under one lock so workers can't miss a wake.
    queue: Mutex<(VecDeque<Job>, bool)>,
    cv: Condvar,
}

/// Fixed-size pool of parked worker threads. Dropping the pool drains the
/// queue and joins every worker.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (at least one).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("camflow-solve-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut q = shared.queue.lock().unwrap();
                            loop {
                                if let Some(job) = q.0.pop_front() {
                                    break job;
                                }
                                if q.1 {
                                    return;
                                }
                                q = shared.cv.wait(q).unwrap();
                            }
                        };
                        // Contain panics to the job: the caller observes the
                        // loss through its result channel, the worker lives.
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    })
                    .expect("spawn solve worker")
            })
            .collect();
        WorkerPool { shared, workers }
    }

    /// Default worker count: the machine's parallelism, bounded so portfolio
    /// planners holding several pools stay reasonable.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job; some parked worker picks it up.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.queue.lock().unwrap().0.push_back(Box::new(job));
        self.shared.cv.notify_one();
    }
}

/// A lazily-spawned, shareable slot for a [`WorkerPool`].
///
/// The staged planner wants two things at once: pool threads spawned only
/// when a parallel solve actually happens (a serial or single-component
/// planner should never pay thread startup), and *one* pool shared by every
/// planning context of a portfolio (`coordinator::portfolio`) instead of one
/// pool per candidate. A `PoolSlot` provides both — contexts hold
/// `Arc<PoolSlot>` clones, and the first parallel solve through any of them
/// spawns the workers that all of them then share.
#[derive(Default)]
pub struct PoolSlot {
    slot: OnceLock<Arc<WorkerPool>>,
}

impl PoolSlot {
    pub fn new() -> Self {
        PoolSlot::default()
    }

    /// The shared pool, spawning its workers on first use.
    pub fn get(&self) -> Arc<WorkerPool> {
        Arc::clone(
            self.slot
                .get_or_init(|| Arc::new(WorkerPool::new(WorkerPool::default_threads()))),
        )
    }

    /// True once some parallel solve has spawned the workers.
    pub fn spawned(&self) -> bool {
        self.slot.get().is_some()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().1 = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;

    #[test]
    fn runs_every_job_across_workers() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for i in 0..64usize {
            let hits = Arc::clone(&hits);
            let tx = tx.clone();
            pool.execute(move || {
                hits.fetch_add(1, Ordering::Relaxed);
                tx.send(i).unwrap();
            });
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn a_panicking_job_does_not_kill_the_worker() {
        let pool = WorkerPool::new(1);
        let (tx, rx) = mpsc::channel();
        {
            let tx = tx.clone();
            pool.execute(move || {
                let _keep = tx; // dropped unsent on panic
                panic!("job panic");
            });
        }
        pool.execute(move || tx.send(42u32).unwrap());
        // The single worker must survive the first job to run the second.
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = mpsc::channel();
        for _ in 0..8 {
            let tx = tx.clone();
            pool.execute(move || tx.send(()).unwrap());
        }
        drop(tx);
        assert_eq!(rx.iter().count(), 8);
        drop(pool); // must not hang
    }

    #[test]
    fn pool_slot_is_lazy_and_shared() {
        let slot = Arc::new(PoolSlot::new());
        assert!(!slot.spawned(), "no workers before the first get()");
        let a = Arc::clone(&slot);
        let b = Arc::clone(&slot);
        let pa = a.get();
        assert!(slot.spawned());
        let pb = b.get();
        assert!(Arc::ptr_eq(&pa, &pb), "every holder must see one pool");
        let (tx, rx) = mpsc::channel();
        pb.execute(move || tx.send(7u8).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn zero_threads_is_clamped_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        let (tx, rx) = mpsc::channel();
        pool.execute(move || tx.send(1u8).unwrap());
        assert_eq!(rx.recv().unwrap(), 1);
    }
}
