//! Summary statistics used by the bench harness, metrics, and experiments.

/// Online + batch summary of a sample set.
///
/// Empty-set convention: `mean`, `min`, `max`, and `percentile` all return
/// NaN (previously `min`/`max` returned ±∞, which silently survived
/// comparisons that NaN would have surfaced).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn var(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Percentile via nearest-rank (`⌈q/100·n⌉`-th sorted sample); q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((q / 100.0) * n as f64).ceil() as isize - 1;
        self.samples[rank.clamp(0, n as isize - 1) as usize]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Relative difference |a-b| / max(|a|,|b|,eps) — used when comparing measured
/// ratios against the paper's reported ones.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_of_known_set() {
        let s = Summary::from_slice(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::from_slice(&(1..=100).map(|x| x as f64).collect::<Vec<_>>());
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
        assert!(s.min().is_nan(), "empty min must match the NaN convention");
        assert!(s.max().is_nan(), "empty max must match the NaN convention");
    }

    #[test]
    fn min_max() {
        let s = Summary::from_slice(&[3.0, -1.0, 7.5]);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 7.5);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert!(rel_diff(10.0, 11.0) > 0.0);
        assert_eq!(rel_diff(5.0, 5.0), 0.0);
        assert!((rel_diff(10.0, 11.0) - rel_diff(11.0, 10.0)).abs() < 1e-15);
    }
}
