//! Tiny property-testing harness (the `proptest` crate is not vendored).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` random inputs; on
//! failure it performs greedy shrinking via the input's `Shrink` impl and
//! panics with the minimal counterexample and the reproducing seed.

use super::rng::Rng;
use std::fmt::Debug;

/// Types that can propose structurally smaller variants of themselves.
pub trait Shrink: Sized {
    /// Candidate smaller values, roughly ordered by aggressiveness.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|v| v as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            out.push(self.trunc());
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Halve, drop one element, shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        for i in 0..self.len().min(4) {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        for (i, x) in self.iter().enumerate().take(4) {
            for sx in x.shrink() {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`; shrink on failure.
///
/// `prop` returns `Err(reason)` (or panics) to signal failure.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Clone + Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> std::result::Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(reason) = run_guarded(&prop, &input) {
            let (min_input, min_reason) = shrink_loop(&prop, input, reason);
            panic!(
                "property failed (seed={seed}, case={case}): {min_reason}\n\
                 minimal counterexample: {min_input:?}"
            );
        }
    }
}

fn run_guarded<T, P>(prop: &P, input: &T) -> std::result::Result<(), String>
where
    T: Debug,
    P: Fn(&T) -> std::result::Result<(), String>,
{
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(input))) {
        Ok(r) => r,
        Err(e) => {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".to_string());
            Err(format!("panicked: {msg}"))
        }
    }
}

fn shrink_loop<T, P>(prop: &P, mut input: T, mut reason: String) -> (T, String)
where
    T: Shrink + Clone + Debug,
    P: Fn(&T) -> std::result::Result<(), String>,
{
    // Greedy: take the first shrunk candidate that still fails; stop when no
    // candidate fails or after a bounded number of rounds.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in input.shrink() {
            if let Err(r) = run_guarded(prop, &cand) {
                input = cand;
                reason = r;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (input, reason)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            1,
            200,
            |r| r.range_u64(0, 1000),
            |&x| {
                if x.wrapping_add(1) > x || x == u64::MAX {
                    Ok(())
                } else {
                    Err("overflow".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks_and_panics() {
        check(
            2,
            200,
            |r| r.range_u64(0, 1000),
            |&x| if x < 10 { Ok(()) } else { Err(format!("{x} >= 10")) },
        );
    }

    #[test]
    fn vec_shrink_produces_smaller() {
        let v = vec![5u64, 6, 7, 8];
        let shrunk = v.shrink();
        assert!(shrunk.iter().any(|s| s.len() < v.len()));
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property "sum < 100" fails; shrinker should find a small vec.
        let result = std::panic::catch_unwind(|| {
            check(
                3,
                100,
                |r| (0..20).map(|_| r.range_u64(0, 50)).collect::<Vec<u64>>(),
                |v| {
                    if v.iter().sum::<u64>() < 100 {
                        Ok(())
                    } else {
                        Err("sum too big".into())
                    }
                },
            )
        });
        let msg = match result {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("expected failure"),
        };
        assert!(msg.contains("minimal counterexample"));
    }
}
