//! Minimal JSON parser/serializer (serde is not vendored in this image).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest, scenario files, and run configs. Errors carry the byte
//! offset for debuggability.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; errors mention the key for diagnosis.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| Error::config(format!("missing JSON field '{key}'")))
    }
    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.get(key)?
            .as_f64()
            .ok_or_else(|| Error::config(format!("field '{key}' is not a number")))
    }
    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?
            .as_usize()
            .ok_or_else(|| Error::config(format!("field '{key}' is not a non-negative integer")))
    }
    pub fn get_str(&self, key: &str) -> Result<&str> {
        self.get(key)?
            .as_str()
            .ok_or_else(|| Error::config(format!("field '{key}' is not a string")))
    }
    pub fn get_arr(&self, key: &str) -> Result<&[Value]> {
        self.get(key)?
            .as_arr()
            .ok_or_else(|| Error::config(format!("field '{key}' is not an array")))
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Value>) -> Value {
        Value::Arr(items)
    }
    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
}

pub fn parse(input: &str) -> Result<Value> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing data after JSON value"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            offset: self.i,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        let start = self.i - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, 0, true);
    out
}

/// Serialize compactly.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, 0, false);
    out
}

fn write_value(out: &mut String, v: &Value, indent: usize, pretty: bool) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_escaped(out, s),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                }
                write_value(out, item, indent + 1, pretty);
            }
            if pretty {
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
            }
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                }
                write_escaped(out, k);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(out, val, indent + 1, pretty);
            }
            if pretty {
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
            }
            out.push('}');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"c\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn error_carries_offset() {
        match parse("[1, x]") {
            Err(Error::Json { offset, .. }) => assert_eq!(offset, 4),
            other => panic!("expected Json error, got {other:?}"),
        }
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Value::obj(vec![
            ("name", Value::str("vgg16")),
            ("batch", Value::num(4.0)),
            ("shapes", Value::arr(vec![Value::num(1.0), Value::num(64.0)])),
            ("ok", Value::Bool(true)),
        ]);
        for s in [to_string(&v), to_string_pretty(&v)] {
            assert_eq!(parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn real_manifest_roundtrip() {
        let src = r#"{
          "version": 1,
          "models": [
            {"name": "vgg16", "batch": 1, "param_shapes": [[3,3,3,8],[8]],
             "flops_per_frame": 20700000}
          ]
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get_usize("version").unwrap(), 1);
        let models = v.get_arr("models").unwrap();
        assert_eq!(models[0].get_str("name").unwrap(), "vgg16");
        assert_eq!(models[0].get_f64("flops_per_frame").unwrap(), 20.7e6);
        let reparsed = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(reparsed, v);
    }

    #[test]
    fn typed_getters_error_cleanly() {
        let v = parse(r#"{"a": "x"}"#).unwrap();
        assert!(v.get_f64("a").is_err());
        assert!(v.get("missing").is_err());
        assert!(v.get_usize("a").is_err());
    }

    #[test]
    fn negative_not_usize() {
        let v = parse(r#"{"a": -3}"#).unwrap();
        assert!(v.get_usize("a").is_err());
        assert_eq!(v.get("a").unwrap().as_i64(), Some(-3));
    }
}
