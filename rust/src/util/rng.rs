//! Deterministic PRNG: SplitMix64 seeding + Xoshiro256** generation.
//!
//! Replaces the unavailable `rand` crate. All simulations, workload
//! generators, and property tests seed from here so every experiment is
//! reproducible from a single u64.

/// Xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent sub-stream (e.g. per camera, per worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [lo, hi) (hi exclusive; lo < hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (mean 1/rate); rate > 0.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference to a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            let v = r.range_u64(10, 20);
            assert!((10..20).contains(&v));
            let f = r.range_f64(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&f));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
