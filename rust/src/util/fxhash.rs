//! A dependency-free Fx-style hasher for the planner's hot maps.
//!
//! `std`'s default `HashMap` hasher is SipHash-1-3: DoS-resistant, but ~10×
//! slower than a multiply-xor hash on the short fixed-size keys the planner
//! uses everywhere (bit-packed floats, `u32` group ids, stream keys). None
//! of those maps are fed attacker-controlled keys — they hold the planner's
//! own derived state — so the hot paths trade the DoS resistance away:
//! the eligibility memo, the solution memo, the arc-flow graph cache, and
//! Expand's stream→slot maps all key through [`FxHashMap`].
//!
//! The algorithm is the word-at-a-time multiply-rotate-xor scheme used by
//! the Firefox/rustc "FxHash" family: fold each 8-byte word `w` into the
//! state as `h = (rotl(h, 5) ^ w) * K` with an odd 64-bit constant `K`.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// The odd multiplier: pi's fractional bits, as used by the Fx family.
const K: u64 = 0x517c_c1b7_2722_0a95;

/// Word-at-a-time multiply-rotate-xor hasher. Not DoS-resistant — use only
/// for maps whose keys the process itself derives.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(K);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            // Pad the tail into one word; its length rides in the top byte
            // (rem is at most 7 bytes, so byte 7 is always free) so "ab"
            // and "ab\0" fold differently.
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            buf[7] = rem.len() as u8;
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// A `HashMap` keyed through [`FxHasher`]. Construct with
/// `FxHashMap::default()` (`new()` is not available on non-default
/// hashers).
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// A `HashSet` hashed through [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::{BuildHasher, Hash};

    fn hash_of<T: Hash>(v: &T) -> u64 {
        FxBuildHasher::default().hash_one(v)
    }

    #[test]
    fn equal_keys_hash_equal_and_unequal_usually_differ() {
        assert_eq!(hash_of(&(1u64, 2u64)), hash_of(&(1u64, 2u64)));
        assert_ne!(hash_of(&(1u64, 2u64)), hash_of(&(2u64, 1u64)));
        assert_eq!(hash_of(&"stream-key"), hash_of(&"stream-key"));
        assert_ne!(hash_of(&"ab"), hash_of(&"ab\0"), "tail length must fold in");
    }

    #[test]
    fn map_and_set_behave_like_std() {
        let mut m: FxHashMap<(u64, u64, u64), usize> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert((i, i.wrapping_mul(31), i ^ 0xF0F0), i as usize);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m.get(&(i, i.wrapping_mul(31), i ^ 0xF0F0)), Some(&(i as usize)));
        }
        let mut s: FxHashSet<String> = FxHashSet::default();
        assert!(s.insert("a".into()));
        assert!(!s.insert("a".into()));
    }

    #[test]
    fn spread_is_not_degenerate() {
        // 4k sequential keys should not collapse into a handful of hashes.
        let mut seen: FxHashSet<u64> = FxHashSet::default();
        for i in 0..4096u64 {
            seen.insert(hash_of(&i));
        }
        assert!(seen.len() > 4000, "only {} distinct hashes", seen.len());
    }
}
