//! Small self-contained substrates: PRNG, statistics, JSON, property testing.
//!
//! The build image has no network access and only the `xla` crate's dependency
//! closure vendored, so the usual ecosystem crates (`rand`, `serde`,
//! `proptest`, `criterion`) are re-implemented here at the scale this project
//! needs. See DESIGN.md "Substitutions".

pub mod bitset;
pub mod fxhash;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::Rng;
pub use stats::Summary;

/// Format a dollar amount the way the paper's tables do (`$1.676`).
pub fn fmt_usd(v: f64) -> String {
    format!("${:.3}", v)
}

/// Round to `d` decimal places (used when comparing costs to paper rows).
pub fn round_dp(v: f64, d: u32) -> f64 {
    let m = 10f64.powi(d as i32);
    (v * m).round() / m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_usd_matches_paper_style() {
        assert_eq!(fmt_usd(1.676), "$1.676");
        assert_eq!(fmt_usd(0.65), "$0.650");
    }

    #[test]
    fn round_dp_works() {
        assert_eq!(round_dp(1.23456, 2), 1.23);
        assert_eq!(round_dp(0.4191, 3), 0.419);
    }
}
