//! Fixed-width bitsets for the planner's hot paths.
//!
//! The front-end used to key its group-coalescing maps on a heap-allocated
//! `Vec<bool>` region mask: every request paid an allocation plus a
//! byte-by-byte hash/compare per map operation, which dominated re-plan time
//! at the 10k-stream metro scale. A [`BitSet`] is `Copy`, pointer-free, and
//! hashes as a handful of words, so `GroupKey`s become plain values and the
//! interning arena ([`GroupArena`](crate::coordinator::eligibility::GroupArena))
//! can hand out dense `u32` ids for them.
//!
//! Two widths are used in the crate:
//!
//! * [`RegionMask`] (256 bits) — eligible-region masks over
//!   `catalog.regions`,
//! * [`BinMask`] (512 bits) — item↔bin-type compatibility in the packing
//!   layer (offerings = instance types × regions).

/// A fixed-width bitset over `64 * W` bits. `Copy`, cheaply hashable, and
/// totally ordered (lexicographic on words, ascending bit index within).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BitSet<const W: usize> {
    words: [u64; W],
}

/// Eligible-region bitmask: supports catalogs of up to 256 regions (the
/// built-in catalog has 15; the planner rejects larger catalogs up front).
pub type RegionMask = BitSet<4>;

/// Bin-type bitmask for the packing layer: up to 512 offerings. Problems
/// with more bin types fall back to the scan paths (see
/// [`PackingProblem::placeable_masks`](crate::packing::PackingProblem::placeable_masks)).
pub type BinMask = BitSet<8>;

impl<const W: usize> Default for BitSet<W> {
    fn default() -> Self {
        BitSet { words: [0; W] }
    }
}

impl<const W: usize> BitSet<W> {
    /// Number of addressable bits.
    pub const CAPACITY: usize = 64 * W;

    /// The empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// The set `{0, 1, .., n-1}`. Panics if `n > CAPACITY`.
    pub fn full(n: usize) -> Self {
        assert!(n <= Self::CAPACITY, "BitSet::full({n}) exceeds {} bits", Self::CAPACITY);
        let mut words = [0u64; W];
        let (full_words, rem) = (n / 64, n % 64);
        for w in words.iter_mut().take(full_words) {
            *w = u64::MAX;
        }
        if rem > 0 {
            words[full_words] = (1u64 << rem) - 1;
        }
        BitSet { words }
    }

    /// Set bit `i`. Panics if `i >= CAPACITY`.
    #[inline]
    pub fn set(&mut self, i: usize) {
        assert!(i < Self::CAPACITY, "BitSet bit {i} exceeds {} bits", Self::CAPACITY);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Test bit `i` (out-of-range bits read as unset).
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        i < Self::CAPACITY && (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// True iff any bit is set.
    pub fn any(&self) -> bool {
        self.words.iter().any(|&w| w != 0)
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate set bits in ascending order.
    pub fn ones(&self) -> Ones<W> {
        Ones { words: self.words, word: 0 }
    }
}

/// Iterator over the set bits of a [`BitSet`], ascending.
pub struct Ones<const W: usize> {
    words: [u64; W],
    word: usize,
}

impl<const W: usize> Iterator for Ones<W> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.word < W {
            let w = self.words[self.word];
            if w != 0 {
                let bit = w.trailing_zeros() as usize;
                self.words[self.word] = w & (w - 1);
                return Some(self.word * 64 + bit);
            }
            self.word += 1;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip_across_word_boundaries() {
        let mut s = RegionMask::new();
        for i in [0, 1, 63, 64, 65, 127, 128, 255] {
            assert!(!s.get(i));
            s.set(i);
            assert!(s.get(i));
        }
        assert_eq!(s.count(), 8);
        assert_eq!(s.ones().collect::<Vec<_>>(), vec![0, 1, 63, 64, 65, 127, 128, 255]);
    }

    #[test]
    fn full_matches_per_bit_sets() {
        for n in [0, 1, 15, 63, 64, 65, 200, 256] {
            let full = RegionMask::full(n);
            let mut manual = RegionMask::new();
            for i in 0..n {
                manual.set(i);
            }
            assert_eq!(full, manual, "full({n})");
            assert_eq!(full.count(), n);
            assert_eq!(full.any(), n > 0);
        }
    }

    #[test]
    fn out_of_range_get_is_false() {
        let s = RegionMask::full(256);
        assert!(!s.get(256));
        assert!(!s.get(usize::MAX));
    }

    #[test]
    #[should_panic]
    fn out_of_range_set_panics() {
        let mut s = RegionMask::new();
        s.set(256);
    }

    #[test]
    fn equal_sets_hash_equal() {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let mut a = BinMask::new();
        let mut b = BinMask::new();
        a.set(300);
        b.set(300);
        let h = |s: &BinMask| {
            let mut hh = DefaultHasher::new();
            s.hash(&mut hh);
            hh.finish()
        };
        assert_eq!(a, b);
        assert_eq!(h(&a), h(&b));
    }
}
