//! Minimal benchmark harness (criterion is not vendored in this image).
//!
//! Benches are `harness = false` binaries that use [`Bench`] to run warmup +
//! timed iterations and print a fixed-width table — the same rows/series the
//! paper's tables and figures report.

pub mod closedloop;
pub mod portfolio;
pub mod schema;
pub mod spot;

use crate::util::Summary;
use std::time::Instant;

/// One benchmark runner.
pub struct Bench {
    pub warmup_iters: usize,
    pub measure_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, measure_iters: 10 }
    }
}

/// Result of a timed run.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub iters: usize,
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup_iters: warmup, measure_iters: iters }
    }

    /// Time `f` (called once per iteration).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Timing {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            s.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        Timing {
            name: name.to_string(),
            mean_ms: s.mean(),
            p50_ms: s.p50(),
            p99_ms: s.p99(),
            min_ms: s.min(),
            max_ms: s.max(),
            iters: self.measure_iters,
        }
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} mean {:>9.3} ms  p50 {:>9.3}  p99 {:>9.3}  min {:>9.3}  max {:>9.3}  (n={})",
            self.name, self.mean_ms, self.p50_ms, self.p99_ms, self.min_ms, self.max_ms, self.iters
        )
    }
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_added(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:<w$} | ", w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bench::new(1, 5);
        let mut count = 0;
        let t = b.run("noop", || count += 1);
        assert_eq!(count, 6); // warmup + measured
        assert_eq!(t.iters, 5);
        assert!(t.mean_ms >= 0.0);
        assert!(t.min_ms <= t.max_ms);
        assert!(t.to_string().contains("noop"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Strategy", "Cost"]);
        t.row(&["ST1".to_string(), "$1.676".to_string()]);
        t.row(&["ST3".to_string(), "$0.650".to_string()]);
        let s = t.render();
        assert!(s.contains("Strategy"));
        assert!(s.contains("$0.650"));
        assert_eq!(s.lines().count(), 4);
        assert_eq!(t.rows_added(), 2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["A", "B"]);
        t.row(&["only-one".to_string()]);
    }
}
