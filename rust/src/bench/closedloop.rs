//! Closed-loop feedback scenarios shared by `bench_closedloop` and the
//! integration suite.
//!
//! Living in the library (rather than inside the bench binary) keeps the
//! `BENCH_closedloop.json` fields and the schema test in
//! `tests/integration.rs` in lockstep: both call [`run`] and read the same
//! [`ClosedLoopOutcome`]. Both scenarios drive the full loop —
//! [`SimExecutor`] epoch → [`FeedbackController::observe`] →
//! [`AdaptiveManager::replan_with_feedback`] → next epoch — on a one-type
//! one-region CPU catalog (`c4.2xlarge` @ `us-east-2`) where every packing
//! is exactly computable by hand:
//!
//! * **Over-declared fleet** ([`run_overdeclared_scenario`]) — four
//!   VGG16@1fps VGA streams whose true frames cost *half* the declared
//!   profile. The declared plan needs one box per stream; once the
//!   controller's cost EWMA converges to 0.5 the re-plan packs three
//!   streams per box. The bar: closed-loop plan cost ≤ (here: strictly
//!   below) the declared-demand plan cost, with no drops and no sheds,
//!   and fleet utilization *rises* as the fleet right-sizes.
//! * **Under-declared fleet** ([`run_underdeclared_scenario`]) — four
//!   ZF@1.5fps VGA streams whose true frames cost *twice* the declared
//!   profile, so the declared two-box plan is overloaded 1.5×. Open-loop
//!   the queues overflow and drop indefinitely; closed-loop the degrade
//!   tiers shed fps as the queue crosses the high-water mark, the cost
//!   estimate corrects to 2.0, the next re-plan provisions real capacity,
//!   and sustained headroom restores every tier. The bar: the final epoch's
//!   drop rate is bounded (≤ 1%) while the no-feedback control keeps
//!   dropping (> 10%), and no stream is ever shed to zero fps.
//!
//! Each epoch re-simulates the current plan from an empty queue (a fluid
//! approximation: in-flight frames do not migrate across re-plans).
//!
//! [`SimExecutor`]: crate::server::sim::SimExecutor
//! [`FeedbackController::observe`]: crate::server::feedback::FeedbackController::observe
//! [`AdaptiveManager::replan_with_feedback`]: crate::coordinator::adaptive::AdaptiveManager::replan_with_feedback

use crate::cameras::{camera_at, StreamRequest};
use crate::catalog::Catalog;
use crate::cloudsim::CloudSim;
use crate::coordinator::adaptive::AdaptiveManager;
use crate::coordinator::{Plan, Planner, PlannerConfig};
use crate::geo::cities;
use crate::profiles::{Program, Resolution};
use crate::server::feedback::{FeedbackConfig, FeedbackController};
use crate::server::sim::{SimConfig, SimExecutor};
use crate::util::json::Value;

/// Over-declared scenario measurements ([`run_overdeclared_scenario`]).
#[derive(Clone, Debug)]
pub struct OverDeclared {
    /// Hourly cost of the plan built from declared demand.
    pub declared_usd_per_hour: f64,
    /// Hourly cost after the feedback loop converged (the bar: ≤ declared).
    pub closedloop_usd_per_hour: f64,
    /// Drop rate of the final (right-sized) epoch — expected ≈ 0.
    pub final_drop_rate: f64,
    /// Mean fleet utilization under the declared plan / the converged plan.
    pub fleet_util_declared: f64,
    pub fleet_util_closed: f64,
    /// `SolverMetrics::feedback_streams` accumulated by the manager's
    /// context — streams provisioned from observed demand.
    pub feedback_streams: u64,
}

/// Under-declared scenario measurements ([`run_underdeclared_scenario`]).
#[derive(Clone, Debug)]
pub struct UnderDeclared {
    pub declared_usd_per_hour: f64,
    /// Hourly cost once the plan provisions for the observed (2×) demand.
    pub corrected_usd_per_hour: f64,
    /// Drop rate of the first epoch (declared plan, true load 1.5×).
    pub epoch0_drop_rate: f64,
    /// Drop rate of the final epoch (the bounded bar: ≤ 1%).
    pub final_drop_rate: f64,
    /// Drop rate of the open-loop control over the same horizon.
    pub nofeedback_drop_rate: f64,
    /// Deepest degrade tier any stream was planned at.
    pub max_shed_tier: u8,
    /// Peak `ServeReport::streams_shed` across the epochs.
    pub peak_streams_shed: usize,
    /// `SolverMetrics::degraded_tier_streams` accumulated by the manager.
    pub degraded_tier_streams: u64,
}

/// Everything the closed-loop scenarios measure, mirrored (flattened with
/// `over_` / `under_` prefixes) into `BENCH_closedloop.json` by
/// [`ClosedLoopOutcome::to_json`].
#[derive(Clone, Debug)]
pub struct ClosedLoopOutcome {
    pub over: OverDeclared,
    pub under: UnderDeclared,
}

impl ClosedLoopOutcome {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("over_declared_usd_per_hour", Value::num(self.over.declared_usd_per_hour)),
            ("over_closedloop_usd_per_hour", Value::num(self.over.closedloop_usd_per_hour)),
            ("over_final_drop_rate", Value::num(self.over.final_drop_rate)),
            ("over_fleet_util_declared", Value::num(self.over.fleet_util_declared)),
            ("over_fleet_util_closed", Value::num(self.over.fleet_util_closed)),
            ("over_feedback_streams", Value::num(self.over.feedback_streams as f64)),
            ("under_declared_usd_per_hour", Value::num(self.under.declared_usd_per_hour)),
            ("under_corrected_usd_per_hour", Value::num(self.under.corrected_usd_per_hour)),
            ("under_epoch0_drop_rate", Value::num(self.under.epoch0_drop_rate)),
            ("under_final_drop_rate", Value::num(self.under.final_drop_rate)),
            ("under_nofeedback_drop_rate", Value::num(self.under.nofeedback_drop_rate)),
            ("under_max_shed_tier", Value::num(self.under.max_shed_tier as f64)),
            ("under_peak_streams_shed", Value::num(self.under.peak_streams_shed as f64)),
            (
                "under_degraded_tier_streams",
                Value::num(self.under.degraded_tier_streams as f64),
            ),
        ])
    }
}

/// One CPU type in one region: every packing below is hand-checkable and
/// the closed loop's effects show up purely as instance *counts*.
fn cpu_catalog() -> Catalog {
    Catalog::builtin().restrict(Some(&["c4.2xlarge"]), Some(&["us-east-2"]))
}

fn chicago_workload(program: Program, fps: f64, n: usize) -> Vec<StreamRequest> {
    (0..n)
        .map(|i| {
            StreamRequest::new(
                camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                program,
                fps,
            )
        })
        .collect()
}

/// Clone the manager's deployed state so a sim epoch can run while the
/// manager stays mutable for the next re-plan.
fn current_state(mgr: &AdaptiveManager) -> (Vec<StreamRequest>, Plan) {
    let (r, p) = mgr.current.as_ref().expect("manager has a deployed plan");
    (r.clone(), p.clone())
}

/// Over-declared fleet: true cost 0.5× declared; the loop halves the fleet.
/// Panics if any closed-loop invariant breaks — the bench and the test
/// suite both gate on it.
pub fn run_overdeclared_scenario() -> OverDeclared {
    let catalog = cpu_catalog();
    let mut mgr = AdaptiveManager::new(Planner::new(catalog.clone(), PlannerConfig::st1()));
    let mut fc = FeedbackController::new(FeedbackConfig::default());
    let mut cloud = CloudSim::new(catalog.clone());
    // Declared: 4.91 vcpus per stream -> one box each. At the true 0.5x
    // compute cost: 2.53 vcpus -> three per box.
    let requests = chicago_workload(Program::Vgg16, 1.0, 4);
    let true_scale = vec![0.5; requests.len()];

    mgr.replan(requests).unwrap();
    let (declared_requests, declared_plan) = current_state(&mgr);
    let declared_usd = declared_plan.cost_per_hour;
    cloud.apply_plan(&declared_plan).unwrap();
    cloud.set_plan_loads(&declared_plan, &declared_requests).unwrap();
    let fleet_util_declared = cloud.fleet_utilization();

    let mut final_drop_rate = 1.0;
    let mut last_changed = usize::MAX;
    for epoch in 0..3 {
        let (reqs, plan) = current_state(&mgr);
        let sim =
            SimExecutor::new(&catalog, &plan, &reqs, &true_scale, SimConfig::default()).unwrap();
        let out = sim.run().unwrap();
        assert_eq!(
            out.report.streams_shed, 0,
            "an over-declared fleet must never shed (epoch {epoch}): {:?}",
            out.report
        );
        assert!(
            out.report.drop_rate() < 0.01,
            "an over-declared fleet must not drop (epoch {epoch}): {:?}",
            out.report
        );
        final_drop_rate = out.report.drop_rate();
        fc.observe(&out.windows);
        // The closed loop carries the fed-back workload forward, so
        // `changed` is the true feedback delta between consecutive plans.
        let (_, changed) = mgr.replan_with_feedback(reqs, &fc).unwrap();
        last_changed = changed;
    }
    assert_eq!(
        last_changed, 0,
        "the cost estimate must converge to a zero-delta (no-op) re-plan"
    );

    let (final_requests, final_plan) = current_state(&mgr);
    let closedloop_usd = final_plan.cost_per_hour;
    // The acceptance bar, and by construction strictly cheaper here.
    assert!(
        closedloop_usd <= declared_usd + 1e-9,
        "closed-loop plan ${closedloop_usd}/h exceeds declared ${declared_usd}/h"
    );
    assert!(
        closedloop_usd < declared_usd - 1e-9,
        "observed 0.5x demand must consolidate the fleet: ${closedloop_usd}/h vs ${declared_usd}/h"
    );
    cloud.apply_plan(&final_plan).unwrap();
    cloud.set_plan_loads(&final_plan, &final_requests).unwrap();
    let fleet_util_closed = cloud.fleet_utilization();
    assert!(
        fleet_util_closed > fleet_util_declared,
        "right-sizing must raise fleet utilization: {fleet_util_closed} vs {fleet_util_declared}"
    );
    let feedback_streams = mgr.ctx.main.solver.feedback_streams.get();
    assert!(feedback_streams > 0, "re-plans must count feedback-provisioned streams");
    OverDeclared {
        declared_usd_per_hour: declared_usd,
        closedloop_usd_per_hour: closedloop_usd,
        final_drop_rate,
        fleet_util_declared,
        fleet_util_closed,
        feedback_streams,
    }
}

/// Under-declared fleet: true cost 2× declared; degrade tiers shed before
/// wholesale drops, the corrected re-plan provisions real capacity, and
/// sustained headroom restores every tier. Panics on any broken invariant.
pub fn run_underdeclared_scenario() -> UnderDeclared {
    let catalog = cpu_catalog();
    let mut mgr = AdaptiveManager::new(Planner::new(catalog.clone(), PlannerConfig::st1()));
    let mut fc = FeedbackController::new(FeedbackConfig::default());
    // Declared: 3.17 vcpus per stream -> two per box (two boxes). True
    // frames cost 2x, so each box carries 12 vcpu-s/s of work against an
    // 8-vcpu budget: the queue overflows a 32-deep FIFO around t=32s.
    let requests = chicago_workload(Program::Zf, 1.5, 4);
    let true_scale = vec![2.0; requests.len()];
    let sim_cfg = SimConfig { queue_capacity: 32, ..SimConfig::default() };

    mgr.replan(requests).unwrap();
    let (declared_requests, declared_plan) = current_state(&mgr);
    let declared_usd = declared_plan.cost_per_hour;

    // Open-loop control: the declared plan serves the whole three-epoch
    // horizon with no feedback. Its drop rate never recovers.
    let nofb_cfg = SimConfig { duration_s: 3.0 * sim_cfg.duration_s, ..sim_cfg.clone() };
    let nofb = SimExecutor::new(&catalog, &declared_plan, &declared_requests, &true_scale, nofb_cfg)
        .unwrap()
        .run()
        .unwrap();
    let nofeedback_drop_rate = nofb.report.drop_rate();
    assert!(
        nofeedback_drop_rate > 0.1,
        "the open-loop control must keep dropping: {:?}",
        nofb.report
    );

    let mut epoch_drops = Vec::new();
    let mut max_shed_tier = 0u8;
    let mut peak_streams_shed = 0usize;
    let mut last_changed = usize::MAX;
    for _epoch in 0..3 {
        let (reqs, plan) = current_state(&mgr);
        // Degrade never silences: every planned stream keeps a positive
        // effective rate at every tier.
        for r in &reqs {
            assert!(r.effective_fps() > 0.0, "stream shed to zero fps: {:?}", r.feedback);
        }
        let sim = SimExecutor::new(&catalog, &plan, &reqs, &true_scale, sim_cfg.clone()).unwrap();
        let out = sim.run().unwrap();
        epoch_drops.push(out.report.drop_rate());
        peak_streams_shed = peak_streams_shed.max(out.report.streams_shed);
        fc.observe(&out.windows);
        let (_, changed) = mgr.replan_with_feedback(reqs, &fc).unwrap();
        last_changed = changed;
        let tier_now = mgr
            .current
            .as_ref()
            .unwrap()
            .0
            .iter()
            .map(|r| r.feedback.shed_tier)
            .max()
            .unwrap_or(0);
        max_shed_tier = max_shed_tier.max(tier_now);
    }

    let epoch0_drop_rate = epoch_drops[0];
    let final_drop_rate = *epoch_drops.last().unwrap();
    assert!(
        epoch0_drop_rate > 0.05,
        "the declared plan must visibly drop under 1.5x load: {epoch_drops:?}"
    );
    // The acceptance bar: the closed loop bounds the drop rate.
    assert!(
        final_drop_rate <= 0.01,
        "closed loop failed to bound the drop rate: {epoch_drops:?}"
    );
    assert!(max_shed_tier >= 1, "backpressure must engage the degrade tiers");
    assert!(peak_streams_shed > 0, "shed streams must surface in the serve report");
    assert_eq!(last_changed, 0, "feedback must converge to a zero-delta re-plan");
    let (final_requests, final_plan) = current_state(&mgr);
    assert!(
        final_requests.iter().all(|r| r.feedback.shed_tier == 0),
        "sustained headroom must restore every tier: {final_requests:?}"
    );
    let corrected_usd = final_plan.cost_per_hour;
    assert!(
        corrected_usd > declared_usd,
        "the corrected plan must provision for the observed 2x demand: \
         ${corrected_usd}/h vs ${declared_usd}/h"
    );
    let degraded_tier_streams = mgr.ctx.main.solver.degraded_tier_streams.get();
    assert!(degraded_tier_streams > 0, "re-plans must count degraded-tier streams");
    UnderDeclared {
        declared_usd_per_hour: declared_usd,
        corrected_usd_per_hour: corrected_usd,
        epoch0_drop_rate,
        final_drop_rate,
        nofeedback_drop_rate,
        max_shed_tier,
        peak_streams_shed,
        degraded_tier_streams,
    }
}

/// Run both scenarios and collect the bench/JSON outcome.
pub fn run() -> ClosedLoopOutcome {
    ClosedLoopOutcome { over: run_overdeclared_scenario(), under: run_underdeclared_scenario() }
}
