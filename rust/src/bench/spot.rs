//! Spot-priced deferred-analytics replay shared by `bench_spot` and the
//! integration suite.
//!
//! A 24-hour diurnal trace (live stream counts swell through the morning and
//! evening; [`diurnal_backfill`] queries arrive in the matching bursts) is
//! replayed twice through the joint planner — once with the spot market
//! enabled and once on-demand-only — over the two-type CPU pool
//! (`c4.2xlarge` + `c4.8xlarge` @ `us-east-2`). Each simulated hour the
//! [`SpotPlanner`] re-plans live + backfill from the remaining unit-hours,
//! the hour's placements execute, and a seeded [`PreemptionInjector`] storm
//! revokes occupied spot instances (with the 2-minute warning, so the
//! revoked hour's work checkpoints); revocations are absorbed through
//! [`SpotPlanner::absorb_revocation`] — the structural-delta path that moves
//! only the stranded placements.
//!
//! The bars, asserted inside [`run`] so the bench binary and
//! `tests/integration.rs` gate identically:
//!
//! * the spot-enabled replay's executed backfill cost is **strictly below**
//!   the on-demand-only replay's (and the live fleets cost the same —
//!   live streams never ride revocable capacity),
//! * the deadline-miss rate under preemption storms stays ≤ 1%,
//! * the storm actually fires (revocations > 0) in the spot replay and
//!   cannot fire in the on-demand-only replay,
//! * a zero-preemption round is a bit-identical no-op: the absorb path
//!   returns the schedule unchanged and the live fleet reproduces the
//!   previous hour's slots exactly,
//! * a forced single-lane revocation re-homes or sheds the stranded item
//!   while every other item's placements stay bit-identical.
//!
//! Everything is deterministic: fixed seeds, no threads, no wall clock.
//! Emits `BENCH_spot.json` (via the binary) so savings and miss rates are
//! tracked across PRs.

use crate::cameras::camera_at;
use crate::cameras::scenarios::{diurnal_backfill, BackfillQuery};
use crate::cameras::StreamRequest;
use crate::catalog::Catalog;
use crate::cloudsim::{CloudSim, InstanceId, PreemptionInjector};
use crate::coordinator::spot::{JointPlan, SpotPlanner, SpotPlannerConfig};
use crate::coordinator::PlannerConfig;
use crate::geo::cities;
use crate::packing::mcvbp::{BackfillItem, LaneKind};
use crate::profiles::{Program, Resolution};
use crate::util::json::Value;
use std::collections::{BTreeMap, BTreeSet};

/// Replay length: arrivals stop at hour 23 and every deadline lands below
/// 24 + 12, so 36 hours drains the queue completely.
const REPLAY_HOURS: usize = 36;
/// The injector is held for one hour mid-morning-burst to pin the
/// zero-preemption identity bars.
const QUIET_HOUR: usize = 7;
/// One hour later a single occupied lane is force-revoked (non-destructively)
/// to pin the structural-delta re-home bars on live data.
const FORCED_REHOME_HOUR: usize = 8;
/// Preemption-rate multiplier: a storm, not the background rate.
const STORM_INTENSITY: f64 = 6.0;
const STORM_SEED: u64 = 1901_0634;
const BACKFILL_QUERIES: usize = 80;

/// Live-fleet stream counts per trace hour (the diurnal curve); the drain
/// tail past hour 23 stays at the overnight level. Hours 6 and 7 are equal
/// on purpose: the quiet-hour bar compares their live fleets bit-for-bit.
const LIVE_COUNTS: [usize; 24] = [
    2, 2, 2, 2, 2, 3, 4, 4, 5, 6, 6, 5, 4, 4, 4, 4, 5, 6, 6, 6, 5, 4, 3, 2,
];

/// Executed-cost and outcome counters for one replay configuration.
#[derive(Clone, Debug)]
pub struct ReplaySummary {
    /// Σ over executed hours of the occupied paid lane-hour prices.
    pub backfill_usd: f64,
    /// Σ live-plan hourly cost — identical across configurations.
    pub live_usd: f64,
    /// Spot instances revoked by the storm over the whole replay.
    pub revocations: usize,
    /// Distinct items the absorb path re-homed after a revocation.
    pub rehomed_items: usize,
    /// Queries not fully scanned by their deadline (shed or starved).
    pub deadline_misses: usize,
    /// Unit-hours executed.
    pub completed_units: usize,
    /// Rounds where the certified gate adopted the spot schedule.
    pub spot_rounds: usize,
}

/// Both replays plus the derived headline numbers, mirrored into
/// `BENCH_spot.json` by [`SpotOutcome::to_json`].
#[derive(Clone, Debug)]
pub struct SpotOutcome {
    pub queries: usize,
    pub total_units: usize,
    pub spot: ReplaySummary,
    pub od_only: ReplaySummary,
    /// `1 − spot.backfill_usd / od_only.backfill_usd` — the headline bar.
    pub savings_frac: f64,
    /// Spot-replay `deadline_misses / queries` — the ≤ 1% bar.
    pub miss_rate: f64,
}

impl SpotOutcome {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("queries", Value::num(self.queries as f64)),
            ("total_units", Value::num(self.total_units as f64)),
            ("spot_backfill_usd", Value::num(self.spot.backfill_usd)),
            ("spot_live_usd", Value::num(self.spot.live_usd)),
            ("spot_revocations", Value::num(self.spot.revocations as f64)),
            ("spot_rehomed_items", Value::num(self.spot.rehomed_items as f64)),
            ("spot_deadline_misses", Value::num(self.spot.deadline_misses as f64)),
            ("spot_completed_units", Value::num(self.spot.completed_units as f64)),
            ("spot_rounds_adopted", Value::num(self.spot.spot_rounds as f64)),
            ("od_backfill_usd", Value::num(self.od_only.backfill_usd)),
            ("od_deadline_misses", Value::num(self.od_only.deadline_misses as f64)),
            ("od_completed_units", Value::num(self.od_only.completed_units as f64)),
            ("savings_frac", Value::num(self.savings_frac)),
            ("miss_rate", Value::num(self.miss_rate)),
        ])
    }
}

/// The two Table-I CPU boxes in the Fig-3 region: the small box prices slack
/// finely, the big box is the only lane that fits heavy VGG16 scan units.
fn bench_catalog() -> Catalog {
    Catalog::builtin().restrict(Some(&["c4.2xlarge", "c4.8xlarge"]), Some(&["us-east-2"]))
}

fn live_requests(hour: usize) -> Vec<StreamRequest> {
    let n = if hour < LIVE_COUNTS.len() {
        LIVE_COUNTS[hour]
    } else {
        LIVE_COUNTS[0] // drain tail: overnight level
    };
    (0..n)
        .map(|i| {
            StreamRequest::new(
                camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::XGA, 30.0),
                Program::Zf,
                0.5,
            )
        })
        .collect()
}

fn backfill_queries() -> Vec<BackfillQuery> {
    diurnal_backfill(BACKFILL_QUERIES, 42)
}

/// Replay the trace with or without the spot market. Panics on any broken
/// invariant — the bench and the test suite both gate on it.
fn replay(use_spot: bool) -> ReplaySummary {
    let catalog = bench_catalog();
    let spot_cfg = SpotPlannerConfig { horizon_hours: 48, use_spot, lanes_per_offering: 2 };
    let mut planner = SpotPlanner::new(catalog.clone(), PlannerConfig::st1(), spot_cfg);
    let mut sim = CloudSim::new(catalog);
    let mut injector = PreemptionInjector::new(STORM_SEED, STORM_INTENSITY);

    let queries = backfill_queries();
    let base_items = SpotPlanner::items_from_queries(&queries);
    let mut remaining: BTreeMap<u64, usize> =
        base_items.iter().map(|it| (it.id, it.units)).collect();
    let mut shed: BTreeSet<u64> = BTreeSet::new();
    let mut rehomed: BTreeSet<u64> = BTreeSet::new();
    // One persistent sim instance per spot-lane ordinal, provisioned when
    // the lane first carries work and cleared after a revocation.
    let mut pool: Vec<Option<InstanceId>> = Vec::new();

    let mut out = ReplaySummary {
        backfill_usd: 0.0,
        live_usd: 0.0,
        revocations: 0,
        rehomed_items: 0,
        deadline_misses: 0,
        completed_units: 0,
        spot_rounds: 0,
    };
    let mut prev_round: Option<(usize, Vec<(u64, String)>, f64)> = None;

    for hour in 0..REPLAY_HOURS {
        let requests = live_requests(hour);
        let items: Vec<BackfillItem> = base_items
            .iter()
            .zip(&queries)
            .filter(|(it, q)| {
                q.arrival_hour <= hour && remaining[&it.id] > 0 && !shed.contains(&it.id)
            })
            .map(|(it, _)| BackfillItem { units: remaining[&it.id], ..it.clone() })
            .collect();
        let plan = planner.plan(&requests, &items, hour).expect("joint plan");
        shed.extend(plan.schedule.shed.iter().copied());
        if plan.spot_adopted {
            out.spot_rounds += 1;
        }
        out.live_usd += plan.live.cost_per_hour;
        let fleet: Vec<(u64, String)> =
            plan.live.instances.iter().map(|i| (i.slot_id, i.label.clone())).collect();

        if hour == QUIET_HOUR {
            // Zero-preemption round: the live fleet reproduces the previous
            // hour's slots bit-for-bit (the request table is equal there)...
            let (n, prev_fleet, prev_usd) = prev_round.as_ref().expect("hour 7 has a past");
            assert_eq!(requests.len(), *n, "LIVE_COUNTS[6] and [7] must match");
            assert_eq!(&fleet, prev_fleet, "quiet hour must not move the live fleet");
            assert!((plan.live.cost_per_hour - prev_usd).abs() < 1e-12);
            // ...and the absorb path with nothing revoked is an identity.
            let (repaired, moved) = planner.absorb_revocation(&plan, &items, &[], hour + 1);
            assert!(moved.is_empty(), "no preemption, no re-homing");
            assert_eq!(repaired, plan.schedule, "zero-preemption absorb must be a no-op");
        }
        if hour == FORCED_REHOME_HOUR {
            forced_rehome_check(&planner, &plan, &items, hour);
        }
        prev_round = Some((requests.len(), fleet, plan.live.cost_per_hour));

        // Storm: one sim instance per occupied spot lane, then one injector
        // step over the hour. Ordinal j is the j-th Spot lane of the grid —
        // stable across rounds because the paid-lane layout is.
        let spot_lane_idx: Vec<usize> = plan
            .lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == LaneKind::Spot)
            .map(|(i, _)| i)
            .collect();
        pool.resize(spot_lane_idx.len(), None);
        let occupied: BTreeSet<usize> =
            plan.schedule.placements.iter().filter(|p| p.hour == hour).map(|p| p.lane).collect();
        for (j, &li) in spot_lane_idx.iter().enumerate() {
            if occupied.contains(&li) && pool[j].is_none() {
                let (ti, ri) = plan.lane_offerings[li].expect("paid lane has an offering");
                pool[j] = Some(sim.provision_spot(ti, ri).expect("spot pool exists"));
            }
        }
        let revoked_ids = if hour == QUIET_HOUR {
            Vec::new()
        } else {
            injector.step(&mut sim, 3600.0)
        };
        sim.advance(3600.0);
        let revoked_lanes: Vec<usize> = revoked_ids
            .iter()
            .filter_map(|id| pool.iter().position(|slot| *slot == Some(*id)))
            .map(|j| spot_lane_idx[j])
            .collect();
        assert_eq!(revoked_lanes.len(), revoked_ids.len(), "every revocation maps to a lane");
        for slot in pool.iter_mut() {
            if matches!(slot, Some(id) if revoked_ids.contains(id)) {
                *slot = None;
            }
        }
        out.revocations += revoked_lanes.len();

        // Absorb the storm as a structural delta: the revoked hour's work
        // checkpoints under the 2-minute warning, so the cut is at hour + 1.
        let schedule = if revoked_lanes.is_empty() {
            plan.schedule.clone()
        } else {
            let (repaired, moved) =
                planner.absorb_revocation(&plan, &items, &revoked_lanes, hour + 1);
            rehomed.extend(moved);
            shed.extend(repaired.shed.iter().copied());
            repaired
        };

        // Execute the hour: bill each occupied paid lane-hour once, retire
        // one unit per placement.
        let mut cells: Vec<usize> =
            schedule.placements.iter().filter(|p| p.hour == hour).map(|p| p.lane).collect();
        cells.sort_unstable();
        cells.dedup();
        out.backfill_usd += cells.iter().map(|&l| plan.lanes[l].hourly_cost).sum::<f64>();
        for p in schedule.placements.iter().filter(|p| p.hour == hour) {
            *remaining.get_mut(&p.item).expect("placed item is tracked") -= 1;
            out.completed_units += 1;
        }
    }

    out.rehomed_items = rehomed.len();
    out.deadline_misses = remaining.values().filter(|&&u| u > 0).count();
    out
}

/// Force-revoke one lane that still carries future work and check the
/// structural-delta contract on the live schedule (non-destructively — the
/// round's real plan is not modified).
fn forced_rehome_check(
    planner: &SpotPlanner,
    plan: &JointPlan,
    items: &[BackfillItem],
    hour: usize,
) {
    let Some(target) = plan.schedule.placements.iter().find(|p| p.hour > hour) else {
        return; // nothing scheduled past this hour — nothing to strand
    };
    let (repaired, moved) = planner.absorb_revocation(plan, items, &[target.lane], hour + 1);
    assert!(
        repaired.placements.iter().all(|p| p.lane != target.lane || p.hour <= hour),
        "the revoked lane must be empty from the cut hour on"
    );
    assert!(
        moved.contains(&target.item) || repaired.shed.contains(&target.item),
        "the stranded item must be re-homed or shed explicitly, never lost"
    );
    for it in items {
        if moved.contains(&it.id) || repaired.shed.contains(&it.id) {
            continue;
        }
        let before: Vec<_> =
            plan.schedule.placements.iter().filter(|p| p.item == it.id).collect();
        let after: Vec<_> = repaired.placements.iter().filter(|p| p.item == it.id).collect();
        assert_eq!(before, after, "re-home moved non-preempted item {}", it.id);
    }
}

/// Run both replays and assert the cross-configuration bars.
pub fn run() -> SpotOutcome {
    let spot = replay(true);
    let od_only = replay(false);
    let queries = backfill_queries().len();
    let total_units: usize =
        SpotPlanner::items_from_queries(&backfill_queries()).iter().map(|i| i.units).sum();

    assert!(
        spot.backfill_usd < od_only.backfill_usd,
        "spot-enabled backfill (${:.3}) must undercut on-demand-only (${:.3})",
        spot.backfill_usd,
        od_only.backfill_usd
    );
    assert!(
        (spot.live_usd - od_only.live_usd).abs() < 1e-9,
        "the live fleet never rides the spot market, so its cost cannot move"
    );
    assert!(spot.spot_rounds > 0, "the certified gate must adopt spot at least once");
    assert_eq!(od_only.spot_rounds, 0, "spot adoption with use_spot=false");
    assert!(spot.revocations > 0, "the storm must actually revoke spot capacity");
    assert_eq!(od_only.revocations, 0, "an on-demand-only fleet has nothing to revoke");

    let miss_rate = spot.deadline_misses as f64 / queries as f64;
    assert!(
        miss_rate <= 0.01,
        "deadline-miss rate {miss_rate} exceeds 1% under the preemption storm \
         ({} of {queries} queries)",
        spot.deadline_misses
    );
    let savings_frac = 1.0 - spot.backfill_usd / od_only.backfill_usd;
    SpotOutcome { queries, total_units, spot, od_only, savings_frac, miss_rate }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_trace_shape() {
        assert_eq!(LIVE_COUNTS.len(), 24);
        assert_eq!(LIVE_COUNTS[QUIET_HOUR - 1], LIVE_COUNTS[QUIET_HOUR]);
        for q in backfill_queries() {
            assert!(q.arrival_hour + (q.deadline_hours.floor() as usize) < REPLAY_HOURS);
        }
    }

    #[test]
    fn bench_catalog_offers_spot_on_both_types() {
        let c = bench_catalog();
        assert_eq!(c.types.len(), 2);
        assert_eq!(c.regions.len(), 1);
        for o in &c.offerings {
            let q = o.spot.expect("both CPU boxes carry spot quotes");
            assert!(q.hourly_usd < o.hourly_usd);
        }
    }
}
