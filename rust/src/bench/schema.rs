//! Canonical bench-artifact schemas.
//!
//! Every `BENCH_*.json` artifact a bench binary writes is documented
//! field-by-field in `docs/BENCH_SCHEMAS.md`. This module is the machine
//! half of that contract: each [`ArtifactSchema`] lists the exact field
//! names and JSON kinds an artifact must carry, [`validate`] checks a
//! just-built document against its schema (the solver / planet / spot
//! binaries call it right before writing the file), and
//! `tests/integration.rs` cross-checks every schema field against the
//! artifact's section of the markdown page — so the JSON, this module, and
//! the docs cannot drift apart silently in any direction.
//!
//! Validation is *exact*: a missing field, a wrong JSON kind, and an
//! undeclared extra field are all errors. Renaming a bench output without
//! updating the schema (or documenting it) fails the bench lane, not a
//! reader three PRs later.

use crate::util::json::Value;

/// Expected JSON kind of one schema field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Num,
    Bool,
    Str,
}

impl Kind {
    fn matches(self, v: &Value) -> bool {
        matches!(
            (self, v),
            (Kind::Num, Value::Num(_)) | (Kind::Bool, Value::Bool(_)) | (Kind::Str, Value::Str(_))
        )
    }

    fn name(self) -> &'static str {
        match self {
            Kind::Num => "number",
            Kind::Bool => "bool",
            Kind::Str => "string",
        }
    }
}

/// One named scalar field of an artifact object.
#[derive(Clone, Copy, Debug)]
pub struct Field {
    pub name: &'static str,
    pub kind: Kind,
}

const fn n(name: &'static str) -> Field {
    Field { name, kind: Kind::Num }
}

const fn b(name: &'static str) -> Field {
    Field { name, kind: Kind::Bool }
}

const fn s(name: &'static str) -> Field {
    Field { name, kind: Kind::Str }
}

/// The full shape of one `BENCH_*.json` artifact: scalar top-level fields,
/// arrays of uniform objects, and nested scalar objects. Together the three
/// lists enumerate *every* top-level key the artifact may carry.
#[derive(Clone, Copy, Debug)]
pub struct ArtifactSchema {
    /// Artifact file name, and the heading key in `docs/BENCH_SCHEMAS.md`.
    pub artifact: &'static str,
    pub top: &'static [Field],
    /// `(key, per-entry fields)` — the array must be non-empty and every
    /// entry must carry exactly the listed fields.
    pub arrays: &'static [(&'static str, &'static [Field])],
    /// `(key, fields)` — nested objects with exactly the listed fields.
    pub objects: &'static [(&'static str, &'static [Field])],
}

impl ArtifactSchema {
    /// Every field name the schema mentions (top-level keys, array keys and
    /// their entry fields, object keys and their fields) — what the docs
    /// page must mention, one by one.
    pub fn field_names(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = self.top.iter().map(|f| f.name).collect();
        for (name, fields) in self.arrays.iter().chain(self.objects.iter()) {
            out.push(name);
            out.extend(fields.iter().map(|f| f.name));
        }
        out
    }
}

const SOLVER_CLASS_FIELDS: &[Field] = &[
    s("class"),
    n("rows"),
    n("cols"),
    n("nnz_per_col"),
    n("lps"),
    n("dense_ms"),
    n("dantzig_ms"),
    n("partial_ms"),
    n("dense_iterations"),
    n("dantzig_iterations"),
    n("partial_iterations"),
    n("dense_iters_per_sec"),
    n("dantzig_iters_per_sec"),
    n("partial_iters_per_sec"),
    n("speedup_partial"),
    n("priced_cols_per_iter_dantzig"),
    n("priced_cols_per_iter_partial"),
    n("full_sweeps_partial"),
    n("ftran_per_iter"),
    n("btran_per_iter"),
    n("refactorizations"),
    n("eta_fill_watermark"),
    n("eta_fill_cap"),
    n("degenerate_pivots"),
];

const SOLVER_DELTA_FIELDS: &[Field] = &[
    s("scenario"),
    n("cold_ms"),
    n("delta_ms"),
    n("speedup"),
    n("ghost_groups"),
    n("appeared_groups"),
    n("lp_warm"),
    n("lp_cold"),
    n("cost_delta"),
    n("proven_optimal"),
];

const SOLVER_CALIBRATION_FIELDS: &[Field] =
    &[n("node_cost_rows_weight"), s("model"), s("derivation")];

/// `BENCH_solver.json` — written by `bench_solver`.
pub static SOLVER: ArtifactSchema = ArtifactSchema {
    artifact: "BENCH_solver.json",
    top: &[s("bench")],
    arrays: &[("classes", SOLVER_CLASS_FIELDS), ("structural_delta", SOLVER_DELTA_FIELDS)],
    objects: &[("calibration", SOLVER_CALIBRATION_FIELDS)],
};

const PLANET_TOP_FIELDS: &[Field] = &[
    s("bench"),
    n("metros"),
    n("streams"),
    n("shards"),
    n("cold_all_ms"),
    n("warm_noop_ms"),
    n("warm_one_dirty_ms"),
    n("warm_mixed_ms"),
    n("warm_uniform_ms"),
    n("price_fanout_all_ms"),
    n("fanout_over_one_dirty"),
    n("uniform_over_one_dirty"),
    n("sharded_usd_per_hour"),
    n("unsharded_usd_per_hour"),
    b("cost_parity"),
    b("exact_complete"),
    b("all_main"),
    n("donors"),
    b("lenient"),
];

const PLANET_DIRTY_FIELDS: &[Field] = &[
    n("cold"),
    n("noop"),
    n("skew"),
    n("restore"),
    n("mixed"),
    n("uniform"),
    n("fanout"),
];

const PLANET_STRUCTURAL_FIELDS: &[Field] =
    &[n("delta_hits"), n("ghost_groups"), n("appeared_groups")];

/// `BENCH_planet.json` — written by `bench_planet`.
pub static PLANET: ArtifactSchema = ArtifactSchema {
    artifact: "BENCH_planet.json",
    top: PLANET_TOP_FIELDS,
    arrays: &[],
    objects: &[("dirty", PLANET_DIRTY_FIELDS), ("structural", PLANET_STRUCTURAL_FIELDS)],
};

const SPOT_FIELDS: &[Field] = &[
    n("queries"),
    n("total_units"),
    n("spot_backfill_usd"),
    n("spot_live_usd"),
    n("spot_revocations"),
    n("spot_rehomed_items"),
    n("spot_deadline_misses"),
    n("spot_completed_units"),
    n("spot_rounds_adopted"),
    n("od_backfill_usd"),
    n("od_deadline_misses"),
    n("od_completed_units"),
    n("savings_frac"),
    n("miss_rate"),
];

/// `BENCH_spot.json` — written by `bench_spot`.
pub static SPOT: ArtifactSchema = ArtifactSchema {
    artifact: "BENCH_spot.json",
    top: &[s("bench"), n("loop_ms")],
    arrays: &[],
    objects: &[("spot", SPOT_FIELDS)],
};

fn kind_of(v: &Value) -> &'static str {
    match v {
        Value::Null => "null",
        Value::Bool(_) => "bool",
        Value::Num(_) => "number",
        Value::Str(_) => "string",
        Value::Arr(_) => "array",
        Value::Obj(_) => "object",
    }
}

fn check_fields(obj: &Value, fields: &[Field], ctx: &str, errs: &mut Vec<String>) {
    for f in fields {
        match obj.get(f.name) {
            Err(_) => errs.push(format!("{ctx}: missing `{}`", f.name)),
            Ok(v) if !f.kind.matches(v) => errs.push(format!(
                "{ctx}: `{}` is {}, expected {}",
                f.name,
                kind_of(v),
                f.kind.name()
            )),
            Ok(_) => {}
        }
    }
}

/// Flag any key of `obj` that the schema does not declare.
fn check_no_extras(obj: &Value, declared: &[&str], ctx: &str, errs: &mut Vec<String>) {
    if let Value::Obj(map) = obj {
        for key in map.keys() {
            if !declared.contains(&key.as_str()) {
                errs.push(format!("{ctx}: undeclared field `{key}`"));
            }
        }
    } else {
        errs.push(format!("{ctx}: expected a JSON object, got {}", kind_of(obj)));
    }
}

/// Check a just-built artifact document against its schema. Returns every
/// problem at once (joined with `; `) so a drifted bench fails with the
/// full delta, not one field per run.
pub fn validate(doc: &Value, schema: &ArtifactSchema) -> Result<(), String> {
    let mut errs = Vec::new();
    let ctx = schema.artifact;
    let declared: Vec<&str> = schema
        .top
        .iter()
        .map(|f| f.name)
        .chain(schema.arrays.iter().map(|&(name, _)| name))
        .chain(schema.objects.iter().map(|&(name, _)| name))
        .collect();
    check_no_extras(doc, &declared, ctx, &mut errs);
    check_fields(doc, schema.top, ctx, &mut errs);

    for &(name, fields) in schema.arrays {
        match doc.get_arr(name) {
            Err(e) => errs.push(format!("{ctx}: {e}")),
            Ok(entries) => {
                if entries.is_empty() {
                    errs.push(format!("{ctx}: array `{name}` is empty"));
                }
                let entry_names: Vec<&str> = fields.iter().map(|f| f.name).collect();
                for (i, entry) in entries.iter().enumerate() {
                    let ectx = format!("{ctx} {name}[{i}]");
                    check_no_extras(entry, &entry_names, &ectx, &mut errs);
                    check_fields(entry, fields, &ectx, &mut errs);
                }
            }
        }
    }
    for &(name, fields) in schema.objects {
        match doc.get(name) {
            Err(e) => errs.push(format!("{ctx}: {e}")),
            Ok(nested) => {
                let nested_names: Vec<&str> = fields.iter().map(|f| f.name).collect();
                let nctx = format!("{ctx} {name}");
                check_no_extras(nested, &nested_names, &nctx, &mut errs);
                check_fields(nested, fields, &nctx, &mut errs);
            }
        }
    }
    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs.join("; "))
    }
}

/// Slice the artifact's section (`## \`BENCH_x.json\`` up to the next `##`
/// heading) out of the `docs/BENCH_SCHEMAS.md` text.
pub fn doc_section<'a>(doc: &'a str, artifact: &str) -> Option<&'a str> {
    let needle = format!("## `{artifact}`");
    let start = doc.find(&needle)?;
    let rest = &doc[start..];
    let end = rest[needle.len()..].find("\n## ").map_or(rest.len(), |i| needle.len() + i);
    Some(&rest[..end])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spot_doc() -> Value {
        let fields: Vec<(&str, Value)> =
            SPOT_FIELDS.iter().map(|f| (f.name, Value::num(1.0))).collect();
        Value::obj(vec![
            ("bench", Value::str("spot")),
            ("loop_ms", Value::num(2.5)),
            ("spot", Value::obj(fields)),
        ])
    }

    #[test]
    fn a_conforming_document_validates() {
        validate(&spot_doc(), &SPOT).unwrap();
    }

    #[test]
    fn missing_extra_and_miskinded_fields_are_all_reported() {
        let mut doc = spot_doc();
        if let Value::Obj(map) = &mut doc {
            map.insert("surprise".into(), Value::num(1.0));
            map.insert("loop_ms".into(), Value::str("fast"));
            if let Some(Value::Obj(spot)) = map.get_mut("spot") {
                spot.remove("miss_rate");
            }
        }
        let err = validate(&doc, &SPOT).unwrap_err();
        assert!(err.contains("undeclared field `surprise`"), "{err}");
        assert!(err.contains("`loop_ms` is string, expected number"), "{err}");
        assert!(err.contains("missing `miss_rate`"), "{err}");
    }

    #[test]
    fn empty_arrays_are_rejected() {
        let doc = Value::obj(vec![
            ("bench", Value::str("solver")),
            ("classes", Value::arr(vec![])),
            ("structural_delta", Value::arr(vec![])),
            (
                "calibration",
                Value::obj(vec![
                    ("node_cost_rows_weight", Value::num(8.0)),
                    ("model", Value::str("m")),
                    ("derivation", Value::str("d")),
                ]),
            ),
        ]);
        let err = validate(&doc, &SOLVER).unwrap_err();
        assert!(err.contains("array `classes` is empty"), "{err}");
    }

    #[test]
    fn schemas_have_unique_field_names_per_object() {
        for schema in [&SOLVER, &PLANET, &SPOT] {
            let groups: Vec<&[Field]> = [schema.top]
                .into_iter()
                .chain(schema.arrays.iter().map(|&(_, f)| f))
                .chain(schema.objects.iter().map(|&(_, f)| f))
                .collect();
            for fields in groups {
                let mut names: Vec<&str> = fields.iter().map(|f| f.name).collect();
                names.sort_unstable();
                let before = names.len();
                names.dedup();
                assert_eq!(before, names.len(), "{}: duplicate field", schema.artifact);
            }
        }
    }

    #[test]
    fn doc_section_slices_one_heading() {
        let md = "intro\n\n## `A.json`\n\n* `x`\n\n## `B.json`\n\n* `y`\n";
        let a = doc_section(md, "A.json").unwrap();
        assert!(a.contains("`x`") && !a.contains("`y`"));
        let b = doc_section(md, "B.json").unwrap();
        assert!(b.contains("`y`"));
        assert!(doc_section(md, "C.json").is_none());
    }
}
