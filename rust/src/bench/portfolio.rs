//! Portfolio-runtime scenarios shared by `bench_adaptive` and the
//! integration suite.
//!
//! Living in the library (rather than inside the bench binary) keeps the
//! `BENCH_adaptive.json` portfolio fields and the schema test in
//! `tests/integration.rs` in lockstep: both call [`run`] and read the same
//! [`PortfolioOutcome`].
//!
//! Two deterministic scenarios, both probe-calibrated so they do not depend
//! on hand-tuned arc-flow node counts:
//!
//! * **Winner flip** ([`run_flip_scenario`]) — a two-region Fig-3-S1-shaped
//!   workload whose exact GPU consolidation is invisible to every greedy
//!   rule. The static graph budget is pinned to the nearest-only problem's
//!   measured need, so the nearest-exact candidate always completes its
//!   exact phase while the two-region GCL problem always walls. Under
//!   GPU-favourable prices all candidates agree (ties keep GCL); restoring
//!   the CPU price flips the winner to the nearest-exact candidate on an
//!   *unchanged* workload — and slot continuity must keep the deployed
//!   fleet byte-stable across the flip.
//! * **Shared runtime** ([`run_pool_scenario`]) — a two-cluster worldwide
//!   workload (a dominant multi-tier London cluster plus a trivial Tokyo
//!   donor) re-planned three times through one portfolio context: all three
//!   candidates dispatch their per-cluster solves to the one shared worker
//!   pool, and the third re-plan's escalation for the walled London cluster
//!   draws on the slack the nearest-exact candidate's allocation published
//!   the round before — the cross-candidate budget pool at work.

use crate::cameras::{camera_at, StreamRequest};
use crate::catalog::Catalog;
use crate::cloudsim::CloudSim;
use crate::coordinator::adaptive::AdaptiveManager;
use crate::coordinator::pipeline::{plan_with_context, PlanContext, ReplanContext};
use crate::coordinator::portfolio::Candidate;
use crate::coordinator::{LocationPolicy, Planner, PlannerConfig};
use crate::geo::cities;
use crate::profiles::{Program, Resolution};
use crate::util::json::Value;

/// Everything the portfolio scenarios measure, mirrored verbatim into
/// `BENCH_adaptive.json`'s `portfolio` object by [`PortfolioOutcome::to_json`].
#[derive(Clone, Debug)]
pub struct PortfolioOutcome {
    /// Churn ratio of the forced winner-flip re-plan (unchanged workload).
    pub flip_churn_ratio: f64,
    /// Churn ratio of the sticky same-winner control re-plan.
    pub sticky_churn_ratio: f64,
    /// Winner flips the scenario's manager observed (expected: exactly 1).
    pub winner_flips: u64,
    /// Instances provisioned / terminated by the flip re-plan (expected 0).
    pub flip_provisioned: usize,
    pub flip_terminated: usize,
    /// Jobs all three candidates dispatched to the one shared worker pool.
    pub pool_shared_jobs: u64,
    /// Arc-flow node budget drawn from the cross-candidate donated pool.
    pub budget_pooled_donated: u64,
}

impl PortfolioOutcome {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("flip_churn_ratio", Value::num(self.flip_churn_ratio)),
            ("sticky_churn_ratio", Value::num(self.sticky_churn_ratio)),
            ("winner_flips", Value::num(self.winner_flips as f64)),
            ("flip_provisioned", Value::num(self.flip_provisioned as f64)),
            ("flip_terminated", Value::num(self.flip_terminated as f64)),
            ("pool_shared_jobs", Value::num(self.pool_shared_jobs as f64)),
            ("budget_pooled_donated", Value::num(self.budget_pooled_donated as f64)),
        ])
    }
}

/// The flip catalog: the Fig-3 pool types across two US regions, with
/// controlled prices. `us-east-2` stays the uniquely cheapest GPU offering
/// so every candidate's GPU consolidation lands in the same region; both
/// regions' CPU boxes carry `c4_usd` (the price perturbation lever).
/// Public so the winner-flip property test perturbs the *same* catalog the
/// bench measures (no scenario drift between the two).
pub fn flip_catalog(c4_usd: f64) -> Catalog {
    let mut catalog = Catalog::builtin()
        .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-1", "us-east-2"]));
    let g2 = catalog.type_by_name("g2.2xlarge").unwrap();
    let east2 = catalog.region_by_id("us-east-2").unwrap();
    for o in &mut catalog.offerings {
        if o.type_idx == g2 {
            o.hourly_usd = if o.region_idx == east2 { 0.65 } else { 0.80 };
        } else {
            o.hourly_usd = c4_usd;
        }
    }
    catalog
}

/// The Fig-3 S1 demand shape: one VGG16@0.25 plus `n_zf` ZF@0.55 streams on
/// 1600x900 Chicago cameras. Each stream needs most of one c4 on the CPU
/// path, yet the whole set fits a single g2 — and both greedy rules score
/// the c4 better, so only an exact solve finds the consolidation.
pub fn s1_workload(n_zf: usize) -> Vec<StreamRequest> {
    let res = Resolution::HD900;
    let mut v = vec![StreamRequest::new(
        camera_at(100, "Chicago", cities::CHICAGO, res, 30.0),
        Program::Vgg16,
        0.25,
    )];
    for i in 0..n_zf {
        v.push(StreamRequest::new(
            camera_at(200 + i as u64, "Chicago", cities::CHICAGO, res, 30.0),
            Program::Zf,
            0.55,
        ));
    }
    v
}

/// The nearest-exact candidate's configuration, standalone.
pub fn nearest_exact_config() -> PlannerConfig {
    let mut cfg = PlannerConfig::gcl();
    cfg.location = LocationPolicy::NearestOnly;
    cfg
}

/// Probe both candidate problems' arc-flow needs on `requests` — which must
/// be the workload the *flip round* plans, since graph sizes are
/// count-sensitive below the per-bin multiplicity cap — and pin the static
/// graph budget to exactly the nearest-only problem's: the nearest-exact
/// solve of that workload completes, while the two-region GCL problem
/// (strictly more graph: its second region's builds charge the same
/// cumulative budget) always walls on it.
pub fn calibrated_budget(catalog: &Catalog, requests: &[StreamRequest]) -> usize {
    use crate::packing::mcvbp::{solve, SolveOptions};
    let probe_opts = SolveOptions { max_graph_nodes: 2_000_000, ..SolveOptions::default() };
    let need = |cfg: PlannerConfig| -> usize {
        let planner = Planner::new(catalog.clone(), cfg);
        let (problem, _, _) = planner.build_problem(requests).unwrap();
        let (_, st) = solve(&problem, &probe_opts).unwrap();
        st.graph_nodes_before
    };
    let nl = need(nearest_exact_config());
    let gcl = need(PlannerConfig::gcl());
    assert!(
        gcl > nl + 1,
        "two-region problem must need strictly more graph than nearest-only: {gcl} vs {nl}"
    );
    nl
}

/// Winner-flip scenario. Returns (flip churn, sticky churn, flips,
/// provisioned-on-flip, terminated-on-flip); panics if any continuity
/// invariant breaks — the bench and the test suite both gate on it.
pub fn run_flip_scenario() -> (f64, f64, u64, usize, usize) {
    let expensive = flip_catalog(5.0);
    // Calibrate on the workload rounds 2-3 plan (two ZF survivors), not
    // round 1's larger one: graph sizes shrink with stream counts below
    // the per-bin cap, and the walled-GCL guarantee must hold on the flip
    // round itself. Round 1's bigger problem then walls for *every*
    // candidate, which is fine — all heuristics agree on the one GPU box.
    let budget = calibrated_budget(&expensive, &s1_workload(2));
    let mut cfg = PlannerConfig::gcl();
    cfg.solve_opts.max_graph_nodes = budget;
    let mut mgr = AdaptiveManager::new(Planner::new(expensive.clone(), cfg));
    let mut sim = CloudSim::new(expensive);

    // Round 1 — GPU-favourable prices ($5 CPU box): every candidate lands
    // on the one g2@us-east-2 consolidation; the tie keeps the main GCL.
    let r1 = mgr.replan(s1_workload(3)).unwrap();
    assert_eq!(r1.winner, Some(Candidate::Main), "ties must keep GCL: {r1:?}");
    let plan1 = mgr.current_plan().unwrap().clone();
    assert_eq!((plan1.non_gpu, plan1.gpu), (0, 1), "S1 consolidates onto one GPU box");
    sim.apply_plan(&plan1).unwrap();

    // Round 2 — the sticky same-winner control: one ZF camera departs; the
    // survivors must stay on their slot and the winner must not change.
    let r2 = mgr.replan(s1_workload(2)).unwrap();
    assert!(!r2.winner_flipped, "{r2:?}");
    let sticky_churn = r2.churn_ratio();
    sim.apply_plan(mgr.current_plan().unwrap()).unwrap();
    let ids_before: Vec<_> = sim.alive().iter().map(|i| i.id).collect();

    // Round 3 — price perturbation only, workload unchanged: the CPU box
    // returns to $0.419. The exact GPU consolidation now beats every greedy
    // CPU fill, but under the calibrated budget only the nearest-exact
    // candidate completes an exact phase — the winner flips. Slot
    // continuity must keep the fleet byte-stable.
    mgr.planner.catalog = flip_catalog(0.419);
    let r3 = mgr.replan(s1_workload(2)).unwrap();
    assert!(r3.winner_flipped, "price restore must flip the winner: {r3:?}");
    assert_eq!(r3.winner, Some(Candidate::NearestExact), "{r3:?}");
    assert!((r3.cost_after - 0.65).abs() < 1e-9, "flip must keep the GPU box: {r3:?}");
    assert_eq!(r3.streams_moved, 0, "unchanged workload must not move streams: {r3:?}");
    let provisioned: usize = r3.provision.iter().map(|(_, n)| n).sum();
    let terminated: usize = r3.terminate.iter().map(|(_, n)| n).sum();
    assert_eq!((provisioned, terminated), (0, 0), "flip churned the fleet: {r3:?}");
    sim.apply_plan(mgr.current_plan().unwrap()).unwrap();
    let ids_after: Vec<_> = sim.alive().iter().map(|i| i.id).collect();
    assert_eq!(ids_before, ids_after, "flip must keep physical instance ids");

    (r3.churn_ratio(), sticky_churn, mgr.ctx.winner_flips, provisioned, terminated)
}

/// The shared-runtime workload: a dominant London cluster (six GPU-bound
/// VGA fps tiers, `per_tier` cameras each — the tier mix drives the g3
/// arc-flow state space combinatorial, while the single-GPU g2 box holds
/// so few streams that the nearest-only problem's graphs stay tiny) plus a
/// trivial single-group Tokyo cluster. The 10.5–14.2 fps band keeps both
/// RTT circles regional and disjoint: London reaches eu-west-2 +
/// us-east-1, Tokyo only ap-northeast-1.
fn pool_workload(per_tier: usize, drift: f64) -> Vec<StreamRequest> {
    let tiers = [10.5, 11.2, 12.0, 12.8, 13.5, 14.2];
    let mut v = Vec::new();
    for (t, fps) in tiers.iter().enumerate() {
        for cam in 0..per_tier as u64 {
            v.push(StreamRequest::new(
                camera_at(
                    (t * per_tier) as u64 + cam,
                    "London",
                    cities::LONDON,
                    Resolution::VGA,
                    30.0,
                ),
                Program::Zf,
                fps + drift,
            ));
        }
    }
    for cam in 0..2u64 {
        v.push(StreamRequest::new(
            camera_at(1000 + cam, "Tokyo", cities::TOKYO, Resolution::VGA, 30.0),
            Program::Zf,
            11.7,
        ));
    }
    v
}

/// Shared worker-pool + cross-candidate budget-pool scenario. Returns
/// (pool_shared_jobs, budget_pooled_donated); panics if the pool never
/// engages.
pub fn run_pool_scenario() -> (u64, u64) {
    let catalog = Catalog::builtin().restrict(
        Some(&["c4.2xlarge", "g2.2xlarge", "g3.8xlarge"]),
        Some(&["eu-west-2", "us-east-1", "ap-northeast-1"]),
    );

    // Probe each candidate's per-component arc-flow needs at a generous
    // budget, then pin the static budget so every small component donates
    // (2x its need fits under it, with margin) while the dominant London
    // GCL component walls. London's g3 graph grows with the per-tier
    // camera count until the per-bin multiplicity cap saturates it, while
    // every other graph caps out almost immediately — so scaling the fleet
    // up until the probe shows dominance always terminates, and the
    // calibration never depends on hand-assumed node counts.
    let probe = |cfg: &PlannerConfig, per_tier: usize| -> Vec<usize> {
        let mut big = cfg.clone();
        big.solve_opts.max_graph_nodes = 2_000_000;
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &big, &pool_workload(per_tier, 0.0), &mut ctx).unwrap();
        ctx.component_telemetry().iter().map(|t| t.graph_nodes).collect()
    };
    let mut per_tier = 4usize;
    let budget = loop {
        let gcl_needs = probe(&PlannerConfig::gcl(), per_tier);
        let nl_needs = probe(&nearest_exact_config(), per_tier);
        assert!(
            gcl_needs.len() >= 2 && !nl_needs.is_empty(),
            "expected two RTT-disjoint clusters: {gcl_needs:?} {nl_needs:?}"
        );
        let budget = 2 * gcl_needs[1].max(nl_needs[0]) + 200;
        if budget < gcl_needs[0] {
            break budget;
        }
        per_tier *= 2;
        assert!(
            per_tier <= 64,
            "calibration failed to find a dominant hard cluster: \
             gcl {gcl_needs:?}, nl {nl_needs:?}"
        );
    };

    let mut cfg = PlannerConfig::gcl();
    cfg.solve_opts.max_graph_nodes = budget;
    let planner = Planner::new(catalog, cfg);
    let mut ctx = ReplanContext::new();
    // Round 1 fills telemetry; round 2's allocations publish each
    // candidate's slack into the shared pool; round 3's escalation for the
    // walled London cluster finally draws on the other candidates' slack.
    // Each round drifts the London tiers so the hard cluster re-solves
    // (memo hits draw nothing — stable re-plans must stay grant-free).
    for round in 0..3 {
        planner.plan_with(&pool_workload(per_tier, round as f64 * 0.002), &mut ctx).unwrap();
    }
    let jobs = ctx.pool_shared_jobs();
    let pooled = ctx.budget_pooled_donated();
    assert!(
        jobs >= 6,
        "three candidates x two clusters x three rounds must share the pool: {jobs}"
    );
    assert!(
        pooled > 0,
        "the walled London cluster must draw on the alternates' donated slack \
         (calibrated budget {budget}, per_tier {per_tier})"
    );
    (jobs, pooled)
}

/// Run both scenarios and collect the bench/JSON outcome.
pub fn run() -> PortfolioOutcome {
    let (flip_churn_ratio, sticky_churn_ratio, winner_flips, flip_provisioned, flip_terminated) =
        run_flip_scenario();
    let (pool_shared_jobs, budget_pooled_donated) = run_pool_scenario();
    PortfolioOutcome {
        flip_churn_ratio,
        sticky_churn_ratio,
        winner_flips,
        flip_provisioned,
        flip_terminated,
        pool_shared_jobs,
        budget_pooled_donated,
    }
}
