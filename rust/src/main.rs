//! camflow — CLI for the cloud resource manager.
//!
//! Subcommands:
//!   catalog            Print the instance catalog (Table I + extensions).
//!   plan               Plan a scenario/workload with a strategy.
//!   sweep              Cost-vs-fps sweep across NL/ARMVAC/GCL (Fig 6 data).
//!   serve              Plan then serve the workload end-to-end via PJRT.
//!   simulate           24h adaptive-manager simulation on the cloud sim.
//!
//! Run `camflow <cmd> --help` for per-command options.

use camflow::bench::Table;
use camflow::cameras::scenarios;
use camflow::catalog::Catalog;
use camflow::cli::Args;
use camflow::config::{RunConfig, StrategyName};
use camflow::coordinator::{adaptive::AdaptiveManager, Planner};
use camflow::error::Result;
use camflow::util::fmt_usd;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("catalog") => cmd_catalog(args),
        Some("plan") => cmd_plan(args),
        Some("sweep") => cmd_sweep(args),
        Some("serve") => cmd_serve(args),
        Some("simulate") => cmd_simulate(args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
camflow — cloud resource optimization for multi-stream visual analytics
  (reproduction of Kapach et al., IEEE MultiMedia 2019)

USAGE: camflow <command> [options]

COMMANDS:
  catalog                         print the instance catalog (Table I)
  plan     [--scenario N] [--strategy st1|st2|st3|nl|armvac|gcl]
           [--cameras N --fps F --seed S]   plan a workload, print the plan
  sweep    [--cameras N] [--seed S]         Fig-6 cost sweep NL/ARMVAC/GCL
  serve    [--scenario N] [--strategy S] [--duration SEC] [--scale X]
           [--artifacts DIR]                plan + serve end-to-end via PJRT
                                            (requires --features pjrt)
  simulate [--hours H] [--cameras N] [--cold]
                                            adaptive manager on the cloud sim;
                                            --cold disables incremental re-planning
";

fn cmd_catalog(_args: &Args) -> Result<()> {
    let c = Catalog::builtin();
    let mut t = Table::new(&["Vendor", "Instance", "Cores", "Memory (GiB)", "GPU", "Region", "Price/h (US$)"]);
    for o in &c.offerings {
        let ty = &c.types[o.type_idx];
        let rg = &c.regions[o.region_idx];
        t.row(&[
            ty.vendor.to_string(),
            ty.name.to_string(),
            format!("{}", ty.capacity.vcpus as u64),
            format!("{}", ty.capacity.mem_gib),
            format!("{}", ty.capacity.gpus as u64),
            format!("{} ({})", rg.id, rg.city),
            format!("{:.3}", o.hourly_usd),
        ]);
    }
    t.print();
    println!("\n{} types x {} regions, {} offerings", c.types.len(), c.regions.len(), c.offerings.len());
    Ok(())
}

fn load_run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    if let Some(s) = args.opt("strategy") {
        cfg.strategy = s.parse()?;
    }
    cfg.scenario = args.opt_parse("scenario", cfg.scenario)?;
    cfg.num_cameras = args.opt_parse("cameras", cfg.num_cameras)?;
    cfg.target_fps = args.opt_parse("fps", cfg.target_fps)?;
    cfg.seed = args.opt_parse("seed", cfg.seed)?;
    cfg.duration_s = args.opt_parse("duration", cfg.duration_s)?;
    cfg.time_scale = args.opt_parse("scale", cfg.time_scale)?;
    if let Some(d) = args.opt("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    // Location strategies need the full worldwide catalog.
    if matches!(cfg.strategy, StrategyName::Nl | StrategyName::Armvac | StrategyName::Gcl)
        || cfg.scenario == 0
    {
        cfg.fig3_pool = false;
    }
    Ok(cfg)
}

fn print_plan(plan: &camflow::coordinator::Plan, requests: &[camflow::cameras::StreamRequest]) {
    let mut t = Table::new(&["Instance", "Region", "Price/h", "Streams", "Assigned"]);
    for inst in &plan.instances {
        let names: Vec<String> = inst
            .streams
            .iter()
            .map(|&s| requests[s].label())
            .collect();
        t.row(&[
            inst.label.clone(),
            format!("{}", inst.region_idx),
            fmt_usd(inst.hourly_cost),
            format!("{}", inst.streams.len()),
            names.join(", "),
        ]);
    }
    t.print();
    println!(
        "\ntotal: {} instances ({} CPU-only, {} GPU), {}/hour, method={:?}, degraded={}",
        plan.instances.len(),
        plan.non_gpu,
        plan.gpu,
        fmt_usd(plan.cost_per_hour),
        plan.method,
        plan.degraded.len()
    );
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = load_run_config(args)?;
    let requests = cfg.requests()?;
    let planner = Planner::new(cfg.catalog(), cfg.strategy.to_planner_config());
    let plan = planner.plan(&requests)?;
    println!(
        "workload: {} streams, strategy {}",
        requests.len(),
        cfg.strategy.as_str()
    );
    print_plan(&plan, &requests);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let n = args.opt_parse("cameras", 30usize)?;
    let seed = args.opt_parse("seed", 1u64)?;
    let catalog = Catalog::builtin();
    let mut t = Table::new(&["fps", "NL $/h", "ARMVAC $/h", "GCL $/h", "GCL vs NL", "GCL vs ARMVAC"]);
    for fps in [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 20.0, 25.0, 30.0] {
        let requests = scenarios::fig6_workload(n, fps, seed);
        let cost = |s: StrategyName| -> Result<f64> {
            Planner::new(catalog.clone(), s.to_planner_config())
                .plan(&requests)
                .map(|p| p.cost_per_hour)
        };
        let nl = cost(StrategyName::Nl)?;
        let armvac = cost(StrategyName::Armvac)?;
        let gcl = cost(StrategyName::Gcl)?;
        t.row(&[
            format!("{fps}"),
            format!("{nl:.3}"),
            format!("{armvac:.3}"),
            format!("{gcl:.3}"),
            format!("{:.0}%", (1.0 - gcl / nl) * 100.0),
            format!("{:.0}%", (1.0 - gcl / armvac) * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args) -> Result<()> {
    Err(camflow::Error::config(
        "this build has no PJRT serving layer; rebuild with `--features pjrt` \
         (requires the vendored xla crate and `make artifacts`)",
    ))
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_run_config(args)?;
    let requests = cfg.requests()?;
    let planner = Planner::new(cfg.catalog(), cfg.strategy.to_planner_config());
    let plan = planner.plan(&requests)?;
    print_plan(&plan, &requests);

    let serve_cfg = camflow::server::ServeConfig {
        artifacts_dir: cfg.artifacts_dir.clone().into(),
        duration_s: cfg.duration_s,
        time_scale: cfg.time_scale,
        batch_window_ms: cfg.batch_window_ms,
        queue_capacity: 256,
        seed: cfg.seed,
    };
    let fps = plan.delivered_fps(&requests);
    println!(
        "\nserving {} virtual seconds at {}x time compression...",
        cfg.duration_s, cfg.time_scale
    );
    let report = camflow::server::serve(&plan, &requests, &fps, &serve_cfg)?;
    let mut t = Table::new(&["Instance", "Streams", "Frames", "Dropped", "Batches", "Mean batch", "Infer ms", "p50 ms", "p99 ms"]);
    for i in &report.instances {
        t.row(&[
            i.label.clone(),
            format!("{}", i.streams),
            format!("{}", i.frames_analyzed),
            format!("{}", i.frames_dropped),
            format!("{}", i.batches),
            format!("{:.2}", i.mean_batch),
            format!("{:.2}", i.infer_mean_ms),
            format!("{:.2}", i.e2e_p50_ms),
            format!("{:.2}", i.e2e_p99_ms),
        ]);
    }
    t.print();
    println!(
        "\nanalyzed {} frames ({:.2} virtual fps), dropped {} ({:.1}%), detections {}, plan cost {}/h, wall {:.1}s",
        report.total_frames_analyzed,
        report.virtual_throughput_fps,
        report.total_frames_dropped,
        report.drop_rate() * 100.0,
        report.detections,
        fmt_usd(report.plan_cost_per_hour),
        report.real_duration_s,
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    use camflow::cloudsim::CloudSim;
    let hours = args.opt_parse("hours", 24usize)?;
    let n = args.opt_parse("cameras", 12usize)?;
    let seed = args.opt_parse("seed", 3u64)?;
    let cold = args.flag("cold");

    let catalog = Catalog::builtin();
    let planner = Planner::new(catalog.clone(), StrategyName::Gcl.to_planner_config());
    let mut mgr = if cold {
        AdaptiveManager::cold(planner)
    } else {
        AdaptiveManager::new(planner)
    };
    let mut sim = CloudSim::new(catalog);

    let db = camflow::cameras::CameraDb::synthetic(n, seed);
    let mut t = Table::new(&[
        "hour", "fps", "instances", "$/h", "provisioned", "terminated", "moved", "churn",
        "plan ms", "reuse",
    ]);
    let mut static_cost = 0.0f64;
    let mut peak_rate = 0.0f64;
    for h in 0..hours {
        // Rush hours (7-9, 16-18 local) need 8 fps tracking; nights 0.2 fps.
        let fps = match h % 24 {
            7..=9 | 16..=18 => 8.0,
            22 | 23 | 0..=5 => 0.2,
            _ => 1.0,
        };
        let requests = db.workload(camflow::profiles::Program::Zf, fps);
        let t0 = std::time::Instant::now();
        let report = mgr.replan(requests)?;
        let plan_ms = t0.elapsed().as_secs_f64() * 1000.0;
        let plan = mgr.current_plan().unwrap();
        sim.apply_plan(plan)?;
        sim.advance(3600.0);
        peak_rate = peak_rate.max(plan.cost_per_hour);
        t.row(&[
            format!("{h}"),
            format!("{fps}"),
            format!("{}", plan.instances.len()),
            format!("{:.3}", plan.cost_per_hour),
            format!("{}", report.provision.iter().map(|(_, n)| n).sum::<usize>()),
            format!("{}", report.terminate.iter().map(|(_, n)| n).sum::<usize>()),
            format!("{}", report.streams_moved),
            format!("{:.0}%", report.churn_ratio() * 100.0),
            format!("{plan_ms:.1}"),
            format!("{:.0}%", report.pipeline.reuse_ratio() * 100.0),
        ]);
        static_cost += peak_rate; // static provisioning pays peak all day
    }
    t.print();
    println!(
        "\nadaptive total: {}  |  static-peak provisioning: {}  |  saving {:.0}%  ({} re-plans)",
        fmt_usd(sim.accrued_usd()),
        fmt_usd(static_cost),
        (1.0 - sim.accrued_usd() / static_cost) * 100.0,
        if cold { "cold" } else { "warm incremental" }
    );
    Ok(())
}
