//! Built-in instance types, regions, and prices.
//!
//! Table I rows are pinned verbatim; other (type, region) prices follow the
//! vendor's regional multiplier pattern of the era. `us-east-2` carries the
//! Fig-3 experiment pool prices ($0.419 CPU box, $0.650 g2.2xlarge) quoted by
//! the paper's evaluation table.

use super::{Catalog, Dims, InstanceType, Offering, Region, SpotQuote, Vendor};
use crate::geo::GeoPoint;

/// (id, vendor, city, lat, lon, regional price multiplier vs us-east-1)
const REGIONS: &[(&str, Vendor, &str, f64, f64, f64)] = &[
    ("us-east-1", Vendor::Ec2, "Virginia", 38.95, -77.45, 1.000),
    ("us-east-2", Vendor::Ec2, "Ohio", 39.96, -82.99, 1.0528), // Fig-3 pool
    ("us-west-1", Vendor::Ec2, "N. California", 37.35, -121.96, 1.170),
    ("us-west-2", Vendor::Ec2, "Oregon", 45.84, -119.70, 1.000),
    ("eu-west-1", Vendor::Ec2, "Ireland", 53.34, -6.27, 1.110),
    ("eu-west-2", Vendor::Ec2, "London", 51.51, -0.13, 1.196),
    ("eu-central-1", Vendor::Ec2, "Frankfurt", 50.11, 8.68, 1.150),
    ("ap-southeast-1", Vendor::Ec2, "Singapore", 1.35, 103.82, 1.161),
    ("ap-southeast-2", Vendor::Ec2, "Sydney", -33.87, 151.21, 1.250),
    ("ap-northeast-1", Vendor::Ec2, "Tokyo", 35.68, 139.69, 1.260),
    ("ap-south-1", Vendor::Ec2, "Mumbai", 19.08, 72.88, 1.060),
    ("sa-east-1", Vendor::Ec2, "Sao Paulo", -23.55, -46.63, 1.560),
    ("az-us-east", Vendor::Azure, "Virginia (Azure)", 38.80, -78.20, 1.000),
    ("az-west-europe", Vendor::Azure, "Amsterdam", 52.37, 4.90, 1.250),
    ("az-east-asia", Vendor::Azure, "Hong Kong", 22.32, 114.17, 1.628),
];

/// (name, vendor, vCPU, mem GiB, GPUs, GPU mem GiB, base price us-east-1,
///  gpu generation speed factor vs the g2/K520 profiling baseline)
const TYPES: &[(&str, Vendor, f64, f64, f64, f64, f64, f64)] = &[
    // Table I EC2 rows.
    ("c4.2xlarge", Vendor::Ec2, 8.0, 15.0, 0.0, 0.0, 0.398, 1.0),
    ("c4.8xlarge", Vendor::Ec2, 36.0, 60.0, 0.0, 0.0, 1.591, 1.0),
    ("g3.8xlarge", Vendor::Ec2, 32.0, 244.0, 2.0, 16.0, 2.280, 2.5),
    // Prose-quoted EC2 instances.
    ("c5d.9xlarge", Vendor::Ec2, 36.0, 72.0, 0.0, 0.0, 1.728, 1.0),
    ("p3.2xlarge", Vendor::Ec2, 8.0, 61.0, 1.0, 16.0, 3.06, 8.0),
    ("p3.8xlarge", Vendor::Ec2, 32.0, 244.0, 4.0, 64.0, 12.24, 8.0),
    // The Fig-3 evaluation pool GPU box (K520-era g2).
    ("g2.2xlarge", Vendor::Ec2, 8.0, 15.0, 1.0, 4.0, 0.6173, 1.0),
    // Smaller CPU boxes for location experiments (same c4 family pricing).
    ("c4.large", Vendor::Ec2, 2.0, 3.75, 0.0, 0.0, 0.100, 1.0),
    ("c4.xlarge", Vendor::Ec2, 4.0, 7.5, 0.0, 0.0, 0.199, 1.0),
    ("c4.4xlarge", Vendor::Ec2, 16.0, 30.0, 0.0, 0.0, 0.796, 1.0),
    // Table I Azure rows.
    ("D8_v3", Vendor::Azure, 8.0, 32.0, 0.0, 0.0, 0.384, 1.0),
    ("NC24r", Vendor::Azure, 24.0, 224.0, 4.0, 48.0, 3.960, 4.0),
    // Additional Azure family members (2018-era price points) so Azure-only
    // coverage areas can host CPU-heavy and GPU-heavy streams.
    ("D16_v3", Vendor::Azure, 16.0, 64.0, 0.0, 0.0, 0.768, 1.0),
    ("D32_v3", Vendor::Azure, 32.0, 128.0, 0.0, 0.0, 1.536, 1.0),
    ("NC6", Vendor::Azure, 6.0, 56.0, 1.0, 12.0, 0.90, 1.5),
    ("NC12", Vendor::Azure, 12.0, 112.0, 2.0, 24.0, 1.80, 1.5),
    ("NC6s_v3", Vendor::Azure, 6.0, 112.0, 1.0, 16.0, 3.06, 8.0),
    ("NC24s_v3", Vendor::Azure, 24.0, 448.0, 4.0, 64.0, 12.24, 8.0),
];

/// Exact Table-I (and prose) overrides: (type, region) -> price.
/// A negative price marks an explicit N/A (offering withheld in that region).
const OVERRIDES: &[(&str, &str, f64)] = &[
    // Table I, EC2 London / Singapore columns.
    ("c4.2xlarge", "eu-west-2", 0.476),
    ("c4.2xlarge", "ap-southeast-1", 0.462),
    ("c4.8xlarge", "eu-west-2", 1.902),
    ("c4.8xlarge", "ap-southeast-1", 1.848),
    ("g3.8xlarge", "eu-west-2", -1.0), // N/A
    ("g3.8xlarge", "ap-southeast-1", 3.340),
    // Table I, Azure columns.
    ("D8_v3", "az-west-europe", 0.480),
    ("D8_v3", "az-east-asia", 0.625),
    ("NC24r", "az-west-europe", 5.132),
    ("NC24r", "az-east-asia", -1.0), // N/A
    // Fig-3 pool (us-east-2): the paper's $0.419 CPU box and $0.650 GPU box.
    ("c4.2xlarge", "us-east-2", 0.419),
    ("g2.2xlarge", "us-east-2", 0.650),
];

/// Per-type spot quotes: (type, spot price as a fraction of the regional
/// on-demand price, expected revocations per instance-hour). The era's spot
/// markets priced steady CPU families near a third of on-demand with rare
/// revocations; contended GPU pools discounted deeper but revoked far more
/// often. Azure rows model low-priority VMs (the vendor's spot equivalent):
/// a flat ~60% off compute families, ~50% off GPU families. A type absent
/// here has no spot pool anywhere.
const SPOT: &[(&str, f64, f64)] = &[
    ("c4.large", 0.35, 0.03),
    ("c4.xlarge", 0.35, 0.03),
    ("c4.2xlarge", 0.34, 0.04),
    ("c4.4xlarge", 0.33, 0.05),
    ("c4.8xlarge", 0.31, 0.06),
    ("c5d.9xlarge", 0.32, 0.05),
    ("g2.2xlarge", 0.30, 0.08),
    ("g3.8xlarge", 0.28, 0.10),
    ("p3.2xlarge", 0.30, 0.12),
    ("p3.8xlarge", 0.30, 0.12),
    ("D8_v3", 0.40, 0.03),
    ("D16_v3", 0.40, 0.03),
    ("D32_v3", 0.40, 0.03),
    ("NC6", 0.50, 0.08),
    ("NC12", 0.50, 0.08),
    ("NC24r", 0.50, 0.10),
    ("NC6s_v3", 0.50, 0.12),
    ("NC24s_v3", 0.50, 0.12),
];

/// Azure types are offered only in Azure regions and vice versa; GPU types are
/// not offered everywhere (mirrors the paper's N/A cells).
fn offered(ty: &InstanceType, region: &Region) -> bool {
    if ty.vendor != region.vendor {
        return false;
    }
    true
}

pub fn build() -> Catalog {
    let regions: Vec<Region> = REGIONS
        .iter()
        .map(|&(id, vendor, city, lat, lon, _)| Region {
            id,
            vendor,
            city,
            location: GeoPoint::new(lat, lon),
        })
        .collect();
    let types: Vec<InstanceType> = TYPES
        .iter()
        .map(|&(name, vendor, vcpus, mem, gpus, gpu_mem, _, gpu_speed)| InstanceType {
            vendor,
            name,
            capacity: Dims::new(vcpus, mem, gpus, gpu_mem),
            gpu_speed,
        })
        .collect();

    let mut offerings = Vec::new();
    for (ti, (tname, _, _, _, _, _, base, _)) in TYPES.iter().enumerate() {
        for (ri, (rid, _, _, _, _, mult)) in REGIONS.iter().enumerate() {
            if !offered(&types[ti], &regions[ri]) {
                continue;
            }
            let mut price = base * mult;
            let mut skip = false;
            for &(oty, org, op) in OVERRIDES {
                if oty == *tname && org == *rid {
                    if op < 0.0 {
                        skip = true;
                    } else {
                        price = op;
                    }
                }
            }
            if skip {
                continue;
            }
            let hourly_usd = (price * 10000.0).round() / 10000.0;
            let spot = SPOT.iter().find(|&&(n, _, _)| n == *tname).map(|&(_, frac, rate)| {
                SpotQuote {
                    hourly_usd: (hourly_usd * frac * 10000.0).round() / 10000.0,
                    preemption_rate_per_hour: rate,
                }
            });
            offerings.push(Offering { type_idx: ti, region_idx: ri, hourly_usd, spot });
        }
    }
    Catalog { types, regions, offerings }
}
