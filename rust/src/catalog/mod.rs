//! Cloud instance catalog: instance types, regions, and per-region prices.
//!
//! Reproduces Table I of the paper (EC2 c4.2xlarge / c4.8xlarge / g3.8xlarge,
//! Azure D8 v3 / NC24r at Virginia/London/Singapore resp. US-East/W-Europe/
//! E-Asia) plus the instances quoted in prose (c5d.9xlarge $1.728, p3.2xlarge
//! $3.06, p3.8xlarge $12.24) and the Fig-3 experiment pool (a $0.419 CPU box
//! and the $0.650 g2.2xlarge GPU box).
//!
//! Resource dimensions follow Kaseb et al. \[7\]: vCPUs, memory (GiB), GPUs,
//! GPU memory (GiB) — the 4-dimensional vector bin packing space.

pub mod prices;

use crate::geo::GeoPoint;

/// The paper's four resource dimensions (Kaseb et al. \[7\]).
pub const NUM_DIMS: usize = 4;

/// A demand or capacity vector over (vCPU, mem GiB, GPU, GPU-mem GiB).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Dims {
    pub vcpus: f64,
    pub mem_gib: f64,
    pub gpus: f64,
    pub gpu_mem_gib: f64,
}

impl Dims {
    /// The all-zero vector.
    pub const ZERO: Dims = Dims::new(0.0, 0.0, 0.0, 0.0);

    pub const fn new(vcpus: f64, mem_gib: f64, gpus: f64, gpu_mem_gib: f64) -> Self {
        Dims { vcpus, mem_gib, gpus, gpu_mem_gib }
    }

    pub fn as_array(&self) -> [f64; NUM_DIMS] {
        [self.vcpus, self.mem_gib, self.gpus, self.gpu_mem_gib]
    }

    pub fn from_array(a: [f64; NUM_DIMS]) -> Self {
        Dims::new(a[0], a[1], a[2], a[3])
    }

    /// Component-wise `self + other`.
    pub fn add(&self, other: &Dims) -> Dims {
        Dims::new(
            self.vcpus + other.vcpus,
            self.mem_gib + other.mem_gib,
            self.gpus + other.gpus,
            self.gpu_mem_gib + other.gpu_mem_gib,
        )
    }

    /// Component-wise scale.
    pub fn scale(&self, k: f64) -> Dims {
        Dims::new(self.vcpus * k, self.mem_gib * k, self.gpus * k, self.gpu_mem_gib * k)
    }

    /// True iff every component of `self` fits within `cap`.
    pub fn fits_in(&self, cap: &Dims) -> bool {
        const EPS: f64 = 1e-9;
        self.vcpus <= cap.vcpus + EPS
            && self.mem_gib <= cap.mem_gib + EPS
            && self.gpus <= cap.gpus + EPS
            && self.gpu_mem_gib <= cap.gpu_mem_gib + EPS
    }

    /// Max over dimensions of self/cap (utilization); dims with zero capacity
    /// count as infinite when demanded.
    pub fn max_utilization(&self, cap: &Dims) -> f64 {
        let mut m: f64 = 0.0;
        for (d, c) in self.as_array().iter().zip(cap.as_array()) {
            if *d <= 0.0 {
                continue;
            }
            if c <= 0.0 {
                return f64::INFINITY;
            }
            m = m.max(d / c);
        }
        m
    }

    pub fn is_zero(&self) -> bool {
        self.as_array().iter().all(|&v| v == 0.0)
    }
}

/// Cloud vendor (the paper evaluates EC2 and quotes Azure prices).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Vendor {
    Ec2,
    Azure,
}

impl std::fmt::Display for Vendor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Vendor::Ec2 => write!(f, "EC2"),
            Vendor::Azure => write!(f, "Azure"),
        }
    }
}

/// An instance *type* (configuration): capacity vector + vendor + name.
#[derive(Clone, Debug)]
pub struct InstanceType {
    pub vendor: Vendor,
    pub name: &'static str,
    pub capacity: Dims,
    /// GPU generation speed multiplier relative to the profiling baseline
    /// (g2-class K520 = 1.0; g3-class M60 ≈ 2.5; p3-class V100 ≈ 8). A
    /// stream's GPU-time demand is divided by this factor on that type.
    pub gpu_speed: f64,
}

impl InstanceType {
    pub fn has_gpu(&self) -> bool {
        self.capacity.gpus > 0.0
    }
}

/// A cloud data-center region with geographic coordinates.
#[derive(Clone, Debug)]
pub struct Region {
    pub id: &'static str,
    pub vendor: Vendor,
    pub city: &'static str,
    pub location: GeoPoint,
}

/// A spot-market quote for an offering: the discounted hourly price and the
/// expected revocation rate of the pool. Spot capacity is reclaimable — the
/// temporal packing axis discounts a spot bin's usable capacity by the
/// revocation rate, and the simulator's preemption injector revokes spot
/// instances with a [2-minute warning](crate::cloudsim::SPOT_WARNING_S).
#[derive(Clone, Copy, Debug)]
pub struct SpotQuote {
    /// Discounted hourly price, strictly below the on-demand price.
    pub hourly_usd: f64,
    /// Expected revocations per instance-hour, in (0, 1).
    pub preemption_rate_per_hour: f64,
}

/// A priced offering: (instance type, region, hourly USD), plus the
/// spot-market quote when the type has a spot pool in that region. Live
/// streams are always planned against the on-demand price; only deferred
/// backfill ([`crate::coordinator::spot`]) ever sees the quote.
#[derive(Clone, Copy, Debug)]
pub struct Offering {
    pub type_idx: usize,
    pub region_idx: usize,
    pub hourly_usd: f64,
    pub spot: Option<SpotQuote>,
}

/// The full catalog.
#[derive(Clone, Debug)]
pub struct Catalog {
    pub types: Vec<InstanceType>,
    pub regions: Vec<Region>,
    pub offerings: Vec<Offering>,
}

impl Catalog {
    /// The built-in catalog (see module docs / prices.rs).
    pub fn builtin() -> Catalog {
        prices::build()
    }

    pub fn type_by_name(&self, name: &str) -> Option<usize> {
        self.types.iter().position(|t| t.name == name)
    }

    pub fn region_by_id(&self, id: &str) -> Option<usize> {
        self.regions.iter().position(|r| r.id == id)
    }

    /// Price of a type in a region, if offered there.
    pub fn price(&self, type_idx: usize, region_idx: usize) -> Option<f64> {
        self.offerings
            .iter()
            .find(|o| o.type_idx == type_idx && o.region_idx == region_idx)
            .map(|o| o.hourly_usd)
    }

    /// Spot price of a type in a region, if a spot pool is quoted there.
    pub fn spot_price(&self, type_idx: usize, region_idx: usize) -> Option<f64> {
        self.spot_quote(type_idx, region_idx).map(|q| q.hourly_usd)
    }

    /// Full spot quote (price + revocation rate) of a type in a region.
    pub fn spot_quote(&self, type_idx: usize, region_idx: usize) -> Option<SpotQuote> {
        self.offerings
            .iter()
            .find(|o| o.type_idx == type_idx && o.region_idx == region_idx)
            .and_then(|o| o.spot)
    }

    /// All offerings in a region.
    pub fn offerings_in(&self, region_idx: usize) -> Vec<Offering> {
        self.offerings
            .iter()
            .copied()
            .filter(|o| o.region_idx == region_idx)
            .collect()
    }

    /// Restrict to a subset of type names and/or region ids (None = keep all).
    /// Offerings are filtered consistently; indices are re-mapped.
    pub fn restrict(&self, type_names: Option<&[&str]>, region_ids: Option<&[&str]>) -> Catalog {
        let keep_type: Vec<bool> = self
            .types
            .iter()
            .map(|t| type_names.map_or(true, |ns| ns.contains(&t.name)))
            .collect();
        let keep_region: Vec<bool> = self
            .regions
            .iter()
            .map(|r| region_ids.map_or(true, |ids| ids.contains(&r.id)))
            .collect();
        let mut type_map = vec![usize::MAX; self.types.len()];
        let mut region_map = vec![usize::MAX; self.regions.len()];
        let mut types = Vec::new();
        let mut regions = Vec::new();
        for (i, t) in self.types.iter().enumerate() {
            if keep_type[i] {
                type_map[i] = types.len();
                types.push(t.clone());
            }
        }
        for (i, r) in self.regions.iter().enumerate() {
            if keep_region[i] {
                region_map[i] = regions.len();
                regions.push(r.clone());
            }
        }
        let offerings = self
            .offerings
            .iter()
            .filter(|o| keep_type[o.type_idx] && keep_region[o.region_idx])
            .map(|o| Offering {
                type_idx: type_map[o.type_idx],
                region_idx: region_map[o.region_idx],
                hourly_usd: o.hourly_usd,
                spot: o.spot,
            })
            .collect();
        Catalog { types, regions, offerings }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_fits_and_add() {
        let a = Dims::new(2.0, 4.0, 0.0, 0.0);
        let b = Dims::new(1.0, 1.0, 1.0, 2.0);
        let cap = Dims::new(4.0, 8.0, 1.0, 4.0);
        assert!(a.fits_in(&cap));
        assert!(a.add(&b).fits_in(&cap));
        assert!(!a.add(&b).add(&b).fits_in(&cap));
    }

    #[test]
    fn dims_utilization() {
        let d = Dims::new(4.0, 4.0, 0.0, 0.0);
        let cap = Dims::new(8.0, 16.0, 0.0, 0.0);
        assert!((d.max_utilization(&cap) - 0.5).abs() < 1e-12);
        let g = Dims::new(0.0, 0.0, 0.5, 0.0);
        assert!(g.max_utilization(&cap).is_infinite());
    }

    #[test]
    fn builtin_has_table1_types() {
        let c = Catalog::builtin();
        for name in ["c4.2xlarge", "c4.8xlarge", "g3.8xlarge", "D8_v3", "NC24r"] {
            assert!(c.type_by_name(name).is_some(), "missing {name}");
        }
    }

    #[test]
    fn table1_prices_exact() {
        // Table I of the paper, verbatim.
        let c = Catalog::builtin();
        let cases = [
            ("c4.2xlarge", "us-east-1", Some(0.398)),
            ("c4.2xlarge", "eu-west-2", Some(0.476)),
            ("c4.2xlarge", "ap-southeast-1", Some(0.462)),
            ("c4.8xlarge", "us-east-1", Some(1.591)),
            ("c4.8xlarge", "eu-west-2", Some(1.902)),
            ("c4.8xlarge", "ap-southeast-1", Some(1.848)),
            ("g3.8xlarge", "us-east-1", Some(2.280)),
            ("g3.8xlarge", "eu-west-2", None), // N/A in Table I
            ("g3.8xlarge", "ap-southeast-1", Some(3.340)),
            ("D8_v3", "az-us-east", Some(0.384)),
            ("D8_v3", "az-west-europe", Some(0.480)),
            ("D8_v3", "az-east-asia", Some(0.625)),
            ("NC24r", "az-us-east", Some(3.960)),
            ("NC24r", "az-west-europe", Some(5.132)),
            ("NC24r", "az-east-asia", None), // N/A in Table I
        ];
        for (ty, rg, want) in cases {
            let t = c.type_by_name(ty).unwrap();
            let r = c.region_by_id(rg).unwrap();
            let got = c.price(t, r);
            match want {
                Some(p) => assert_eq!(got, Some(p), "{ty}@{rg}"),
                None => assert_eq!(got, None, "{ty}@{rg} should be N/A"),
            }
        }
    }

    #[test]
    fn prose_prices_exact() {
        let c = Catalog::builtin();
        let cases = [
            ("c5d.9xlarge", "us-east-1", 1.728),
            ("p3.2xlarge", "us-east-1", 3.06),
            ("p3.8xlarge", "us-east-1", 12.24),
            ("g2.2xlarge", "us-east-2", 0.650),
            ("c4.2xlarge", "us-east-2", 0.419),
        ];
        for (ty, rg, want) in cases {
            let t = c.type_by_name(ty).unwrap();
            let r = c.region_by_id(rg).unwrap();
            assert_eq!(c.price(t, r), Some(want), "{ty}@{rg}");
        }
    }

    #[test]
    fn azure_d8v3_singapore_premium_is_63_percent() {
        // The paper: Azure D8 v3 costs 63% more in (East Asia) than in US East:
        // 0.625 / 0.384 = 1.63.
        let c = Catalog::builtin();
        let t = c.type_by_name("D8_v3").unwrap();
        let hi = c.price(t, c.region_by_id("az-east-asia").unwrap()).unwrap();
        let lo = c.price(t, c.region_by_id("az-us-east").unwrap()).unwrap();
        assert!((hi / lo - 1.63).abs() < 0.01);
    }

    #[test]
    fn gpu_flags() {
        let c = Catalog::builtin();
        assert!(c.types[c.type_by_name("g3.8xlarge").unwrap()].has_gpu());
        assert!(c.types[c.type_by_name("p3.2xlarge").unwrap()].has_gpu());
        assert!(!c.types[c.type_by_name("c4.2xlarge").unwrap()].has_gpu());
    }

    #[test]
    fn restrict_remaps_consistently() {
        let c = Catalog::builtin();
        let small = c.restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        assert_eq!(small.types.len(), 2);
        assert_eq!(small.regions.len(), 1);
        assert!(!small.offerings.is_empty());
        for o in &small.offerings {
            assert!(o.type_idx < small.types.len());
            assert_eq!(o.region_idx, 0);
        }
        let t = small.type_by_name("c4.2xlarge").unwrap();
        assert_eq!(small.price(t, 0), Some(0.419));
    }

    #[test]
    fn bigger_cpu_instances_cheaper_per_core() {
        // c4.8xlarge undercuts c4.2xlarge per vCPU — the Fig-5 effect.
        let c = Catalog::builtin();
        let r = c.region_by_id("us-east-1").unwrap();
        let t2 = c.type_by_name("c4.2xlarge").unwrap();
        let t8 = c.type_by_name("c4.8xlarge").unwrap();
        let per_core_2 = c.price(t2, r).unwrap() / c.types[t2].capacity.vcpus;
        let per_core_8 = c.price(t8, r).unwrap() / c.types[t8].capacity.vcpus;
        assert!(per_core_8 < per_core_2);
    }

    #[test]
    fn every_offering_indexes_valid() {
        let c = Catalog::builtin();
        for o in &c.offerings {
            assert!(o.type_idx < c.types.len());
            assert!(o.region_idx < c.regions.len());
            assert!(o.hourly_usd > 0.0);
        }
    }

    #[test]
    fn spot_quotes_are_strict_discounts_with_bounded_risk() {
        let c = Catalog::builtin();
        let mut quoted = 0usize;
        for o in &c.offerings {
            if let Some(q) = o.spot {
                assert!(q.hourly_usd > 0.0, "spot price must be positive");
                assert!(
                    q.hourly_usd < o.hourly_usd,
                    "spot {} must undercut on-demand {}",
                    q.hourly_usd,
                    o.hourly_usd
                );
                assert!(
                    q.preemption_rate_per_hour > 0.0 && q.preemption_rate_per_hour < 1.0,
                    "revocation rate out of (0, 1)"
                );
                quoted += 1;
            }
        }
        assert!(quoted > 0, "the builtin catalog quotes at least one spot pool");
    }

    #[test]
    fn restrict_carries_spot_quotes_through_the_remap() {
        let c = Catalog::builtin();
        let t = c.type_by_name("c4.2xlarge").unwrap();
        let r = c.region_by_id("us-east-2").unwrap();
        let full = c.spot_price(t, r).expect("c4.2xlarge has a spot pool");
        let small = c.restrict(Some(&["c4.2xlarge"]), Some(&["us-east-2"]));
        assert_eq!(small.spot_price(0, 0), Some(full));
        let q = small.spot_quote(0, 0).unwrap();
        assert_eq!(
            q.preemption_rate_per_hour,
            c.spot_quote(t, r).unwrap().preemption_rate_per_hour
        );
    }
}
