//! Serving→planning feedback: fold per-window serving observations into
//! per-stream [`DemandFeedback`] for the next re-plan.
//!
//! The controller watches [`InstanceWindow`]s from either executor and
//! maintains, per stream:
//!
//! - an **observed cost estimate**: an EWMA of measured analysis seconds
//!   per frame relative to the declared profile. Published to the planner
//!   as [`DemandFeedback::cost_scale`] only through a quantize-and-deadband
//!   step, so EWMA jitter cannot dirty a re-plan (the drift signature hashes
//!   the published value, not the raw estimate).
//! - a **backpressure degrade tier** ([`DemandFeedback::shed_tier`]): when
//!   an instance shows sustained pressure — queue depth or drop rate over
//!   threshold — its streams shed one fps tier (each tier halves
//!   [`effective_fps`](crate::cameras::StreamRequest::effective_fps))
//!   *before* the queue has to drop frames wholesale. Sustained headroom
//!   restores one tier at a time.
//!
//! # Thresholds and hysteresis (defaults)
//!
//! | knob | default | meaning |
//! |------|---------|---------|
//! | `ewma_alpha` | 0.3 | weight of the newest window's cost ratio |
//! | `publish_quantum` | 0.05 | published `cost_scale` snaps to this grid |
//! | `publish_deadband` | 0.05 | relative EWMA move needed to re-publish |
//! | `scale_min` / `scale_max` | 0.25 / 4.0 | clamp on published scale |
//! | `queue_high_water` | 0.75 | queue fill fraction that triggers a shed |
//! | `drop_degrade` | 0.01 | window drop rate that triggers a shed |
//! | `util_restore` | 0.6 | utilization ceiling that counts as headroom |
//! | `restore_windows` | 3 | consecutive calm windows before restoring |
//! | `max_tier` | 3 | deepest shed (fps / 8); never sheds to zero |
//!
//! # Worked example: a 0.5 fps camera under pressure
//!
//! A camera declared at 0.5 fps lands on an instance whose queue climbs to
//! 80% of capacity (> `queue_high_water`) during a window. Every stream on
//! that instance sheds one tier, so the camera drops to tier 1 = 0.25 fps —
//! its frames are planned and paced at half rate, but none are discarded.
//! If pressure persists (say its true cost is 4× the declared profile) the
//! next windows shed further: tier 2 = 0.125 fps, tier 3 = 0.0625 fps, and
//! there it stays — `max_tier = 3` guarantees a stream is never shed to
//! zero. Meanwhile the cost EWMA converges toward 4.0 and the published
//! `cost_scale` follows (clamped at `scale_max`), so the *next re-plan*
//! provisions real capacity for it. Once the new plan absorbs the load and
//! the instance shows three consecutive windows (`restore_windows`) with
//! utilization ≤ 0.6 and zero drops, the camera climbs back one tier per
//! calm window: 0.125, 0.25, and finally its declared 0.5 fps.

use super::sim::InstanceWindow;
use crate::cameras::{DemandFeedback, StreamRequest};
use std::collections::HashMap;

/// Controller thresholds; see the module table for semantics.
#[derive(Clone, Debug)]
pub struct FeedbackConfig {
    pub ewma_alpha: f64,
    pub publish_quantum: f64,
    pub publish_deadband: f64,
    pub scale_min: f64,
    pub scale_max: f64,
    pub queue_high_water: f64,
    pub drop_degrade: f64,
    pub util_restore: f64,
    pub restore_windows: u32,
    pub max_tier: u8,
}

impl Default for FeedbackConfig {
    fn default() -> Self {
        FeedbackConfig {
            ewma_alpha: 0.3,
            publish_quantum: 0.05,
            publish_deadband: 0.05,
            scale_min: 0.25,
            scale_max: 4.0,
            queue_high_water: 0.75,
            drop_degrade: 0.01,
            util_restore: 0.6,
            restore_windows: 3,
            max_tier: 3,
        }
    }
}

#[derive(Clone, Debug)]
struct StreamState {
    /// EWMA of measured/declared cost per frame; None until first sample.
    ewma_ratio: Option<f64>,
    /// Last published (quantized) cost scale; 1.0 = profile as declared.
    published_scale: f64,
    tier: u8,
    /// Consecutive calm windows observed while shed (resets on pressure).
    calm_windows: u32,
}

impl Default for StreamState {
    fn default() -> Self {
        StreamState { ewma_ratio: None, published_scale: 1.0, tier: 0, calm_windows: 0 }
    }
}

/// Folds serving windows into per-stream demand feedback (module docs).
#[derive(Default)]
pub struct FeedbackController {
    cfg: FeedbackConfig,
    states: HashMap<usize, StreamState>,
}

impl FeedbackController {
    pub fn new(cfg: FeedbackConfig) -> Self {
        FeedbackController { cfg, states: HashMap::new() }
    }

    /// Quantize an EWMA estimate onto the publish grid, clamped.
    fn quantize(&self, ratio: f64) -> f64 {
        let q = (ratio / self.cfg.publish_quantum).round() * self.cfg.publish_quantum;
        q.clamp(self.cfg.scale_min, self.cfg.scale_max)
    }

    /// Fold one batch of observation windows into the per-stream estimates.
    pub fn observe(&mut self, windows: &[InstanceWindow]) {
        for w in windows {
            let queue_frac = if w.queue_capacity == 0 {
                0.0
            } else {
                w.window.queue_depth / w.queue_capacity as f64
            };
            let pressured = queue_frac >= self.cfg.queue_high_water
                || w.window.drop_rate() >= self.cfg.drop_degrade;
            let calm = !pressured
                && w.utilization <= self.cfg.util_restore
                && w.window.frames_dropped == 0;
            for s in &w.streams {
                let st = self.states.entry(s.stream_idx).or_default();
                // Cost estimate: only windows that analyzed frames carry a
                // measurable ratio.
                if s.frames_analyzed > 0 && s.declared_cost_s > 0.0 {
                    let ratio = s.measured_cost_s / s.declared_cost_s;
                    let ewma = match st.ewma_ratio {
                        None => ratio,
                        Some(prev) => {
                            prev + self.cfg.ewma_alpha * (ratio - prev)
                        }
                    };
                    st.ewma_ratio = Some(ewma);
                    // Deadband: re-publish only on a real move, then snap to
                    // the grid so the planner sees a stable value.
                    let rel = (ewma - st.published_scale).abs() / st.published_scale.max(1e-9);
                    if rel > self.cfg.publish_deadband {
                        let q = self.quantize(ewma);
                        if q != st.published_scale {
                            st.published_scale = q;
                        }
                    }
                }
                // Degrade tiers: shed on pressure, restore after sustained
                // headroom. One tier per window in either direction.
                if pressured {
                    st.calm_windows = 0;
                    if st.tier < self.cfg.max_tier {
                        st.tier += 1;
                    }
                } else if st.tier > 0 && calm {
                    st.calm_windows += 1;
                    if st.calm_windows >= self.cfg.restore_windows {
                        st.tier -= 1;
                        // Keep credit so each further calm window restores
                        // another tier (the worked example's one-per-window
                        // climb) without re-earning the full streak.
                        st.calm_windows = self.cfg.restore_windows.saturating_sub(1);
                    }
                } else if st.tier > 0 {
                    st.calm_windows = 0;
                }
            }
        }
    }

    /// Current feedback for one stream (default when never observed).
    pub fn feedback_for(&self, stream_idx: usize) -> DemandFeedback {
        match self.states.get(&stream_idx) {
            Some(st) => DemandFeedback { cost_scale: st.published_scale, shed_tier: st.tier },
            None => DemandFeedback::default(),
        }
    }

    /// Write the published estimates into the request slice (indices match
    /// the stream indices reported in the observation windows). Returns how
    /// many requests changed — 0 means the next re-plan is untouched by
    /// feedback (the zero-delta no-op property).
    pub fn apply(&self, requests: &mut [StreamRequest]) -> usize {
        let mut changed = 0;
        for (i, req) in requests.iter_mut().enumerate() {
            let fb = self.feedback_for(i);
            if fb != req.feedback {
                req.feedback = fb;
                changed += 1;
            }
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricsWindow;
    use crate::server::sim::StreamWindow;

    fn window(
        queue_depth: f64,
        dropped: u64,
        utilization: f64,
        streams: Vec<StreamWindow>,
    ) -> InstanceWindow {
        let analyzed: u64 = streams.iter().map(|s| s.frames_analyzed).sum();
        InstanceWindow {
            slot_id: 1,
            window: MetricsWindow {
                frames_in: analyzed + dropped,
                frames_analyzed: analyzed,
                frames_dropped: dropped,
                batches: 1,
                queue_depth,
            },
            queue_capacity: 64,
            utilization,
            streams,
        }
    }

    fn stream(idx: usize, analyzed: u64, measured: f64, declared: f64) -> StreamWindow {
        StreamWindow {
            stream_idx: idx,
            frames_emitted: analyzed,
            frames_analyzed: analyzed,
            frames_dropped: 0,
            measured_cost_s: measured,
            declared_cost_s: declared,
        }
    }

    #[test]
    fn cost_estimate_converges_and_publishes_quantized() {
        let mut fc = FeedbackController::new(FeedbackConfig::default());
        // Frames consistently cost half the declared profile.
        for _ in 0..10 {
            fc.observe(&[window(1.0, 0, 0.3, vec![stream(0, 10, 1.0, 2.0)])]);
        }
        let fb = fc.feedback_for(0);
        assert!((fb.cost_scale - 0.5).abs() < 1e-9, "{fb:?}");
        assert_eq!(fb.shed_tier, 0);
    }

    #[test]
    fn deadband_suppresses_jitter() {
        let mut fc = FeedbackController::new(FeedbackConfig::default());
        for _ in 0..10 {
            fc.observe(&[window(1.0, 0, 0.3, vec![stream(0, 10, 1.0, 2.0)])]);
        }
        let before = fc.feedback_for(0).cost_scale;
        // ±3% wobble around the same true ratio: inside the 5% deadband.
        for (i, r) in [0.515, 0.49, 0.51, 0.492].iter().enumerate() {
            fc.observe(&[window(1.0, 0, 0.3, vec![stream(0, 10, r * 2.0, 2.0)])]);
            assert_eq!(fc.feedback_for(0).cost_scale, before, "window {i}");
        }
    }

    #[test]
    fn published_scale_is_clamped() {
        let mut fc = FeedbackController::new(FeedbackConfig::default());
        for _ in 0..20 {
            fc.observe(&[window(1.0, 0, 0.3, vec![stream(0, 10, 100.0, 1.0)])]);
        }
        assert_eq!(fc.feedback_for(0).cost_scale, 4.0);
    }

    #[test]
    fn pressure_sheds_and_sustained_headroom_restores() {
        let cfg = FeedbackConfig::default();
        let mut fc = FeedbackController::new(cfg.clone());
        // Queue at 80% of 64 (> high water): shed one tier per window, but
        // never beyond max_tier.
        for i in 1..=5u8 {
            fc.observe(&[window(52.0, 0, 0.95, vec![stream(0, 5, 5.0, 5.0)])]);
            assert_eq!(fc.feedback_for(0).shed_tier, i.min(cfg.max_tier));
        }
        // Calm windows: restore one tier per window after the streak.
        let mut tiers = Vec::new();
        for _ in 0..6 {
            fc.observe(&[window(0.0, 0, 0.2, vec![stream(0, 5, 5.0, 5.0)])]);
            tiers.push(fc.feedback_for(0).shed_tier);
        }
        // First two calm windows only build the streak; then one per window.
        assert_eq!(tiers, vec![3, 3, 2, 1, 0, 0]);
    }

    #[test]
    fn restore_hysteresis_pins_the_exact_tier_trajectory() {
        let cfg = FeedbackConfig::default();
        assert_eq!(cfg.restore_windows, 3, "trajectory below is pinned to the 3-window streak");
        let mut fc = FeedbackController::new(cfg);
        let pressure = || window(52.0, 0, 0.95, vec![stream(0, 5, 5.0, 5.0)]);
        let calm = || window(0.0, 0, 0.2, vec![stream(0, 5, 5.0, 5.0)]);
        // Not pressured, but utilization above `util_restore`: such a window
        // neither sheds nor counts toward the calm streak — it resets it.
        let neutral = || window(0.0, 0, 0.7, vec![stream(0, 5, 5.0, 5.0)]);

        let steps: Vec<(InstanceWindow, u8)> = vec![
            (pressure(), 1),
            (pressure(), 2),
            (calm(), 2),     // streak 1 of 3
            (calm(), 2),     // streak 2 of 3
            (pressure(), 3), // pressure wipes the streak and sheds
            (calm(), 3),
            (calm(), 3),
            (pressure(), 3), // capped at max_tier; streak wiped again
            (calm(), 3),     // the full streak must be re-earned...
            (calm(), 3),
            (calm(), 2),     // ...and the 3rd consecutive calm window restores
            (calm(), 1),     // restore credit: one further tier per calm window
            (pressure(), 2), // a climb is interrupted immediately
            (calm(), 2),
            (calm(), 2),
            (neutral(), 2), // neither calm nor pressured: streak resets
            (calm(), 2),
            (calm(), 2),
            (calm(), 1), // restore again waits the full three calm windows
        ];
        for (i, (w, want)) in steps.into_iter().enumerate() {
            fc.observe(&[w]);
            assert_eq!(fc.feedback_for(0).shed_tier, want, "window {i}");
        }
    }

    #[test]
    fn drop_rate_alone_triggers_a_shed() {
        let mut fc = FeedbackController::new(FeedbackConfig::default());
        // 2% drops with an empty queue still counts as pressure.
        fc.observe(&[window(0.0, 2, 0.5, vec![stream(0, 98, 9.0, 9.0)])]);
        assert_eq!(fc.feedback_for(0).shed_tier, 1);
    }

    #[test]
    fn apply_reports_exact_change_count_and_zero_on_noop() {
        use crate::cameras::camera_at;
        use crate::geo::cities;
        use crate::profiles::{Program, Resolution};
        let mut requests: Vec<StreamRequest> = (0..3)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                    Program::Zf,
                    1.0,
                )
            })
            .collect();
        let fc = FeedbackController::new(FeedbackConfig::default());
        // Nothing observed: everything stays default, nothing changes.
        assert_eq!(fc.apply(&mut requests), 0);
        assert!(requests.iter().all(|r| r.feedback.is_default()));

        let mut fc = FeedbackController::new(FeedbackConfig::default());
        for _ in 0..10 {
            fc.observe(&[window(1.0, 0, 0.3, vec![stream(1, 10, 3.0, 2.0)])]);
        }
        assert_eq!(fc.apply(&mut requests), 1);
        assert!((requests[1].feedback.cost_scale - 1.5).abs() < 1e-9);
        // Re-applying the same estimates is a no-op.
        assert_eq!(fc.apply(&mut requests), 0);
    }

    #[test]
    fn degrade_never_silences_a_stream() {
        let cfg = FeedbackConfig::default();
        let mut fc = FeedbackController::new(cfg.clone());
        for _ in 0..50 {
            fc.observe(&[window(64.0, 100, 1.0, vec![stream(0, 1, 9.0, 1.0)])]);
        }
        let fb = fc.feedback_for(0);
        assert_eq!(fb.shed_tier, cfg.max_tier);
        // The worked example's 0.5 fps camera at the deepest tier.
        use crate::cameras::camera_at;
        use crate::geo::cities;
        use crate::profiles::{Program, Resolution};
        let mut req = StreamRequest::new(
            camera_at(0, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
            Program::Zf,
            0.5,
        );
        req.feedback = fb;
        assert!((req.effective_fps() - 0.0625).abs() < 1e-12);
        assert!(req.effective_fps() > 0.0);
    }
}
