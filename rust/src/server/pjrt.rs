//! PJRT-backed serving: stream sources → router → per-instance dynamic
//! batcher → PJRT executor workers.
//!
//! Rust owns the event loop (std threads + channels; no async runtime is
//! needed at these rates). Each planned instance gets an executor thread
//! with its own [`Engine`] — mirroring the paper's runtime where each cloud
//! instance runs the analysis programs for its assigned streams. Frames are
//! generated at each camera's delivered rate (time-compressed by
//! `time_scale` so sub-fps cameras can be exercised in seconds), routed to
//! their planned instance, batched per program, and analyzed.
//!
//! The feature-free counterpart is [`super::sim::SimExecutor`], which
//! exercises the same [`ServeReport`] contract without PJRT artifacts.

use super::source::FrameSource;
use super::{InstanceReport, ServeConfig, ServeReport};
use crate::cameras::StreamRequest;
use crate::coordinator::Plan;
use crate::error::{Error, Result};
use crate::metrics::ServingMetrics;
use crate::runtime::Engine;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A captured frame in flight.
pub struct FrameEvent {
    /// Index into the request slice.
    pub stream_idx: usize,
    pub program: crate::profiles::Program,
    pub seq: u64,
    pub captured_at: Instant,
    pub pixels: Vec<f32>,
}

/// Executor thread: one per planned instance.
fn executor_loop(
    label: String,
    engine: Engine,
    rx: Receiver<FrameEvent>,
    metrics: Arc<ServingMetrics>,
    detections: Arc<std::sync::atomic::AtomicU64>,
    window: Duration,
) -> Result<()> {
    use std::collections::HashMap;
    // Per-program pending queues.
    let mut pending: HashMap<&'static str, Vec<FrameEvent>> = HashMap::new();
    let mut deadline: Option<Instant> = None;
    let frame_len = engine.manifest.input_size * engine.manifest.input_size * 3;

    let flush = |name: &'static str, items: &mut Vec<FrameEvent>| -> Result<()> {
        if items.is_empty() {
            return Ok(());
        }
        let n = items.len();
        let batch = engine
            .manifest
            .batch_for(name, n)
            .ok_or_else(|| Error::serving(format!("{label}: no artifact for {name}")))?;
        // Run in chunks of `batch`.
        let mut idx = 0;
        while idx < n {
            let take = (n - idx).min(batch);
            let chunk = &items[idx..idx + take];
            let mut buf = Vec::with_capacity(take * frame_len);
            for ev in chunk {
                buf.extend_from_slice(&ev.pixels);
            }
            let t0 = Instant::now();
            let det = engine.infer_padded(name, batch, &buf, take)?;
            let infer_t = t0.elapsed();
            metrics.infer_latency.record(infer_t);
            metrics.record_batch_size(take);
            for (i, ev) in chunk.iter().enumerate() {
                metrics.e2e_latency.record(ev.captured_at.elapsed());
                metrics.frames_analyzed.inc();
                detections.fetch_add(det.count_above(i, 0.0) as u64, Ordering::Relaxed);
            }
            idx += take;
        }
        items.clear();
        Ok(())
    };

    loop {
        let timeout = match deadline {
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => Duration::from_secs(3600),
        };
        match rx.recv_timeout(timeout) {
            Ok(ev) => {
                metrics.frames_in.inc();
                let name = ev.program.artifact_name();
                let q = pending.entry(name).or_default();
                q.push(ev);
                if deadline.is_none() {
                    deadline = Some(Instant::now() + window);
                }
                // Flush early when a full max batch is queued.
                let max_batch = engine
                    .manifest
                    .batches_for(name)
                    .last()
                    .copied()
                    .unwrap_or(1);
                if q.len() >= max_batch {
                    let mut items = std::mem::take(q);
                    flush(name, &mut items)?;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                for (name, q) in pending.iter_mut() {
                    let mut items = std::mem::take(q);
                    flush(name, &mut items)?;
                }
                deadline = None;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                for (name, q) in pending.iter_mut() {
                    let mut items = std::mem::take(q);
                    flush(name, &mut items)?;
                }
                return Ok(());
            }
        }
        metrics
            .queue_depth
            .set(pending.values().map(|q| q.len()).sum::<usize>() as f64);
    }
}

/// Serve a plan's workload for `cfg.duration_s` virtual seconds.
///
/// `delivered_fps` should come from [`Plan::delivered_fps`].
pub fn serve(
    plan: &Plan,
    requests: &[StreamRequest],
    delivered_fps: &[f64],
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    if plan.instances.is_empty() {
        return Err(Error::serving("plan has no instances"));
    }
    let stop = Arc::new(AtomicBool::new(false));
    let detections = Arc::new(std::sync::atomic::AtomicU64::new(0));
    // Executors signal here once their engine is compiled; the frame clock
    // starts only then (otherwise compile time shows up as queueing latency).
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();

    // Spawn one executor per planned instance.
    let mut senders: Vec<SyncSender<FrameEvent>> = Vec::new();
    let mut handles = Vec::new();
    let mut per_instance_metrics = Vec::new();
    let mut route = vec![usize::MAX; requests.len()]; // stream -> instance
    for (ii, inst) in plan.instances.iter().enumerate() {
        for &s in &inst.streams {
            route[s] = ii;
        }
        // Load only the variants this instance needs (all batch sizes of
        // each program, so the batcher can pick).
        let manifest = crate::runtime::Manifest::load(&cfg.artifacts_dir)?;
        let mut needed: Vec<(String, usize)> = Vec::new();
        for &s in &inst.streams {
            let name = requests[s].program.artifact_name();
            if !needed.iter().any(|(n, _)| n == name) {
                for b in manifest.batches_for(name) {
                    needed.push((name.to_string(), b));
                }
            }
        }
        let (tx, rx) = sync_channel::<FrameEvent>(cfg.queue_capacity);
        let metrics = Arc::new(ServingMetrics::new());
        per_instance_metrics.push(metrics.clone());
        let label = inst.label.clone();
        let window = Duration::from_millis(cfg.batch_window_ms);
        let det = detections.clone();
        let artifacts_dir = cfg.artifacts_dir.clone();
        let ready = ready_tx.clone();
        handles.push(std::thread::spawn(move || {
            // The PJRT wrappers are not Send: each executor thread builds its
            // own engine (its own CPU client + compiled executables).
            let needed_refs: Vec<(&str, usize)> =
                needed.iter().map(|(n, b)| (n.as_str(), *b)).collect();
            let engine = Engine::load_filtered(&artifacts_dir, Some(&needed_refs))?;
            let _ = ready.send(());
            executor_loop(label, engine, rx, metrics, det, window)
        }));
        senders.push(tx);
    }
    if route.iter().any(|&r| r == usize::MAX) {
        return Err(Error::serving("a stream has no planned instance"));
    }
    // Wait for every executor's engine (bounded: compile is seconds/model).
    drop(ready_tx);
    for _ in 0..plan.instances.len() {
        ready_rx
            .recv_timeout(Duration::from_secs(300))
            .map_err(|_| Error::serving("executor failed to initialize"))?;
    }
    let started = Instant::now();

    // Generator: emit frames at each stream's delivered fps (virtual clock).
    let mut sources: Vec<FrameSource> = requests
        .iter()
        .enumerate()
        .map(|(i, r)| FrameSource::new(i as u64 ^ cfg.seed, r.camera.resolution, 64))
        .collect();
    // Event queue of (next virtual time, stream).
    let mut next_at: Vec<f64> = delivered_fps
        .iter()
        .map(|&f| if f > 0.0 { 1.0 / f } else { f64::INFINITY })
        .collect();
    let mut seq = vec![0u64; requests.len()];
    let mut dropped_total = 0u64;

    loop {
        // Earliest next frame.
        let (s, &t) = match next_at
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_finite())
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        {
            Some(x) => x,
            None => break,
        };
        if t > cfg.duration_s {
            break;
        }
        // Pace real time: virtual t maps to real t/scale.
        let real_target = Duration::from_secs_f64(t / cfg.time_scale);
        let elapsed = started.elapsed();
        if real_target > elapsed {
            std::thread::sleep(real_target - elapsed);
        }
        let ev = FrameEvent {
            stream_idx: s,
            program: requests[s].program,
            seq: seq[s],
            captured_at: Instant::now(),
            pixels: sources[s].next_frame(),
        };
        seq[s] += 1;
        match senders[route[s]].try_send(ev) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                per_instance_metrics[route[s]].frames_dropped.inc();
                dropped_total += 1;
            }
            Err(TrySendError::Disconnected(_)) => {
                return Err(Error::serving("executor died"));
            }
        }
        next_at[s] = t + 1.0 / delivered_fps[s];
    }

    // Close inputs, drain executors.
    drop(senders);
    for h in handles {
        h.join()
            .map_err(|_| Error::serving("executor panicked"))??;
    }
    stop.store(true, Ordering::Relaxed);

    let real_duration_s = started.elapsed().as_secs_f64();
    let mut instances = Vec::new();
    let mut total_analyzed = 0;
    for (inst, m) in plan.instances.iter().zip(&per_instance_metrics) {
        total_analyzed += m.frames_analyzed.get();
        instances.push(InstanceReport {
            slot_id: inst.slot_id,
            label: inst.label.clone(),
            streams: inst.streams.len(),
            frames_in: m.frames_in.get(),
            frames_analyzed: m.frames_analyzed.get(),
            frames_dropped: m.frames_dropped.get(),
            batches: m.batches.get(),
            mean_batch: m.mean_batch_size(),
            infer_mean_ms: m.infer_latency.mean_us() / 1e3,
            e2e_p50_ms: m.e2e_latency.percentile_us(50.0) / 1e3,
            e2e_p99_ms: m.e2e_latency.percentile_us(99.0) / 1e3,
        });
    }
    Ok(ServeReport {
        instances,
        virtual_duration_s: cfg.duration_s,
        real_duration_s,
        total_frames_analyzed: total_analyzed,
        total_frames_dropped: dropped_total,
        virtual_throughput_fps: total_analyzed as f64 / cfg.duration_s,
        plan_cost_per_hour: plan.cost_per_hour,
        detections: detections.load(Ordering::Relaxed),
        streams_shed: requests.iter().filter(|r| r.feedback.shed_tier > 0).count(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::{camera_at, StreamRequest};
    use crate::catalog::Catalog;
    use crate::coordinator::{Planner, PlannerConfig};
    use crate::geo::cities;
    use crate::profiles::{Program, Resolution};

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn small_plan() -> (crate::coordinator::Plan, Vec<StreamRequest>) {
        let requests = vec![
            StreamRequest::new(
                camera_at(0, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                Program::Zf,
                2.0,
            ),
            StreamRequest::new(
                camera_at(1, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                Program::Vgg16,
                1.0,
            ),
        ];
        let catalog =
            Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let plan = Planner::new(catalog, PlannerConfig::st3()).plan(&requests).unwrap();
        (plan, requests)
    }

    #[test]
    fn serve_small_workload_end_to_end() {
        let (plan, requests) = small_plan();
        let fps = plan.delivered_fps(&requests);
        let cfg = ServeConfig {
            artifacts_dir: artifacts_dir(),
            duration_s: 10.0,
            time_scale: 20.0,
            batch_window_ms: 20,
            queue_capacity: 64,
            seed: 7,
        };
        let report = serve(&plan, &requests, &fps, &cfg).unwrap();
        // 10 virtual seconds at 2 + 1 fps ≈ 30 frames expected.
        assert!(report.total_frames_analyzed >= 20, "{report:?}");
        assert!(report.drop_rate() < 0.2, "{report:?}");
        assert!(report.virtual_throughput_fps > 2.0);
        assert!(report.plan_cost_per_hour > 0.0);
        let sum: u64 = report.instances.iter().map(|i| i.frames_analyzed).sum();
        assert_eq!(sum, report.total_frames_analyzed);
    }

    #[test]
    fn serve_rejects_empty_plan() {
        let (plan, requests) = small_plan();
        let mut empty = plan.clone();
        empty.instances.clear();
        let cfg = ServeConfig { artifacts_dir: artifacts_dir(), ..Default::default() };
        assert!(serve(&empty, &requests, &[1.0, 1.0], &cfg).is_err());
    }
}
