//! Synthetic frame sources.
//!
//! Network cameras are not reachable from this environment, so each stream
//! gets a deterministic synthetic scene: a moving bright blob over low-level
//! noise, downscaled to the analysis input size. The content changes frame
//! to frame (the blob moves), exercising the full fetch→decode→analyze path
//! with non-constant data.

use crate::profiles::Resolution;
use crate::util::Rng;

/// Generates analysis-ready frames (input_size × input_size × 3, f32 in `[0,1]`).
pub struct FrameSource {
    rng: Rng,
    input_size: usize,
    /// Blob position/velocity in unit coordinates.
    x: f64,
    y: f64,
    dx: f64,
    dy: f64,
    /// Native resolution drives the noise texture period (cameras with more
    /// pixels yield smoother downscaled frames).
    smoothing: f64,
    frame_no: u64,
}

impl FrameSource {
    pub fn new(seed: u64, native: Resolution, input_size: usize) -> Self {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xCAFE);
        let x = rng.f64();
        let y = rng.f64();
        let dx = rng.range_f64(-0.05, 0.05);
        let dy = rng.range_f64(-0.05, 0.05);
        FrameSource {
            rng,
            input_size,
            x,
            y,
            dx,
            dy,
            smoothing: (native.megapixels() / 0.3).clamp(0.5, 8.0),
            frame_no: 0,
        }
    }

    /// Produce the next frame (row-major HWC).
    pub fn next_frame(&mut self) -> Vec<f32> {
        let n = self.input_size;
        let mut out = vec![0.0f32; n * n * 3];
        // Background noise, dimmed by smoothing.
        let noise_amp = (0.25 / self.smoothing) as f32;
        for v in out.iter_mut() {
            *v = self.rng.f32() * noise_amp + 0.1;
        }
        // Moving blob (a Gaussian bump) — the "object" detectors look at.
        let cx = self.x * n as f64;
        let cy = self.y * n as f64;
        let sigma = n as f64 / 8.0;
        for r in 0..n {
            for c in 0..n {
                let d2 = (r as f64 - cy).powi(2) + (c as f64 - cx).powi(2);
                let b = (-(d2) / (2.0 * sigma * sigma)).exp() as f32;
                let base = (r * n + c) * 3;
                out[base] += 0.8 * b;
                out[base + 1] += 0.6 * b;
                out[base + 2] += 0.4 * b;
            }
        }
        for v in out.iter_mut() {
            *v = v.clamp(0.0, 1.0);
        }
        // Advance the blob, bouncing at the borders.
        self.x += self.dx;
        self.y += self.dy;
        if !(0.05..=0.95).contains(&self.x) {
            self.dx = -self.dx;
            self.x = self.x.clamp(0.05, 0.95);
        }
        if !(0.05..=0.95).contains(&self.y) {
            self.dy = -self.dy;
            self.y = self.y.clamp(0.05, 0.95);
        }
        self.frame_no += 1;
        out
    }

    pub fn frames_produced(&self) -> u64 {
        self.frame_no
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_are_valid_and_sized() {
        let mut s = FrameSource::new(1, Resolution::VGA, 64);
        let f = s.next_frame();
        assert_eq!(f.len(), 64 * 64 * 3);
        assert!(f.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = FrameSource::new(5, Resolution::VGA, 64);
        let mut b = FrameSource::new(5, Resolution::VGA, 64);
        assert_eq!(a.next_frame(), b.next_frame());
        let mut c = FrameSource::new(6, Resolution::VGA, 64);
        assert_ne!(a.next_frame(), c.next_frame());
    }

    #[test]
    fn content_changes_between_frames() {
        let mut s = FrameSource::new(2, Resolution::HD720, 64);
        let f1 = s.next_frame();
        let f2 = s.next_frame();
        assert_ne!(f1, f2);
        assert_eq!(s.frames_produced(), 2);
    }

    #[test]
    fn blob_brightens_center_region() {
        // The frame must contain a clearly bright region (the blob).
        let mut s = FrameSource::new(3, Resolution::VGA, 64);
        let f = s.next_frame();
        let max = f.iter().cloned().fold(0.0f32, f32::max);
        assert!(max > 0.5, "max={max}");
    }
}
