//! The serving layer and the closed loop it feeds back into planning.
//!
//! Two executors implement the same serving contract:
//!
//! - [`sim::SimExecutor`] (always available): a deterministic tick-based
//!   fluid simulation of the per-instance serving loop — synthetic frame
//!   arrivals, a bounded input queue, and a service budget derived from the
//!   planned instance's capacity. No threads, no wall clock, no RNG, so the
//!   closed-loop harness and tier-1 tests run under default features.
//! - `pjrt::serve` (feature `pjrt`): the real runtime — one executor
//!   thread per planned instance with its own PJRT engine
//!   (`crate::runtime::Engine`), dynamic per-program batching, and
//!   virtual-clock frame pacing.
//!
//! Both produce a [`ServeReport`] plus per-window observations
//! ([`sim::InstanceWindow`]) that [`feedback::FeedbackController`] folds
//! into per-stream [`DemandFeedback`](crate::cameras::DemandFeedback):
//! an EWMA of measured cost per frame relative to the declared profile
//! (`cost_scale`) and a backpressure degrade tier (`shed_tier`). The
//! coordinator's next re-plan provisions from those observed estimates —
//! the drift-signature machinery (`coordinator::eligibility`,
//! `coordinator::shard::drift_sig`) ensures only streams whose observed
//! demand actually moved dirty their shard.

pub mod feedback;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sim;
pub mod source;

#[cfg(feature = "pjrt")]
pub use pjrt::{serve, FrameEvent};

use crate::coordinator::SlotId;

/// Serving configuration (shared by both executors; `artifacts_dir`,
/// `batch_window_ms`, and `time_scale` only matter to the PJRT path).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Virtual seconds to serve.
    pub duration_s: f64,
    /// Virtual-to-real time compression (30 = a 0.5 fps camera emits a frame
    /// every 2000/30 ≈ 67 real ms).
    pub time_scale: f64,
    /// Dynamic batching window (real milliseconds).
    pub batch_window_ms: u64,
    /// Per-instance input queue depth before frames are dropped.
    pub queue_capacity: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".into(),
            duration_s: 60.0,
            time_scale: 30.0,
            batch_window_ms: 30,
            queue_capacity: 256,
            seed: 0,
        }
    }
}

/// Per-instance outcome.
#[derive(Clone, Debug)]
pub struct InstanceReport {
    /// Stable slot identity of the planned instance — lets serving reports
    /// from consecutive re-plans be correlated per instance (a surviving
    /// slot keeps its id across sticky re-plans).
    pub slot_id: SlotId,
    pub label: String,
    pub streams: usize,
    pub frames_in: u64,
    pub frames_analyzed: u64,
    pub frames_dropped: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub infer_mean_ms: f64,
    pub e2e_p50_ms: f64,
    pub e2e_p99_ms: f64,
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub instances: Vec<InstanceReport>,
    pub virtual_duration_s: f64,
    pub real_duration_s: f64,
    pub total_frames_analyzed: u64,
    pub total_frames_dropped: u64,
    /// Analyzed frames per *virtual* second (compare against Σ stream fps).
    pub virtual_throughput_fps: f64,
    pub plan_cost_per_hour: f64,
    /// Total detections above objectness 0 (sanity signal).
    pub detections: u64,
    /// Streams served at a backpressure degrade tier (> 0) during this run —
    /// shed to a lower fps *before* their frames had to drop.
    pub streams_shed: usize,
}

impl ServeReport {
    /// Dropped / (analyzed + dropped); 0.0 when no frames completed either
    /// way (an idle run is not a lossy run).
    pub fn drop_rate(&self) -> f64 {
        let total = self.total_frames_analyzed + self.total_frames_dropped;
        if total == 0 {
            0.0
        } else {
            self.total_frames_dropped as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_report() -> ServeReport {
        ServeReport {
            instances: Vec::new(),
            virtual_duration_s: 0.0,
            real_duration_s: 0.0,
            total_frames_analyzed: 0,
            total_frames_dropped: 0,
            virtual_throughput_fps: 0.0,
            plan_cost_per_hour: 0.0,
            detections: 0,
            streams_shed: 0,
        }
    }

    #[test]
    fn drop_rate_of_idle_run_is_zero_not_nan() {
        let r = empty_report();
        assert_eq!(r.drop_rate(), 0.0);
    }

    #[test]
    fn drop_rate_all_dropped_is_one() {
        let r = ServeReport { total_frames_dropped: 17, ..empty_report() };
        assert_eq!(r.drop_rate(), 1.0);
    }

    #[test]
    fn drop_rate_is_fraction_of_completed_frames() {
        let r = ServeReport {
            total_frames_analyzed: 75,
            total_frames_dropped: 25,
            ..empty_report()
        };
        assert!((r.drop_rate() - 0.25).abs() < 1e-12);
    }
}
