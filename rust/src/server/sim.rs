//! Deterministic serving simulation — the feature-free executor.
//!
//! [`SimExecutor`] replays a plan's serving loop as a tick-based fluid
//! model: each planned instance has a bounded input FIFO and a per-tick
//! service budget derived from its catalog capacity; each assigned stream
//! emits frames at its delivered fps via a fractional credit accumulator.
//! There are no threads, no RNG, and no wall clock, so two runs over the
//! same inputs are bit-identical — this is what the closed-loop bench and
//! tier-1 tests drive under default features (the PJRT path in
//! `super::pjrt` needs compiled artifacts).
//!
//! The *true* per-frame cost of a stream is its declared profile cost
//! multiplied by a caller-supplied `true_cost_scale` — 1.0 models an honest
//! declaration; < 1.0 an over-declared profile (actual frames are cheaper);
//! > 1.0 an under-declared one (queues build, frames drop). Per-window
//! [`StreamWindow`] observations always report the *unscaled* declared cost
//! next to the measured cost, so the feedback controller can estimate the
//! ratio without knowing the ground truth.

use super::{InstanceReport, ServeReport};
use crate::cameras::StreamRequest;
use crate::catalog::Catalog;
use crate::coordinator::{Plan, SlotId};
use crate::error::{Error, Result};
use crate::metrics::{MetricsWindow, ServingMetrics};
use std::collections::VecDeque;

/// Simulation knobs.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Virtual seconds to simulate.
    pub duration_s: f64,
    /// Simulation step. Frames arriving within one tick are indistinguishable.
    pub tick_s: f64,
    /// Observation-window length; one [`InstanceWindow`] per instance per
    /// window is emitted for the feedback controller.
    pub window_s: f64,
    /// Per-instance input FIFO depth; a full queue evicts its *oldest*
    /// frame (counted as dropped for that frame's stream).
    pub queue_capacity: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { duration_s: 60.0, tick_s: 0.25, window_s: 5.0, queue_capacity: 64 }
    }
}

/// Per-stream observations over one window on one instance.
#[derive(Clone, Debug)]
pub struct StreamWindow {
    /// Index into the request slice.
    pub stream_idx: usize,
    pub frames_emitted: u64,
    pub frames_analyzed: u64,
    pub frames_dropped: u64,
    /// Measured (true) analysis seconds consumed by this stream's analyzed
    /// frames — what a real executor would report from timers.
    pub measured_cost_s: f64,
    /// What the declared profile predicts for the same analyzed frames
    /// (always unscaled by feedback; the controller's denominator).
    pub declared_cost_s: f64,
}

/// One instance's observations over one window — the unit the feedback
/// controller consumes ([`super::feedback::FeedbackController::observe`]).
#[derive(Clone, Debug)]
pub struct InstanceWindow {
    pub slot_id: SlotId,
    /// Instance-level counter deltas for the window (queue depth is the
    /// end-of-window reading).
    pub window: MetricsWindow,
    pub queue_capacity: usize,
    /// Served seconds / available service budget over the window.
    pub utilization: f64,
    pub streams: Vec<StreamWindow>,
}

/// The whole simulated run.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    pub report: ServeReport,
    /// Every instance window, in time order (all instances of window 0,
    /// then window 1, ...).
    pub windows: Vec<InstanceWindow>,
}

struct QueuedFrame {
    stream: usize,
    emitted_at: f64,
}

/// Deterministic per-instance serving simulation (module docs).
pub struct SimExecutor<'a> {
    catalog: &'a Catalog,
    plan: &'a Plan,
    requests: &'a [StreamRequest],
    delivered_fps: Vec<f64>,
    true_cost_scale: Vec<f64>,
    cfg: SimConfig,
}

impl<'a> SimExecutor<'a> {
    /// `true_cost_scale[i]` multiplies stream `i`'s declared per-frame cost
    /// to obtain its actual cost (1.0 = honest). Must match `requests` in
    /// length; the plan must assign every stream.
    pub fn new(
        catalog: &'a Catalog,
        plan: &'a Plan,
        requests: &'a [StreamRequest],
        true_cost_scale: &[f64],
        cfg: SimConfig,
    ) -> Result<Self> {
        if plan.instances.is_empty() {
            return Err(Error::serving("plan has no instances"));
        }
        if true_cost_scale.len() != requests.len() {
            return Err(Error::serving("true_cost_scale length != requests length"));
        }
        let mut routed = vec![false; requests.len()];
        for inst in &plan.instances {
            for &s in &inst.streams {
                routed[s] = true;
            }
        }
        if routed.iter().any(|&r| !r) {
            return Err(Error::serving("a stream has no planned instance"));
        }
        Ok(SimExecutor {
            catalog,
            plan,
            requests,
            delivered_fps: plan.delivered_fps(requests),
            true_cost_scale: true_cost_scale.to_vec(),
            cfg,
        })
    }

    /// Declared per-frame cost of stream `s` on instance `inst`, in the
    /// instance's service-budget unit (GPU-seconds on GPU instances after
    /// the device speed factor, vcpu-seconds on CPU instances).
    fn declared_frame_cost(&self, inst_idx: usize, s: usize) -> f64 {
        let inst = &self.plan.instances[inst_idx];
        let req = &self.requests[s];
        let profile = req.program.profile();
        let mpix = req.camera.resolution.megapixels();
        if inst.has_gpu {
            profile.gpu_sec_per_mpix_frame * mpix / self.catalog.types[inst.type_idx].gpu_speed
        } else {
            profile.cpu_sec_per_mpix_frame * mpix
        }
    }

    /// Simulate `cfg.duration_s` virtual seconds; deterministic.
    pub fn run(&self) -> Result<SimOutcome> {
        let cfg = &self.cfg;
        let n_req = self.requests.len();
        let n_inst = self.plan.instances.len();
        let ticks = (cfg.duration_s / cfg.tick_s).ceil() as u64;
        let ticks_per_window = ((cfg.window_s / cfg.tick_s).round() as u64).max(1);

        let mut route = vec![usize::MAX; n_req];
        for (ii, inst) in self.plan.instances.iter().enumerate() {
            for &s in &inst.streams {
                route[s] = ii;
            }
        }
        // Per-instance service capacity per second (GPU or vcpu units).
        let budget_rate: Vec<f64> = self
            .plan
            .instances
            .iter()
            .map(|inst| {
                let cap = self.catalog.types[inst.type_idx].capacity;
                if inst.has_gpu {
                    cap.gpus
                } else {
                    cap.vcpus
                }
            })
            .collect();
        let declared: Vec<f64> =
            (0..n_req).map(|s| self.declared_frame_cost(route[s], s)).collect();
        let true_cost: Vec<f64> =
            (0..n_req).map(|s| declared[s] * self.true_cost_scale[s].max(0.0)).collect();

        let metrics: Vec<ServingMetrics> = (0..n_inst).map(|_| ServingMetrics::new()).collect();
        let mut last_window: Vec<MetricsWindow> = vec![MetricsWindow::default(); n_inst];
        let mut queues: Vec<VecDeque<QueuedFrame>> = (0..n_inst).map(|_| VecDeque::new()).collect();
        let mut credit = vec![0.0f64; n_req];
        let mut carry = vec![0.0f64; n_inst];
        // Window accumulators.
        let mut w_emitted = vec![0u64; n_req];
        let mut w_analyzed = vec![0u64; n_req];
        let mut w_dropped = vec![0u64; n_req];
        let mut w_measured = vec![0.0f64; n_req];
        let mut w_declared = vec![0.0f64; n_req];
        let mut w_busy = vec![0.0f64; n_inst];
        let mut windows = Vec::new();

        for tick in 0..ticks {
            let now = (tick + 1) as f64 * cfg.tick_s;
            // Arrivals: fractional credit accumulates per stream.
            for s in 0..n_req {
                credit[s] += self.delivered_fps[s] * cfg.tick_s;
                while credit[s] >= 1.0 {
                    credit[s] -= 1.0;
                    let ii = route[s];
                    metrics[ii].frames_in.inc();
                    w_emitted[s] += 1;
                    if queues[ii].len() >= cfg.queue_capacity {
                        // Backpressure: evict the oldest queued frame.
                        if let Some(old) = queues[ii].pop_front() {
                            metrics[ii].frames_dropped.inc();
                            w_dropped[old.stream] += 1;
                        }
                    }
                    queues[ii].push_back(QueuedFrame { stream: s, emitted_at: now - cfg.tick_s });
                }
            }
            // Service: spend this tick's budget (plus carry) FIFO-first.
            for ii in 0..n_inst {
                let mut budget = carry[ii] + budget_rate[ii] * cfg.tick_s;
                let mut served = 0usize;
                while let Some(front) = queues[ii].front() {
                    let cost = true_cost[front.stream];
                    if cost > budget {
                        break;
                    }
                    budget -= cost;
                    let f = queues[ii].pop_front().unwrap();
                    served += 1;
                    metrics[ii].frames_analyzed.inc();
                    metrics[ii].infer_latency.record_us(cost * 1e6);
                    metrics[ii].e2e_latency.record_us((now - f.emitted_at).max(0.0) * 1e6);
                    w_analyzed[f.stream] += 1;
                    w_measured[f.stream] += cost;
                    w_declared[f.stream] += declared[f.stream];
                    w_busy[ii] += cost;
                }
                if served > 0 {
                    metrics[ii].record_batch_size(served);
                }
                // Unused budget carries only while work is waiting; idle
                // capacity is lost (a real executor cannot bank idle time).
                carry[ii] = if queues[ii].is_empty() { 0.0 } else { budget };
                metrics[ii].queue_depth.set(queues[ii].len() as f64);
            }
            // Window roll-up.
            if (tick + 1) % ticks_per_window == 0 || tick + 1 == ticks {
                let window_s = cfg.tick_s * (((tick % ticks_per_window) + 1) as f64);
                for (ii, inst) in self.plan.instances.iter().enumerate() {
                    let streams = inst
                        .streams
                        .iter()
                        .map(|&s| StreamWindow {
                            stream_idx: s,
                            frames_emitted: w_emitted[s],
                            frames_analyzed: w_analyzed[s],
                            frames_dropped: w_dropped[s],
                            measured_cost_s: w_measured[s],
                            declared_cost_s: w_declared[s],
                        })
                        .collect();
                    windows.push(InstanceWindow {
                        slot_id: inst.slot_id,
                        window: metrics[ii].take_window(&mut last_window[ii]),
                        queue_capacity: cfg.queue_capacity,
                        utilization: w_busy[ii] / (budget_rate[ii] * window_s).max(1e-12),
                        streams,
                    });
                    w_busy[ii] = 0.0;
                }
                w_emitted.fill(0);
                w_analyzed.fill(0);
                w_dropped.fill(0);
                w_measured.fill(0.0);
                w_declared.fill(0.0);
            }
        }

        let mut instances = Vec::new();
        let mut total_analyzed = 0;
        let mut total_dropped = 0;
        for (inst, m) in self.plan.instances.iter().zip(&metrics) {
            total_analyzed += m.frames_analyzed.get();
            total_dropped += m.frames_dropped.get();
            instances.push(InstanceReport {
                slot_id: inst.slot_id,
                label: inst.label.clone(),
                streams: inst.streams.len(),
                frames_in: m.frames_in.get(),
                frames_analyzed: m.frames_analyzed.get(),
                frames_dropped: m.frames_dropped.get(),
                batches: m.batches.get(),
                mean_batch: m.mean_batch_size(),
                infer_mean_ms: m.infer_latency.mean_us() / 1e3,
                e2e_p50_ms: m.e2e_latency.percentile_us(50.0) / 1e3,
                e2e_p99_ms: m.e2e_latency.percentile_us(99.0) / 1e3,
            });
        }
        Ok(SimOutcome {
            report: ServeReport {
                instances,
                virtual_duration_s: cfg.duration_s,
                real_duration_s: 0.0, // simulated; no wall clock
                total_frames_analyzed: total_analyzed,
                total_frames_dropped: total_dropped,
                virtual_throughput_fps: total_analyzed as f64 / cfg.duration_s,
                plan_cost_per_hour: self.plan.cost_per_hour,
                detections: 0,
                streams_shed: self
                    .requests
                    .iter()
                    .filter(|r| r.feedback.shed_tier > 0)
                    .count(),
            },
            windows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::camera_at;
    use crate::coordinator::{Planner, PlannerConfig};
    use crate::geo::cities;
    use crate::profiles::{Program, Resolution};

    fn small_workload() -> (Catalog, Plan, Vec<StreamRequest>) {
        let requests = vec![
            StreamRequest::new(
                camera_at(0, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                Program::Zf,
                2.0,
            ),
            StreamRequest::new(
                camera_at(1, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                Program::Vgg16,
                1.0,
            ),
        ];
        let catalog =
            Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let plan = Planner::new(catalog.clone(), PlannerConfig::st3()).plan(&requests).unwrap();
        (catalog, plan, requests)
    }

    #[test]
    fn honest_declarations_do_not_drop() {
        let (catalog, plan, requests) = small_workload();
        let scale = vec![1.0; requests.len()];
        let sim =
            SimExecutor::new(&catalog, &plan, &requests, &scale, SimConfig::default()).unwrap();
        let out = sim.run().unwrap();
        // 60 virtual seconds at 2 + 1 fps ≈ 180 frames.
        assert!(out.report.total_frames_analyzed >= 150, "{:?}", out.report);
        assert!(out.report.drop_rate() < 0.05, "{:?}", out.report);
        assert_eq!(out.report.streams_shed, 0);
        let sum: u64 = out.report.instances.iter().map(|i| i.frames_analyzed).sum();
        assert_eq!(sum, out.report.total_frames_analyzed);
    }

    #[test]
    fn simulation_is_deterministic() {
        let (catalog, plan, requests) = small_workload();
        let scale = vec![1.3, 0.8];
        let run = || {
            SimExecutor::new(&catalog, &plan, &requests, &scale, SimConfig::default())
                .unwrap()
                .run()
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.report.total_frames_analyzed, b.report.total_frames_analyzed);
        assert_eq!(a.report.total_frames_dropped, b.report.total_frames_dropped);
        assert_eq!(a.windows.len(), b.windows.len());
        for (wa, wb) in a.windows.iter().zip(&b.windows) {
            assert_eq!(wa.window, wb.window);
            assert_eq!(wa.utilization.to_bits(), wb.utilization.to_bits());
            for (sa, sb) in wa.streams.iter().zip(&wb.streams) {
                assert_eq!(sa.frames_analyzed, sb.frames_analyzed);
                assert_eq!(sa.measured_cost_s.to_bits(), sb.measured_cost_s.to_bits());
            }
        }
    }

    #[test]
    fn windows_expose_the_true_cost_ratio() {
        let (catalog, plan, requests) = small_workload();
        // Both streams over-declared 2x: true frames cost half the profile.
        let scale = vec![0.5; requests.len()];
        let sim =
            SimExecutor::new(&catalog, &plan, &requests, &scale, SimConfig::default()).unwrap();
        let out = sim.run().unwrap();
        let mut checked = 0;
        for w in &out.windows {
            for s in &w.streams {
                if s.frames_analyzed > 0 {
                    let ratio = s.measured_cost_s / s.declared_cost_s;
                    assert!((ratio - 0.5).abs() < 1e-9, "ratio={ratio}");
                    checked += 1;
                }
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn under_declared_streams_build_queues_and_drop() {
        let (catalog, plan, requests) = small_workload();
        // True cost far above declared: service cannot keep up.
        let scale = vec![20.0; requests.len()];
        let sim =
            SimExecutor::new(&catalog, &plan, &requests, &scale, SimConfig::default()).unwrap();
        let out = sim.run().unwrap();
        assert!(out.report.total_frames_dropped > 0, "{:?}", out.report);
        assert!(out.report.drop_rate() > 0.2, "{:?}", out.report);
        // Late windows should show a deep queue on at least one instance.
        let deep = out
            .windows
            .iter()
            .any(|w| w.window.queue_depth >= 0.5 * w.queue_capacity as f64);
        assert!(deep);
    }

    #[test]
    fn rejects_mismatched_inputs() {
        let (catalog, plan, requests) = small_workload();
        assert!(SimExecutor::new(&catalog, &plan, &requests, &[1.0], SimConfig::default()).is_err());
        let mut empty = plan.clone();
        empty.instances.clear();
        assert!(
            SimExecutor::new(&catalog, &empty, &requests, &[1.0, 1.0], SimConfig::default())
                .is_err()
        );
    }
}
