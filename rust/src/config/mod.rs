//! Typed run configuration: JSON-backed, used by the CLI and examples.

use crate::error::{Error, Result};
use crate::util::json::{self, Value};
use std::path::Path;

/// Which paper strategy to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyName {
    St1,
    St2,
    St3,
    Nl,
    Armvac,
    Gcl,
}

impl StrategyName {
    pub const ALL: [StrategyName; 6] = [
        StrategyName::St1,
        StrategyName::St2,
        StrategyName::St3,
        StrategyName::Nl,
        StrategyName::Armvac,
        StrategyName::Gcl,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            StrategyName::St1 => "st1",
            StrategyName::St2 => "st2",
            StrategyName::St3 => "st3",
            StrategyName::Nl => "nl",
            StrategyName::Armvac => "armvac",
            StrategyName::Gcl => "gcl",
        }
    }

    pub fn to_planner_config(self) -> crate::coordinator::PlannerConfig {
        use crate::coordinator::PlannerConfig as P;
        match self {
            StrategyName::St1 => P::st1(),
            StrategyName::St2 => P::st2(),
            StrategyName::St3 => P::st3(),
            StrategyName::Nl => P::nl(),
            StrategyName::Armvac => P::armvac(),
            StrategyName::Gcl => P::gcl(),
        }
    }
}

impl std::str::FromStr for StrategyName {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "st1" => Ok(StrategyName::St1),
            "st2" => Ok(StrategyName::St2),
            "st3" => Ok(StrategyName::St3),
            "nl" => Ok(StrategyName::Nl),
            "armvac" => Ok(StrategyName::Armvac),
            "gcl" => Ok(StrategyName::Gcl),
            other => Err(Error::config(format!(
                "unknown strategy '{other}' (st1|st2|st3|nl|armvac|gcl)"
            ))),
        }
    }
}

/// End-to-end run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub strategy: StrategyName,
    /// Fig-3 scenario number (1..=3) or 0 for a synthetic workload.
    pub scenario: usize,
    /// Synthetic-workload knobs (used when scenario == 0).
    pub num_cameras: usize,
    pub target_fps: f64,
    pub seed: u64,
    /// Serving knobs.
    pub artifacts_dir: String,
    pub duration_s: f64,
    pub time_scale: f64,
    pub batch_window_ms: u64,
    /// Restrict to the Fig-3 instance pool.
    pub fig3_pool: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            strategy: StrategyName::St3,
            scenario: 1,
            num_cameras: 10,
            target_fps: 1.0,
            seed: 0,
            artifacts_dir: "artifacts".into(),
            duration_s: 30.0,
            time_scale: 30.0,
            batch_window_ms: 30,
            fig3_pool: true,
        }
    }
}

impl RunConfig {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("strategy", Value::str(self.strategy.as_str())),
            ("scenario", Value::num(self.scenario as f64)),
            ("num_cameras", Value::num(self.num_cameras as f64)),
            ("target_fps", Value::num(self.target_fps)),
            ("seed", Value::num(self.seed as f64)),
            ("artifacts_dir", Value::str(self.artifacts_dir.clone())),
            ("duration_s", Value::num(self.duration_s)),
            ("time_scale", Value::num(self.time_scale)),
            ("batch_window_ms", Value::num(self.batch_window_ms as f64)),
            ("fig3_pool", Value::Bool(self.fig3_pool)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let d = RunConfig::default();
        let get_or = |key: &str, default: f64| -> f64 {
            v.get_f64(key).unwrap_or(default)
        };
        Ok(RunConfig {
            strategy: match v.get_str("strategy") {
                Ok(s) => s.parse()?,
                Err(_) => d.strategy,
            },
            scenario: get_or("scenario", d.scenario as f64) as usize,
            num_cameras: get_or("num_cameras", d.num_cameras as f64) as usize,
            target_fps: get_or("target_fps", d.target_fps),
            seed: get_or("seed", d.seed as f64) as u64,
            artifacts_dir: v
                .get_str("artifacts_dir")
                .map(|s| s.to_string())
                .unwrap_or(d.artifacts_dir),
            duration_s: get_or("duration_s", d.duration_s),
            time_scale: get_or("time_scale", d.time_scale),
            batch_window_ms: get_or("batch_window_ms", d.batch_window_ms as f64) as u64,
            fig3_pool: v
                .get("fig3_pool")
                .ok()
                .and_then(|b| b.as_bool())
                .unwrap_or(d.fig3_pool),
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&json::parse(&text)?)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, json::to_string_pretty(&self.to_json()))?;
        Ok(())
    }

    /// Materialize the workload this config describes.
    pub fn requests(&self) -> Result<Vec<crate::cameras::StreamRequest>> {
        use crate::cameras::scenarios;
        Ok(match self.scenario {
            0 => scenarios::fig6_workload(self.num_cameras, self.target_fps, self.seed),
            1 => scenarios::fig3_scenario1().requests,
            2 => scenarios::fig3_scenario2().requests,
            3 => scenarios::fig3_scenario3().requests,
            other => {
                return Err(Error::config(format!(
                    "scenario {other} out of range (0..=3)"
                )))
            }
        })
    }

    /// The catalog this config plans against.
    pub fn catalog(&self) -> crate::catalog::Catalog {
        let c = crate::catalog::Catalog::builtin();
        if self.fig3_pool {
            c.restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]))
        } else {
            c
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        let cfg = RunConfig::default();
        let v = cfg.to_json();
        let back = RunConfig::from_json(&v).unwrap();
        assert_eq!(back.strategy, cfg.strategy);
        assert_eq!(back.scenario, cfg.scenario);
        assert_eq!(back.duration_s, cfg.duration_s);
        assert_eq!(back.fig3_pool, cfg.fig3_pool);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("camflow-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.json");
        let mut cfg = RunConfig::default();
        cfg.strategy = StrategyName::Gcl;
        cfg.scenario = 0;
        cfg.num_cameras = 42;
        cfg.save(&path).unwrap();
        let back = RunConfig::load(&path).unwrap();
        assert_eq!(back.strategy, StrategyName::Gcl);
        assert_eq!(back.num_cameras, 42);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = json::parse(r#"{"strategy": "nl"}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.strategy, StrategyName::Nl);
        assert_eq!(cfg.scenario, RunConfig::default().scenario);
    }

    #[test]
    fn strategy_parse_errors() {
        assert!("bogus".parse::<StrategyName>().is_err());
        for s in StrategyName::ALL {
            assert_eq!(s.as_str().parse::<StrategyName>().unwrap(), s);
        }
    }

    #[test]
    fn scenario_materialization() {
        for scn in 1..=3usize {
            let cfg = RunConfig { scenario: scn, ..Default::default() };
            assert!(!cfg.requests().unwrap().is_empty());
        }
        let bad = RunConfig { scenario: 9, ..Default::default() };
        assert!(bad.requests().is_err());
    }
}
