//! # camflow
//!
//! Reproduction of *"Cloud Resource Optimization for Processing Multiple
//! Streams of Visual Data"* (Kapach et al., IEEE MultiMedia 2019).
//!
//! camflow is a three-layer system:
//!
//! * **L3 (this crate)** — the paper's contribution: a cloud **resource
//!   manager** that selects the cheapest set of cloud instances (type ×
//!   location) able to analyze many network-camera streams, formulated as
//!   multi-dimensional multiple-choice vector bin packing (arc-flow + MILP),
//!   with location-aware strategies (NL / ARMVAC / GCL) and adaptive runtime
//!   re-packing. It also owns the serving runtime: stream router, dynamic
//!   batcher, simulated cloud, metrics, CLI.
//! * **L2 (python/compile/model.py, build-time)** — the analysis programs
//!   (compact VGG16 / ZF detectors) written in JAX and AOT-lowered to HLO
//!   text.
//! * **L1 (python/compile/kernels/, build-time)** — the Pallas tiled matmul
//!   kernel backing every conv/dense layer of the analysis programs.
//!
//! ## The staged planning pipeline
//!
//! Planning is an explicit four-stage pipeline
//! ([`coordinator::pipeline`]): **Eligibility → ProblemBuild → Solve →
//! Expand**. Each stage emits a cacheable artifact, and a
//! [`PlanContext`](coordinator::pipeline::PlanContext) persists those
//! artifacts across re-plans so the *dynamic* manager
//! ([`coordinator::adaptive`]) works incrementally:
//!
//! * per-camera eligibility masks are memoized by (location, fps) in the
//!   context's eligibility cache ([`coordinator::eligibility`]),
//! * per-group demand vectors are memoized by group identity in the
//!   context's demand cache,
//! * compressed arc-flow graphs are memoized by (capacity grid, quantized
//!   item multiset) in a shared [`packing::arcflow::GraphCache`],
//! * the previous packing is translated onto the new problem and seeds both
//!   the greedy warm-start fill ([`packing::heuristic::warm_start_fill`])
//!   and the exact solver's incumbent cut
//!   ([`packing::mcvbp::solve_with`]),
//! * the previous stream→instance assignment is matched against by the
//!   sticky Expand stage ([`coordinator::expand`]): surviving instances
//!   keep their stable [`SlotId`](coordinator::SlotId) and their streams,
//!   so `streams_moved` tracks the packing diff, not queue order.
//!
//! The Solve stage additionally decomposes the packing problem into
//! independent per-region-cluster subproblems (streams whose RTT circles
//! cannot overlap never share an instance) and solves them on parallel
//! `std::thread` scopes — the decomposition is exact, so plan costs are
//! unchanged wherever the monolithic exact solve completed within budget
//! (and only ever improve where it had to fall back to a heuristic),
//! while wall-clock drops on worldwide workloads.
//!
//! ## Features
//!
//! The request path (PJRT artifact loading + serving) is gated behind the
//! `pjrt` feature because it needs the vendored `xla` crate and `make
//! artifacts`; the default build is dependency-free and every planning,
//! packing, solver, and simulation test runs without it. The end-to-end
//! serving tests additionally sit behind `pjrt-tests`.

pub mod bench;
pub mod cameras;
pub mod catalog;
pub mod cli;
pub mod cloudsim;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod geo;
pub mod metrics;
pub mod packing;
pub mod profiles;
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod solver;
pub mod util;

pub use error::{Error, Result};
