//! # camflow
//!
//! Reproduction of *"Cloud Resource Optimization for Processing Multiple
//! Streams of Visual Data"* (Kapach et al., IEEE MultiMedia 2019).
//!
//! camflow is a three-layer system:
//!
//! * **L3 (this crate)** — the paper's contribution: a cloud **resource
//!   manager** that selects the cheapest set of cloud instances (type ×
//!   location) able to analyze many network-camera streams, formulated as
//!   multi-dimensional multiple-choice vector bin packing (arc-flow + MILP),
//!   with location-aware strategies (NL / ARMVAC / GCL), adaptive runtime
//!   re-packing, and a closed serving→planning feedback loop. It also owns
//!   the serving runtime: stream router, dynamic batcher, deterministic
//!   serving simulator, simulated cloud, metrics, CLI.
//! * **L2 (python/compile/model.py, build-time)** — the analysis programs
//!   (compact VGG16 / ZF detectors) written in JAX and AOT-lowered to HLO
//!   text.
//! * **L1 (python/compile/kernels/, build-time)** — the Pallas tiled matmul
//!   kernel backing every conv/dense layer of the analysis programs.
//!
//! A prose tour of the architecture (stage pipeline, shard/arbiter split,
//! solver stack, feedback loop) lives in `ARCHITECTURE.md` at the repo
//! root; this page stays close to the module surface.
//!
//! ## The staged planning pipeline
//!
//! Planning is an explicit four-stage pipeline
//! ([`coordinator::pipeline`]): **Eligibility → ProblemBuild → Solve →
//! Expand**. Each stage emits a cacheable artifact, and a
//! [`PlanContext`](coordinator::pipeline::PlanContext) persists those
//! artifacts across re-plans so the *dynamic* manager
//! ([`coordinator::adaptive`]) works incrementally:
//!
//! * per-camera eligibility masks (fixed-width
//!   [`RegionMask`](coordinator::eligibility::RegionMask) bitsets) are
//!   memoized by (location, fps) in the context's eligibility cache
//!   ([`coordinator::eligibility`]),
//! * per-request group assignments are **dirty-tracked**: the context diffs
//!   each request slice against the previous one by stable
//!   [`StreamKey`](cameras::StreamKey) + fingerprint, so a warm re-plan's
//!   front-end cost is proportional to workload *drift*, not fleet size —
//!   unchanged streams reuse their interned
//!   [`GroupId`](coordinator::eligibility::GroupId) without touching
//!   eligibility or grouping at all (bit-identical to a cold rebuild,
//!   property-tested),
//! * per-group demand vectors are memoized by interned group identity in
//!   the context's demand cache,
//! * compressed arc-flow graphs are memoized by (capacity grid, quantized
//!   item multiset) in a shared [`packing::arcflow::GraphCache`],
//! * the previous packing is translated onto the new problem and seeds both
//!   the greedy warm-start fill ([`packing::heuristic::warm_start_fill`])
//!   and the exact solver's incumbent cut
//!   ([`packing::mcvbp::solve_with`]),
//! * the previous stream→instance assignment is matched against by the
//!   sticky Expand stage ([`coordinator::expand`]): surviving instances
//!   keep their stable [`SlotId`](coordinator::SlotId) and their streams,
//!   so `streams_moved` tracks the packing diff, not queue order.
//!
//! The Solve stage additionally decomposes the packing problem into
//! independent per-region-cluster subproblems (streams whose RTT circles
//! cannot overlap never share an instance) and dispatches them to a
//! persistent worker pool owned by the context
//! ([`util::pool::WorkerPool`]) — workers park between re-plans instead of
//! paying thread spawn/teardown each time. The decomposition is exact, so
//! plan costs are unchanged wherever the monolithic exact solve completed
//! within budget (and only ever improve where it had to fall back to a
//! heuristic), while wall-clock drops on worldwide workloads. The hot maps
//! throughout (eligibility memo, solution memo, graph cache, Expand's
//! stream→slot maps) hash through the dependency-free
//! [`util::fxhash::FxHasher`] instead of SipHash.
//!
//! ## Adaptive budgets & delta-solve reuse (10k+ streams)
//!
//! Two mechanisms keep re-plans exact at metro scale (thousands of cameras
//! per city):
//!
//! * **Adaptive solver budgets** ([`coordinator::budget`]) — each
//!   component's arc-flow-node / ILP-variable / branch-and-bound budgets
//!   are re-derived every re-plan from its own telemetry plus a global
//!   pool: trivial components donate predicted slack, components that hit
//!   a budget wall escalate from the pool, and nobody ever drops below the
//!   static seed budgets ([`packing::mcvbp::SolveOptions`]'s defaults).
//! * **Delta-solve reuse** — the solution memo additionally indexes
//!   subproblems by *structure* (bins + demand vectors, counts excluded).
//!   A re-plan whose subproblem differs from a memoized exact solve by a
//!   bounded demand delta re-enters the solver warm: the cached optimal
//!   basis is re-installed and repaired by a dual-simplex pass
//!   ([`solver::simplex::resume_from_basis`]) and the cached branching
//!   order replays in [`solver::bnb`]. Every warm step is certified; the
//!   uncertifiable ones fall back to the cold path under the same budgets,
//!   so warm results are exactly as optimal as cold ones.
//! * **Structural delta-solve (PR 6, widened in PR 9)** — the delta path
//!   also spans *bounded structural* drift: a small **set** of whole
//!   groups appearing and/or vanishing in one re-plan. Vanished groups
//!   are re-inserted as zero-coverage **ghosts**
//!   ([`packing::mcvbp::GhostGroup`], ascending augmented-list positions)
//!   so the joint ILP reconstructs the cached solve's column space
//!   exactly and their change collapses to an RHS delta; appeared groups
//!   trigger a **block-by-block basis translation**
//!   ([`packing::mcvbp::PrevLayout`] →
//!   [`solver::simplex::complete_basis`]) of the cached basis into the
//!   wider column space. A mixed re-plan combines both: ghosts first
//!   reduce it to a pure appeared-group translation over the augmented
//!   item list ([`coordinator::pipeline`] aligns the old and new group
//!   lists by longest-common-subsequence over demand-vector identity).
//!   Everything rides the same certified-or-cold machinery and is counted
//!   separately (`structural_delta_hits` / `structural_ghost_groups` /
//!   `structural_appeared_groups` / `structural_reuses`).
//!
//! The LP substrate itself is a *revised* simplex over a product-form eta
//! factorization ([`solver::factor`]): per-iteration cost scales with basis
//! size and column sparsity instead of tableau width. The eta file is
//! **compacted** (PR 9) — one flat entry arena, exact-identity etas
//! elided, refactorization triggered by measured fill — a storage-only
//! change kept provably bit-identical to an append-only replay
//! (`prop_compacted_eta_matches_reference`). Pricing runs in two modes
//! ([`solver::simplex::Pricing`]): full Dantzig, pinned to the dense
//! tableau's bit-for-bit reference
//! ([`solver::simplex::solve_lp_dense`], property-tested in
//! `tests/properties.rs`), and candidate-list **partial pricing**
//! ([`solver::simplex::solve_lp_partial`], the exact solver's default) —
//! repricing a bounded candidate list most iterations and certifying
//! optimality with a final full sweep, exactness property-tested by
//! objective parity against dense. All three race in `bench_solver`.
//!
//! ## The unified portfolio runtime (PR 5)
//!
//! The GCL configuration evaluates a three-candidate portfolio every
//! re-plan (exact RTT-filtered, ARMVAC-greedy, nearest-exact) and adopts
//! the cheapest plan. [`coordinator::portfolio`] runs the candidates on
//! *shared* infrastructure: one lazily-spawned solve-worker pool
//! ([`util::pool::PoolSlot`]) spans all three contexts, each candidate's
//! budget allocation publishes its predicted slack into a cross-candidate
//! pool ([`coordinator::budget::allocate_pooled`] — the alternates' donated
//! slack funds the main exact solve, floored at the static seed and never
//! below the isolated allocation), and after every re-plan the *winning*
//! candidate's stream→slot assignment is seeded into all three contexts,
//! so a winner flip expands against the deployed fleet: an unchanged
//! workload yields zero provision/terminate across a forced flip, and
//! identical plans keep identical instance ids. Plan costs stay
//! bit-identical to the three-independent-contexts baseline wherever exact
//! phases complete (property-tested).
//!
//! ## The metro-sharded planner (PR 7)
//!
//! At planet scale the fleet is partitioned into **shards** — connected
//! components of the per-request eligibility masks
//! ([`coordinator::shard::ShardedPlanner`]) — each owning its own portfolio
//! [`ReplanContext`](coordinator::portfolio::ReplanContext) and re-planning
//! (concurrently) only when its own drift signature
//! ([`coordinator::shard`]'s `drift_sig`) changes. A global arbiter owns
//! what must stay shared: the solve-worker pool, the arc-flow graph cache,
//! the cross-shard slack ledger
//! ([`coordinator::budget::ShardSlackLedger`]), and catalog/price fan-out
//! (a `(catalog, config)` signature change dirties every shard). Sharded
//! plan cost is asserted at parity with the single-context plan wherever
//! every shard's exact phase completes.
//!
//! ## Closed-loop serving feedback (PR 8)
//!
//! Serving observations flow back into planning. Either executor — the
//! deterministic, feature-free [`server::sim::SimExecutor`] or the PJRT
//! runtime (`server::pjrt`, feature `pjrt`) — emits per-window
//! per-instance observations ([`server::sim::InstanceWindow`]);
//! [`server::feedback::FeedbackController`] folds them into per-stream
//! [`DemandFeedback`](cameras::DemandFeedback): an EWMA of measured cost
//! per frame relative to the declared profile (published under a
//! quantize-and-deadband step) and a backpressure **degrade tier** that
//! halves a stream's effective fps per tier — shedding load *before* the
//! queue drops frames, never shedding a stream to zero, and restoring
//! under sustained headroom. The planner consumes feedback through the
//! demand path ([`profiles::ProgramProfile::demand_cpu_scaled`] /
//! [`demand_gpu_scaled`](profiles::ProgramProfile::demand_gpu_scaled) and
//! [`effective_fps`](cameras::StreamRequest::effective_fps)), and the
//! fingerprint/drift-signature machinery ensures a feedback delta dirties
//! exactly the streams whose observed demand moved — default feedback is
//! **bit-identical** to the pre-feedback plan (property-tested in
//! `prop_zero_feedback_delta_is_plan_noop`).
//!
//! ## Bench artifacts
//!
//! Field-by-field schema documentation for every bench JSON lives in
//! `docs/BENCH_SCHEMAS.md`:
//!
//! * `BENCH_adaptive.json` — adaptive re-planning + portfolio continuity
//!   (`bench_adaptive`, scenarios in [`bench::portfolio`]),
//! * `BENCH_scale.json` — 10k-stream warm/cold parity and front-end drift
//!   proportionality (`bench_scale`),
//! * `BENCH_planet.json` — metro-sharded planet run (`bench_planet`),
//! * `BENCH_solver.json` — dense vs full-Dantzig vs partial-pricing
//!   simplex race plus structural-delta timings (`bench_solver`),
//! * `BENCH_closedloop.json` — closed-loop feedback bars
//!   (`bench_closedloop`, scenarios in [`bench::closedloop`]).
//!
//! ## Features
//!
//! The default build is dependency-free: every planning, packing, solver,
//! cloud-simulation, serving-simulation, and feedback test runs with no
//! features enabled. The `pjrt` feature gates only the real inference path
//! — PJRT artifact loading (the `runtime` module) and the threaded serving
//! runtime (`server::pjrt`) — because it needs the vendored `xla` crate and
//! `make artifacts`. The end-to-end PJRT serving tests additionally sit
//! behind `pjrt-tests`.

pub mod bench;
pub mod cameras;
pub mod catalog;
pub mod cli;
pub mod cloudsim;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod geo;
pub mod metrics;
pub mod packing;
pub mod profiles;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod solver;
pub mod util;

pub use error::{Error, Result};
