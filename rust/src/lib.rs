//! # camflow
//!
//! Reproduction of *"Cloud Resource Optimization for Processing Multiple
//! Streams of Visual Data"* (Kapach et al., IEEE MultiMedia 2019).
//!
//! camflow is a three-layer system:
//!
//! * **L3 (this crate)** — the paper's contribution: a cloud **resource
//!   manager** that selects the cheapest set of cloud instances (type ×
//!   location) able to analyze many network-camera streams, formulated as
//!   multi-dimensional multiple-choice vector bin packing (arc-flow + MILP),
//!   with location-aware strategies (NL / ARMVAC / GCL) and adaptive runtime
//!   re-packing. It also owns the serving runtime: stream router, dynamic
//!   batcher, simulated cloud, metrics, CLI.
//! * **L2 (python/compile/model.py, build-time)** — the analysis programs
//!   (compact VGG16 / ZF detectors) written in JAX and AOT-lowered to HLO
//!   text.
//! * **L1 (python/compile/kernels/, build-time)** — the Pallas tiled matmul
//!   kernel backing every conv/dense layer of the analysis programs.
//!
//! ## The staged planning pipeline
//!
//! Planning is an explicit four-stage pipeline
//! ([`coordinator::pipeline`]): **Eligibility → ProblemBuild → Solve →
//! Expand**. Each stage emits a cacheable artifact, and a
//! [`PlanContext`](coordinator::pipeline::PlanContext) persists those
//! artifacts across re-plans so the *dynamic* manager
//! ([`coordinator::adaptive`]) works incrementally:
//!
//! * per-camera eligibility masks (fixed-width
//!   [`RegionMask`](coordinator::eligibility::RegionMask) bitsets) are
//!   memoized by (location, fps) in the context's eligibility cache
//!   ([`coordinator::eligibility`]),
//! * per-request group assignments are **dirty-tracked**: the context diffs
//!   each request slice against the previous one by stable
//!   [`StreamKey`](cameras::StreamKey) + fingerprint, so a warm re-plan's
//!   front-end cost is proportional to workload *drift*, not fleet size —
//!   unchanged streams reuse their interned
//!   [`GroupId`](coordinator::eligibility::GroupId) without touching
//!   eligibility or grouping at all (bit-identical to a cold rebuild,
//!   property-tested),
//! * per-group demand vectors are memoized by interned group identity in
//!   the context's demand cache,
//! * compressed arc-flow graphs are memoized by (capacity grid, quantized
//!   item multiset) in a shared [`packing::arcflow::GraphCache`],
//! * the previous packing is translated onto the new problem and seeds both
//!   the greedy warm-start fill ([`packing::heuristic::warm_start_fill`])
//!   and the exact solver's incumbent cut
//!   ([`packing::mcvbp::solve_with`]),
//! * the previous stream→instance assignment is matched against by the
//!   sticky Expand stage ([`coordinator::expand`]): surviving instances
//!   keep their stable [`SlotId`](coordinator::SlotId) and their streams,
//!   so `streams_moved` tracks the packing diff, not queue order.
//!
//! The Solve stage additionally decomposes the packing problem into
//! independent per-region-cluster subproblems (streams whose RTT circles
//! cannot overlap never share an instance) and dispatches them to a
//! persistent worker pool owned by the context
//! ([`util::pool::WorkerPool`]) — workers park between re-plans instead of
//! paying thread spawn/teardown each time. The decomposition is exact, so
//! plan costs are unchanged wherever the monolithic exact solve completed
//! within budget (and only ever improve where it had to fall back to a
//! heuristic), while wall-clock drops on worldwide workloads. The hot maps
//! throughout (eligibility memo, solution memo, graph cache, Expand's
//! stream→slot maps) hash through the dependency-free
//! [`util::fxhash::FxHasher`] instead of SipHash.
//!
//! ## Adaptive budgets & delta-solve reuse (10k+ streams)
//!
//! Two mechanisms keep re-plans exact at metro scale (thousands of cameras
//! per city):
//!
//! * **Adaptive solver budgets** ([`coordinator::budget`]) — each
//!   component's arc-flow-node / ILP-variable / branch-and-bound budgets
//!   are re-derived every re-plan from its own telemetry plus a global
//!   pool: trivial components donate predicted slack, components that hit
//!   a budget wall escalate from the pool, and nobody ever drops below the
//!   static seed budgets ([`packing::mcvbp::SolveOptions`]'s defaults).
//! * **Delta-solve reuse** — the solution memo additionally indexes
//!   subproblems by *structure* (bins + demand vectors, counts excluded).
//!   A re-plan whose subproblem differs from a memoized exact solve by a
//!   bounded demand delta re-enters the solver warm: the cached optimal
//!   basis is re-installed and repaired by a dual-simplex pass
//!   ([`solver::simplex::resume_from_basis`]) and the cached branching
//!   order replays in [`solver::bnb`]. Every warm step is certified; the
//!   uncertifiable ones fall back to the cold path under the same budgets,
//!   so warm results are exactly as optimal as cold ones.
//! * **Structural delta-solve (PR 6)** — the delta path also spans
//!   *bounded structural* drift: one whole group appearing or vanishing.
//!   A vanished group is re-inserted as a zero-coverage **ghost**
//!   ([`packing::mcvbp::GhostGroup`]) so the joint ILP reconstructs the
//!   cached solve's column space exactly and the structural change
//!   collapses to an RHS delta; an appeared group triggers a
//!   **block-by-block basis translation** ([`packing::mcvbp::PrevLayout`] →
//!   [`solver::simplex::complete_basis`]) of the cached basis into the
//!   wider column space. Both directions ride the same certified-or-cold
//!   machinery and are counted separately
//!   (`structural_delta_hits` / `structural_reuses`).
//!
//! The LP substrate itself is a *revised* simplex over a product-form eta
//! factorization ([`solver::factor`]): per-iteration cost scales with basis
//! size and column sparsity instead of tableau width, with the dense
//! tableau retained as the bit-for-bit reference
//! ([`solver::simplex::solve_lp_dense`], property-tested in
//! `tests/properties.rs`, raced in `bench_solver`).
//!
//! ## The unified portfolio runtime (PR 5)
//!
//! The GCL configuration evaluates a three-candidate portfolio every
//! re-plan (exact RTT-filtered, ARMVAC-greedy, nearest-exact) and adopts
//! the cheapest plan. [`coordinator::portfolio`] runs the candidates on
//! *shared* infrastructure: one lazily-spawned solve-worker pool
//! ([`util::pool::PoolSlot`]) spans all three contexts, each candidate's
//! budget allocation publishes its predicted slack into a cross-candidate
//! pool ([`coordinator::budget::allocate_pooled`] — the alternates' donated
//! slack funds the main exact solve, floored at the static seed and never
//! below the isolated allocation), and after every re-plan the *winning*
//! candidate's stream→slot assignment is seeded into all three contexts,
//! so a winner flip expands against the deployed fleet: an unchanged
//! workload yields zero provision/terminate across a forced flip, and
//! identical plans keep identical instance ids. Plan costs stay
//! bit-identical to the three-independent-contexts baseline wherever exact
//! phases complete (property-tested).
//!
//! ## `BENCH_adaptive.json` `portfolio` object (written by `bench_adaptive`)
//!
//! * `flip_churn_ratio` — churn ratio of the forced winner-flip re-plan on
//!   an unchanged workload (asserted ≤ `sticky_churn_ratio` + 0.05),
//! * `sticky_churn_ratio` — the same-winner control re-plan's churn ratio,
//! * `winner_flips` — winner changes the scenario observed (asserted ≥ 1),
//! * `flip_provisioned` / `flip_terminated` — fleet changes on the flip
//!   re-plan (asserted 0: continuity keeps the deployed fleet),
//! * `pool_shared_jobs` — solve jobs all three candidates dispatched to
//!   the one shared worker pool (asserted > 0),
//! * `budget_pooled_donated` — arc-flow node budget drawn from the
//!   cross-candidate donated pool beyond the isolated allocations
//!   (asserted > 0).
//!
//! The scenarios live in [`bench::portfolio`], so `tests/integration.rs`
//! schema-checks exactly the fields the bench writes.
//!
//! ## `BENCH_scale.json` (written by `bench_scale`, gated in CI)
//!
//! * `parity[]` — per 10k-stream scenario: `streams`, `fps`, `cold_ms`,
//!   `warm_ms`, `speedup` (wall-clock, recorded-not-gated under
//!   `BENCH_LENIENT_TIMING`), `cold_usd_per_hour` / `warm_usd_per_hour`,
//!   `reuse_ratio`, `delta_solve_hits` (near-match memo reuses — asserted
//!   > 0), `components`, `cold_exact_complete` (every component exact and
//!   proven), `warm_equals_cold` (cost parity, asserted whenever both
//!   sides completed their exact phase). Front-end fields (PR 4):
//!   `cold_front_ms` / `warm_front_ms` (Eligibility + ProblemBuild
//!   wall-clock) and `front_speedup` — the warm ≈1%-drift re-plan's
//!   front-end is asserted ≥ 5× faster than the cold full rebuild's —
//!   plus `front_unchanged` / `front_changed` (the dirty-tracking split,
//!   asserted to equal the constructed drift exactly) and per-stage
//!   breakdowns `cold_stage_ms` / `warm_stage_ms` with `eligibility`,
//!   `build`, `solve`, and `expand` entries.
//! * `exact_recovery` — the calibrated fallback-recovery scenario:
//!   `probe_need_max`/`probe_need_second` (measured per-component arc-flow
//!   needs), `static_budget` (pinned between them), `static_fallbacks`
//!   (asserted ≥ 1: the seed behaviour starves the hard metro),
//!   `adaptive_fallbacks` (asserted 0: the pool-funded re-solve recovers
//!   exactness), `budget_donated_nodes`, and the static/adaptive/probe
//!   `usd_per_hour` triple.
//! * `lp_reuse` — `lp_warm_resumes` vs `lp_cold_solves` node LPs across
//!   the warm runs (the dual-simplex resume at work).
//!
//! ## `BENCH_planet.json` (written by `bench_planet`, gated in CI)
//!
//! Planet-scale run of the metro-sharded planner
//! ([`coordinator::shard::ShardedPlanner`]): 100 metros in 8 region basins,
//! ~10k streams, with skewed drift. Shards are connected components of the
//! per-request eligibility masks, each owning its own portfolio
//! [`coordinator::portfolio::ReplanContext`] and re-planning (concurrently)
//! only when its own drift arrives; a global arbiter owns the shared worker
//! pool, graph cache, cross-shard slack ledger
//! ([`coordinator::budget::ShardSlackLedger`]), and catalog/price fan-out.
//!
//! * `metros` / `streams` / `shards` — workload shape (100 / 10_200 / 8),
//! * `cold_all_ms` — cold round, all 8 shards planning concurrently,
//! * `warm_noop_ms` — no-drift round (asserted: 0 dirty shards, plans and
//!   cost reused bit-identically),
//! * `warm_one_dirty_ms` — one camera leaves one metro (asserted: exactly
//!   1 dirty shard, warm-started via the delta paths),
//! * `warm_uniform_ms` — one camera leaves every basin (asserted: 8 dirty
//!   shards); `uniform_over_one_dirty` is the warm ratio, gated only
//!   without `BENCH_LENIENT_TIMING` since dirty shards re-plan
//!   concurrently,
//! * `price_fanout_all_ms` — one offering's price changes: the
//!   `(catalog, config)` signature dirties all shards cold;
//!   `fanout_over_one_dirty` (asserted ≥ 5 unconditionally — the
//!   dirty-shard-bounded wall-clock bar),
//! * `sharded_usd_per_hour` / `unsharded_usd_per_hour` / `cost_parity` —
//!   the sharded total vs one single-context plan; parity to 1e-6 is
//!   asserted cold, after the skewed warm round, and after the fan-out
//!   (certified-or-cold gate: every shard exact-complete with the Main
//!   candidate — also property-tested in `prop_sharded_plan_cost_equals_`
//!   `unsharded_on_disjoint_metros`),
//! * `dirty` — dirty-shard count per round (`cold`, `noop`, `skew`,
//!   `restore`, `uniform`, `fanout`),
//! * `exact_complete` / `all_main` / `donors` / `lenient` — gate inputs
//!   (every re-planned shard donates its residual budget slack into the
//!   cross-shard ledger; `donors` is asserted = 8).
//!
//! ## `BENCH_solver.json` (written by `bench_solver`, gated in CI)
//!
//! * `classes[]` — one entry per LP component class (`paper_scale`,
//!   `metro`, and `wide_sparse` — the largest exact component class):
//!   * `rows` / `cols` / `nnz_per_col` / `lps` — the class shape and how
//!     many random covering LPs were solved,
//!   * `dense_ms` / `revised_ms` — whole-set wall clock per core,
//!   * `dense_iterations` / `revised_iterations` — simplex pivots summed
//!     over the set (both phases),
//!   * `dense_iters_per_sec` / `revised_iters_per_sec` — pivot throughput;
//!     on `wide_sparse` the bench asserts revised ≥ dense
//!     (recorded-not-gated under `BENCH_LENIENT_TIMING`),
//!   * `speedup` — `dense_ms / revised_ms`,
//!   * `ftran_per_iter` / `btran_per_iter` — factorization solves per
//!     pivot (revised only; dense has no factorization),
//!   * `refactorizations` — threshold-triggered eta-file rebuilds,
//!   * `degenerate_pivots` — pivots whose min-ratio step was ~0 (the
//!     stalling the two-tier Dantzig band skips when it can).
//! * `calibration` — provenance of the branch-and-bound node guard:
//!   `node_cost_rows_weight` (the `NODE_COST_ROWS_WEIGHT` constant in
//!   [`coordinator::budget::milp_node_cost`]), the `model` formula, and the
//!   `derivation` note tying the weight to the measured `wide_sparse`
//!   dense/revised cost ratio.
//!
//! Every timed LP is additionally asserted dense==revised on outcome
//! variant and objective bits, making the bench a large-sample parity sweep
//! on top of the property suite.
//!
//! ## Features
//!
//! The request path (PJRT artifact loading + serving) is gated behind the
//! `pjrt` feature because it needs the vendored `xla` crate and `make
//! artifacts`; the default build is dependency-free and every planning,
//! packing, solver, and simulation test runs without it. The end-to-end
//! serving tests additionally sit behind `pjrt-tests`.

pub mod bench;
pub mod cameras;
pub mod catalog;
pub mod cli;
pub mod cloudsim;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod geo;
pub mod metrics;
pub mod packing;
pub mod profiles;
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod solver;
pub mod util;

pub use error::{Error, Result};
