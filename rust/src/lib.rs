//! # camflow
//!
//! Reproduction of *"Cloud Resource Optimization for Processing Multiple
//! Streams of Visual Data"* (Kapach et al., IEEE MultiMedia 2019).
//!
//! camflow is a three-layer system:
//!
//! * **L3 (this crate)** — the paper's contribution: a cloud **resource
//!   manager** that selects the cheapest set of cloud instances (type ×
//!   location) able to analyze many network-camera streams, formulated as
//!   multi-dimensional multiple-choice vector bin packing (arc-flow + MILP),
//!   with location-aware strategies (NL / ARMVAC / GCL) and adaptive runtime
//!   re-packing. It also owns the serving runtime: stream router, dynamic
//!   batcher, simulated cloud, metrics, CLI.
//! * **L2 (python/compile/model.py, build-time)** — the analysis programs
//!   (compact VGG16 / ZF detectors) written in JAX and AOT-lowered to HLO
//!   text.
//! * **L1 (python/compile/kernels/, build-time)** — the Pallas tiled matmul
//!   kernel backing every conv/dense layer of the analysis programs.
//!
//! The request path is pure Rust: artifacts produced by `make artifacts` are
//! loaded via the PJRT C API (`xla` crate) and executed in-process.

pub mod bench;
pub mod cameras;
pub mod catalog;
pub mod cli;
pub mod cloudsim;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod geo;
pub mod metrics;
pub mod packing;
pub mod profiles;
pub mod runtime;
pub mod server;
pub mod solver;
pub mod util;

pub use error::{Error, Result};
