//! Minimal argument parser (clap is not vendored in this image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments (excluding argv\[0\]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: everything after is positional.
                    out.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|_| {
                Error::config(format!("cannot parse --{name} value '{s}'"))
            }),
        }
    }

    /// First positional argument (the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_subcommand() {
        let a = parse(&["plan", "extra"]);
        assert_eq!(a.subcommand(), Some("plan"));
        assert_eq!(a.positional, vec!["plan", "extra"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse(&["serve", "--fps", "2.5", "--exact", "--mode=fast"]);
        assert_eq!(a.opt("fps"), Some("2.5"));
        assert!(a.flag("exact"));
        assert_eq!(a.opt("mode"), Some("fast"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn typed_parse_with_default() {
        let a = parse(&["x", "--n", "12"]);
        assert_eq!(a.opt_parse("n", 0usize).unwrap(), 12);
        assert_eq!(a.opt_parse("m", 7usize).unwrap(), 7);
        let bad = parse(&["x", "--n", "abc"]);
        assert!(bad.opt_parse("n", 0usize).is_err());
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["run", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["run", "--not-a-flag"]);
        assert!(!a.flag("not-a-flag"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["x", "--delta", "-3"]);
        // "-3" doesn't start with --, so it's consumed as the value.
        assert_eq!(a.opt("delta"), Some("-3"));
    }
}
