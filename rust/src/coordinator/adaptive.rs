//! Adaptive resource management ("the resource manager is dynamic and its
//! decisions may change over time because the demands may vary").
//!
//! The manager keeps the current plan **and** a persistent
//! [`ReplanContext`]: when the workload changes (rush-hour frame-rate
//! increases, cameras joining/leaving, program swaps) it re-plans
//! *incrementally* — unchanged cameras keep their cached eligibility masks
//! and demand vectors, unchanged region clusters reuse their arc-flow
//! graphs, and the previous packing seeds branch-and-bound as the incumbent
//! instead of the cold FFD start — then computes the **migration diff**:
//! which instances to keep, provision, terminate, and which streams move.
//! Warm vs cold re-plan latency is benchmarked in `bench_adaptive` (the
//! paper: "These methods can make resource decisions quickly and be applied
//! during runtime", cf. Kaseb et al. \[14\]).

use super::pipeline::{PipelineStats, ReplanContext};
use super::{Plan, Planner};
use crate::cameras::StreamRequest;
use crate::error::Result;

/// What changes when moving from one plan to the next.
#[derive(Clone, Debug, Default)]
pub struct MigrationReport {
    /// Instance labels to provision (counts).
    pub provision: Vec<(String, usize)>,
    /// Instance labels to terminate (counts).
    pub terminate: Vec<(String, usize)>,
    /// Number of instances carried over unchanged (same type+location).
    pub kept: usize,
    /// Streams whose host instance type/location changed.
    pub streams_moved: usize,
    /// Hourly cost before/after.
    pub cost_before: f64,
    pub cost_after: f64,
    /// Pipeline telemetry of the re-plan (cache reuse, warm start,
    /// decomposition width).
    pub pipeline: PipelineStats,
}

impl MigrationReport {
    pub fn cost_delta(&self) -> f64 {
        self.cost_after - self.cost_before
    }
}

/// Count instances by label.
fn census(plan: &Plan) -> std::collections::BTreeMap<String, usize> {
    let mut m = std::collections::BTreeMap::new();
    for inst in &plan.instances {
        *m.entry(inst.label.clone()).or_insert(0) += 1;
    }
    m
}

/// Per-stream host label (keyed by the request's camera id + program), used
/// to detect stream moves across re-plans even when request order changes.
fn stream_hosts(
    plan: &Plan,
    requests: &[StreamRequest],
) -> std::collections::BTreeMap<(u64, &'static str), String> {
    let mut m = std::collections::BTreeMap::new();
    for inst in &plan.instances {
        for &s in &inst.streams {
            let r = &requests[s];
            m.insert((r.camera.id, r.program.name()), inst.label.clone());
        }
    }
    m
}

/// The adaptive manager: owns the current plan, the persistent pipeline
/// context, and re-plans on demand drift.
pub struct AdaptiveManager {
    pub planner: Planner,
    pub current: Option<(Vec<StreamRequest>, Plan)>,
    /// Persistent stage caches + previous solution for warm re-plans.
    pub ctx: ReplanContext,
    /// When false, every re-plan runs cold (fresh context) — the A/B lever
    /// used by `bench_adaptive` and `camflow simulate --cold`.
    pub warm: bool,
}

impl AdaptiveManager {
    pub fn new(planner: Planner) -> Self {
        AdaptiveManager { planner, current: None, ctx: ReplanContext::new(), warm: true }
    }

    /// A manager that re-plans from scratch every time (the seed behaviour).
    pub fn cold(planner: Planner) -> Self {
        AdaptiveManager { warm: false, ..AdaptiveManager::new(planner) }
    }

    pub fn current_plan(&self) -> Option<&Plan> {
        self.current.as_ref().map(|(_, p)| p)
    }

    /// Re-plan for a new workload; returns the migration diff.
    pub fn replan(&mut self, requests: Vec<StreamRequest>) -> Result<MigrationReport> {
        let new_plan = if self.warm {
            self.planner.plan_with(&requests, &mut self.ctx)?
        } else {
            self.planner.plan(&requests)?
        };
        let mut report = MigrationReport {
            cost_after: new_plan.cost_per_hour,
            pipeline: new_plan.pipeline.clone(),
            ..Default::default()
        };

        if let Some((old_requests, old_plan)) = &self.current {
            report.cost_before = old_plan.cost_per_hour;
            let old_census = census(old_plan);
            let new_census = census(&new_plan);
            for (label, &n_new) in &new_census {
                let n_old = old_census.get(label).copied().unwrap_or(0);
                if n_new > n_old {
                    report.provision.push((label.clone(), n_new - n_old));
                }
                report.kept += n_new.min(n_old);
            }
            for (label, &n_old) in &old_census {
                let n_new = new_census.get(label).copied().unwrap_or(0);
                if n_old > n_new {
                    report.terminate.push((label.clone(), n_old - n_new));
                }
            }
            // Stream moves: host label changed for a surviving stream.
            let old_hosts = stream_hosts(old_plan, old_requests);
            let new_hosts = stream_hosts(&new_plan, &requests);
            for (key, new_label) in &new_hosts {
                if let Some(old_label) = old_hosts.get(key) {
                    if old_label != new_label {
                        report.streams_moved += 1;
                    }
                }
            }
        } else {
            // Cold start: everything is a provision.
            for (label, n) in census(&new_plan) {
                report.provision.push((label, n));
            }
        }

        self.current = Some((requests, new_plan));
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::{camera_at, StreamRequest};
    use crate::catalog::Catalog;
    use crate::coordinator::PlannerConfig;
    use crate::geo::cities;
    use crate::profiles::{Program, Resolution};

    fn planner() -> Planner {
        let catalog =
            Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        Planner::new(catalog, PlannerConfig::st3())
    }

    fn workload(fps: f64, n: usize) -> Vec<StreamRequest> {
        (0..n)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                    Program::Zf,
                    fps,
                )
            })
            .collect()
    }

    #[test]
    fn cold_start_provisions_everything() {
        let mut mgr = AdaptiveManager::new(planner());
        let report = mgr.replan(workload(0.5, 4)).unwrap();
        assert!(report.provision.iter().map(|(_, n)| n).sum::<usize>() >= 1);
        assert!(report.terminate.is_empty());
        assert_eq!(report.cost_before, 0.0);
        assert!(report.cost_after > 0.0);
        assert!(!report.pipeline.warm_started, "first plan has no seed");
    }

    #[test]
    fn rush_hour_scales_up_then_down() {
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(workload(0.5, 4)).unwrap();
        let calm_cost = mgr.current_plan().unwrap().cost_per_hour;

        // Rush hour: 8 fps requires GPUs -> cost rises, instances provisioned.
        let up = mgr.replan(workload(8.0, 4)).unwrap();
        assert!(up.cost_delta() > 0.0);
        assert!(!up.provision.is_empty());

        // Calm again: cost returns, terminations issued.
        let down = mgr.replan(workload(0.5, 4)).unwrap();
        assert!(down.cost_delta() < 0.0);
        assert!(!down.terminate.is_empty());
        assert!((mgr.current_plan().unwrap().cost_per_hour - calm_cost).abs() < 1e-9);
    }

    #[test]
    fn identical_workload_is_stable() {
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(workload(1.0, 6)).unwrap();
        let report = mgr.replan(workload(1.0, 6)).unwrap();
        assert!(report.provision.is_empty(), "{report:?}");
        assert!(report.terminate.is_empty(), "{report:?}");
        assert_eq!(report.cost_delta(), 0.0);
        assert!(report.pipeline.warm_started, "second re-plan must warm-start");
        assert!(report.pipeline.elig_cache_hits > 0);
    }

    #[test]
    fn camera_departure_releases_capacity() {
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(workload(8.0, 6)).unwrap();
        let report = mgr.replan(workload(8.0, 2)).unwrap();
        assert!(report.cost_delta() < 0.0);
        assert!(!report.terminate.is_empty());
    }

    #[test]
    fn warm_and_cold_managers_agree_over_a_demand_swing() {
        let mut warm = AdaptiveManager::new(planner());
        let mut cold = AdaptiveManager::cold(planner());
        for fps in [0.5, 8.0, 8.0, 1.0, 0.5] {
            let w = warm.replan(workload(fps, 5)).unwrap();
            let c = cold.replan(workload(fps, 5)).unwrap();
            assert!(
                (w.cost_after - c.cost_after).abs() < 1e-9,
                "warm {w:?} diverged from cold {c:?} at {fps} fps"
            );
        }
    }
}
