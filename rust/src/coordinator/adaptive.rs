//! Adaptive resource management ("the resource manager is dynamic and its
//! decisions may change over time because the demands may vary").
//!
//! The manager keeps the current plan **and** a persistent
//! [`ReplanContext`]: when the workload changes (rush-hour frame-rate
//! increases, cameras joining/leaving, program swaps) it re-plans
//! *incrementally* — unchanged cameras keep their cached eligibility masks
//! and demand vectors, unchanged region clusters reuse their arc-flow
//! graphs, and the previous packing seeds branch-and-bound as the incumbent
//! instead of the cold FFD start — then computes the **migration diff**:
//! which instances to keep, provision, terminate, and which streams move.
//! Warm vs cold re-plan latency is benchmarked in `bench_adaptive` (the
//! paper: "These methods can make resource decisions quickly and be applied
//! during runtime", cf. Kaseb et al. \[14\]); 10k-stream-scale re-plans with
//! adaptive solver budgets and delta-solve reuse are gated in `bench_scale`.
//!
//! Each [`MigrationReport`] carries the re-plan's [`PipelineStats`],
//! including the solver telemetry that drives the adaptive budget
//! allocator: exact-vs-fallback component counts, delta-solve reuses, warm
//! LP resumes, and donated budget. The cumulative roll-up lives on the
//! context (`ctx.main.solver`, a [`SolverMetrics`]).
//!
//! [`SolverMetrics`]: crate::metrics::SolverMetrics

use super::pipeline::PipelineStats;
use super::portfolio::{Candidate, ReplanContext};
use super::{Plan, Planner, SlotId};
use crate::cameras::{stream_keys, StreamRequest};
use crate::error::Result;
use std::collections::{BTreeMap, HashMap, VecDeque};

/// What changes when moving from one plan to the next.
///
/// All counts are **per instance**, derived from the old↔new instance
/// pairing: an old instance either survives (pairs with a new one — by
/// stable slot id when the sticky Expand carried it over, otherwise with a
/// same-label instance)
/// or is terminated; unpaired new instances are provisioned. A stream
/// "moves" when its host instance — not merely its host *label* — changes.
/// The pairing mirrors [`CloudSim::apply_plan`]'s reconciliation (stable
/// slot bindings first, then a same-label FIFO); sticky re-plans resolve
/// almost entirely through slot ids, where the two agree exactly. Only the
/// FIFO tie-breaks can differ (plan order here vs oldest-physical-id in the
/// simulator) when several same-label instances lack slot bindings.
///
/// [`CloudSim::apply_plan`]: crate::cloudsim::CloudSim::apply_plan
#[derive(Clone, Debug, Default)]
pub struct MigrationReport {
    /// Instance labels to provision (counts).
    pub provision: Vec<(String, usize)>,
    /// Instance labels to terminate (counts).
    pub terminate: Vec<(String, usize)>,
    /// Instances carried over (paired old→new, same type+location).
    pub kept: usize,
    /// Surviving streams whose host instance changed.
    pub streams_moved: usize,
    /// Streams present in both the old and new workload (the churn
    /// denominator; departed and newly arrived streams can't "move").
    pub streams_surviving: usize,
    /// Hourly cost before/after.
    pub cost_before: f64,
    pub cost_after: f64,
    /// Pipeline telemetry of the re-plan (cache reuse, warm start,
    /// decomposition width).
    pub pipeline: PipelineStats,
    /// Portfolio candidate whose plan this re-plan adopted (`None` for a
    /// cold manager — it plans through a throwaway context).
    pub winner: Option<Candidate>,
    /// True when the adopted candidate differs from the previous re-plan's
    /// — a portfolio winner flip. Slot continuity keeps the fleet stable
    /// across it: a flip onto a shape-identical plan moves nothing.
    pub winner_flipped: bool,
}

impl MigrationReport {
    pub fn cost_delta(&self) -> f64 {
        self.cost_after - self.cost_before
    }

    /// Fraction of surviving streams that moved, in [0, 1] (0 when no
    /// stream survived).
    pub fn churn_ratio(&self) -> f64 {
        if self.streams_surviving == 0 {
            0.0
        } else {
            self.streams_moved as f64 / self.streams_surviving as f64
        }
    }

    /// Merge another shard's migration report into this one — the fleet
    /// roll-up for [`shard`](super::shard)'s per-shard re-plans. Label
    /// counts merge per label, stream/instance counts and costs sum,
    /// pipeline telemetry absorbs; `winner` survives only if every absorbed
    /// report agrees on it (shards can adopt different candidates).
    pub fn absorb(&mut self, other: &MigrationReport) {
        let merge = |into: &mut Vec<(String, usize)>, from: &[(String, usize)]| {
            let mut m: BTreeMap<String, usize> = into.drain(..).collect();
            for (label, n) in from {
                *m.entry(label.clone()).or_insert(0) += n;
            }
            *into = m.into_iter().collect();
        };
        merge(&mut self.provision, &other.provision);
        merge(&mut self.terminate, &other.terminate);
        self.kept += other.kept;
        self.streams_moved += other.streams_moved;
        self.streams_surviving += other.streams_surviving;
        self.cost_before += other.cost_before;
        self.cost_after += other.cost_after;
        self.pipeline.absorb(&other.pipeline);
        if self.winner != other.winner {
            self.winner = None;
        }
        self.winner_flipped |= other.winner_flipped;
    }
}

/// Count instances by label (cold-start provisioning only).
fn census(plan: &Plan) -> BTreeMap<String, usize> {
    let mut m = BTreeMap::new();
    for inst in &plan.instances {
        *m.entry(inst.label.clone()).or_insert(0) += 1;
    }
    m
}

/// Pair each old plan instance with the new instance it survives as:
/// stable [`SlotId`] match first (sticky re-plans carry slot ids across),
/// then remaining same-label instances in plan order (covers cold re-plans,
/// whose slot ids are all fresh). Returns `pair[old_idx] = Some(new_idx)`.
fn pair_instances(old: &Plan, new: &Plan) -> Vec<Option<usize>> {
    let mut pair: Vec<Option<usize>> = vec![None; old.instances.len()];
    let mut new_taken = vec![false; new.instances.len()];
    let by_slot: HashMap<SlotId, usize> =
        new.instances.iter().enumerate().map(|(i, inst)| (inst.slot_id, i)).collect();
    for (oi, inst) in old.instances.iter().enumerate() {
        if let Some(&ni) = by_slot.get(&inst.slot_id) {
            if new.instances[ni].label == inst.label && !new_taken[ni] {
                pair[oi] = Some(ni);
                new_taken[ni] = true;
            }
        }
    }
    let mut free: BTreeMap<&str, VecDeque<usize>> = BTreeMap::new();
    for (ni, inst) in new.instances.iter().enumerate() {
        if !new_taken[ni] {
            free.entry(inst.label.as_str()).or_default().push_back(ni);
        }
    }
    for (oi, inst) in old.instances.iter().enumerate() {
        if pair[oi].is_none() {
            if let Some(ni) = free.get_mut(inst.label.as_str()).and_then(|v| v.pop_front()) {
                pair[oi] = Some(ni);
            }
        }
    }
    pair
}

/// The adaptive manager: owns the current plan, the persistent pipeline
/// context, and re-plans on demand drift.
pub struct AdaptiveManager {
    pub planner: Planner,
    pub current: Option<(Vec<StreamRequest>, Plan)>,
    /// Persistent stage caches + previous solution for warm re-plans.
    pub ctx: ReplanContext,
    /// When false, every re-plan runs cold (fresh context) — the A/B lever
    /// used by `bench_adaptive` and `camflow simulate --cold`.
    pub warm: bool,
}

impl AdaptiveManager {
    pub fn new(planner: Planner) -> Self {
        AdaptiveManager { planner, current: None, ctx: ReplanContext::new(), warm: true }
    }

    /// A manager that re-plans from scratch every time (the seed behaviour).
    pub fn cold(planner: Planner) -> Self {
        AdaptiveManager { warm: false, ..AdaptiveManager::new(planner) }
    }

    pub fn current_plan(&self) -> Option<&Plan> {
        self.current.as_ref().map(|(_, p)| p)
    }

    /// Re-plan for a new workload; returns the migration diff.
    pub fn replan(&mut self, requests: Vec<StreamRequest>) -> Result<MigrationReport> {
        let prev_winner = self.ctx.last_winner;
        let new_plan = if self.warm {
            self.planner.plan_with(&requests, &mut self.ctx)?
        } else {
            self.planner.plan(&requests)?
        };
        let mut report = migration_diff(
            self.current.as_ref().map(|(r, p)| (r.as_slice(), p)),
            &requests,
            &new_plan,
        );
        if self.warm {
            report.winner = self.ctx.last_winner;
            report.winner_flipped = matches!(
                (prev_winner, self.ctx.last_winner),
                (Some(a), Some(b)) if a != b
            );
        }

        self.current = Some((requests, new_plan));
        Ok(report)
    }

    /// The closed-loop entry point: fold the serving feedback controller's
    /// published per-stream estimates
    /// ([`FeedbackController::apply`](crate::server::feedback::FeedbackController::apply))
    /// into `requests`, then re-plan. Returns the migration report plus how
    /// many requests the feedback actually changed — 0 means the re-plan
    /// saw a workload bit-identical to plain [`replan`](Self::replan)
    /// (unchanged observed demand dirties nothing; property-tested as
    /// `prop_zero_feedback_delta_is_plan_noop`).
    pub fn replan_with_feedback(
        &mut self,
        mut requests: Vec<StreamRequest>,
        controller: &crate::server::feedback::FeedbackController,
    ) -> Result<(MigrationReport, usize)> {
        let changed = controller.apply(&mut requests);
        let report = self.replan(requests)?;
        Ok((report, changed))
    }
}

/// Compute the migration diff between an (optional) deployed plan and its
/// successor — the accounting core of [`AdaptiveManager::replan`], shared
/// with the per-shard re-plans in [`shard`](super::shard). Fills everything
/// except the portfolio fields (`winner`/`winner_flipped`), which only the
/// caller's context knows.
pub(crate) fn migration_diff(
    old: Option<(&[StreamRequest], &Plan)>,
    new_requests: &[StreamRequest],
    new_plan: &Plan,
) -> MigrationReport {
    let mut report = MigrationReport {
        cost_after: new_plan.cost_per_hour,
        pipeline: new_plan.pipeline.clone(),
        ..Default::default()
    };
    if let Some((old_requests, old_plan)) = old {
        report.cost_before = old_plan.cost_per_hour;
        // Per-instance pairing: which old instance survives as which
        // new one. Unpaired news are provisions, unpaired olds are
        // terminations — no label-census approximation.
        let pair = pair_instances(old_plan, new_plan);
        report.kept = pair.iter().flatten().count();
        let mut new_paired = vec![false; new_plan.instances.len()];
        for &ni in pair.iter().flatten() {
            new_paired[ni] = true;
        }
        let mut provision: BTreeMap<String, usize> = BTreeMap::new();
        for (ni, inst) in new_plan.instances.iter().enumerate() {
            if !new_paired[ni] {
                *provision.entry(inst.label.clone()).or_insert(0) += 1;
            }
        }
        report.provision = provision.into_iter().collect();
        let mut terminate: BTreeMap<String, usize> = BTreeMap::new();
        for (oi, inst) in old_plan.instances.iter().enumerate() {
            if pair[oi].is_none() {
                *terminate.entry(inst.label.clone()).or_insert(0) += 1;
            }
        }
        report.terminate = terminate.into_iter().collect();
        // Stream moves, by full stream identity (camera + program + fps
        // tier + occurrence): a surviving stream moved iff its new host
        // is not the instance its old host survives as.
        let old_keys = stream_keys(old_requests);
        let new_keys = stream_keys(new_requests);
        let mut old_host: HashMap<_, usize> = HashMap::new();
        for (oi, inst) in old_plan.instances.iter().enumerate() {
            for &s in &inst.streams {
                old_host.insert(old_keys[s], oi);
            }
        }
        for (ni, inst) in new_plan.instances.iter().enumerate() {
            for &s in &inst.streams {
                if let Some(&oi) = old_host.get(&new_keys[s]) {
                    report.streams_surviving += 1;
                    if pair[oi] != Some(ni) {
                        report.streams_moved += 1;
                    }
                }
            }
        }
    } else {
        // Cold start: everything is a provision.
        for (label, n) in census(new_plan) {
            report.provision.push((label, n));
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::{camera_at, StreamRequest};
    use crate::catalog::Catalog;
    use crate::coordinator::PlannerConfig;
    use crate::geo::cities;
    use crate::profiles::{Program, Resolution};

    fn planner() -> Planner {
        let catalog =
            Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        Planner::new(catalog, PlannerConfig::st3())
    }

    fn workload(fps: f64, n: usize) -> Vec<StreamRequest> {
        (0..n)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                    Program::Zf,
                    fps,
                )
            })
            .collect()
    }

    #[test]
    fn cold_start_provisions_everything() {
        let mut mgr = AdaptiveManager::new(planner());
        let report = mgr.replan(workload(0.5, 4)).unwrap();
        assert!(report.provision.iter().map(|(_, n)| n).sum::<usize>() >= 1);
        assert!(report.terminate.is_empty());
        assert_eq!(report.cost_before, 0.0);
        assert!(report.cost_after > 0.0);
        assert!(!report.pipeline.warm_started, "first plan has no seed");
    }

    #[test]
    fn rush_hour_scales_up_then_down() {
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(workload(0.5, 4)).unwrap();
        let calm_cost = mgr.current_plan().unwrap().cost_per_hour;

        // Rush hour: 8 fps requires GPUs -> cost rises, instances provisioned.
        let up = mgr.replan(workload(8.0, 4)).unwrap();
        assert!(up.cost_delta() > 0.0);
        assert!(!up.provision.is_empty());

        // Calm again: cost returns, terminations issued.
        let down = mgr.replan(workload(0.5, 4)).unwrap();
        assert!(down.cost_delta() < 0.0);
        assert!(!down.terminate.is_empty());
        assert!((mgr.current_plan().unwrap().cost_per_hour - calm_cost).abs() < 1e-9);
    }

    #[test]
    fn identical_workload_is_stable() {
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(workload(1.0, 6)).unwrap();
        let report = mgr.replan(workload(1.0, 6)).unwrap();
        assert!(report.provision.is_empty(), "{report:?}");
        assert!(report.terminate.is_empty(), "{report:?}");
        assert_eq!(report.cost_delta(), 0.0);
        assert_eq!(report.streams_moved, 0, "sticky re-plan must not move streams");
        assert_eq!(report.streams_surviving, 6);
        assert_eq!(report.churn_ratio(), 0.0);
        assert_eq!(report.kept, mgr.current_plan().unwrap().instances.len());
        assert_eq!(report.winner, Some(super::Candidate::Main));
        assert!(!report.winner_flipped, "a single-strategy manager never flips");
        assert!(report.pipeline.warm_started, "second re-plan must warm-start");
        assert_eq!(
            report.pipeline.front_unchanged,
            6,
            "identical re-plan must reuse every request's front-end state"
        );
    }

    #[test]
    fn same_camera_fps_tiers_are_tracked_as_distinct_streams() {
        // Regression: move accounting used to key streams by (camera id,
        // program), so two tiers of the same camera+program collided in the
        // host map and the second silently shadowed the first.
        let tiers = || -> Vec<StreamRequest> {
            let cam = camera_at(0, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0);
            vec![
                StreamRequest::new(cam.clone(), Program::Zf, 0.5),
                StreamRequest::new(cam.clone(), Program::Zf, 1.0),
                StreamRequest::new(cam, Program::Zf, 1.0), // exact duplicate
            ]
        };
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(tiers()).unwrap();
        let report = mgr.replan(tiers()).unwrap();
        assert_eq!(report.streams_surviving, 3, "all tiers + duplicates tracked");
        assert_eq!(report.streams_moved, 0);
    }

    #[test]
    fn departure_moves_at_most_the_packing_diff() {
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(workload(1.0, 6)).unwrap();
        // One camera leaves; the five survivors may consolidate, but a
        // sticky re-plan must not re-deal all of them.
        let report = mgr.replan(workload(1.0, 5)).unwrap();
        assert_eq!(report.streams_surviving, 5);
        assert!(
            report.streams_moved < 5,
            "sticky expand re-dealt every surviving stream: {report:?}"
        );
    }

    #[test]
    fn camera_departure_releases_capacity() {
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(workload(8.0, 6)).unwrap();
        let report = mgr.replan(workload(8.0, 2)).unwrap();
        assert!(report.cost_delta() < 0.0);
        assert!(!report.terminate.is_empty());
    }

    #[test]
    fn replan_reports_solver_telemetry_and_delta_reuse() {
        let mut mgr = AdaptiveManager::new(planner());
        mgr.replan(workload(1.0, 6)).unwrap();
        // One camera joins: the single component's subproblem differs by a
        // single count, so the re-plan rides the delta-solve path.
        let report = mgr.replan(workload(1.0, 7)).unwrap();
        let p = &report.pipeline;
        assert_eq!(p.components_exact + p.components_fallback, p.components);
        assert_eq!(p.delta_solve_hits, 1, "{p:?}");
        assert_eq!(mgr.ctx.main.solver.delta_reuses.get(), 1);
        assert!(mgr.ctx.main.solver.subproblems.get() >= 2);
        // The cumulative summary renders (diagnostic surface).
        assert!(mgr.ctx.main.solver.summary().contains("delta=1"));
    }

    #[test]
    fn migration_reports_roll_up_across_shards() {
        let mut a = MigrationReport {
            provision: vec![("cpu@r".to_string(), 2)],
            terminate: vec![("gpu@r".to_string(), 1)],
            kept: 3,
            streams_moved: 1,
            streams_surviving: 10,
            cost_before: 1.0,
            cost_after: 2.0,
            winner: Some(Candidate::Main),
            ..Default::default()
        };
        let b = MigrationReport {
            provision: vec![("cpu@r".to_string(), 1), ("x@r".to_string(), 4)],
            kept: 2,
            streams_surviving: 5,
            cost_before: 0.5,
            cost_after: 0.25,
            winner: Some(Candidate::Main),
            winner_flipped: true,
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.provision, vec![("cpu@r".to_string(), 3), ("x@r".to_string(), 4)]);
        assert_eq!(a.terminate, vec![("gpu@r".to_string(), 1)]);
        assert_eq!((a.kept, a.streams_moved, a.streams_surviving), (5, 1, 15));
        assert!((a.cost_after - 2.25).abs() < 1e-12);
        assert_eq!(a.winner, Some(Candidate::Main), "agreeing winners survive");
        assert!(a.winner_flipped);
        let c = MigrationReport { winner: Some(Candidate::NearestExact), ..Default::default() };
        a.absorb(&c);
        assert_eq!(a.winner, None, "disagreeing winners clear the roll-up");
    }

    #[test]
    fn warm_and_cold_managers_agree_over_a_demand_swing() {
        let mut warm = AdaptiveManager::new(planner());
        let mut cold = AdaptiveManager::cold(planner());
        for fps in [0.5, 8.0, 8.0, 1.0, 0.5] {
            let w = warm.replan(workload(fps, 5)).unwrap();
            let c = cold.replan(workload(fps, 5)).unwrap();
            assert!(
                (w.cost_after - c.cost_after).abs() < 1e-9,
                "warm {w:?} diverged from cold {c:?} at {fps} fps"
            );
        }
    }
}
