//! Joint live + deferred-backfill planning over the spot market.
//!
//! The live pipeline ([`pipeline`]) keeps its contract untouched: live
//! streams are planned against **on-demand** offerings only — a live stream
//! never lands on revocable capacity, so the live half of a joint plan is
//! bit-identical to what [`Planner::plan_single`](super::Planner::plan_single)
//! would produce. Deferred backfill ([`BackfillQuery`]) rides the temporal
//! axis instead ([`crate::packing::mcvbp::pack_backfill`]): its unit-hours
//! pack first into the slack the live fleet already pays for, then into
//! spot instances at the catalog's discounted quotes, with plain on-demand
//! lanes as the overflow for non-preemptible work.
//!
//! The spot schedule is adopted through a **certified gate**, mirroring the
//! exact-vs-greedy adoption rule in the MCVBP core: the planner always
//! computes the on-demand-only baseline schedule too, and switches to the
//! spot schedule only when it is strictly cheaper without shedding more
//! jobs. `prop_spot_plan_never_costlier_than_on_demand_only` pins exactly
//! this invariant.
//!
//! Revocations are absorbed as a *structural delta*
//! ([`crate::packing::mcvbp::rehome_backfill`], the temporal analogue of the
//! PR-6 ghost path): revoked lanes are ghost-zeroed from the revocation hour
//! on and only the stranded placements move — every surviving placement and
//! the entire on-demand live fleet stay bit-identical.

use super::pipeline::{self, PlanContext};
use super::{HardwareFilter, Plan, PlannerConfig};
use crate::cameras::scenarios::BackfillQuery;
use crate::cameras::StreamRequest;
use crate::catalog::{Catalog, Dims};
use crate::error::Result;
use crate::packing::mcvbp::{
    pack_backfill, rehome_backfill, BackfillItem, BackfillSchedule, LaneKind, TemporalLane,
};

/// Spot/backfill planning knobs, on top of the live [`PlannerConfig`].
#[derive(Clone, Debug)]
pub struct SpotPlannerConfig {
    /// Length of the temporal axis, in hours from trace start.
    pub horizon_hours: usize,
    /// False disables the spot lanes entirely — the on-demand-only baseline
    /// configuration the bench compares against.
    pub use_spot: bool,
    /// Paid lanes offered per catalog offering (one lane = one instance the
    /// backfill packer may open).
    pub lanes_per_offering: usize,
}

impl Default for SpotPlannerConfig {
    fn default() -> Self {
        SpotPlannerConfig { horizon_hours: 48, use_spot: true, lanes_per_offering: 4 }
    }
}

/// A joint plan: the on-demand live fleet plus the backfill schedule over
/// the temporal lane grid.
#[derive(Clone, Debug)]
pub struct JointPlan {
    /// The live plan — on-demand only, byte-for-byte what the plain
    /// pipeline produces for the same requests.
    pub live: Plan,
    /// The temporal lane grid the schedule indexes into: live-slack lanes
    /// first (aligned with `live.instances`), then the paid lanes.
    pub lanes: Vec<TemporalLane>,
    /// Catalog (type, region) behind each paid lane; `None` for live slack.
    pub lane_offerings: Vec<Option<(usize, usize)>>,
    /// The adopted backfill schedule.
    pub schedule: BackfillSchedule,
    /// Cost of the adopted schedule's paid lane-hours.
    pub backfill_cost: f64,
    /// Cost of the certified on-demand-only baseline schedule.
    pub baseline_cost: f64,
    /// True when the spot schedule passed the gate (strictly cheaper, no
    /// extra shedding) and was adopted over the baseline.
    pub spot_adopted: bool,
}

impl JointPlan {
    /// Hourly cost of the paid lanes occupied during `hour` — the billing
    /// integrand the simulator accrues.
    pub fn paid_cost_at(&self, hour: usize) -> f64 {
        let mut lanes: Vec<usize> = self
            .schedule
            .placements
            .iter()
            .filter(|p| p.hour == hour)
            .map(|p| p.lane)
            .collect();
        lanes.sort_unstable();
        lanes.dedup();
        lanes.iter().map(|&l| self.lanes[l].hourly_cost).sum()
    }
}

/// The joint live + backfill planner. Owns the persistent [`PlanContext`]
/// so hourly live re-plans stay sticky and incremental.
pub struct SpotPlanner {
    pub catalog: Catalog,
    pub config: PlannerConfig,
    pub spot: SpotPlannerConfig,
    ctx: PlanContext,
}

impl SpotPlanner {
    pub fn new(catalog: Catalog, config: PlannerConfig, spot: SpotPlannerConfig) -> Self {
        SpotPlanner { catalog, config, spot, ctx: PlanContext::new() }
    }

    /// Quantize queries into temporal work items: scanning one hour of
    /// stored footage at the query's sampling rate is one unit-hour of work
    /// at the program's CPU-path demand, the deadline is absolute (trace
    /// hours), and the preemptible flag rides through.
    pub fn items_from_queries(queries: &[BackfillQuery]) -> Vec<BackfillItem> {
        queries
            .iter()
            .map(|q| BackfillItem {
                id: q.id,
                demand: q.program.profile().demand_cpu(q.scan_fps, q.camera.resolution),
                units: (q.span_hours.ceil() as usize).max(1),
                deadline_hour: q.arrival_hour + (q.deadline_hours.floor() as usize).max(1),
                preemptible: q.preemptible,
            })
            .collect()
    }

    /// Plan both job classes for the state at `now_hour`: the live fleet
    /// through the sticky pipeline, then backfill over the temporal grid
    /// (slack + paid lanes, all starting at `now_hour`). The spot schedule
    /// is adopted only through the certified gate against the
    /// on-demand-only baseline.
    pub fn plan(
        &mut self,
        requests: &[StreamRequest],
        items: &[BackfillItem],
        now_hour: usize,
    ) -> Result<JointPlan> {
        let live =
            pipeline::plan_with_context(&self.catalog, &self.config, requests, &mut self.ctx)?;
        let horizon = self.spot.horizon_hours;

        // Live-slack lanes, aligned with live.instances (expand builds one
        // instance per packed bin, index-aligned).
        let mut slack_lanes = Vec::with_capacity(live.instances.len());
        for (i, inst) in live.instances.iter().enumerate() {
            let cap = self.catalog.types[inst.type_idx].capacity.scale(self.config.headroom);
            let load = live.packing.bins[i].total_demand(&live.problem);
            let cap = cap.as_array();
            let load = load.as_array();
            let mut free = [0.0; crate::catalog::NUM_DIMS];
            for d in 0..free.len() {
                free[d] = (cap[d] - load[d]).max(0.0);
            }
            slack_lanes.push(TemporalLane {
                label: inst.label.clone(),
                kind: LaneKind::LiveSlack,
                usable: Dims::from_array(free),
                hourly_cost: 0.0,
                from_hour: now_hour,
            });
        }

        let (spot_paid, od_paid) = self.paid_lanes(now_hour);

        // On-demand-only baseline: slack + on-demand lanes.
        let mut base_lanes = slack_lanes.clone();
        let base_paid_start = base_lanes.len();
        base_lanes.extend(od_paid.iter().map(|(l, _)| l.clone()));
        let baseline = pack_backfill(&base_lanes, items, horizon);

        // Spot-enabled: slack + spot lanes + on-demand overflow (the only
        // paid capacity non-preemptible items may use).
        let adopt_spot = if self.spot.use_spot {
            let mut lanes = slack_lanes.clone();
            lanes.extend(spot_paid.iter().map(|(l, _)| l.clone()));
            lanes.extend(od_paid.iter().map(|(l, _)| l.clone()));
            let schedule = pack_backfill(&lanes, items, horizon);
            // Certified gate: strictly cheaper, and no extra shedding.
            if schedule.cost < baseline.cost && schedule.shed.len() <= baseline.shed.len() {
                let mut lane_offerings: Vec<Option<(usize, usize)>> =
                    vec![None; slack_lanes.len()];
                lane_offerings.extend(spot_paid.iter().map(|&(_, o)| Some(o)));
                lane_offerings.extend(od_paid.iter().map(|&(_, o)| Some(o)));
                Some((lanes, lane_offerings, schedule))
            } else {
                None
            }
        } else {
            None
        };

        let baseline_cost = baseline.cost;
        let (lanes, lane_offerings, schedule, spot_adopted) = match adopt_spot {
            Some((lanes, offs, schedule)) => (lanes, offs, schedule, true),
            None => {
                let mut offs: Vec<Option<(usize, usize)>> = vec![None; base_paid_start];
                offs.extend(od_paid.iter().map(|&(_, o)| Some(o)));
                (base_lanes, offs, baseline, false)
            }
        };
        let backfill_cost = schedule.cost;
        Ok(JointPlan {
            live,
            lanes,
            lane_offerings,
            schedule,
            backfill_cost,
            baseline_cost,
            spot_adopted,
        })
    }

    /// Absorb a revocation storm: ghost-zero the revoked lanes from `hour`
    /// on and re-home only the stranded placements. The live fleet is not
    /// consulted, let alone touched. Returns the repaired schedule and the
    /// moved item ids.
    pub fn absorb_revocation(
        &self,
        plan: &JointPlan,
        items: &[BackfillItem],
        revoked_lanes: &[usize],
        hour: usize,
    ) -> (BackfillSchedule, Vec<u64>) {
        rehome_backfill(
            &plan.lanes,
            items,
            &plan.schedule,
            revoked_lanes,
            hour,
            self.spot.horizon_hours,
        )
    }

    /// The paid lane candidates at `now_hour`: `lanes_per_offering` copies
    /// per hardware-eligible offering — spot lanes (risk-discounted usable
    /// capacity, quoted price) and on-demand lanes (full usable capacity,
    /// listed price). Catalog order keeps the grid deterministic.
    #[allow(clippy::type_complexity)]
    fn paid_lanes(
        &self,
        now_hour: usize,
    ) -> (Vec<(TemporalLane, (usize, usize))>, Vec<(TemporalLane, (usize, usize))>) {
        let mut spot = Vec::new();
        let mut od = Vec::new();
        for o in &self.catalog.offerings {
            let ty = &self.catalog.types[o.type_idx];
            let allowed = match self.config.hardware {
                HardwareFilter::CpuOnly => !ty.has_gpu(),
                HardwareFilter::GpuOnly => ty.has_gpu(),
                HardwareFilter::Both => true,
            };
            if !allowed {
                continue;
            }
            let label =
                format!("{}@{}", ty.name, self.catalog.regions[o.region_idx].id);
            let usable = ty.capacity.scale(self.config.headroom);
            for _ in 0..self.spot.lanes_per_offering {
                od.push((
                    TemporalLane {
                        label: label.clone(),
                        kind: LaneKind::OnDemand,
                        usable,
                        hourly_cost: o.hourly_usd,
                        from_hour: now_hour,
                    },
                    (o.type_idx, o.region_idx),
                ));
                if let Some(q) = o.spot {
                    spot.push((
                        TemporalLane {
                            label: label.clone(),
                            kind: LaneKind::Spot,
                            usable: usable.scale(1.0 - q.preemption_rate_per_hour),
                            hourly_cost: q.hourly_usd,
                            from_hour: now_hour,
                        },
                        (o.type_idx, o.region_idx),
                    ));
                }
            }
        }
        (spot, od)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::camera_at;
    use crate::cameras::scenarios::{diurnal_backfill, flash_crowd_backfill};
    use crate::geo::cities;
    use crate::profiles::{Program, Resolution};

    fn small_catalog() -> Catalog {
        Catalog::builtin().restrict(Some(&["c4.2xlarge"]), Some(&["us-east-2"]))
    }

    fn live_requests(n: usize) -> Vec<StreamRequest> {
        (0..n)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::XGA, 30.0),
                    Program::Zf,
                    0.5,
                )
            })
            .collect()
    }

    #[test]
    fn live_fleet_never_lands_on_spot() {
        let catalog = small_catalog();
        let mut p = SpotPlanner::new(catalog.clone(), PlannerConfig::st1(), Default::default());
        let items = SpotPlanner::items_from_queries(&diurnal_backfill(20, 5));
        let jp = p.plan(&live_requests(3), &items, 0).unwrap();
        let od = catalog.price(0, 0).unwrap();
        for inst in &jp.live.instances {
            assert_eq!(inst.hourly_cost, od, "live instance billed off the on-demand sheet");
        }
        // Slack lanes mirror the live fleet one-to-one and are free.
        let slack = jp.lanes.iter().filter(|l| l.kind == LaneKind::LiveSlack).count();
        assert_eq!(slack, jp.live.instances.len());
        assert!(jp
            .lanes
            .iter()
            .filter(|l| l.kind == LaneKind::LiveSlack)
            .all(|l| l.hourly_cost == 0.0));
    }

    #[test]
    fn certified_gate_never_adopts_a_costlier_spot_schedule() {
        let catalog = small_catalog();
        let mut p = SpotPlanner::new(catalog, PlannerConfig::st1(), Default::default());
        let items = SpotPlanner::items_from_queries(&diurnal_backfill(40, 11));
        let jp = p.plan(&live_requests(2), &items, 0).unwrap();
        assert!(jp.backfill_cost <= jp.baseline_cost + 1e-9);
        if jp.spot_adopted {
            assert!(jp.backfill_cost < jp.baseline_cost);
        }
    }

    #[test]
    fn joint_plan_respects_deadlines_or_sheds_explicitly() {
        let catalog = small_catalog();
        let mut p = SpotPlanner::new(catalog, PlannerConfig::st1(), Default::default());
        let queries = flash_crowd_backfill(25, 2, 9);
        let items = SpotPlanner::items_from_queries(&queries);
        let jp = p.plan(&live_requests(2), &items, 0).unwrap();
        for item in &items {
            let placed =
                jp.schedule.placements.iter().filter(|pl| pl.item == item.id).count();
            if jp.schedule.shed.contains(&item.id) {
                assert_eq!(placed, 0, "shed item {} holds capacity", item.id);
            } else {
                assert_eq!(placed, item.units, "item {} under-scheduled", item.id);
                assert!(jp
                    .schedule
                    .placements
                    .iter()
                    .filter(|pl| pl.item == item.id)
                    .all(|pl| pl.hour < item.deadline_hour));
            }
        }
    }

    #[test]
    fn non_preemptible_overflow_uses_on_demand_lanes() {
        let catalog = small_catalog();
        let mut p = SpotPlanner::new(catalog, PlannerConfig::st1(), Default::default());
        // No live fleet slack to hide in: tiny live load, heavy
        // non-preemptible backfill.
        let mut queries = diurnal_backfill(30, 3);
        for q in &mut queries {
            q.preemptible = false;
            q.arrival_hour = 0;
        }
        let items = SpotPlanner::items_from_queries(&queries);
        let jp = p.plan(&live_requests(1), &items, 0).unwrap();
        for pl in &jp.schedule.placements {
            assert_ne!(
                jp.lanes[pl.lane].kind,
                LaneKind::Spot,
                "non-preemptible unit on a spot lane"
            );
        }
    }
}
