//! The GCL planning **portfolio** as a unified runtime.
//!
//! The GCL configuration continuously re-selects the cheapest of three
//! candidate strategies ([`Planner::plan_with`]): the exact RTT-filtered
//! solve (the paper's GCL), the ARMVAC greedy fill over the same
//! eligibility, and the nearest-location exact solve. Before PR 5 the three
//! candidates were fully independent [`PlanContext`]s — each owned its own
//! solve-worker pool and its own budget slack, and each chained its own
//! stream→slot assignment, so a (rare) winner flip restarted slots fresh
//! and re-dealt the fleet even when the flipped-to plan was shape-identical
//! to the deployed one. This module owns the shared runtime instead:
//!
//! * **one worker pool** — a [`PoolSlot`] installed into all three
//!   contexts, so every candidate's parallel per-region solves share a
//!   single set of parked threads (spawned lazily by whichever candidate
//!   needs them first),
//! * **one arc-flow graph cache** — a [`GraphCache`] installed into all
//!   three contexts. The cache is content-addressed (capacity grid +
//!   quantized item list), and the candidates solve the *same workload*
//!   under eligibility variations, so most of their per-bin-type graphs
//!   coincide — whichever candidate builds a graph first, the other two
//!   get it as a hit instead of re-running the compression,
//! * **one cross-candidate budget pool** ([`SharedBudgetPool`]) — each
//!   candidate's allocation publishes its leftover predicted slack
//!   (`budget::allocate_pooled`), and the other candidates draw on it next
//!   round. In practice the nearest-exact alternate solves a restricted
//!   (cheaper) problem, so its donated slack funds the main exact solve —
//!   the cross-strategy amortization argument of Chameleon (Jiang et al.)
//!   applied to solver budgets,
//! * **winner-flip slot continuity** — after every re-plan the *winning*
//!   candidate's stream→slot assignment is seeded into all three contexts,
//!   so whichever candidate wins the next round expands against the
//!   deployed fleet. An unchanged workload therefore yields zero
//!   provision/terminate across a forced winner flip, and identical plans
//!   keep identical instance ids end to end (`CloudSim::apply_plan`
//!   reconciles by the same slot ids).
//!
//! None of this changes plan *costs* where exact phases complete: pooled
//! budgets only grow (floored at the static seed, and an exact optimum is
//! budget-independent), assignment seeding changes which concrete stream
//! lands on which concrete instance but never the packing, and the worker
//! pool is pure mechanism — so portfolio plans stay bit-identical to the
//! three-independent-contexts baseline wherever exact phases complete
//! (property-tested, together with the flip-churn invariants, in
//! `tests/properties.rs`).
//!
//! [`PlanContext`]: super::pipeline::PlanContext
//! [`Planner::plan_with`]: super::Planner::plan_with
//! [`PoolSlot`]: crate::util::pool::PoolSlot

use super::budget::AxisSlack;
use super::pipeline::{plan_with_pool, PlanContext};
use super::{LocationPolicy, Plan, Planner, PlannerConfig, SolverKind};
use crate::cameras::StreamRequest;
use crate::error::Result;
use crate::packing::arcflow::GraphCache;
use crate::util::pool::PoolSlot;
use std::sync::Arc;

/// One candidate strategy of the GCL portfolio.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Candidate {
    /// The configured strategy itself (GCL: RTT-filtered + exact).
    Main,
    /// ARMVAC's cheapest-instance greedy fill over the same RTT-filtered
    /// eligibility.
    RttGreedy,
    /// Nearest-location exact solve.
    NearestExact,
}

impl Candidate {
    pub const ALL: [Candidate; 3] =
        [Candidate::Main, Candidate::RttGreedy, Candidate::NearestExact];

    fn index(self) -> usize {
        match self {
            Candidate::Main => 0,
            Candidate::RttGreedy => 1,
            Candidate::NearestExact => 2,
        }
    }
}

/// Cross-candidate budget pool: the slack each candidate's most recent
/// allocation published. A candidate allocating budgets draws on the
/// *other* candidates' donations — never its own, which is already part of
/// its internal pool. Donations are replaced wholesale every time a
/// candidate plans, so a stale entry (e.g. published under an old catalog)
/// survives at most one re-plan; slack is structural (graph nodes, ILP
/// sizes), not price-dependent, so even that round is merely conservative.
#[derive(Clone, Copy, Debug, Default)]
pub struct SharedBudgetPool {
    donated: [AxisSlack; 3],
}

impl SharedBudgetPool {
    /// The share available to `who` this round: the other candidates' last
    /// published donations, summed.
    pub fn available_for(&self, who: Candidate) -> AxisSlack {
        let mut sum = AxisSlack::default();
        for c in Candidate::ALL {
            if c != who {
                sum = sum.plus(&self.donated[c.index()]);
            }
        }
        sum
    }

    /// Record the slack `who`'s latest allocation left over.
    pub fn publish(&mut self, who: Candidate, slack: AxisSlack) {
        self.donated[who.index()] = slack;
    }
}

/// Portfolio planning state for [`Planner::plan_with`]: one pipeline
/// context per candidate plus the shared runtime — the worker-pool slot all
/// three contexts solve on, the cross-candidate budget pool, and the
/// winner bookkeeping behind flip continuity.
///
/// [`Planner::plan_with`]: super::Planner::plan_with
pub struct ReplanContext {
    pub main: PlanContext,
    pub alt_rtt_greedy: PlanContext,
    pub alt_nearest_exact: PlanContext,
    /// Cross-candidate donated budget slack (see [`SharedBudgetPool`]).
    pub budget_pool: SharedBudgetPool,
    /// The candidate whose plan won the most recent re-plan.
    pub last_winner: Option<Candidate>,
    /// Winner changes observed across consecutive re-plans.
    pub winner_flips: u64,
}

impl Default for ReplanContext {
    fn default() -> Self {
        ReplanContext::new()
    }
}

impl ReplanContext {
    pub fn new() -> Self {
        // One worker-pool slot shared by every candidate: whichever context
        // solves in parallel first spawns the threads all of them reuse.
        // Likewise one graph cache: the candidates pack the same workload,
        // so a graph any of them compresses is a hit for the other two
        // (and it survives candidate-local signature clears).
        let slot = Arc::new(PoolSlot::new());
        let graphs = Arc::new(GraphCache::new());
        let mut main = PlanContext::new();
        let mut alt_rtt_greedy = PlanContext::new();
        let mut alt_nearest_exact = PlanContext::new();
        main.share_pool(Arc::clone(&slot));
        alt_rtt_greedy.share_pool(Arc::clone(&slot));
        alt_nearest_exact.share_pool(slot);
        main.share_graphs(Arc::clone(&graphs));
        alt_rtt_greedy.share_graphs(Arc::clone(&graphs));
        alt_nearest_exact.share_graphs(graphs);
        ReplanContext {
            main,
            alt_rtt_greedy,
            alt_nearest_exact,
            budget_pool: SharedBudgetPool::default(),
            last_winner: None,
            winner_flips: 0,
        }
    }

    /// Total jobs the candidates have dispatched to the shared worker pool
    /// (the cumulative `pool_jobs` roll-up across all three contexts —
    /// they share one pool, so this is that pool's job count).
    pub fn pool_shared_jobs(&self) -> u64 {
        self.main.solver.pool_jobs.get()
            + self.alt_rtt_greedy.solver.pool_jobs.get()
            + self.alt_nearest_exact.solver.pool_jobs.get()
    }

    /// Total arc-flow node budget the candidates have drawn from the
    /// cross-candidate pool (beyond their isolated allocations).
    pub fn budget_pooled_donated(&self) -> u64 {
        self.main.solver.budget_pooled_donated.get()
            + self.alt_rtt_greedy.solver.budget_pooled_donated.get()
            + self.alt_nearest_exact.solver.budget_pooled_donated.get()
    }

    fn ctx_of(&self, who: Candidate) -> &PlanContext {
        match who {
            Candidate::Main => &self.main,
            Candidate::RttGreedy => &self.alt_rtt_greedy,
            Candidate::NearestExact => &self.alt_nearest_exact,
        }
    }

    /// Fleet-level solver telemetry: the three candidate contexts' counters
    /// absorbed into one fresh [`SolverMetrics`](crate::metrics::SolverMetrics).
    pub fn solver_rollup(&self) -> crate::metrics::SolverMetrics {
        let total = crate::metrics::SolverMetrics::new();
        total.absorb(&self.main.solver);
        total.absorb(&self.alt_rtt_greedy.solver);
        total.absorb(&self.alt_nearest_exact.solver);
        total
    }
}

/// Run one portfolio re-plan through `ctx` and return the cheapest
/// candidate's plan (strictly-cheaper alternates win; ties keep the main
/// strategy, so an exact-complete GCL never flips away).
///
/// Non-portfolio configurations (anything but RTT-filtered + exact) plan
/// only the main context — exactly [`plan_with_context`]'s semantics.
///
/// [`plan_with_context`]: super::pipeline::plan_with_context
pub fn plan(
    planner: &Planner,
    requests: &[StreamRequest],
    ctx: &mut ReplanContext,
) -> Result<Plan> {
    plan_with_slack(planner, requests, ctx, AxisSlack::default())
}

/// [`plan`] with an `external` cross-**shard** budget share: the slack the
/// other shards' ledger entries donate is added to the main candidate's
/// pool input (`budget::allocate_pooled` floors every component at the
/// static seed, so a zero share reproduces [`plan`] exactly). Only the main
/// candidate draws the cross-shard share — the alternates keep drawing the
/// in-context cross-candidate pool, so the ledger's donation is never
/// double-counted inside one portfolio round.
pub fn plan_with_slack(
    planner: &Planner,
    requests: &[StreamRequest],
    ctx: &mut ReplanContext,
    external: AxisSlack,
) -> Result<Plan> {
    let pool_in = ctx.budget_pool.available_for(Candidate::Main).plus(&external);
    let mut best =
        plan_with_pool(&planner.catalog, &planner.config, requests, &mut ctx.main, pool_in)?;
    ctx.budget_pool.publish(Candidate::Main, ctx.main.pool_out);
    let mut winner = Candidate::Main;

    if planner.config.location == LocationPolicy::RttFiltered
        && planner.config.solver == SolverKind::Exact
    {
        let alts: [(Candidate, &mut PlanContext, LocationPolicy, SolverKind); 2] = [
            (
                Candidate::RttGreedy,
                &mut ctx.alt_rtt_greedy,
                LocationPolicy::RttFiltered,
                SolverKind::ArmvacGreedy,
            ),
            (
                Candidate::NearestExact,
                &mut ctx.alt_nearest_exact,
                LocationPolicy::NearestOnly,
                SolverKind::Exact,
            ),
        ];
        for (cand, alt_ctx, location, solver) in alts {
            let alt_config = PlannerConfig {
                hardware: planner.config.hardware,
                location,
                solver,
                headroom: planner.config.headroom,
                solve_opts: planner.config.solve_opts.clone(),
                parallel_regions: planner.config.parallel_regions,
            };
            let pool_in = ctx.budget_pool.available_for(cand);
            match plan_with_pool(&planner.catalog, &alt_config, requests, alt_ctx, pool_in) {
                Ok(p) => {
                    ctx.budget_pool.publish(cand, alt_ctx.pool_out);
                    if p.cost_per_hour < best.cost_per_hour {
                        best = p;
                        winner = cand;
                    }
                }
                // A failing candidate donates nothing this round — without
                // this, its last successful round's slack would linger in
                // the pool indefinitely (the one-round-staleness invariant
                // the pool's documentation promises).
                Err(_) => ctx.budget_pool.publish(cand, AxisSlack::default()),
            }
        }

        // Winner-flip slot continuity: the winner's plan is what gets
        // deployed, so every candidate's next Expand must match against
        // *its* assignment — not the private chain each context grew on its
        // own. With this seed, a flip onto a shape-identical plan
        // reproduces the previous fleet assignment bit for bit. The winner
        // already holds its own assignment, so only the two losers are
        // (re)seeded — the assignment is fleet-sized.
        if let Some(assign) = ctx.ctx_of(winner).assignment().cloned() {
            match winner {
                Candidate::Main => {
                    ctx.alt_rtt_greedy.seed_assignment(assign.clone());
                    ctx.alt_nearest_exact.seed_assignment(assign);
                }
                Candidate::RttGreedy => {
                    ctx.main.seed_assignment(assign.clone());
                    ctx.alt_nearest_exact.seed_assignment(assign);
                }
                Candidate::NearestExact => {
                    ctx.main.seed_assignment(assign.clone());
                    ctx.alt_rtt_greedy.seed_assignment(assign);
                }
            }
        }
        if let Some(prev) = ctx.last_winner {
            if prev != winner {
                ctx.winner_flips += 1;
            }
        }
    }
    ctx.last_winner = Some(winner);
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::{camera_at, StreamRequest};
    use crate::catalog::Catalog;
    use crate::geo::cities;
    use crate::profiles::{Program, Resolution};

    fn worldwide_requests() -> Vec<StreamRequest> {
        let mut reqs = Vec::new();
        for (i, city) in [cities::CHICAGO, cities::NEW_YORK].iter().enumerate() {
            reqs.push(StreamRequest::new(
                camera_at(i as u64, "us", *city, Resolution::VGA, 30.0),
                Program::Zf,
                15.0,
            ));
        }
        reqs.push(StreamRequest::new(
            camera_at(100, "asia", cities::TOKYO, Resolution::VGA, 30.0),
            Program::Zf,
            15.0,
        ));
        reqs
    }

    #[test]
    fn contexts_share_one_worker_pool_slot() {
        let ctx = ReplanContext::new();
        assert!(Arc::ptr_eq(ctx.main.pool_slot(), ctx.alt_rtt_greedy.pool_slot()));
        assert!(Arc::ptr_eq(ctx.main.pool_slot(), ctx.alt_nearest_exact.pool_slot()));
        assert!(!ctx.main.pool_slot().spawned(), "pool must stay lazy until a solve");
    }

    #[test]
    fn contexts_share_one_graph_cache() {
        let ctx = ReplanContext::new();
        assert!(Arc::ptr_eq(ctx.main.graph_cache(), ctx.alt_rtt_greedy.graph_cache()));
        assert!(Arc::ptr_eq(ctx.main.graph_cache(), ctx.alt_nearest_exact.graph_cache()));
    }

    #[test]
    fn graph_cache_identity_survives_planning() {
        // Planning installs each context's signature (clearing its caches);
        // the shared graph cache must keep its identity through that — and
        // the candidates' combined builds must land in the one cache.
        let planner =
            Planner::new(Catalog::builtin(), crate::coordinator::PlannerConfig::gcl());
        let mut ctx = ReplanContext::new();
        let before = Arc::clone(ctx.main.graph_cache());
        plan(&planner, &worldwide_requests(), &mut ctx).unwrap();
        assert!(Arc::ptr_eq(&before, ctx.main.graph_cache()));
        assert!(Arc::ptr_eq(ctx.main.graph_cache(), ctx.alt_rtt_greedy.graph_cache()));
        assert!(Arc::ptr_eq(ctx.main.graph_cache(), ctx.alt_nearest_exact.graph_cache()));
        let (_, misses) = ctx.main.graph_cache().stats();
        assert!(misses > 0, "the candidates' graph builds land in the one cache");
    }

    #[test]
    fn shared_pool_excludes_own_donation() {
        let mut pool = SharedBudgetPool::default();
        let a = AxisSlack { graph_nodes: 100, milp_vars: 10, milp_nodes: 20 };
        let b = AxisSlack { graph_nodes: 7, milp_vars: 1, milp_nodes: 2 };
        pool.publish(Candidate::Main, a);
        pool.publish(Candidate::NearestExact, b);
        assert_eq!(pool.available_for(Candidate::RttGreedy), a.plus(&b));
        assert_eq!(pool.available_for(Candidate::Main), b, "own slack excluded");
        assert_eq!(pool.available_for(Candidate::NearestExact), a);
        // Re-publishing replaces, not accumulates.
        pool.publish(Candidate::Main, AxisSlack::default());
        assert_eq!(pool.available_for(Candidate::NearestExact), AxisSlack::default());
    }

    #[test]
    fn portfolio_replan_runs_all_candidates_on_the_shared_pool() {
        let planner =
            Planner::new(Catalog::builtin(), crate::coordinator::PlannerConfig::gcl());
        let mut ctx = ReplanContext::new();
        let requests = worldwide_requests();
        let p = plan(&planner, &requests, &mut ctx).unwrap();
        assert!(p.cost_per_hour > 0.0);
        assert_eq!(ctx.last_winner, Some(Candidate::Main), "exact GCL wins ties");
        assert_eq!(ctx.winner_flips, 0);
        // Two RTT-disjoint clusters => every candidate dispatched >= 2 jobs
        // to the one shared pool.
        assert!(ctx.main.pool_slot().spawned());
        assert!(
            ctx.pool_shared_jobs() >= 6,
            "three candidates x two components: {}",
            ctx.pool_shared_jobs()
        );
    }

    #[test]
    fn winner_assignment_is_seeded_into_every_candidate() {
        let planner =
            Planner::new(Catalog::builtin(), crate::coordinator::PlannerConfig::gcl());
        let mut ctx = ReplanContext::new();
        let requests = worldwide_requests();
        plan(&planner, &requests, &mut ctx).unwrap();
        let main = ctx.main.assignment().expect("winner assignment seeded");
        for alt in [&ctx.alt_rtt_greedy, &ctx.alt_nearest_exact] {
            let a = alt.assignment().expect("alternates seeded too");
            assert_eq!(a.slots.len(), main.slots.len());
            for (x, y) in a.slots.iter().zip(&main.slots) {
                assert_eq!(x.slot_id, y.slot_id);
                assert_eq!(x.label, y.label);
                assert_eq!(x.streams, y.streams);
            }
        }
    }

    #[test]
    fn non_portfolio_config_plans_main_only() {
        let catalog = Catalog::builtin()
            .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let planner = Planner::new(catalog, crate::coordinator::PlannerConfig::st3());
        let mut ctx = ReplanContext::new();
        let requests = vec![StreamRequest::new(
            camera_at(0, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
            Program::Zf,
            1.0,
        )];
        plan(&planner, &requests, &mut ctx).unwrap();
        assert_eq!(ctx.last_winner, Some(Candidate::Main));
        assert!(ctx.alt_rtt_greedy.assignment().is_none(), "alternates untouched");
        assert!(ctx.alt_nearest_exact.assignment().is_none());
    }
}
