//! Pipeline stage 4 — **Expand**: turn the solved packing's per-group counts
//! into per-instance stream assignments for the serving layer.
//!
//! The expansion is **sticky**: when a previous plan's assignment is
//! available (threaded through the
//! [`PlanContext`](super::pipeline::PlanContext)), the new assignment is
//! computed as a matching against the old one. Every new bin is paired with
//! the previous slot of the same instance type + region that shares the
//! most surviving streams; paired bins inherit the slot's stable
//! [`SlotId`] and keep each old stream in place as long as the new packing
//! still counts room for its group there. Only the residual — the true
//! packing diff — is placed by greedy transfer from the unassigned queues.
//! A cold expansion (no previous assignment) degenerates to the
//! deterministic request-order deal with fresh slot ids.
//!
//! Without stickiness, every re-plan re-dealt all streams from scratch, so
//! `streams_moved` churned with queue order rather than with the packing
//! diff — and each spurious move is a real reconnection and warm-state loss
//! on the serving layer.
//!
//! The matching target need not come from *this* planner's previous plan:
//! a [`PrevAssignment`] is keyed only by stable stream keys and bin labels
//! ("type@region"), so the portfolio (`coordinator::portfolio`) seeds the
//! **winning** candidate's assignment into every candidate context, and a
//! price update may carry the assignment across a cache clear. Entries the
//! new problem cannot reproduce (departed streams, labels the catalog no
//! longer offers) simply never pair — stale state degrades to the cold
//! deal, never to a wrong assignment.

use super::{PlannedInstance, SlotId};
use crate::cameras::StreamKey;
use crate::error::{Error, Result};
use crate::packing::{Packing, PackingProblem};
use crate::util::fxhash::{FxHashMap, FxHashSet};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide slot id allocator: ids must stay unique across every
/// planning context (the portfolio planner runs several), so surviving and
/// fresh slots can never collide in a fleet reconciliation.
static NEXT_SLOT_ID: AtomicU64 = AtomicU64::new(1);

fn fresh_slot_id() -> SlotId {
    NEXT_SLOT_ID.fetch_add(1, Ordering::Relaxed)
}

/// One slot of the previous plan's assignment: its stable id, the bin label
/// it was provisioned as, and the streams it hosted (by stable key).
#[derive(Clone, Debug)]
pub struct PrevSlot {
    pub slot_id: SlotId,
    /// Bin identity ("type@region") — slots only stick to same-label bins.
    pub label: String,
    pub streams: Vec<StreamKey>,
}

/// The previous plan's stream→instance assignment, kept by the pipeline
/// context so the next Expand can match against it.
#[derive(Clone, Debug, Default)]
pub struct PrevAssignment {
    pub slots: Vec<PrevSlot>,
}

impl PrevAssignment {
    /// Capture an assignment from a finished expansion. `keys[s]` is the
    /// stable identity of request index `s`.
    pub fn capture(instances: &[PlannedInstance], keys: &[StreamKey]) -> Self {
        PrevAssignment {
            slots: instances
                .iter()
                .map(|inst| PrevSlot {
                    slot_id: inst.slot_id,
                    label: inst.label.clone(),
                    streams: inst.streams.iter().map(|&s| keys[s]).collect(),
                })
                .collect(),
        }
    }
}

/// Expand group counts into per-instance stream lists, minimizing movement
/// against `prev` when present.
///
/// `keys[s]` must be the stable identity of request index `s` for every
/// index appearing in `members`. `exact_cert_skipped` is incremented once
/// per label block where greedy demonstrably left overlap on the table but
/// the block was too large for the exact certification pass
/// ([`EXACT_MATCH_CAP`]) — previously a silent skip; the pipeline surfaces
/// it as [`PipelineStats::exact_cert_skipped`](super::pipeline::PipelineStats).
pub fn run(
    problem: &PackingProblem,
    packing: &Packing,
    members: &[Vec<usize>],
    keys: &[StreamKey],
    prev: Option<&PrevAssignment>,
    exact_cert_skipped: &mut usize,
) -> Result<Vec<PlannedInstance>> {
    let nb = packing.bins.len();

    // Group of each request index (dense: members cover indices into
    // `keys`), and stable key → request index.
    let mut group_of: Vec<usize> = vec![usize::MAX; keys.len()];
    let mut key_to_idx: FxHashMap<StreamKey, usize> = FxHashMap::default();
    for (g, mem) in members.iter().enumerate() {
        for &s in mem {
            group_of[s] = g;
            key_to_idx.insert(keys[s], s);
        }
    }

    // Remaining per-group need of each new bin (consumed by kept streams
    // first, then by the transfer queues).
    let mut need: Vec<Vec<usize>> = packing.bins.iter().map(|b| b.counts.clone()).collect();
    let mut kept: Vec<Vec<usize>> = vec![Vec::new(); nb];
    let mut slot_of_bin: Vec<Option<SlotId>> = vec![None; nb];
    let mut placed: FxHashSet<usize> = FxHashSet::default();

    if let Some(prev) = prev {
        // Surviving streams of each previous slot, bucketed by new group.
        let survivors: Vec<FxHashMap<usize, usize>> = prev
            .slots
            .iter()
            .map(|slot| {
                let mut per_group: FxHashMap<usize, usize> = FxHashMap::default();
                for k in &slot.streams {
                    if let Some(&idx) = key_to_idx.get(k) {
                        *per_group.entry(group_of[idx]).or_insert(0) += 1;
                    }
                }
                per_group
            })
            .collect();

        // Slots only ever pair with same-label bins, so the matching
        // decomposes per label (BTreeMap for deterministic label order).
        let mut slots_by_label: std::collections::BTreeMap<&str, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (si, slot) in prev.slots.iter().enumerate() {
            slots_by_label.entry(slot.label.as_str()).or_default().push(si);
        }
        let mut bins_by_label: FxHashMap<&str, Vec<usize>> = FxHashMap::default();
        for (bi, bin) in packing.bins.iter().enumerate() {
            bins_by_label
                .entry(problem.bins[bin.bin_type].label.as_str())
                .or_default()
                .push(bi);
        }

        let mut slot_taken = vec![false; prev.slots.len()];
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        for (label, slots) in &slots_by_label {
            let Some(bins) = bins_by_label.get(label) else { continue };
            // Candidate pairings with *positive* kept-stream overlap, found
            // via a group→bin index so cross-group pairs are never visited.
            let mut bins_of_group: FxHashMap<usize, Vec<usize>> = FxHashMap::default();
            for &bi in bins {
                for (g, &c) in packing.bins[bi].counts.iter().enumerate() {
                    if c > 0 {
                        bins_of_group.entry(g).or_default().push(bi);
                    }
                }
            }
            let mut cands: Vec<(usize, usize, usize)> = Vec::new();
            for &si in slots {
                let mut touched: Vec<usize> = survivors[si]
                    .keys()
                    .filter_map(|g| bins_of_group.get(g))
                    .flatten()
                    .copied()
                    .collect();
                touched.sort_unstable();
                touched.dedup();
                for bi in touched {
                    let overlap: usize = survivors[si]
                        .iter()
                        .map(|(&g, &n)| {
                            n.min(packing.bins[bi].counts.get(g).copied().unwrap_or(0))
                        })
                        .sum();
                    if overlap > 0 {
                        cands.push((overlap, si, bi));
                    }
                }
            }
            // Greedy max-overlap matching; ties resolve in slot/bin order,
            // so an unchanged packing reproduces the previous pairing
            // exactly. Labels partition both slots and bins, so gating on
            // local taken-sets equals gating on the global ones.
            cands.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
            let mut s_taken: FxHashSet<usize> = FxHashSet::default();
            let mut b_taken: FxHashSet<usize> = FxHashSet::default();
            let mut chosen: Vec<(usize, usize)> = Vec::new();
            let mut greedy_total = 0usize;
            for &(ov, si, bi) in &cands {
                if !s_taken.contains(&si) && !b_taken.contains(&bi) {
                    s_taken.insert(si);
                    b_taken.insert(bi);
                    chosen.push((si, bi));
                    greedy_total += ov;
                }
            }
            // Certified matching: greedy is provably optimal whenever it
            // meets the cheap upper bound min(Σ per-slot best, Σ per-bin
            // best) — in particular on every unchanged re-plan, where each
            // slot's own bin is its best. Only when greedy demonstrably
            // leaves overlap on the table (and the label block is small
            // enough for the O(n³) solve) does the exact assignment run,
            // and its matching is adopted only when *strictly* better — so
            // greedy's tie-breaking, and with it bit-for-bit reproduction
            // of identical re-plans, is preserved.
            if greedy_total < matching_upper_bound(&cands) {
                if slots.len().max(bins.len()) <= EXACT_MATCH_CAP {
                    if let Some((exact_total, exact_pairs)) =
                        exact_matching(slots, bins, &cands)
                    {
                        if exact_total > greedy_total {
                            chosen = exact_pairs;
                        }
                    }
                } else {
                    // Greedy may be sub-optimal here and the O(n³) check
                    // can't afford to say — count the blind spot instead of
                    // skipping silently.
                    *exact_cert_skipped += 1;
                }
            }
            for (si, bi) in chosen {
                slot_taken[si] = true;
                slot_of_bin[bi] = Some(prev.slots[si].slot_id);
                pairs.push((si, bi));
            }
            // Zero-overlap remainder pairs FIFO: the *instance* survives
            // even if all its streams were re-dealt.
            let leftover: Vec<usize> =
                bins.iter().copied().filter(|&bi| slot_of_bin[bi].is_none()).collect();
            let mut leftover = leftover.into_iter();
            for &si in slots {
                if slot_taken[si] {
                    continue;
                }
                let Some(bi) = leftover.next() else { break };
                slot_taken[si] = true;
                slot_of_bin[bi] = Some(prev.slots[si].slot_id);
                pairs.push((si, bi));
            }
        }
        // Apply the keeps: each paired bin retains its slot's surviving
        // streams, bounded by the bin's per-group counts.
        for (si, bi) in pairs {
            for k in &prev.slots[si].streams {
                if let Some(&idx) = key_to_idx.get(k) {
                    let g = group_of[idx];
                    if need[bi][g] > 0 && placed.insert(idx) {
                        need[bi][g] -= 1;
                        kept[bi].push(idx);
                    }
                }
            }
        }
    }

    // Transfer queues: members not kept in place, in request order.
    let mut unassigned: Vec<VecDeque<usize>> = members
        .iter()
        .map(|m| m.iter().copied().filter(|s| !placed.contains(s)).collect())
        .collect();

    let mut instances = Vec::with_capacity(nb);
    for (bi, bin) in packing.bins.iter().enumerate() {
        let bt = &problem.bins[bin.bin_type];
        let mut streams = std::mem::take(&mut kept[bi]);
        for (g, &c) in need[bi].iter().enumerate() {
            for _ in 0..c {
                let idx = unassigned[g]
                    .pop_front()
                    .ok_or_else(|| Error::solver("packing/member mismatch"))?;
                streams.push(idx);
            }
        }
        instances.push(PlannedInstance {
            slot_id: slot_of_bin[bi].unwrap_or_else(fresh_slot_id),
            bin_type: bin.bin_type,
            type_idx: bt.type_idx,
            region_idx: bt.region_idx,
            label: bt.label.clone(),
            hourly_cost: bt.cost,
            has_gpu: bt.has_gpu,
            streams,
        });
    }
    // A packing that under-covers a group would silently drop streams in
    // release builds if this were only debug-asserted — make it hard.
    let dropped: usize = unassigned.iter().map(VecDeque::len).sum();
    if dropped > 0 {
        return Err(Error::solver(format!(
            "packing under-covers the workload: {dropped} stream(s) left unassigned"
        )));
    }
    Ok(instances)
}

/// Largest per-label slot/bin block the exact assignment solve runs on.
/// Beyond this, greedy stands alone — the O(n³) pass would dominate Expand,
/// and large blocks are exactly where greedy's per-slot-best bound is
/// almost always met anyway.
pub const EXACT_MATCH_CAP: usize = 96;

/// Cheap upper bound on any slot↔bin matching's kept-stream total: each
/// slot contributes at most its best single-bin overlap and each bin at
/// most its best single-slot overlap, whichever sum is tighter. Greedy
/// meeting this bound certifies it optimal without an exact solve.
fn matching_upper_bound(cands: &[(usize, usize, usize)]) -> usize {
    let mut per_slot: FxHashMap<usize, usize> = FxHashMap::default();
    let mut per_bin: FxHashMap<usize, usize> = FxHashMap::default();
    for &(ov, si, bi) in cands {
        let s = per_slot.entry(si).or_insert(0);
        *s = (*s).max(ov);
        let b = per_bin.entry(bi).or_insert(0);
        *b = (*b).max(ov);
    }
    per_slot.values().sum::<usize>().min(per_bin.values().sum())
}

/// Exact maximum-overlap matching for one label's slot/bin block: builds
/// the (zero-padded square) overlap matrix over the label's slots × bins
/// and runs the Hungarian solve. Returns the matching's kept-stream total
/// and its positive-overlap pairs in slot order.
fn exact_matching(
    slots: &[usize],
    bins: &[usize],
    cands: &[(usize, usize, usize)],
) -> Option<(usize, Vec<(usize, usize)>)> {
    let n = slots.len().max(bins.len());
    if n == 0 {
        return None;
    }
    let row_of: FxHashMap<usize, usize> =
        slots.iter().enumerate().map(|(r, &si)| (si, r)).collect();
    let col_of: FxHashMap<usize, usize> =
        bins.iter().enumerate().map(|(c, &bi)| (bi, c)).collect();
    let mut w = vec![vec![0u64; n]; n];
    for &(ov, si, bi) in cands {
        w[row_of[&si]][col_of[&bi]] = ov as u64;
    }
    let m = hungarian_max(n, &w);
    let mut total = 0usize;
    let mut pairs = Vec::new();
    for (r, &c) in m.iter().enumerate() {
        if r < slots.len() && c < bins.len() && w[r][c] > 0 {
            total += w[r][c] as usize;
            pairs.push((slots[r], bins[c]));
        }
    }
    Some((total, pairs))
}

/// Maximum-weight perfect matching on an `n`×`n` weight matrix —
/// Kuhn–Munkres over dual potentials, O(n³), run as a minimization of
/// `maxw - w[i][j]`. Returns `row → col`. Deterministic: no randomized
/// tie-breaking anywhere, so re-runs reproduce the same matching.
fn hungarian_max(n: usize, w: &[Vec<u64>]) -> Vec<usize> {
    const INF: i64 = i64::MAX / 4;
    let maxw = w.iter().flat_map(|r| r.iter()).copied().max().unwrap_or(0) as i64;
    let cost = |i: usize, j: usize| maxw - w[i][j] as i64;
    // 1-based arrays with a virtual column 0, per the standard potentials
    // formulation: p[j] is the row matched to column j, way[j] the previous
    // column on the alternating path.
    let mut u = vec![0i64; n + 1];
    let mut v = vec![0i64; n + 1];
    let mut p = vec![0usize; n + 1];
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost(i0 - 1, j - 1) - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut ans = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            ans[p[j] - 1] = j - 1;
        }
    }
    ans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::Dims;
    use crate::packing::{BinType, ItemGroup, PackedBin};

    /// Distinct dummy keys for request indices 0..n.
    fn dummy_keys(n: usize) -> Vec<StreamKey> {
        (0..n)
            .map(|i| StreamKey {
                camera_id: i as u64,
                program: "ZF",
                fps_bits: 1.0f64.to_bits(),
                occurrence: 0,
            })
            .collect()
    }

    fn problem_with(count: usize, bins: usize) -> PackingProblem {
        PackingProblem::new(
            vec![ItemGroup {
                label: "g".into(),
                count,
                demand_per_bin: vec![Some(Dims::new(1.0, 1.0, 0.0, 0.0)); bins],
            }],
            (0..bins)
                .map(|_| BinType {
                    label: "cpu@r".into(),
                    capacity: Dims::new(8.0, 15.0, 0.0, 0.0),
                    cost: 1.0,
                    type_idx: 4,
                    region_idx: 2,
                    has_gpu: false,
                })
                .collect(),
        )
    }

    fn tiny_problem() -> PackingProblem {
        problem_with(3, 1)
    }

    #[test]
    fn expansion_assigns_members_in_request_order() {
        let problem = tiny_problem();
        let packing = Packing {
            bins: vec![
                PackedBin { bin_type: 0, counts: vec![2] },
                PackedBin { bin_type: 0, counts: vec![1] },
            ],
        };
        let members = vec![vec![7, 9, 11]];
        let instances = run(&problem, &packing, &members, &dummy_keys(12), None, &mut 0).unwrap();
        assert_eq!(instances.len(), 2);
        assert_eq!(instances[0].streams, vec![7, 9]);
        assert_eq!(instances[1].streams, vec![11]);
        assert_eq!(instances[0].type_idx, 4);
        assert_eq!(instances[0].region_idx, 2);
        assert_ne!(instances[0].slot_id, instances[1].slot_id, "slots are distinct");
    }

    #[test]
    fn count_overrun_is_an_error() {
        let problem = tiny_problem();
        let packing = Packing {
            bins: vec![PackedBin { bin_type: 0, counts: vec![4] }],
        };
        let members = vec![vec![0, 1, 2]];
        assert!(run(&problem, &packing, &members, &dummy_keys(3), None, &mut 0).is_err());
    }

    #[test]
    fn under_covering_packing_is_a_hard_error() {
        // Regression: this was only a debug_assert!, so a packing that
        // under-covers a group silently dropped streams in release builds.
        let problem = tiny_problem();
        let packing = Packing {
            bins: vec![PackedBin { bin_type: 0, counts: vec![2] }],
        };
        let members = vec![vec![0, 1, 2]];
        let err = run(&problem, &packing, &members, &dummy_keys(3), None, &mut 0).unwrap_err();
        assert!(err.to_string().contains("under-covers"), "{err}");
    }

    #[test]
    fn sticky_expansion_keeps_streams_on_their_old_slots() {
        let problem = problem_with(4, 1);
        let packing = Packing {
            bins: vec![
                PackedBin { bin_type: 0, counts: vec![2] },
                PackedBin { bin_type: 0, counts: vec![2] },
            ],
        };
        let members = vec![vec![0, 1, 2, 3]];
        let keys = dummy_keys(4);
        // Previous plan hosted [2, 3] on slot 70 and [0, 1] on slot 90 —
        // the reverse of what a cold request-order deal would produce.
        let prev = PrevAssignment {
            slots: vec![
                PrevSlot { slot_id: 70, label: "cpu@r".into(), streams: vec![keys[2], keys[3]] },
                PrevSlot { slot_id: 90, label: "cpu@r".into(), streams: vec![keys[0], keys[1]] },
            ],
        };
        let instances = run(&problem, &packing, &members, &keys, Some(&prev), &mut 0).unwrap();
        assert_eq!(instances[0].slot_id, 70);
        assert_eq!(instances[0].streams, vec![2, 3]);
        assert_eq!(instances[1].slot_id, 90);
        assert_eq!(instances[1].streams, vec![0, 1]);
    }

    #[test]
    fn shrunk_packing_moves_only_the_diff() {
        // Stream 3 departed and the packing consolidated to one bin: the
        // surviving bin keeps its two incumbents and receives exactly one
        // transferred stream.
        let problem = problem_with(3, 1);
        let packing = Packing {
            bins: vec![PackedBin { bin_type: 0, counts: vec![3] }],
        };
        let members = vec![vec![0, 1, 2]];
        let keys = dummy_keys(4);
        let prev = PrevAssignment {
            slots: vec![
                PrevSlot { slot_id: 11, label: "cpu@r".into(), streams: vec![keys[0], keys[1]] },
                PrevSlot { slot_id: 12, label: "cpu@r".into(), streams: vec![keys[2], keys[3]] },
            ],
        };
        let instances = run(&problem, &packing, &members, &keys[..3], Some(&prev), &mut 0).unwrap();
        assert_eq!(instances.len(), 1);
        assert_eq!(instances[0].slot_id, 11, "bin pairs with the larger-overlap slot");
        assert_eq!(instances[0].streams, vec![0, 1, 2]);
    }

    #[test]
    fn label_mismatch_never_sticks() {
        let problem = tiny_problem();
        let packing = Packing {
            bins: vec![PackedBin { bin_type: 0, counts: vec![3] }],
        };
        let members = vec![vec![0, 1, 2]];
        let keys = dummy_keys(3);
        // u64::MAX can never come out of the fresh-id allocator, so a match
        // here could only mean the label-mismatched slot was inherited.
        let prev = PrevAssignment {
            slots: vec![PrevSlot {
                slot_id: u64::MAX,
                label: "gpu@elsewhere".into(),
                streams: vec![keys[0], keys[1], keys[2]],
            }],
        };
        let instances = run(&problem, &packing, &members, &keys, Some(&prev), &mut 0).unwrap();
        assert_ne!(instances[0].slot_id, u64::MAX, "a different bin type is a new slot");
        assert_eq!(instances[0].streams, vec![0, 1, 2]);
    }

    #[test]
    fn assignment_seeded_from_another_candidates_plan_sticks() {
        // The portfolio seeds the *winner's* assignment into every
        // candidate context. A different candidate's expansion must inherit
        // it purely through labels + stream keys — here the seed hosts the
        // streams out of request order, which a cold deal would never
        // produce, so reproducing it proves the seed was honoured.
        let problem = problem_with(4, 1);
        let packing = Packing {
            bins: vec![
                PackedBin { bin_type: 0, counts: vec![2] },
                PackedBin { bin_type: 0, counts: vec![2] },
            ],
        };
        let members = vec![vec![0, 1, 2, 3]];
        let keys = dummy_keys(4);
        // Winner's deployed fleet: slot 41 hosts {0, 3}, slot 42 hosts {1, 2}.
        let prev = PrevAssignment {
            slots: vec![
                PrevSlot { slot_id: 41, label: "cpu@r".into(), streams: vec![keys[0], keys[3]] },
                PrevSlot { slot_id: 42, label: "cpu@r".into(), streams: vec![keys[1], keys[2]] },
            ],
        };
        let instances = run(&problem, &packing, &members, &keys, Some(&prev), &mut 0).unwrap();
        assert_eq!(instances[0].slot_id, 41);
        assert_eq!(instances[0].streams, vec![0, 3], "out-of-order hosting reproduced");
        assert_eq!(instances[1].slot_id, 42);
        assert_eq!(instances[1].streams, vec![1, 2]);
    }

    #[test]
    fn exact_matching_beats_a_greedy_local_optimum() {
        // Two groups, three bins of one label. Slot A survives {3×g0, 2×g1},
        // slot B {3×g0}; bins X{3×g0}, Y{1×g0 + 2×g1}, Z{2×g0}. Greedy takes
        // A↔X (overlap 3, lowest slot/bin tie-break) and is left with B↔Z
        // (2) — total 5 — while the unique optimum keeps 6: A↔Y (3) + B↔X
        // (3). The upper bound (per-slot bests: 3+3=6) exposes the gap, the
        // Hungarian pass closes it.
        let problem = PackingProblem::new(
            vec![
                ItemGroup {
                    label: "g0".into(),
                    count: 6,
                    demand_per_bin: vec![Some(Dims::new(1.0, 1.0, 0.0, 0.0))],
                },
                ItemGroup {
                    label: "g1".into(),
                    count: 2,
                    demand_per_bin: vec![Some(Dims::new(1.0, 1.0, 0.0, 0.0))],
                },
            ],
            vec![BinType {
                label: "cpu@r".into(),
                capacity: Dims::new(8.0, 15.0, 0.0, 0.0),
                cost: 1.0,
                type_idx: 4,
                region_idx: 2,
                has_gpu: false,
            }],
        );
        let packing = Packing {
            bins: vec![
                PackedBin { bin_type: 0, counts: vec![3, 0] },
                PackedBin { bin_type: 0, counts: vec![1, 2] },
                PackedBin { bin_type: 0, counts: vec![2, 0] },
            ],
        };
        let members = vec![vec![0, 1, 2, 3, 4, 5], vec![6, 7]];
        let keys = dummy_keys(8);
        let prev = PrevAssignment {
            slots: vec![
                PrevSlot {
                    slot_id: 70,
                    label: "cpu@r".into(),
                    streams: vec![keys[0], keys[1], keys[2], keys[6], keys[7]],
                },
                PrevSlot {
                    slot_id: 90,
                    label: "cpu@r".into(),
                    streams: vec![keys[3], keys[4], keys[5]],
                },
            ],
        };
        let instances = run(&problem, &packing, &members, &keys, Some(&prev), &mut 0).unwrap();
        assert_eq!(instances[0].slot_id, 90, "bin X pairs with slot B, not greedy's A");
        assert_eq!(instances[0].streams, vec![3, 4, 5]);
        assert_eq!(instances[1].slot_id, 70);
        assert_eq!(instances[1].streams, vec![0, 6, 7], "A keeps 1×g0 + both g1");
        assert!(
            instances[2].slot_id != 70 && instances[2].slot_id != 90,
            "bin Z is the fresh slot"
        );
        assert_eq!(instances[2].streams, vec![1, 2], "residual transfers in request order");
        // 6 of 8 streams stay in place — the certified optimum.
        let kept = [&instances[0], &instances[1]]
            .iter()
            .map(|i| i.streams.len())
            .sum::<usize>();
        assert_eq!(kept, 6);
    }

    /// The greedy-suboptimal core of `exact_matching_beats_a_greedy_local_optimum`
    /// (slots A {3 g0, 2 g1} and B {3 g0}; bins X {3 g0}, Y {1 g0 + 2 g1},
    /// Z {2 g0}; greedy keeps 5, the optimum keeps 6) padded with `pads`
    /// perfectly-matched one-stream slot/bin pairs of the same label, so
    /// the label block is `3 + pads` bins wide while the certification gap
    /// stays exactly one stream.
    fn certification_gap_scenario(
        pads: usize,
    ) -> (PackingProblem, Packing, Vec<Vec<usize>>, Vec<StreamKey>, PrevAssignment) {
        let ngroups = 2 + pads;
        let unit = Dims::new(1.0, 1.0, 0.0, 0.0);
        let mut groups = vec![
            ItemGroup { label: "g0".into(), count: 6, demand_per_bin: vec![Some(unit)] },
            ItemGroup { label: "g1".into(), count: 2, demand_per_bin: vec![Some(unit)] },
        ];
        for j in 0..pads {
            groups.push(ItemGroup {
                label: format!("pad{j}"),
                count: 1,
                demand_per_bin: vec![Some(unit)],
            });
        }
        let problem = PackingProblem::new(
            groups,
            vec![BinType {
                label: "cpu@r".into(),
                capacity: Dims::new(8.0, 15.0, 0.0, 0.0),
                cost: 1.0,
                type_idx: 4,
                region_idx: 2,
                has_gpu: false,
            }],
        );
        let mut counts_x = vec![0usize; ngroups];
        counts_x[0] = 3;
        let mut counts_y = vec![0usize; ngroups];
        counts_y[0] = 1;
        counts_y[1] = 2;
        let mut counts_z = vec![0usize; ngroups];
        counts_z[0] = 2;
        let mut bins = vec![
            PackedBin { bin_type: 0, counts: counts_x },
            PackedBin { bin_type: 0, counts: counts_y },
            PackedBin { bin_type: 0, counts: counts_z },
        ];
        for j in 0..pads {
            let mut c = vec![0usize; ngroups];
            c[2 + j] = 1;
            bins.push(PackedBin { bin_type: 0, counts: c });
        }
        let packing = Packing { bins };
        let mut members = vec![(0..6).collect::<Vec<usize>>(), vec![6, 7]];
        for j in 0..pads {
            members.push(vec![8 + j]);
        }
        let keys = dummy_keys(8 + pads);
        let mut slots = vec![
            PrevSlot {
                slot_id: 70,
                label: "cpu@r".into(),
                streams: vec![keys[0], keys[1], keys[2], keys[6], keys[7]],
            },
            PrevSlot {
                slot_id: 90,
                label: "cpu@r".into(),
                streams: vec![keys[3], keys[4], keys[5]],
            },
        ];
        for j in 0..pads {
            slots.push(PrevSlot {
                slot_id: 1000 + j as u64,
                label: "cpu@r".into(),
                streams: vec![keys[8 + j]],
            });
        }
        (problem, packing, members, keys, PrevAssignment { slots })
    }

    #[test]
    fn exact_certification_still_runs_at_exactly_the_cap() {
        // pads = cap - 3 → the label block is exactly EXACT_MATCH_CAP bins
        // wide (96): the boundary is inclusive, so the Hungarian pass must
        // still run, recover the optimum, and count no skip.
        let (problem, packing, members, keys, prev) =
            certification_gap_scenario(EXACT_MATCH_CAP - 3);
        let mut skipped = 0usize;
        let instances =
            run(&problem, &packing, &members, &keys, Some(&prev), &mut skipped).unwrap();
        assert_eq!(skipped, 0, "a cap-sized block must still be certified");
        assert_eq!(instances[0].slot_id, 90, "exact matching recovered the optimum at the cap");
        assert_eq!(instances[1].slot_id, 70);
    }

    #[test]
    fn exact_certification_skip_one_past_the_cap_is_counted() {
        // pads = cap - 2 → 97 bins, one past the boundary: greedy's local
        // optimum stands (it demonstrably leaves a stream on the table) and
        // the formerly-silent skip must now be surfaced in the counter.
        let (problem, packing, members, keys, prev) =
            certification_gap_scenario(EXACT_MATCH_CAP - 2);
        let mut skipped = 0usize;
        let instances =
            run(&problem, &packing, &members, &keys, Some(&prev), &mut skipped).unwrap();
        assert_eq!(skipped, 1, "the certification blind spot must be counted, not silent");
        assert_eq!(instances[0].slot_id, 70, "greedy's A-X pairing stands past the cap");
        assert_eq!(instances[2].slot_id, 90, "greedy settles for B-Z");
    }

    fn brute_force_best(n: usize, w: &[Vec<u64>]) -> u64 {
        fn rec(r: usize, n: usize, w: &[Vec<u64>], used: &mut [bool]) -> u64 {
            if r == n {
                return 0;
            }
            let mut best = 0;
            for c in 0..n {
                if !used[c] {
                    used[c] = true;
                    best = best.max(w[r][c] + rec(r + 1, n, w, used));
                    used[c] = false;
                }
            }
            best
        }
        rec(0, n, w, &mut vec![false; n])
    }

    #[test]
    fn hungarian_matches_brute_force_on_small_matrices() {
        let mut seed = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for n in 1..=4 {
            for _ in 0..25 {
                let w: Vec<Vec<u64>> =
                    (0..n).map(|_| (0..n).map(|_| next() % 10).collect()).collect();
                let m = hungarian_max(n, &w);
                let mut seen = vec![false; n];
                for &c in &m {
                    assert!(!seen[c], "not a permutation: {m:?} for {w:?}");
                    seen[c] = true;
                }
                let total: u64 = m.iter().enumerate().map(|(r, &c)| w[r][c]).sum();
                assert_eq!(total, brute_force_best(n, &w), "w={w:?}");
            }
        }
    }

    #[test]
    fn identical_replan_reproduces_the_assignment_bit_for_bit() {
        let problem = problem_with(5, 1);
        let packing = Packing {
            bins: vec![
                PackedBin { bin_type: 0, counts: vec![3] },
                PackedBin { bin_type: 0, counts: vec![2] },
            ],
        };
        let members = vec![vec![0, 1, 2, 3, 4]];
        let keys = dummy_keys(5);
        let first = run(&problem, &packing, &members, &keys, None, &mut 0).unwrap();
        let prev = PrevAssignment::capture(&first, &keys);
        let second = run(&problem, &packing, &members, &keys, Some(&prev), &mut 0).unwrap();
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.slot_id, b.slot_id);
            assert_eq!(a.streams, b.streams);
        }
    }
}
