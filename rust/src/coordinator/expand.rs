//! Pipeline stage 4 — **Expand**: turn the solved packing's per-group counts
//! into per-instance stream assignments for the serving layer.
//!
//! Purely mechanical: each packed bin becomes one [`PlannedInstance`]; group
//! counts are drawn from the group membership queues in request order, so
//! the expansion is deterministic given (packing, members).

use super::PlannedInstance;
use crate::error::{Error, Result};
use crate::packing::{Packing, PackingProblem};

/// Expand group counts into per-instance stream lists.
pub fn run(
    problem: &PackingProblem,
    packing: &Packing,
    members: &[Vec<usize>],
) -> Result<Vec<PlannedInstance>> {
    let mut unassigned: Vec<std::collections::VecDeque<usize>> = members
        .iter()
        .map(|m| m.iter().copied().collect())
        .collect();
    let mut instances = Vec::with_capacity(packing.bins.len());
    for bin in &packing.bins {
        let bt = &problem.bins[bin.bin_type];
        let mut streams = Vec::new();
        for (g, &c) in bin.counts.iter().enumerate() {
            for _ in 0..c {
                let idx = unassigned[g]
                    .pop_front()
                    .ok_or_else(|| Error::solver("packing/member mismatch"))?;
                streams.push(idx);
            }
        }
        instances.push(PlannedInstance {
            bin_type: bin.bin_type,
            type_idx: bt.type_idx,
            region_idx: bt.region_idx,
            label: bt.label.clone(),
            hourly_cost: bt.cost,
            has_gpu: bt.has_gpu,
            streams,
        });
    }
    debug_assert!(unassigned.iter().all(|q| q.is_empty()));
    Ok(instances)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::Dims;
    use crate::packing::{BinType, ItemGroup, PackedBin};

    fn tiny_problem() -> PackingProblem {
        PackingProblem::new(
            vec![ItemGroup {
                label: "g".into(),
                count: 3,
                demand_per_bin: vec![Some(Dims::new(1.0, 1.0, 0.0, 0.0))],
            }],
            vec![BinType {
                label: "cpu@r".into(),
                capacity: Dims::new(8.0, 15.0, 0.0, 0.0),
                cost: 1.0,
                type_idx: 4,
                region_idx: 2,
                has_gpu: false,
            }],
        )
    }

    #[test]
    fn expansion_assigns_members_in_request_order() {
        let problem = tiny_problem();
        let packing = Packing {
            bins: vec![
                PackedBin { bin_type: 0, counts: vec![2] },
                PackedBin { bin_type: 0, counts: vec![1] },
            ],
        };
        let members = vec![vec![7, 9, 11]];
        let instances = run(&problem, &packing, &members).unwrap();
        assert_eq!(instances.len(), 2);
        assert_eq!(instances[0].streams, vec![7, 9]);
        assert_eq!(instances[1].streams, vec![11]);
        assert_eq!(instances[0].type_idx, 4);
        assert_eq!(instances[0].region_idx, 2);
    }

    #[test]
    fn count_overrun_is_an_error() {
        let problem = tiny_problem();
        let packing = Packing {
            bins: vec![PackedBin { bin_type: 0, counts: vec![4] }],
        };
        let members = vec![vec![0, 1, 2]];
        assert!(run(&problem, &packing, &members).is_err());
    }
}
