//! Metro-sharded planning: per-shard plan contexts, event-driven re-plans,
//! and a thin global arbiter.
//!
//! The coordinator's staged pipeline already decomposes a *single* solve into
//! region-connected components. This module promotes that decomposition to
//! the fleet architecture: the stream population is partitioned into **metro
//! shards** — connected components of the per-request eligibility
//! [`RegionMask`]s — and each shard owns a full portfolio
//! [`ReplanContext`] (Main + both alternates) that re-plans *independently*,
//! and concurrently with other shards, only when drift actually lands in its
//! metro (an event-driven dirty set).
//!
//! Because a shard is a mask-connected component, no feasible plan can ever
//! place a shard's stream on another shard's regions; on region-disjoint
//! workloads the sharded optimum therefore equals the unsharded optimum
//! exactly (asserted in `bench_planet` and a property test).
//!
//! The [`ShardedPlanner`] arbiter owns everything genuinely global:
//!
//! - the cross-shard **budget pool** ([`ShardSlackLedger`]): each re-planned
//!   shard publishes its residual `pool_out` slack, and every dirty shard
//!   draws the slack donated by *other* shards as extra B&B pruning budget;
//! - one shared [`PoolSlot`] worker pool and one content-addressed
//!   [`GraphCache`], wired into every shard's three candidate contexts;
//! - catalog/price fan-out: a change of the `(catalog, config)`
//!   [`pipeline::signature`] dirties **all** shards, while a camera
//!   join/leave dirties exactly the shard whose drift signature moved.

use std::collections::hash_map::DefaultHasher;
use std::collections::{BTreeMap, BTreeSet};
use std::hash::{Hash, Hasher};
use std::sync::Arc;

use super::adaptive::{migration_diff, MigrationReport};
use super::budget::{AxisSlack, ShardSlackLedger};
use super::eligibility::{self, EligCache, RegionMask};
use super::pipeline::{self, PipelineStats};
use super::portfolio::{self, Candidate, ReplanContext};
use super::{Plan, Planner};
use crate::cameras::{CameraMode, StreamRequest};
use crate::cloudsim::{CloudSim, InstanceId};
use crate::error::{Error, Result};
use crate::metrics::SolverMetrics;
use crate::packing::arcflow::GraphCache;
use crate::util::pool::{PoolSlot, WorkerPool};

/// Identity of a metro shard: the smallest catalog region index of its
/// mask-connected region cluster. Stable across rounds as long as the
/// catalog's region list is stable, even as cameras join and leave.
pub type ShardId = u32;

/// Arbiter-level event counters (event-driven re-plan accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardEvents {
    /// Planning rounds driven through [`ShardedPlanner::replan`].
    pub rounds: u64,
    /// Shard re-plans actually executed (dirty shards only; clean shards
    /// reuse their deployed plan verbatim).
    pub shard_replans: u64,
    /// `(catalog, config)` signature changes fanned out to every shard.
    pub price_fanouts: u64,
    /// Shards created because drift opened a new metro.
    pub shards_joined: u64,
    /// Shards retired because their metro emptied.
    pub shards_retired: u64,
}

/// One metro shard: a request slice, its portfolio context, and the plan it
/// currently has deployed.
pub struct Shard {
    /// The shard's own three-candidate portfolio state. Public so callers
    /// (and tests) can inspect per-shard pipeline/solver telemetry.
    pub ctx: ReplanContext,
    /// Re-plans this shard has executed since it joined.
    pub replans: u64,
    requests: Vec<StreamRequest>,
    /// For each shard-local request index, its index in the arbiter's most
    /// recent global slice.
    global: Vec<usize>,
    drift_sig: u64,
    /// The deployed `(requests, plan)` pair — kept together so the next
    /// re-plan can diff migrations against exactly what it replaces.
    deployed: Option<(Vec<StreamRequest>, Plan)>,
    last_report: Option<MigrationReport>,
}

impl Shard {
    fn new(pool: &Arc<PoolSlot>, graphs: &Arc<GraphCache>) -> Self {
        let mut ctx = ReplanContext::new();
        // Re-wire all three candidate contexts onto the arbiter's global
        // worker pool and graph cache (replacing the portfolio-local pair
        // `ReplanContext::new` installed).
        ctx.main.share_pool(Arc::clone(pool));
        ctx.alt_rtt_greedy.share_pool(Arc::clone(pool));
        ctx.alt_nearest_exact.share_pool(Arc::clone(pool));
        ctx.main.share_graphs(Arc::clone(graphs));
        ctx.alt_rtt_greedy.share_graphs(Arc::clone(graphs));
        ctx.alt_nearest_exact.share_graphs(Arc::clone(graphs));
        Shard {
            ctx,
            replans: 0,
            requests: Vec::new(),
            global: Vec::new(),
            drift_sig: 0,
            deployed: None,
            last_report: None,
        }
    }

    /// The shard's current request slice (shard-local order).
    pub fn requests(&self) -> &[StreamRequest] {
        &self.requests
    }

    /// Shard-local index -> global index mapping for [`Self::requests`].
    pub fn global_indices(&self) -> &[usize] {
        &self.global
    }

    /// The plan this shard currently has deployed, if any.
    pub fn plan(&self) -> Option<&Plan> {
        self.deployed.as_ref().map(|(_, p)| p)
    }

    /// Migration report of the shard's most recent re-plan.
    pub fn last_report(&self) -> Option<&MigrationReport> {
        self.last_report.as_ref()
    }

    /// Re-plan this shard's slice through the portfolio, drawing `external`
    /// cross-shard slack, and diff migrations against the deployed plan.
    fn replan_slice(&mut self, planner: &Planner, external: AxisSlack) -> Result<()> {
        let prev_winner = self.ctx.last_winner;
        let plan = portfolio::plan_with_slack(planner, &self.requests, &mut self.ctx, external)?;
        let mut report = migration_diff(
            self.deployed.as_ref().map(|(r, p)| (r.as_slice(), p)),
            &self.requests,
            &plan,
        );
        report.winner = self.ctx.last_winner;
        report.winner_flipped =
            matches!((prev_winner, self.ctx.last_winner), (Some(a), Some(b)) if a != b);
        self.deployed = Some((self.requests.clone(), plan));
        self.last_report = Some(report);
        self.replans += 1;
        Ok(())
    }
}

/// One shard's contribution to a [`ShardedPlan`].
#[derive(Clone, Debug)]
pub struct ShardEntry {
    pub shard: ShardId,
    /// The shard's plan; `instances[..].streams` index the *shard-local*
    /// slice — translate through `global` for fleet-wide indices.
    pub plan: Plan,
    /// Shard-local request index -> index into the round's global slice.
    pub global: Vec<usize>,
    /// True when this round actually re-planned the shard (it was dirty).
    pub replanned: bool,
    /// The portfolio candidate whose plan the shard currently deploys.
    pub winner: Option<Candidate>,
}

/// The fleet-wide outcome of one [`ShardedPlanner::replan`] round.
#[derive(Clone, Debug)]
pub struct ShardedPlan {
    /// Per-shard plans in ascending [`ShardId`] order (all shards, dirty or
    /// not).
    pub entries: Vec<ShardEntry>,
    /// Shards whose metro emptied this round (their fleets should be
    /// retired; [`Self::apply_to`] does so).
    pub retired: Vec<ShardId>,
    /// Sum of the per-shard plan costs.
    pub cost_per_hour: f64,
    /// Shards that re-planned this round.
    pub dirty_shards: usize,
    /// Shards alive after this round.
    pub total_shards: usize,
}

impl ShardedPlan {
    /// True when every shard's exact phase ran to completion and proved
    /// optimality for each of its components — the precondition under which
    /// sharded cost equals unsharded cost on region-disjoint workloads.
    pub fn exact_complete(&self) -> bool {
        self.entries.iter().all(|e| {
            e.plan.pipeline.components_fallback == 0
                && e.plan.pipeline.components_proven == e.plan.pipeline.components
        })
    }

    /// True when every shard deploys the Main (full-GCL) candidate.
    pub fn all_main(&self) -> bool {
        self.entries.iter().all(|e| e.winner == Some(Candidate::Main))
    }

    /// Pipeline telemetry summed over the shards that re-planned this round.
    pub fn stats_rollup(&self) -> PipelineStats {
        let mut total = PipelineStats::default();
        for e in self.entries.iter().filter(|e| e.replanned) {
            total.absorb(&e.plan.pipeline);
        }
        total
    }

    /// Apply the round to a [`CloudSim`] fleet: retire emptied shards, then
    /// apply each shard's plan through the shard-scoped path so one metro's
    /// churn never touches another metro's instances. Returns the per-shard
    /// instance ids.
    pub fn apply_to(&self, sim: &mut CloudSim) -> Result<BTreeMap<ShardId, Vec<InstanceId>>> {
        for &id in &self.retired {
            sim.retire_shard(id)?;
        }
        let mut out = BTreeMap::new();
        for e in &self.entries {
            out.insert(e.shard, sim.apply_shard_plan(e.shard, &e.plan)?);
        }
        Ok(out)
    }
}

/// The global arbiter: partitions streams into metro shards, tracks drift
/// per shard, fans out catalog changes, and runs dirty shards' re-plans
/// concurrently over shared global resources.
pub struct ShardedPlanner {
    /// Catalog + config. Mutating either (e.g. a price change) is detected
    /// on the next [`Self::replan`] and fans out to every shard.
    pub planner: Planner,
    /// Event-driven re-plan accounting.
    pub events: ShardEvents,
    shards: BTreeMap<ShardId, Shard>,
    pool: Arc<PoolSlot>,
    graphs: Arc<GraphCache>,
    ledger: ShardSlackLedger,
    catalog_sig: Option<u64>,
    /// Arbiter-level eligibility memo for the partitioner, keyed like the
    /// pipeline's [`EligCache`]; cleared on signature fan-out.
    partition_memo: EligCache,
}

impl ShardedPlanner {
    pub fn new(planner: Planner) -> Self {
        ShardedPlanner {
            planner,
            events: ShardEvents::default(),
            shards: BTreeMap::new(),
            pool: Arc::new(PoolSlot::new()),
            graphs: Arc::new(GraphCache::new()),
            ledger: ShardSlackLedger::new(),
            catalog_sig: None,
            partition_memo: EligCache::default(),
        }
    }

    /// Alive shard ids in ascending order.
    pub fn shard_ids(&self) -> Vec<ShardId> {
        self.shards.keys().copied().collect()
    }

    pub fn shard(&self, id: ShardId) -> Option<&Shard> {
        self.shards.get(&id)
    }

    /// Shards currently donating slack into the cross-shard budget pool.
    pub fn donors(&self) -> usize {
        self.ledger.donors()
    }

    /// Human-readable label for a shard: the id of its anchor region.
    pub fn shard_label(&self, id: ShardId) -> String {
        self.planner
            .catalog
            .regions
            .get(id as usize)
            .map(|r| r.id.to_string())
            .unwrap_or_else(|| format!("s{id}"))
    }

    /// Per-shard solver counter lines (each prefixed `shard=<region-id>`)
    /// followed by an absorbed fleet total.
    pub fn solver_summary(&self) -> String {
        let total = SolverMetrics::new();
        let mut lines = Vec::with_capacity(self.shards.len() + 1);
        for (&id, shard) in &self.shards {
            let roll = shard.ctx.solver_rollup();
            lines.push(roll.summary_for(&self.shard_label(id)));
            total.absorb(&roll);
        }
        lines.push(total.summary_for("total"));
        lines.join("\n")
    }

    /// Fleet-wide migration report: the per-shard reports of the most recent
    /// round, rolled up. `None` until a first re-plan lands.
    pub fn fleet_report(&self) -> Option<MigrationReport> {
        let mut reports = self.shards.values().filter_map(|s| s.last_report.as_ref());
        let first = reports.next()?.clone();
        Some(reports.fold(first, |mut acc, r| {
            acc.absorb(r);
            acc
        }))
    }

    /// One planning round: partition `requests` into metro shards, compute
    /// the dirty set (drift, joins, retirements, catalog fan-out), re-plan
    /// the dirty shards — concurrently when more than one — and assemble the
    /// fleet-wide [`ShardedPlan`].
    pub fn replan(&mut self, requests: &[StreamRequest]) -> Result<ShardedPlan> {
        if requests.is_empty() {
            return Err(Error::config("no stream requests"));
        }
        self.events.rounds += 1;

        // Catalog / price / config fan-out: a signature change invalidates
        // the partition memo and dirties every shard (each shard's contexts
        // detect the same change themselves and rebuild cold).
        let sig = pipeline::signature(&self.planner.catalog, &self.planner.config);
        let fanout = self.catalog_sig != Some(sig);
        if fanout {
            if self.catalog_sig.is_some() {
                self.events.price_fanouts += 1;
            }
            self.catalog_sig = Some(sig);
            self.partition_memo.clear();
        }

        let routed = self.partition(requests);

        // Shards whose metro emptied retire, taking their donation with them.
        let retired: Vec<ShardId> = self
            .shards
            .keys()
            .copied()
            .filter(|id| !routed.contains_key(id))
            .collect();
        for id in &retired {
            self.ledger.retire(*id);
            self.shards.remove(id);
            self.events.shards_retired += 1;
        }

        // Route slices and compute the dirty set.
        let mut dirty: Vec<ShardId> = Vec::new();
        for (id, (reqs, global)) in routed {
            let is_new = !self.shards.contains_key(&id);
            if is_new {
                self.events.shards_joined += 1;
                self.shards.insert(id, Shard::new(&self.pool, &self.graphs));
            }
            let shard = self.shards.get_mut(&id).expect("shard just ensured");
            let drift = drift_sig(&reqs);
            let is_dirty =
                fanout || is_new || shard.deployed.is_none() || drift != shard.drift_sig;
            shard.requests = reqs;
            shard.global = global;
            shard.drift_sig = drift;
            if is_dirty {
                dirty.push(id);
            }
        }

        // Snapshot each dirty shard's cross-shard grant *before* the round
        // so concurrent completion order cannot change any shard's inputs.
        let grants: BTreeMap<ShardId, AxisSlack> = dirty
            .iter()
            .map(|&id| (id, self.ledger.available_for(id)))
            .collect();

        self.events.shard_replans += dirty.len() as u64;
        self.run_round(&dirty, &grants)?;

        // Publish this round's residual slack for future rounds.
        for &id in &dirty {
            let out = self.shards[&id].ctx.main.pool_out;
            self.ledger.publish(id, out);
        }

        // Assemble: every alive shard contributes its deployed plan.
        let mut entries = Vec::with_capacity(self.shards.len());
        let mut cost = 0.0;
        for (&id, shard) in &self.shards {
            let plan = shard
                .plan()
                .expect("every alive shard holds a plan after the round")
                .clone();
            cost += plan.cost_per_hour;
            entries.push(ShardEntry {
                shard: id,
                replanned: dirty.contains(&id),
                winner: shard.ctx.last_winner,
                global: shard.global.clone(),
                plan,
            });
        }
        Ok(ShardedPlan {
            entries,
            retired,
            cost_per_hour: cost,
            dirty_shards: dirty.len(),
            total_shards: self.shards.len(),
        })
    }

    /// Execute the dirty shards' re-plans: inline when trivial, otherwise
    /// across scoped threads (round-robin buckets, bounded by the worker
    /// default) with each thread owning a disjoint set of `&mut Shard`.
    fn run_round(
        &mut self,
        dirty: &[ShardId],
        grants: &BTreeMap<ShardId, AxisSlack>,
    ) -> Result<()> {
        if dirty.len() <= 1 || !self.planner.config.parallel_regions {
            for &id in dirty {
                let shard = self.shards.get_mut(&id).expect("dirty shard exists");
                shard
                    .replan_slice(&self.planner, grants[&id])
                    .map_err(|e| Error::solver(format!("shard {id}: {e}")))?;
            }
            return Ok(());
        }
        let workers = WorkerPool::default_threads().clamp(1, dirty.len());
        let dirty_set: BTreeSet<ShardId> = dirty.iter().copied().collect();
        let mut buckets: Vec<Vec<(ShardId, &mut Shard)>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (i, (id, shard)) in self
            .shards
            .iter_mut()
            .filter(|(id, _)| dirty_set.contains(*id))
            .enumerate()
        {
            buckets[i % workers].push((*id, shard));
        }
        let planner = &self.planner;
        let mut failures: Vec<(ShardId, String)> = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = buckets
                .into_iter()
                .map(|bucket| {
                    scope.spawn(move || {
                        let mut errs: Vec<(ShardId, String)> = Vec::new();
                        for (id, shard) in bucket {
                            if let Err(e) = shard.replan_slice(planner, grants[&id]) {
                                errs.push((id, e.to_string()));
                            }
                        }
                        errs
                    })
                })
                .collect();
            for h in handles {
                failures.extend(h.join().expect("shard re-plan thread panicked"));
            }
        });
        // Deterministic error surfacing: smallest failing shard id wins.
        failures.sort();
        match failures.into_iter().next() {
            Some((id, e)) => Err(Error::solver(format!("shard {id}: {e}"))),
            None => Ok(()),
        }
    }

    /// Partition the global slice into mask-connected metro shards.
    ///
    /// Each request's eligibility [`RegionMask`] is computed through the
    /// arbiter's memo; a union-find over region indices merges every pair of
    /// regions that co-occur in some mask. A shard is one resulting cluster,
    /// identified by its smallest region index; requests route to the
    /// cluster containing their mask.
    fn partition(
        &mut self,
        requests: &[StreamRequest],
    ) -> BTreeMap<ShardId, (Vec<StreamRequest>, Vec<usize>)> {
        let n_regions = self.planner.catalog.regions.len();
        let mut routed: BTreeMap<ShardId, (Vec<StreamRequest>, Vec<usize>)> = BTreeMap::new();
        if n_regions == 0 {
            // Degenerate catalog: a single shard that will fail to plan with
            // the same error the unsharded pipeline reports.
            routed.insert(0, (requests.to_vec(), (0..requests.len()).collect()));
            return routed;
        }
        let masks: Vec<RegionMask> = requests
            .iter()
            .map(|req| {
                let key = (
                    eligibility::canon_f64_bits(req.camera.location.lat),
                    eligibility::canon_f64_bits(req.camera.location.lon),
                    eligibility::canon_f64_bits(req.desired_fps),
                );
                if let Some(&(mask, _)) = self.partition_memo.get(&key) {
                    mask
                } else {
                    let (mask, degraded) = eligibility::eligibility(
                        &self.planner.catalog,
                        self.planner.config.location,
                        req,
                    );
                    self.partition_memo.insert(key, (mask, degraded));
                    mask
                }
            })
            .collect();
        let mut parent: Vec<u32> = (0..n_regions as u32).collect();
        for mask in &masks {
            let mut first: Option<u32> = None;
            for r in mask.ones() {
                match first {
                    None => first = Some(r as u32),
                    Some(f) => uf_union(&mut parent, f, r as u32),
                }
            }
        }
        for (i, (req, mask)) in requests.iter().zip(&masks).enumerate() {
            let anchor = mask.ones().next().unwrap_or(0) as u32;
            let id = uf_find(&mut parent, anchor);
            let entry = routed.entry(id).or_default();
            entry.0.push(req.clone());
            entry.1.push(i);
        }
        routed
    }
}

/// Union-find with path halving. Union always parents the larger root under
/// the smaller, so a cluster's root *is* its minimum region index — exactly
/// the [`ShardId`] convention.
fn uf_find(parent: &mut [u32], mut x: u32) -> u32 {
    while parent[x as usize] != x {
        parent[x as usize] = parent[parent[x as usize] as usize];
        x = parent[x as usize];
    }
    x
}

fn uf_union(parent: &mut [u32], a: u32, b: u32) {
    let (ra, rb) = (uf_find(parent, a), uf_find(parent, b));
    if ra != rb {
        let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
        parent[hi as usize] = lo;
    }
}

/// Order-sensitive content hash of a shard's request slice: any camera
/// join/leave, move, retune, reorder, or published serving-feedback delta
/// (observed cost scale / degrade tier) changes the signature and dirties
/// exactly that shard. Catalog/config changes are tracked separately via
/// [`pipeline::signature`].
fn drift_sig(requests: &[StreamRequest]) -> u64 {
    let mut h = DefaultHasher::new();
    requests.len().hash(&mut h);
    for req in requests {
        req.camera.id.hash(&mut h);
        eligibility::canon_f64_bits(req.camera.location.lat).hash(&mut h);
        eligibility::canon_f64_bits(req.camera.location.lon).hash(&mut h);
        req.camera.resolution.hash(&mut h);
        eligibility::canon_f64_bits(req.camera.native_fps).hash(&mut h);
        let mode = match req.camera.mode {
            CameraMode::Video => 0u8,
            CameraMode::Snapshot => 1,
        };
        mode.hash(&mut h);
        req.program.hash(&mut h);
        eligibility::canon_f64_bits(req.desired_fps).hash(&mut h);
        eligibility::canon_f64_bits(req.feedback.cost_scale).hash(&mut h);
        req.feedback.shed_tier.hash(&mut h);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::camera_at;
    use crate::catalog::Catalog;
    use crate::coordinator::PlannerConfig;
    use crate::geo::GeoPoint;
    use crate::profiles::{Program, Resolution};

    /// The 12 EC2 regions + 4 instance types every sharding test uses: at
    /// fps >= 32 the coverage radius (~2731 km) keeps the 8 region basins
    /// mask-disjoint, so shard structure is known a priori.
    fn ec2_catalog() -> Catalog {
        Catalog::builtin().restrict(
            Some(&["c4.2xlarge", "c4.8xlarge", "g2.2xlarge", "g3.8xlarge"]),
            Some(&[
                "us-east-1",
                "us-east-2",
                "us-west-1",
                "us-west-2",
                "eu-west-1",
                "eu-west-2",
                "eu-central-1",
                "ap-southeast-1",
                "ap-southeast-2",
                "ap-northeast-1",
                "ap-south-1",
                "sa-east-1",
            ]),
        )
    }

    fn cam(id: u64, at: GeoPoint, fps: f64) -> StreamRequest {
        StreamRequest::new(camera_at(id, "metro", at, Resolution::VGA, 30.0), Program::Zf, fps)
    }

    fn virginia() -> GeoPoint {
        GeoPoint::new(38.95, -77.45)
    }

    fn ireland() -> GeoPoint {
        GeoPoint::new(53.34, -6.27)
    }

    fn tokyo() -> GeoPoint {
        GeoPoint::new(35.68, 139.69)
    }

    fn exact_complete(plan: &Plan) -> bool {
        plan.pipeline.components_fallback == 0
            && plan.pipeline.components_proven == plan.pipeline.components
    }

    #[test]
    fn region_disjoint_sharding_matches_the_unsharded_planner() {
        let requests = vec![
            cam(0, virginia(), 32.0),
            cam(1, virginia(), 36.0),
            cam(2, ireland(), 32.0),
            cam(3, ireland(), 40.0),
            cam(4, tokyo(), 36.0),
            cam(5, tokyo(), 36.0),
        ];
        let mut sp = ShardedPlanner::new(Planner::new(ec2_catalog(), PlannerConfig::gcl()));
        let sharded = sp.replan(&requests).unwrap();
        assert_eq!(sharded.total_shards, 3, "three disjoint metros");
        assert_eq!(sharded.dirty_shards, 3, "cold start replans everything");
        assert!(sharded.exact_complete());
        assert!(sharded.all_main(), "exact GCL wins in every shard");

        let unsharded = Planner::new(ec2_catalog(), PlannerConfig::gcl())
            .plan_single(&requests)
            .unwrap();
        assert!(exact_complete(&unsharded));
        assert!(
            (sharded.cost_per_hour - unsharded.cost_per_hour).abs() < 1e-6,
            "sharded {} vs unsharded {}",
            sharded.cost_per_hour,
            unsharded.cost_per_hour
        );
        // Every global request index is covered exactly once.
        let mut covered: Vec<usize> =
            sharded.entries.iter().flat_map(|e| e.global.iter().copied()).collect();
        covered.sort_unstable();
        assert_eq!(covered, (0..requests.len()).collect::<Vec<_>>());
    }

    #[test]
    fn drift_events_dirty_only_their_shard_and_prices_fan_out() {
        let w0 = vec![
            cam(0, virginia(), 32.0),
            cam(1, virginia(), 36.0),
            cam(2, ireland(), 32.0),
            cam(3, ireland(), 36.0),
        ];
        let mut sp = ShardedPlanner::new(Planner::new(ec2_catalog(), PlannerConfig::gcl()));
        let r1 = sp.replan(&w0).unwrap();
        assert_eq!((r1.total_shards, r1.dirty_shards), (2, 2));
        assert_eq!(sp.events.shards_joined, 2);

        // No drift: nothing replans, plans (and cost) are reused verbatim.
        let r2 = sp.replan(&w0).unwrap();
        assert_eq!(r2.dirty_shards, 0);
        assert_eq!(r2.cost_per_hour, r1.cost_per_hour, "bit-identical reuse");
        assert!(r2.entries.iter().all(|e| !e.replanned));

        // A camera joins Ireland: exactly that shard replans.
        let mut w1 = w0.clone();
        w1.push(cam(9, ireland(), 40.0));
        let r3 = sp.replan(&w1).unwrap();
        assert_eq!(r3.dirty_shards, 1);
        assert_eq!(sp.events.shards_joined, 2, "a join in an existing metro adds no shard");
        let replanned: Vec<ShardId> =
            r3.entries.iter().filter(|e| e.replanned).map(|e| e.shard).collect();
        assert_eq!(replanned.len(), 1);
        let irish = replanned[0];
        assert_eq!(sp.shard(irish).unwrap().requests().len(), 3);

        // A price change fans out to every shard.
        sp.planner.catalog.offerings[0].hourly_usd += 0.017;
        let r4 = sp.replan(&w1).unwrap();
        assert_eq!(r4.dirty_shards, 2);
        assert_eq!(sp.events.price_fanouts, 1);
        assert_eq!(sp.events.rounds, 4);
        assert_eq!(sp.events.shard_replans, 2 + 0 + 1 + 2);

        // Post-fan-out parity against a fresh unsharded solve of the mutated
        // catalog.
        let unsharded = Planner::new(sp.planner.catalog.clone(), PlannerConfig::gcl())
            .plan_single(&w1)
            .unwrap();
        assert!(r4.exact_complete() && exact_complete(&unsharded));
        assert!((r4.cost_per_hour - unsharded.cost_per_hour).abs() < 1e-6);
    }

    /// A camera moving between metros must re-enter through the structural
    /// delta path on *both* sides of the boundary: a vanished group in the
    /// shard it left, an appeared group in the shard it joined.
    #[test]
    fn cross_shard_churn_takes_the_structural_delta_path_in_both_shards() {
        let before = vec![
            cam(0, virginia(), 32.0),
            cam(1, virginia(), 32.5),
            cam(2, virginia(), 36.0),
            cam(10, ireland(), 33.0),
            cam(11, ireland(), 34.0),
            cam(12, ireland(), 35.0),
        ];
        // Camera 0 moves Virginia -> Ireland keeping its 32.0 fps tier,
        // which is unique in Ireland: one vanished group in Virginia, one
        // appeared group in Ireland.
        let after = vec![
            cam(1, virginia(), 32.5),
            cam(2, virginia(), 36.0),
            cam(10, ireland(), 33.0),
            cam(11, ireland(), 34.0),
            cam(12, ireland(), 35.0),
            cam(0, ireland(), 32.0),
        ];
        let mut sp = ShardedPlanner::new(Planner::new(ec2_catalog(), PlannerConfig::gcl()));
        let r1 = sp.replan(&before).unwrap();
        assert_eq!((r1.total_shards, r1.dirty_shards), (2, 2));

        let r2 = sp.replan(&after).unwrap();
        assert_eq!(r2.dirty_shards, 2, "the move dirties exactly both boundary shards");
        assert_eq!(sp.events.shards_joined, 2, "no shard joined or retired");
        assert_eq!(sp.events.shards_retired, 0);

        for id in sp.shard_ids() {
            let sh = sp.shard(id).unwrap();
            // Both shards warm-started through the structural (appeared /
            // vanished group) path — not the same-structure delta path, and
            // not a cold solve.
            assert_eq!(sh.ctx.main.stats.structural_delta_hits, 1, "{:?}", sh.ctx.main.stats);
            assert_eq!(sh.ctx.main.stats.delta_solve_hits, 0, "{:?}", sh.ctx.main.stats);
            assert_eq!(sh.ctx.main.solver.structural_reuses.get(), 1);
            match sh.requests().len() {
                // Virginia kept 2 untouched requests and lost one group.
                2 => assert_eq!(
                    (sh.ctx.main.stats.front_unchanged, sh.ctx.main.stats.front_changed),
                    (2, 0),
                    "{:?}",
                    sh.ctx.main.stats
                ),
                // Ireland kept its 3 and gained the migrant.
                4 => assert_eq!(
                    (sh.ctx.main.stats.front_unchanged, sh.ctx.main.stats.front_changed),
                    (3, 1),
                    "{:?}",
                    sh.ctx.main.stats
                ),
                n => panic!("unexpected shard size {n}"),
            }
        }

        // Certified-or-cold: the warm sharded round still matches a cold
        // unsharded solve exactly.
        let unsharded = Planner::new(ec2_catalog(), PlannerConfig::gcl())
            .plan_single(&after)
            .unwrap();
        assert!(r2.exact_complete() && exact_complete(&unsharded));
        assert!((r2.cost_per_hour - unsharded.cost_per_hour).abs() < 1e-6);
    }

    #[test]
    fn shards_share_the_arbiters_pool_caches_and_slack_ledger() {
        let requests = vec![
            cam(0, virginia(), 32.0),
            cam(1, ireland(), 36.0),
            cam(2, tokyo(), 40.0),
        ];
        let mut sp = ShardedPlanner::new(Planner::new(ec2_catalog(), PlannerConfig::gcl()));
        sp.replan(&requests).unwrap();
        let ids = sp.shard_ids();
        assert_eq!(ids.len(), 3);
        let first = sp.shard(ids[0]).unwrap();
        for &id in &ids[1..] {
            let sh = sp.shard(id).unwrap();
            assert!(
                Arc::ptr_eq(first.ctx.main.pool_slot(), sh.ctx.main.pool_slot()),
                "one worker pool for the whole fleet"
            );
            assert!(
                Arc::ptr_eq(first.ctx.main.graph_cache(), sh.ctx.main.graph_cache()),
                "one graph cache for the whole fleet"
            );
        }
        // Every re-planned shard published into the ledger, and the summary
        // is labelled per shard.
        assert_eq!(sp.donors(), 3);
        let summary = sp.solver_summary();
        assert!(summary.contains("shard=us-east-1"), "{summary}");
        assert!(summary.contains("shard=total"), "{summary}");
        assert!(sp.fleet_report().is_some());
    }

    #[test]
    fn shard_retirement_is_event_driven_and_fleet_scoped() {
        let catalog = ec2_catalog();
        let w0 = vec![
            cam(0, virginia(), 32.0),
            cam(1, virginia(), 36.0),
            cam(2, ireland(), 32.0),
            cam(3, ireland(), 36.0),
        ];
        let mut sp = ShardedPlanner::new(Planner::new(catalog.clone(), PlannerConfig::gcl()));
        let r1 = sp.replan(&w0).unwrap();
        let mut sim = CloudSim::new(catalog);
        r1.apply_to(&mut sim).unwrap();
        assert!((sim.hourly_rate() - r1.cost_per_hour).abs() < 1e-9);

        // Ireland's metro empties: its shard retires and, on apply, its
        // instances terminate, while Virginia is untouched (still clean).
        let w1 = vec![cam(0, virginia(), 32.0), cam(1, virginia(), 36.0)];
        let r2 = sp.replan(&w1).unwrap();
        assert_eq!(r2.total_shards, 1);
        assert_eq!(r2.retired.len(), 1);
        assert_eq!(r2.dirty_shards, 0, "Virginia's slice did not drift");
        assert_eq!(sp.events.shards_retired, 1);
        r2.apply_to(&mut sim).unwrap();
        assert!((sim.hourly_rate() - r2.cost_per_hour).abs() < 1e-9);
        assert!(r2.cost_per_hour < r1.cost_per_hour);
    }
}
