//! The resource manager — the paper's contribution.
//!
//! Given a set of [`StreamRequest`]s (camera × analysis program × desired
//! fps), a [`Catalog`] of priced instance offerings, and the program
//! [`profiles`](crate::profiles), the planner:
//!
//! 1. derives each stream's **eligible locations** from the RTT/frame-rate
//!    coupling (Fig 4: the coverage circle around each camera),
//! 2. builds the **multi-dimensional multiple-choice packing problem**
//!    (streams = boxes with CPU-path and GPU-path demand vectors; offerings
//!    = trucks), applying the 90% utilization headroom rule,
//! 3. solves it with the configured strategy:
//!    * hardware filter — ST1 (CPU-only), ST2 (GPU-only), ST3 (both,
//!      Kaseb et al. \[7\]),
//!    * location policy — NL (nearest location), ARMVAC (RTT filter +
//!      cheapest-fill, Mohan et al. \[6\]), GCL (RTT filter + exact arc-flow
//!      packing, Mohan et al. \[8\]),
//! 4. expands the packing into per-instance stream assignments for the
//!    serving layer.

pub mod adaptive;

use crate::cameras::StreamRequest;
use crate::catalog::Catalog;
use crate::error::{Error, Result};
use crate::geo;
use crate::packing::mcvbp::{self, SolveMethod, SolveOptions};
use crate::packing::{heuristic, BinType, ItemGroup, Packing, PackingProblem};

/// ST1 / ST2 / ST3 hardware filters (Fig 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HardwareFilter {
    /// ST1: instances with only CPUs.
    CpuOnly,
    /// ST2: instances with GPUs.
    GpuOnly,
    /// ST3: select freely between CPU and GPU instances (Kaseb's method).
    Both,
}

/// Location policies (Fig 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocationPolicy {
    /// No geographic restriction (single-region experiments, Fig 3).
    Unrestricted,
    /// NL: each stream may only use its nearest region.
    NearestOnly,
    /// ARMVAC/GCL: regions within the RTT budget for the desired fps;
    /// falls back to the nearest region (with degraded fps) if none qualify.
    RttFiltered,
}

/// Packing algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Exact arc-flow + branch-and-bound (with FFD incumbent).
    Exact,
    /// ARMVAC's cheapest-instance-first greedy fill.
    ArmvacGreedy,
    /// First-fit-decreasing by cost-efficiency.
    Ffd,
}

/// Full planner configuration.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    pub hardware: HardwareFilter,
    pub location: LocationPolicy,
    pub solver: SolverKind,
    /// Per-dimension utilization cap (paper: 0.90).
    pub headroom: f64,
    pub solve_opts: SolveOptions,
}

impl PlannerConfig {
    fn preset(hardware: HardwareFilter, location: LocationPolicy, solver: SolverKind) -> Self {
        PlannerConfig {
            hardware,
            location,
            solver,
            headroom: crate::packing::DEFAULT_HEADROOM,
            solve_opts: SolveOptions::default(),
        }
    }

    /// Fig 3 ST1: CPU-only instances.
    pub fn st1() -> Self {
        Self::preset(HardwareFilter::CpuOnly, LocationPolicy::Unrestricted, SolverKind::Exact)
    }
    /// Fig 3 ST2: GPU-only instances.
    pub fn st2() -> Self {
        Self::preset(HardwareFilter::GpuOnly, LocationPolicy::Unrestricted, SolverKind::Exact)
    }
    /// Fig 3 ST3: Kaseb's CPU+GPU multiple-choice method.
    pub fn st3() -> Self {
        Self::preset(HardwareFilter::Both, LocationPolicy::Unrestricted, SolverKind::Exact)
    }
    /// Fig 6 NL: nearest location only (same greedy fill rule as ARMVAC —
    /// the baseline manager differs from ARMVAC only in location choice).
    pub fn nl() -> Self {
        Self::preset(HardwareFilter::Both, LocationPolicy::NearestOnly, SolverKind::ArmvacGreedy)
    }
    /// Fig 6 ARMVAC: RTT filter + cheapest-instance greedy fill.
    pub fn armvac() -> Self {
        Self::preset(HardwareFilter::Both, LocationPolicy::RttFiltered, SolverKind::ArmvacGreedy)
    }
    /// Fig 6 GCL: RTT filter + exact multiple-choice packing.
    pub fn gcl() -> Self {
        Self::preset(HardwareFilter::Both, LocationPolicy::RttFiltered, SolverKind::Exact)
    }
}

/// One provisioned instance in a plan.
#[derive(Clone, Debug)]
pub struct PlannedInstance {
    /// Index into `plan.problem.bins`.
    pub bin_type: usize,
    /// Catalog indices + label for display / provisioning.
    pub type_idx: usize,
    pub region_idx: usize,
    pub label: String,
    pub hourly_cost: f64,
    pub has_gpu: bool,
    /// Indices into the request slice handed to `plan()`.
    pub streams: Vec<usize>,
}

/// The planner's output.
#[derive(Clone, Debug)]
pub struct Plan {
    pub problem: PackingProblem,
    pub packing: Packing,
    pub instances: Vec<PlannedInstance>,
    pub cost_per_hour: f64,
    pub non_gpu: usize,
    pub gpu: usize,
    /// Requests that could not meet their desired fps from any eligible
    /// region (served from the nearest region at a capped rate).
    pub degraded: Vec<usize>,
    pub method: SolveMethod,
    /// Region coordinates (from the catalog) for delivered-fps accounting.
    pub region_locations: Vec<geo::GeoPoint>,
}

impl Plan {
    /// The per-request delivered fps (equals desired unless degraded).
    pub fn delivered_fps(&self, requests: &[StreamRequest]) -> Vec<f64> {
        requests
            .iter()
            .enumerate()
            .map(|(i, r)| {
                if self.degraded.contains(&i) {
                    let inst = self
                        .instances
                        .iter()
                        .find(|inst| inst.streams.contains(&i))
                        .expect("stream not assigned");
                    let rtt = r
                        .camera
                        .location
                        .rtt_ms(&self.region_locations[inst.region_idx]);
                    geo::fps_cap(rtt).min(r.desired_fps)
                } else {
                    r.desired_fps
                }
            })
            .collect()
    }

    /// Number of distinct regions used.
    pub fn regions_used(&self) -> usize {
        let mut rs: Vec<usize> = self.instances.iter().map(|i| i.region_idx).collect();
        rs.sort_unstable();
        rs.dedup();
        rs.len()
    }
}

/// The resource manager.
#[derive(Clone)]
pub struct Planner {
    pub catalog: Catalog,
    pub config: PlannerConfig,
}

impl Planner {
    pub fn new(catalog: Catalog, config: PlannerConfig) -> Self {
        Planner { catalog, config }
    }

    /// Compute the eligible-region bitmask for one request, plus the
    /// degraded flag (no region inside the coverage circle).
    fn eligibility(&self, req: &StreamRequest) -> (Vec<bool>, bool) {
        let n = self.catalog.regions.len();
        match self.config.location {
            LocationPolicy::Unrestricted => (vec![true; n], false),
            LocationPolicy::NearestOnly => {
                // Nearest data center of each vendor (a camera operator can
                // pick either provider's closest region).
                let nearest = self.nearest_regions_per_vendor(req);
                let mut mask = vec![false; n];
                let mut any_ok = false;
                for &r in &nearest {
                    mask[r] = true;
                    any_ok |= geo::reachable(
                        &req.camera.location,
                        &self.catalog.regions[r].location,
                        req.desired_fps,
                    );
                }
                (mask, !any_ok)
            }
            LocationPolicy::RttFiltered => {
                let mut mask: Vec<bool> = self
                    .catalog
                    .regions
                    .iter()
                    .map(|r| geo::reachable(&req.camera.location, &r.location, req.desired_fps))
                    .collect();
                if mask.iter().any(|&m| m) {
                    (mask, false)
                } else {
                    // Best effort: nearest regions, degraded fps.
                    mask = vec![false; n];
                    for r in self.nearest_regions_per_vendor(req) {
                        mask[r] = true;
                    }
                    (mask, true)
                }
            }
        }
    }

    /// Nearest region of each vendor present in the catalog.
    fn nearest_regions_per_vendor(&self, req: &StreamRequest) -> Vec<usize> {
        let mut best: std::collections::BTreeMap<&'static str, (usize, f64)> =
            std::collections::BTreeMap::new();
        for (i, r) in self.catalog.regions.iter().enumerate() {
            let d = req.camera.location.distance_km(&r.location);
            let key = match r.vendor {
                crate::catalog::Vendor::Ec2 => "ec2",
                crate::catalog::Vendor::Azure => "azure",
            };
            let e = best.entry(key).or_insert((i, d));
            if d < e.1 {
                *e = (i, d);
            }
        }
        best.values().map(|&(i, _)| i).collect()
    }

    /// Build the packing problem. Returns (problem, group members, degraded).
    pub fn build_problem(
        &self,
        requests: &[StreamRequest],
    ) -> Result<(PackingProblem, Vec<Vec<usize>>, Vec<usize>)> {
        if requests.is_empty() {
            return Err(Error::config("no stream requests"));
        }
        // Bin types: offerings passing the hardware filter.
        let bins: Vec<BinType> = self
            .catalog
            .offerings
            .iter()
            .filter(|o| {
                let has_gpu = self.catalog.types[o.type_idx].has_gpu();
                match self.config.hardware {
                    HardwareFilter::CpuOnly => !has_gpu,
                    HardwareFilter::GpuOnly => has_gpu,
                    HardwareFilter::Both => true,
                }
            })
            .map(|o| {
                let ty = &self.catalog.types[o.type_idx];
                let rg = &self.catalog.regions[o.region_idx];
                BinType {
                    label: format!("{}@{}", ty.name, rg.id),
                    capacity: ty.capacity,
                    cost: o.hourly_usd,
                    type_idx: o.type_idx,
                    region_idx: o.region_idx,
                    has_gpu: ty.has_gpu(),
                }
            })
            .collect();
        if bins.is_empty() {
            return Err(Error::infeasible("no instance offerings pass the hardware filter"));
        }

        // Group requests by (program, fps, resolution, eligibility mask).
        struct Key {
            program: crate::profiles::Program,
            fps_milli: u64,
            res: crate::profiles::Resolution,
            mask: Vec<bool>,
            degraded: bool,
        }
        let mut keys: Vec<Key> = Vec::new();
        let mut members: Vec<Vec<usize>> = Vec::new();
        let mut degraded_requests: Vec<usize> = Vec::new();
        for (i, req) in requests.iter().enumerate() {
            let (mask, degraded) = self.eligibility(req);
            if degraded {
                degraded_requests.push(i);
            }
            let fps_milli = (req.desired_fps * 1000.0).round() as u64;
            let pos = keys.iter().position(|k| {
                k.program == req.program
                    && k.fps_milli == fps_milli
                    && k.res == req.camera.resolution
                    && k.mask == mask
                    && k.degraded == degraded
            });
            match pos {
                Some(g) => members[g].push(i),
                None => {
                    keys.push(Key {
                        program: req.program,
                        fps_milli,
                        res: req.camera.resolution,
                        mask,
                        degraded,
                    });
                    members.push(vec![i]);
                }
            }
        }

        // Demand vectors per (group, bin type).
        let items: Vec<ItemGroup> = keys
            .iter()
            .zip(&members)
            .map(|(key, mem)| {
                let profile = key.program.profile();
                let rep = &requests[mem[0]];
                let demand_per_bin = bins
                    .iter()
                    .map(|b| {
                        if !key.mask[b.region_idx] {
                            return None;
                        }
                        // Delivered fps: capped by the region's RTT when the
                        // stream is degraded (best-effort nearest region).
                        let fps = if key.degraded {
                            let rtt = rep
                                .camera
                                .location
                                .rtt_ms(&self.catalog.regions[b.region_idx].location);
                            geo::fps_cap(rtt).min(rep.desired_fps)
                        } else {
                            rep.desired_fps
                        };
                        Some(if b.has_gpu {
                            // Newer GPU generations (g3/p3-class) process the
                            // same stream in proportionally less GPU time.
                            let mut d = profile.demand_gpu(fps, key.res);
                            d.gpus /= self.catalog.types[b.type_idx].gpu_speed;
                            d
                        } else {
                            profile.demand_cpu(fps, key.res)
                        })
                    })
                    .collect();
                ItemGroup {
                    label: format!("{}x{}", rep.label(), mem.len()),
                    count: mem.len(),
                    demand_per_bin,
                }
            })
            .collect();

        let mut problem = PackingProblem::new(items, bins);
        problem.headroom = self.config.headroom;
        Ok((problem, members, degraded_requests))
    }

    /// Produce a full plan for the request set.
    ///
    /// For the GCL configuration (RTT-filtered + exact), the NL and ARMVAC
    /// solutions are also evaluated as candidate incumbents: both are
    /// feasible points of GCL's search space (nearest-location assignments
    /// respect the RTT circles), so GCL returns the cheapest of the three —
    /// exactly the "globally cheapest" semantics of Mohan et al. \[8\], and it
    /// keeps GCL ≤ ARMVAC ≤-ish NL even when the exact phase must fall back
    /// to a heuristic on very large instances.
    pub fn plan(&self, requests: &[StreamRequest]) -> Result<Plan> {
        let mut best = self.plan_single(requests)?;
        if self.config.location == LocationPolicy::RttFiltered
            && self.config.solver == SolverKind::Exact
        {
            for (hw, loc, solver) in [
                (self.config.hardware, LocationPolicy::RttFiltered, SolverKind::ArmvacGreedy),
                (self.config.hardware, LocationPolicy::NearestOnly, SolverKind::Exact),
            ] {
                let alt = Planner::new(
                    self.catalog.clone(),
                    PlannerConfig {
                        hardware: hw,
                        location: loc,
                        solver,
                        headroom: self.config.headroom,
                        solve_opts: self.config.solve_opts.clone(),
                    },
                );
                if let Ok(p) = alt.plan_single(requests) {
                    if p.cost_per_hour < best.cost_per_hour {
                        best = p;
                    }
                }
            }
        }
        Ok(best)
    }

    /// Plan with exactly this configuration (no candidate portfolio).
    pub fn plan_single(&self, requests: &[StreamRequest]) -> Result<Plan> {
        let (problem, members, degraded) = self.build_problem(requests)?;

        let (packing, method) = match self.config.solver {
            SolverKind::Exact => {
                let (p, stats) = mcvbp::solve(&problem, &self.config.solve_opts)?;
                (p, stats.method)
            }
            SolverKind::ArmvacGreedy => {
                (heuristic::armvac_fill(&problem)?, SolveMethod::Heuristic)
            }
            SolverKind::Ffd => {
                (heuristic::first_fit_decreasing(&problem)?, SolveMethod::Heuristic)
            }
        };
        packing.validate(&problem)?;

        // Expand group counts into per-instance stream lists.
        let mut unassigned: Vec<std::collections::VecDeque<usize>> = members
            .iter()
            .map(|m| m.iter().copied().collect())
            .collect();
        let mut instances = Vec::with_capacity(packing.bins.len());
        for bin in &packing.bins {
            let bt = &problem.bins[bin.bin_type];
            let mut streams = Vec::new();
            for (g, &c) in bin.counts.iter().enumerate() {
                for _ in 0..c {
                    let idx = unassigned[g]
                        .pop_front()
                        .ok_or_else(|| Error::solver("packing/member mismatch"))?;
                    streams.push(idx);
                }
            }
            instances.push(PlannedInstance {
                bin_type: bin.bin_type,
                type_idx: bt.type_idx,
                region_idx: bt.region_idx,
                label: bt.label.clone(),
                hourly_cost: bt.cost,
                has_gpu: bt.has_gpu,
                streams,
            });
        }
        debug_assert!(unassigned.iter().all(|q| q.is_empty()));

        let cost = packing.total_cost(&problem);
        let (non_gpu, gpu) = packing.count_by_gpu(&problem);
        Ok(Plan {
            problem,
            packing,
            instances,
            cost_per_hour: cost,
            non_gpu,
            gpu,
            degraded,
            method,
            region_locations: self.catalog.regions.iter().map(|r| r.location).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::scenarios;
    use crate::util::round_dp;

    /// The Fig-3 experiment pool: the paper's $0.419 CPU box + $0.650 GPU box.
    fn fig3_catalog() -> Catalog {
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]))
    }

    fn run(scn: &scenarios::Scenario, cfg: PlannerConfig) -> Result<Plan> {
        Planner::new(fig3_catalog(), cfg).plan(&scn.requests)
    }

    #[test]
    fn fig3_full_table_reproduces() {
        // The paper's Fig-3 table, all nine (scenario, strategy) cells.
        let scns = scenarios::fig3_scenarios();
        let expected = scenarios::fig3_expected();
        let configs = [PlannerConfig::st1(), PlannerConfig::st2(), PlannerConfig::st3()];
        for (si, scn) in scns.iter().enumerate() {
            for (ci, cfg) in configs.iter().enumerate() {
                let got = run(scn, cfg.clone());
                match expected[si][ci] {
                    scenarios::ExpectedOutcome::Fail => {
                        assert!(got.is_err(), "{} ST{} should fail", scn.name, ci + 1);
                    }
                    scenarios::ExpectedOutcome::Selected { non_gpu, gpu, hourly_cost } => {
                        let plan = got.unwrap_or_else(|e| {
                            panic!("{} ST{}: unexpected failure: {e}", scn.name, ci + 1)
                        });
                        assert_eq!(
                            (plan.non_gpu, plan.gpu),
                            (non_gpu, gpu),
                            "{} ST{}: instance mix",
                            scn.name,
                            ci + 1
                        );
                        assert_eq!(
                            round_dp(plan.cost_per_hour, 3),
                            hourly_cost,
                            "{} ST{}: hourly cost",
                            scn.name,
                            ci + 1
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fig3_savings_match_paper() {
        // Savings of each scenario's best strategy vs its worst:
        // S1 61%, S2 36%, S3 3% (paper's savings column).
        let scns = scenarios::fig3_scenarios();
        let mut savings = Vec::new();
        for scn in &scns {
            let costs: Vec<f64> = [PlannerConfig::st1(), PlannerConfig::st2(), PlannerConfig::st3()]
                .into_iter()
                .filter_map(|cfg| run(scn, cfg).ok().map(|p| p.cost_per_hour))
                .collect();
            let max = costs.iter().cloned().fold(0.0, f64::max);
            let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
            savings.push(((1.0 - min / max) * 100.0).round() as i64);
        }
        assert_eq!(savings, vec![61, 36, 3]);
    }

    #[test]
    fn plan_assigns_every_stream_exactly_once() {
        let scn = scenarios::fig3_scenario3();
        let plan = run(&scn, PlannerConfig::st3()).unwrap();
        let mut seen = vec![0usize; scn.requests.len()];
        for inst in &plan.instances {
            for &s in &inst.streams {
                seen[s] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "assignments: {seen:?}");
    }

    #[test]
    fn st1_never_uses_gpu_and_st2_never_cpu() {
        let scn = scenarios::fig3_scenario1();
        let p1 = run(&scn, PlannerConfig::st1()).unwrap();
        assert_eq!(p1.gpu, 0);
        let p2 = run(&scn, PlannerConfig::st2()).unwrap();
        assert_eq!(p2.non_gpu, 0);
    }

    #[test]
    fn empty_request_set_rejected() {
        let planner = Planner::new(fig3_catalog(), PlannerConfig::st3());
        assert!(planner.plan(&[]).is_err());
    }

    #[test]
    fn unrestricted_location_has_no_degraded_streams() {
        let scn = scenarios::fig3_scenario1();
        let plan = run(&scn, PlannerConfig::st3()).unwrap();
        assert!(plan.degraded.is_empty());
        assert_eq!(plan.delivered_fps(&scn.requests), vec![0.25, 0.55, 0.55, 0.55]);
    }

    #[test]
    fn location_policies_order_costs() {
        // GCL <= ARMVAC and GCL <= NL on a worldwide workload.
        let requests = scenarios::fig6_workload(24, 4.0, 5);
        let catalog = Catalog::builtin();
        let nl = Planner::new(catalog.clone(), PlannerConfig::nl()).plan(&requests).unwrap();
        let armvac = Planner::new(catalog.clone(), PlannerConfig::armvac()).plan(&requests).unwrap();
        let gcl = Planner::new(catalog, PlannerConfig::gcl()).plan(&requests).unwrap();
        assert!(gcl.cost_per_hour <= armvac.cost_per_hour + 1e-9);
        assert!(gcl.cost_per_hour <= nl.cost_per_hour + 1e-9);
    }

    #[test]
    fn rtt_filter_restricts_regions() {
        // A single Tokyo camera at 20 fps: eligible regions are near Japan.
        let requests = vec![crate::cameras::StreamRequest::new(
            crate::cameras::camera_at(
                0,
                "Tokyo",
                crate::geo::cities::TOKYO,
                crate::profiles::Resolution::VGA,
                30.0,
            ),
            crate::profiles::Program::Zf,
            20.0,
        )];
        let plan = Planner::new(Catalog::builtin(), PlannerConfig::gcl())
            .plan(&requests)
            .unwrap();
        assert_eq!(plan.instances.len(), 1);
        let region = plan.instances[0].region_idx;
        let loc = plan.region_locations[region];
        assert!(
            crate::geo::cities::TOKYO.distance_km(&loc) < crate::geo::coverage_radius_km(20.0)
        );
    }
}
