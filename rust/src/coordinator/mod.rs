//! The resource manager — the paper's contribution.
//!
//! Planning runs as an explicit staged pipeline (see [`pipeline`]):
//!
//! 1. [`eligibility`] — derive each stream's **eligible locations** from the
//!    RTT/frame-rate coupling (Fig 4: the coverage circle around each
//!    camera) and group identical requests,
//! 2. [`pipeline`]'s ProblemBuild stage — build the **multi-dimensional
//!    multiple-choice packing problem** (streams = boxes with CPU-path and
//!    GPU-path demand vectors; offerings = trucks), applying the 90%
//!    utilization headroom rule,
//! 3. [`pipeline`]'s Solve stage — decompose into independent per-region
//!    subproblems, solve each in parallel with the configured strategy:
//!    * hardware filter — ST1 (CPU-only), ST2 (GPU-only), ST3 (both,
//!      Kaseb et al. \[7\]),
//!    * location policy — NL (nearest location), ARMVAC (RTT filter +
//!      cheapest-fill, Mohan et al. \[6\]), GCL (RTT filter + exact arc-flow
//!      packing, Mohan et al. \[8\]),
//! 4. [`expand`] — expand the packing into per-instance stream assignments
//!    for the serving layer. The expansion is *sticky*: each planned
//!    instance carries a stable [`SlotId`], and on a re-plan every stream
//!    stays on its previous slot whenever the new packing still has room
//!    for its group there, so only the true packing diff moves.
//!
//! Each stage's artifact is cached in a [`pipeline::PlanContext`], so the
//! dynamic manager ([`adaptive`]) re-plans incrementally: unchanged cameras
//! keep their eligibility masks and demand vectors, unchanged region
//! clusters keep their arc-flow graphs, and the previous packing seeds
//! branch-and-bound as the incumbent instead of a cold FFD start.
//!
//! The Solve stage is additionally *budget-adaptive* and *delta-aware*
//! ([`budget`]): per-component solver budgets are re-derived each re-plan
//! from the component's own telemetry plus a global pool (small components
//! donate unused budget to the hard ones, never below the static seed), and
//! subproblems that differ from a memoized one by a bounded demand delta
//! re-enter the solver from the cached optimal basis and branching order
//! instead of solving cold.
//!
//! The GCL configuration plans a candidate **portfolio** ([`portfolio`]):
//! the exact RTT-filtered solve plus the ARMVAC-greedy and nearest-exact
//! alternates, adopting the cheapest plan each re-plan. The portfolio runs
//! on *shared* infrastructure — one solve-worker pool and one
//! cross-candidate budget pool span all three candidate contexts, and the
//! winning candidate's stream→slot assignment is seeded into every context
//! after each re-plan, so a winner flip reproduces the deployed fleet
//! instead of restarting slots fresh.
//!
//! The front-end (Eligibility + ProblemBuild) is *drift-proportional*: the
//! context diffs each request slice against the previous one by stable
//! stream key + fingerprint and re-runs eligibility/grouping only for the
//! drift. Region masks are fixed-width bitsets
//! ([`eligibility::RegionMask`]), group keys are interned to dense
//! [`eligibility::GroupId`]s, the hot maps hash through
//! [`util::fxhash`](crate::util::fxhash), and per-component solves dispatch
//! to a persistent worker pool owned by the context rather than fresh
//! thread scopes.

pub mod adaptive;
pub mod budget;
pub mod eligibility;
pub mod expand;
pub mod pipeline;
pub mod portfolio;
pub mod shard;
pub mod spot;

use crate::cameras::StreamRequest;
use crate::catalog::Catalog;
use crate::error::Result;
use crate::geo;
use crate::packing::mcvbp::{SolveMethod, SolveOptions};
use crate::packing::{Packing, PackingProblem};
use pipeline::{PipelineStats, PlanContext};
use portfolio::ReplanContext;

/// ST1 / ST2 / ST3 hardware filters (Fig 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HardwareFilter {
    /// ST1: instances with only CPUs.
    CpuOnly,
    /// ST2: instances with GPUs.
    GpuOnly,
    /// ST3: select freely between CPU and GPU instances (Kaseb's method).
    Both,
}

/// Location policies (Fig 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocationPolicy {
    /// No geographic restriction (single-region experiments, Fig 3).
    Unrestricted,
    /// NL: each stream may only use its nearest region.
    NearestOnly,
    /// ARMVAC/GCL: regions within the RTT budget for the desired fps;
    /// falls back to the nearest region (with degraded fps) if none qualify.
    RttFiltered,
}

/// Packing algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Exact arc-flow + branch-and-bound (with FFD incumbent).
    Exact,
    /// ARMVAC's cheapest-instance-first greedy fill.
    ArmvacGreedy,
    /// First-fit-decreasing by cost-efficiency.
    Ffd,
}

/// Full planner configuration.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    pub hardware: HardwareFilter,
    pub location: LocationPolicy,
    pub solver: SolverKind,
    /// Per-dimension utilization cap (paper: 0.90).
    pub headroom: f64,
    pub solve_opts: SolveOptions,
    /// Solve independent per-region subproblems on parallel threads.
    pub parallel_regions: bool,
}

impl PlannerConfig {
    fn preset(hardware: HardwareFilter, location: LocationPolicy, solver: SolverKind) -> Self {
        PlannerConfig {
            hardware,
            location,
            solver,
            headroom: crate::packing::DEFAULT_HEADROOM,
            solve_opts: SolveOptions::default(),
            parallel_regions: true,
        }
    }

    /// Fig 3 ST1: CPU-only instances.
    pub fn st1() -> Self {
        Self::preset(HardwareFilter::CpuOnly, LocationPolicy::Unrestricted, SolverKind::Exact)
    }
    /// Fig 3 ST2: GPU-only instances.
    pub fn st2() -> Self {
        Self::preset(HardwareFilter::GpuOnly, LocationPolicy::Unrestricted, SolverKind::Exact)
    }
    /// Fig 3 ST3: Kaseb's CPU+GPU multiple-choice method.
    pub fn st3() -> Self {
        Self::preset(HardwareFilter::Both, LocationPolicy::Unrestricted, SolverKind::Exact)
    }
    /// Fig 6 NL: nearest location only (same greedy fill rule as ARMVAC —
    /// the baseline manager differs from ARMVAC only in location choice).
    pub fn nl() -> Self {
        Self::preset(HardwareFilter::Both, LocationPolicy::NearestOnly, SolverKind::ArmvacGreedy)
    }
    /// Fig 6 ARMVAC: RTT filter + cheapest-instance greedy fill.
    pub fn armvac() -> Self {
        Self::preset(HardwareFilter::Both, LocationPolicy::RttFiltered, SolverKind::ArmvacGreedy)
    }
    /// Fig 6 GCL: RTT filter + exact multiple-choice packing.
    pub fn gcl() -> Self {
        Self::preset(HardwareFilter::Both, LocationPolicy::RttFiltered, SolverKind::Exact)
    }
}

/// Stable identity of one planned instance slot across re-plans.
///
/// The Expand stage assigns each planned instance a process-unique slot id;
/// a re-plan through the same [`PlanContext`] reuses the previous plan's ids
/// for surviving instances (same instance type + region, still needed by
/// the new packing), so downstream consumers — [`adaptive::MigrationReport`]
/// and [`CloudSim::apply_plan`](crate::cloudsim::CloudSim::apply_plan) —
/// can reconcile fleets per instance instead of by label census.
pub type SlotId = u64;

/// One provisioned instance in a plan.
#[derive(Clone, Debug)]
pub struct PlannedInstance {
    /// Stable slot identity: preserved across re-plans while the instance
    /// survives, fresh for newly provisioned slots.
    pub slot_id: SlotId,
    /// Index into `plan.problem.bins`.
    pub bin_type: usize,
    /// Catalog indices + label for display / provisioning.
    pub type_idx: usize,
    pub region_idx: usize,
    pub label: String,
    pub hourly_cost: f64,
    pub has_gpu: bool,
    /// Indices into the request slice handed to `plan()`.
    pub streams: Vec<usize>,
}

/// The planner's output.
#[derive(Clone, Debug)]
pub struct Plan {
    pub problem: PackingProblem,
    pub packing: Packing,
    pub instances: Vec<PlannedInstance>,
    pub cost_per_hour: f64,
    pub non_gpu: usize,
    pub gpu: usize,
    /// Requests that could not meet their desired fps from any eligible
    /// region (served from the nearest region at a capped rate).
    pub degraded: Vec<usize>,
    pub method: SolveMethod,
    /// Region coordinates (from the catalog) for delivered-fps accounting.
    pub region_locations: Vec<geo::GeoPoint>,
    /// Pipeline telemetry: stage-cache reuse, decomposition, warm start.
    pub pipeline: PipelineStats,
}

impl Plan {
    /// The per-request delivered fps: the feedback-shed effective rate
    /// ([`StreamRequest::effective_fps`] — equals desired at tier 0),
    /// RTT-capped for degraded streams.
    pub fn delivered_fps(&self, requests: &[StreamRequest]) -> Vec<f64> {
        requests
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let eff = r.effective_fps();
                if self.degraded.contains(&i) {
                    let inst = self
                        .instances
                        .iter()
                        .find(|inst| inst.streams.contains(&i))
                        .expect("stream not assigned");
                    let rtt = r
                        .camera
                        .location
                        .rtt_ms(&self.region_locations[inst.region_idx]);
                    geo::fps_cap(rtt).min(eff)
                } else {
                    eff
                }
            })
            .collect()
    }

    /// Number of distinct regions used.
    pub fn regions_used(&self) -> usize {
        let mut rs: Vec<usize> = self.instances.iter().map(|i| i.region_idx).collect();
        rs.sort_unstable();
        rs.dedup();
        rs.len()
    }
}

/// The resource manager.
#[derive(Clone)]
pub struct Planner {
    pub catalog: Catalog,
    pub config: PlannerConfig,
}

impl Planner {
    pub fn new(catalog: Catalog, config: PlannerConfig) -> Self {
        Planner { catalog, config }
    }

    /// Build the packing problem. Returns (problem, group members, degraded).
    ///
    /// Compatibility wrapper over the pipeline's Eligibility + ProblemBuild
    /// stages with a throwaway context.
    pub fn build_problem(
        &self,
        requests: &[StreamRequest],
    ) -> Result<(PackingProblem, Vec<Vec<usize>>, Vec<usize>)> {
        pipeline::build_problem(&self.catalog, &self.config, requests)
    }

    /// Produce a full plan for the request set (cold start: no reuse).
    ///
    /// For the GCL configuration (RTT-filtered + exact), the NL and ARMVAC
    /// solutions are also evaluated as candidate incumbents: both are
    /// feasible points of GCL's search space (nearest-location assignments
    /// respect the RTT circles), so GCL returns the cheapest of the three —
    /// exactly the "globally cheapest" semantics of Mohan et al. \[8\], and it
    /// keeps GCL ≤ ARMVAC ≤-ish NL even when the exact phase must fall back
    /// to a heuristic on very large instances.
    pub fn plan(&self, requests: &[StreamRequest]) -> Result<Plan> {
        self.plan_with(requests, &mut ReplanContext::new())
    }

    /// Plan through a persistent [`ReplanContext`]: identical semantics to
    /// [`Planner::plan`], but intermediate artifacts (eligibility masks,
    /// demand vectors, arc-flow graphs, the previous packing) are reused
    /// across calls — the warm-start incremental re-plan path.
    ///
    /// For the GCL configuration this runs the candidate **portfolio** on
    /// shared infrastructure ([`portfolio::plan`]): one worker pool and one
    /// cross-candidate budget pool across all three candidates, and the
    /// winning candidate's stream→slot assignment seeded into every
    /// candidate context so a winner flip keeps the deployed fleet stable.
    pub fn plan_with(&self, requests: &[StreamRequest], ctx: &mut ReplanContext) -> Result<Plan> {
        portfolio::plan(self, requests, ctx)
    }

    /// Plan with exactly this configuration (no candidate portfolio).
    pub fn plan_single(&self, requests: &[StreamRequest]) -> Result<Plan> {
        pipeline::plan_with_context(&self.catalog, &self.config, requests, &mut PlanContext::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::scenarios;
    use crate::util::round_dp;

    /// The Fig-3 experiment pool: the paper's $0.419 CPU box + $0.650 GPU box.
    fn fig3_catalog() -> Catalog {
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]))
    }

    fn run(scn: &scenarios::Scenario, cfg: PlannerConfig) -> Result<Plan> {
        Planner::new(fig3_catalog(), cfg).plan(&scn.requests)
    }

    #[test]
    fn fig3_full_table_reproduces() {
        // The paper's Fig-3 table, all nine (scenario, strategy) cells.
        let scns = scenarios::fig3_scenarios();
        let expected = scenarios::fig3_expected();
        let configs = [PlannerConfig::st1(), PlannerConfig::st2(), PlannerConfig::st3()];
        for (si, scn) in scns.iter().enumerate() {
            for (ci, cfg) in configs.iter().enumerate() {
                let got = run(scn, cfg.clone());
                match expected[si][ci] {
                    scenarios::ExpectedOutcome::Fail => {
                        assert!(got.is_err(), "{} ST{} should fail", scn.name, ci + 1);
                    }
                    scenarios::ExpectedOutcome::Selected { non_gpu, gpu, hourly_cost } => {
                        let plan = got.unwrap_or_else(|e| {
                            panic!("{} ST{}: unexpected failure: {e}", scn.name, ci + 1)
                        });
                        assert_eq!(
                            (plan.non_gpu, plan.gpu),
                            (non_gpu, gpu),
                            "{} ST{}: instance mix",
                            scn.name,
                            ci + 1
                        );
                        assert_eq!(
                            round_dp(plan.cost_per_hour, 3),
                            hourly_cost,
                            "{} ST{}: hourly cost",
                            scn.name,
                            ci + 1
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fig3_savings_match_paper() {
        // Savings of each scenario's best strategy vs its worst:
        // S1 61%, S2 36%, S3 3% (paper's savings column).
        let scns = scenarios::fig3_scenarios();
        let mut savings = Vec::new();
        for scn in &scns {
            let costs: Vec<f64> = [PlannerConfig::st1(), PlannerConfig::st2(), PlannerConfig::st3()]
                .into_iter()
                .filter_map(|cfg| run(scn, cfg).ok().map(|p| p.cost_per_hour))
                .collect();
            let max = costs.iter().cloned().fold(0.0, f64::max);
            let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
            savings.push(((1.0 - min / max) * 100.0).round() as i64);
        }
        assert_eq!(savings, vec![61, 36, 3]);
    }

    #[test]
    fn plan_assigns_every_stream_exactly_once() {
        let scn = scenarios::fig3_scenario3();
        let plan = run(&scn, PlannerConfig::st3()).unwrap();
        let mut seen = vec![0usize; scn.requests.len()];
        for inst in &plan.instances {
            for &s in &inst.streams {
                seen[s] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "assignments: {seen:?}");
    }

    #[test]
    fn st1_never_uses_gpu_and_st2_never_cpu() {
        let scn = scenarios::fig3_scenario1();
        let p1 = run(&scn, PlannerConfig::st1()).unwrap();
        assert_eq!(p1.gpu, 0);
        let p2 = run(&scn, PlannerConfig::st2()).unwrap();
        assert_eq!(p2.non_gpu, 0);
    }

    #[test]
    fn empty_request_set_rejected() {
        let planner = Planner::new(fig3_catalog(), PlannerConfig::st3());
        assert!(planner.plan(&[]).is_err());
    }

    #[test]
    fn unrestricted_location_has_no_degraded_streams() {
        let scn = scenarios::fig3_scenario1();
        let plan = run(&scn, PlannerConfig::st3()).unwrap();
        assert!(plan.degraded.is_empty());
        assert_eq!(plan.delivered_fps(&scn.requests), vec![0.25, 0.55, 0.55, 0.55]);
    }

    #[test]
    fn location_policies_order_costs() {
        // GCL <= ARMVAC and GCL <= NL on a worldwide workload.
        let requests = scenarios::fig6_workload(24, 4.0, 5);
        let catalog = Catalog::builtin();
        let nl = Planner::new(catalog.clone(), PlannerConfig::nl()).plan(&requests).unwrap();
        let armvac = Planner::new(catalog.clone(), PlannerConfig::armvac()).plan(&requests).unwrap();
        let gcl = Planner::new(catalog, PlannerConfig::gcl()).plan(&requests).unwrap();
        assert!(gcl.cost_per_hour <= armvac.cost_per_hour + 1e-9);
        assert!(gcl.cost_per_hour <= nl.cost_per_hour + 1e-9);
    }

    #[test]
    fn rtt_filter_restricts_regions() {
        // A single Tokyo camera at 20 fps: eligible regions are near Japan.
        let requests = vec![crate::cameras::StreamRequest::new(
            crate::cameras::camera_at(
                0,
                "Tokyo",
                crate::geo::cities::TOKYO,
                crate::profiles::Resolution::VGA,
                30.0,
            ),
            crate::profiles::Program::Zf,
            20.0,
        )];
        let plan = Planner::new(Catalog::builtin(), PlannerConfig::gcl())
            .plan(&requests)
            .unwrap();
        assert_eq!(plan.instances.len(), 1);
        let region = plan.instances[0].region_idx;
        let loc = plan.region_locations[region];
        assert!(
            crate::geo::cities::TOKYO.distance_km(&loc) < crate::geo::coverage_radius_km(20.0)
        );
    }

    #[test]
    fn plan_with_context_portfolio_matches_cold_plan() {
        // Warm portfolio re-plans keep GCL's best-of-three semantics.
        let requests = scenarios::fig6_workload(18, 2.0, 9);
        let catalog = Catalog::builtin();
        let planner = Planner::new(catalog, PlannerConfig::gcl());
        let cold = planner.plan(&requests).unwrap();
        let mut ctx = ReplanContext::new();
        planner.plan_with(&requests, &mut ctx).unwrap();
        let warm = planner.plan_with(&requests, &mut ctx).unwrap();
        assert!((warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-9);
    }
}
