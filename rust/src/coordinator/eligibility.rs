//! Pipeline stage 1 — **Eligibility**: derive each request's eligible-region
//! mask from the RTT/frame-rate coupling (Fig 4: the coverage circle around
//! each camera) and coalesce identical requests into [`ItemGroup`]-shaped
//! groups.
//!
//! The stage's artifact is a [`GroupSet`]; per-request eligibility results
//! are memoized in an [`EligCache`] owned by the caller's
//! [`PlanContext`](super::pipeline::PlanContext) — a camera that has not
//! moved and still requests the same rate never recomputes its coverage
//! circle across re-plans.
//!
//! [`ItemGroup`]: crate::packing::ItemGroup

use super::LocationPolicy;
use crate::cameras::StreamRequest;
use crate::catalog::Catalog;
use crate::geo;
use crate::profiles::{Program, Resolution};
use std::collections::HashMap;

/// Identity of a stream group: requests with equal keys are interchangeable
/// for the packing problem (same program, rate, resolution, and
/// eligible-region mask), so they share one demand vector.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub program: Program,
    /// Desired fps in milli-fps (rounded), making the key hashable.
    pub fps_milli: u64,
    pub res: Resolution,
    /// Eligible-region bitmask over `catalog.regions`.
    pub mask: Vec<bool>,
    /// True if no region satisfies the RTT budget (best-effort nearest
    /// region at a capped rate).
    pub degraded: bool,
}

/// Stage-1 artifact: the request grouping plus degraded-request indices.
#[derive(Clone, Debug, Default)]
pub struct GroupSet {
    /// One key per group, in first-seen request order.
    pub keys: Vec<GroupKey>,
    /// `members[g]` = indices (into the request slice) of group `g`.
    pub members: Vec<Vec<usize>>,
    /// Requests that could not meet their desired fps from any eligible
    /// region, in request order.
    pub degraded: Vec<usize>,
}

/// Memo of per-request eligibility: (lat bits, lon bits, fps bits) →
/// (mask, degraded). Valid for one (catalog, location policy) pair — the
/// owning `PlanContext` clears it when either changes.
pub type EligCache = HashMap<(u64, u64, u64), (Vec<bool>, bool)>;

/// Stage output: the grouping plus cache telemetry.
#[derive(Clone, Debug, Default)]
pub struct EligibilityOutcome {
    pub groups: GroupSet,
    pub cache_hits: usize,
    pub cache_misses: usize,
}

/// Compute the eligible-region bitmask for one request, plus the degraded
/// flag (no region inside the coverage circle).
pub fn eligibility(
    catalog: &Catalog,
    policy: LocationPolicy,
    req: &StreamRequest,
) -> (Vec<bool>, bool) {
    let n = catalog.regions.len();
    match policy {
        LocationPolicy::Unrestricted => (vec![true; n], false),
        LocationPolicy::NearestOnly => {
            // Nearest data center of each vendor (a camera operator can
            // pick either provider's closest region).
            let nearest = nearest_regions_per_vendor(catalog, req);
            let mut mask = vec![false; n];
            let mut any_ok = false;
            for &r in &nearest {
                mask[r] = true;
                any_ok |= geo::reachable(
                    &req.camera.location,
                    &catalog.regions[r].location,
                    req.desired_fps,
                );
            }
            (mask, !any_ok)
        }
        LocationPolicy::RttFiltered => {
            let mut mask: Vec<bool> = catalog
                .regions
                .iter()
                .map(|r| geo::reachable(&req.camera.location, &r.location, req.desired_fps))
                .collect();
            if mask.iter().any(|&m| m) {
                (mask, false)
            } else {
                // Best effort: nearest regions, degraded fps.
                mask = vec![false; n];
                for r in nearest_regions_per_vendor(catalog, req) {
                    mask[r] = true;
                }
                (mask, true)
            }
        }
    }
}

/// Nearest region of each vendor present in the catalog.
pub fn nearest_regions_per_vendor(catalog: &Catalog, req: &StreamRequest) -> Vec<usize> {
    let mut best: std::collections::BTreeMap<&'static str, (usize, f64)> =
        std::collections::BTreeMap::new();
    for (i, r) in catalog.regions.iter().enumerate() {
        let d = req.camera.location.distance_km(&r.location);
        let key = match r.vendor {
            crate::catalog::Vendor::Ec2 => "ec2",
            crate::catalog::Vendor::Azure => "azure",
        };
        let e = best.entry(key).or_insert((i, d));
        if d < e.1 {
            *e = (i, d);
        }
    }
    best.values().map(|&(i, _)| i).collect()
}

/// Run the stage: eligibility (memoized) + grouping.
pub fn run(
    catalog: &Catalog,
    policy: LocationPolicy,
    requests: &[StreamRequest],
    cache: &mut EligCache,
) -> EligibilityOutcome {
    let mut out = EligibilityOutcome::default();
    let mut index: HashMap<GroupKey, usize> = HashMap::new();
    for (i, req) in requests.iter().enumerate() {
        let memo_key = (
            req.camera.location.lat.to_bits(),
            req.camera.location.lon.to_bits(),
            req.desired_fps.to_bits(),
        );
        let (mask, degraded) = match cache.get(&memo_key) {
            Some(hit) => {
                out.cache_hits += 1;
                hit.clone()
            }
            None => {
                out.cache_misses += 1;
                let fresh = eligibility(catalog, policy, req);
                cache.insert(memo_key, fresh.clone());
                fresh
            }
        };
        if degraded {
            out.groups.degraded.push(i);
        }
        let key = GroupKey {
            program: req.program,
            fps_milli: (req.desired_fps * 1000.0).round() as u64,
            res: req.camera.resolution,
            mask,
            degraded,
        };
        match index.get(&key) {
            Some(&g) => out.groups.members[g].push(i),
            None => {
                let g = out.groups.keys.len();
                index.insert(key.clone(), g);
                out.groups.keys.push(key);
                out.groups.members.push(vec![i]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::camera_at;
    use crate::geo::cities;

    fn req(id: u64, city: crate::geo::GeoPoint, fps: f64) -> StreamRequest {
        StreamRequest::new(
            camera_at(id, "c", city, Resolution::VGA, 30.0),
            Program::Zf,
            fps,
        )
    }

    #[test]
    fn unrestricted_masks_everything() {
        let catalog = Catalog::builtin();
        let (mask, degraded) =
            eligibility(&catalog, LocationPolicy::Unrestricted, &req(0, cities::CHICAGO, 1.0));
        assert!(mask.iter().all(|&m| m));
        assert!(!degraded);
    }

    #[test]
    fn grouping_coalesces_identical_requests() {
        let catalog = Catalog::builtin();
        let requests = vec![
            req(0, cities::CHICAGO, 1.0),
            req(1, cities::CHICAGO, 1.0),
            req(2, cities::CHICAGO, 2.0),
        ];
        let mut cache = EligCache::new();
        let out = run(&catalog, LocationPolicy::RttFiltered, &requests, &mut cache);
        assert_eq!(out.groups.keys.len(), 2);
        assert_eq!(out.groups.members[0], vec![0, 1]);
        assert_eq!(out.groups.members[1], vec![2]);
        // Same-location same-fps requests hit the memo.
        assert_eq!((out.cache_hits, out.cache_misses), (1, 2));
        // A second run over the same workload is all hits.
        let again = run(&catalog, LocationPolicy::RttFiltered, &requests, &mut cache);
        assert_eq!((again.cache_hits, again.cache_misses), (3, 0));
        assert_eq!(again.groups.keys, out.groups.keys);
    }

    #[test]
    fn far_camera_at_high_fps_degrades_to_nearest() {
        let catalog = Catalog::builtin();
        let mut cache = EligCache::new();
        let requests = vec![req(0, cities::MEXICO_CITY, 60.0)];
        let out = run(&catalog, LocationPolicy::RttFiltered, &requests, &mut cache);
        assert_eq!(out.groups.degraded, vec![0]);
        assert!(out.groups.keys[0].degraded);
        assert!(out.groups.keys[0].mask.iter().any(|&m| m), "nearest fallback");
    }
}
