//! Pipeline stage 1 — **Eligibility**: derive each request's eligible-region
//! mask from the RTT/frame-rate coupling (Fig 4: the coverage circle around
//! each camera) and coalesce identical requests into [`ItemGroup`]-shaped
//! groups.
//!
//! The stage's artifact is a [`GroupSet`]. Per-request state persists in a
//! [`FrontCache`] owned by the caller's
//! [`PlanContext`](super::pipeline::PlanContext):
//!
//! * the **eligibility memo** ([`EligCache`]) — a camera that has not moved
//!   and still requests the same rate never recomputes its coverage circle,
//! * the **group arena** ([`GroupArena`]) — every distinct [`GroupKey`] is
//!   interned once to a dense [`GroupId`], so the hot maps downstream key
//!   on a `u32` instead of re-hashing mask-carrying keys,
//! * the **dirty-tracking index** — the previous request slice's
//!   `StreamKey → (fingerprint, group)` assignment. A re-plan's cost in this
//!   stage is proportional to workload *drift*: requests whose key and
//!   [`Fingerprint`] both match the previous slice skip eligibility and
//!   grouping entirely and reuse their interned group.
//!
//! Masks are fixed-width [`RegionMask`] bitsets (no per-request heap
//! allocation), and float-keyed memo entries canonicalize their bit
//! patterns first ([`canon_f64_bits`]) so `-0.0`/`0.0` coordinates cannot
//! cause spurious misses.
//!
//! [`ItemGroup`]: crate::packing::ItemGroup

use super::LocationPolicy;
use crate::cameras::{stream_keys, StreamKey, StreamRequest};
use crate::catalog::Catalog;
use crate::geo;
use crate::profiles::{Program, Resolution};
use crate::util::fxhash::FxHashMap;

pub use crate::util::bitset::RegionMask;

/// Identity of a stream group: requests with equal keys are interchangeable
/// for the packing problem (same program, rate, resolution, and
/// eligible-region mask), so they share one demand vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub program: Program,
    /// Desired fps in milli-fps (rounded), making the key hashable.
    pub fps_milli: u64,
    pub res: Resolution,
    /// Eligible-region bitmask over `catalog.regions`.
    pub mask: RegionMask,
    /// True if no region satisfies the RTT budget (best-effort nearest
    /// region at a capped rate).
    pub degraded: bool,
    /// Observed cost scale in milli (rounded; open-loop default 1000).
    /// Streams whose measured demand diverged must not share a demand
    /// vector with streams still on the profile.
    pub cost_milli: u64,
    /// Backpressure degrade tier (open-loop default 0): each tier halves
    /// the provisioned fps, so tiers group apart.
    pub shed_tier: u8,
}

/// Dense id of an interned [`GroupKey`] in a [`GroupArena`]. Stable for the
/// lifetime of the owning context's arena.
pub type GroupId = u32;

/// Interning arena for [`GroupKey`]s: each distinct key is stored once and
/// addressed by a dense [`GroupId`], so demand memos, warm-start seed
/// translation, and the dirty-tracking index all key on a `u32`.
#[derive(Clone, Debug, Default)]
pub struct GroupArena {
    keys: Vec<GroupKey>,
    index: FxHashMap<GroupKey, GroupId>,
}

impl GroupArena {
    /// Id of `key`, interning it on first sight.
    pub fn intern(&mut self, key: GroupKey) -> GroupId {
        match self.index.get(&key) {
            Some(&id) => id,
            None => {
                let id = self.keys.len() as GroupId;
                self.keys.push(key);
                self.index.insert(key, id);
                id
            }
        }
    }

    /// The key behind `id`. Panics on a foreign id.
    pub fn key(&self, id: GroupId) -> &GroupKey {
        &self.keys[id as usize]
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

/// Stage-1 artifact: the request grouping plus degraded-request indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GroupSet {
    /// One key per group, in first-seen request order.
    pub keys: Vec<GroupKey>,
    /// `members[g]` = indices (into the request slice) of group `g`.
    pub members: Vec<Vec<usize>>,
    /// Requests that could not meet their desired fps from any eligible
    /// region, in request order.
    pub degraded: Vec<usize>,
}

/// Canonical bit pattern of an `f64` for cache keys. `-0.0` and `0.0` are
/// numerically identical inputs to every geo computation, but their raw bit
/// patterns differ — keying a memo on raw `to_bits` made signed-zero
/// coordinates (and distinct NaN payloads) miss entries they semantically
/// own, silently duplicating work each re-plan.
pub fn canon_f64_bits(v: f64) -> u64 {
    if v == 0.0 {
        0 // +0.0 and -0.0 collapse to the +0.0 pattern
    } else if v.is_nan() {
        f64::NAN.to_bits()
    } else {
        v.to_bits()
    }
}

/// Memo of per-request eligibility: canonical (lat bits, lon bits, fps
/// bits) → (mask, degraded). Valid for one (catalog, location policy) pair —
/// the owning `PlanContext` clears it when either changes.
pub type EligCache = FxHashMap<(u64, u64, u64), (RegionMask, bool)>;

/// Everything request-local the front-end depends on that is *not* already
/// part of the stream's [`StreamKey`] (which pins camera id, program, exact
/// fps, and duplicate occurrence): camera position, resolution, and the
/// serving-loop feedback fields. A request whose key and fingerprint both
/// match the previous re-plan's is guaranteed to group identically, so the
/// incremental path may reuse its group — and a published feedback delta
/// (cost scale or degrade tier) changes the fingerprint, dirtying exactly
/// that stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    lat_bits: u64,
    lon_bits: u64,
    res: Resolution,
    cost_bits: u64,
    shed_tier: u8,
}

/// Fingerprint of one request (canonical float bits).
pub fn fingerprint(req: &StreamRequest) -> Fingerprint {
    Fingerprint {
        lat_bits: canon_f64_bits(req.camera.location.lat),
        lon_bits: canon_f64_bits(req.camera.location.lon),
        res: req.camera.resolution,
        cost_bits: canon_f64_bits(req.feedback.cost_scale),
        shed_tier: req.feedback.shed_tier,
    }
}

/// Persistent front-end state owned by a
/// [`PlanContext`](super::pipeline::PlanContext): the eligibility memo, the
/// group-interning arena, and the previous slice's dirty-tracking index.
#[derive(Debug, Default)]
pub struct FrontCache {
    pub elig: EligCache,
    pub arena: GroupArena,
    /// Previous request slice: stream key → (fingerprint, interned group).
    prev: Option<FxHashMap<StreamKey, (Fingerprint, GroupId)>>,
}

impl FrontCache {
    /// Drop the dirty-tracking index (the next run re-derives every group
    /// assignment, still through the memo and arena).
    pub fn clear_prev(&mut self) {
        self.prev = None;
    }

    /// Drop the arena and the dirty-tracking index, keeping the eligibility
    /// memo. Previously returned [`GroupId`]s become dangling — callers
    /// must also drop anything keyed on them (demand memo, warm seed).
    pub fn clear_groups(&mut self) {
        self.arena = GroupArena::default();
        self.prev = None;
    }
}

/// Stage output: the grouping plus cache telemetry.
#[derive(Clone, Debug, Default)]
pub struct EligibilityOutcome {
    pub groups: GroupSet,
    /// Interned arena id of each group, aligned with `groups.keys`.
    pub group_ids: Vec<GroupId>,
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Requests whose group assignment was reused from the previous slice
    /// via the dirty-tracking index (no eligibility or key work at all).
    pub unchanged: usize,
    /// Requests that ran the per-request front-end (added or changed since
    /// the previous slice — the workload drift).
    pub changed: usize,
}

/// Compute the eligible-region bitmask for one request, plus the degraded
/// flag (no region inside the coverage circle).
pub fn eligibility(
    catalog: &Catalog,
    policy: LocationPolicy,
    req: &StreamRequest,
) -> (RegionMask, bool) {
    let n = catalog.regions.len();
    assert!(
        n <= RegionMask::CAPACITY,
        "catalog has {n} regions; RegionMask supports at most {}",
        RegionMask::CAPACITY
    );
    match policy {
        LocationPolicy::Unrestricted => (RegionMask::full(n), false),
        LocationPolicy::NearestOnly => {
            // Nearest data center of each vendor (a camera operator can
            // pick either provider's closest region).
            let nearest = nearest_regions_per_vendor(catalog, req);
            let mut mask = RegionMask::new();
            let mut any_ok = false;
            for &r in &nearest {
                mask.set(r);
                any_ok |= geo::reachable(
                    &req.camera.location,
                    &catalog.regions[r].location,
                    req.desired_fps,
                );
            }
            (mask, !any_ok)
        }
        LocationPolicy::RttFiltered => {
            let mut mask = RegionMask::new();
            for (r, region) in catalog.regions.iter().enumerate() {
                if geo::reachable(&req.camera.location, &region.location, req.desired_fps) {
                    mask.set(r);
                }
            }
            if mask.any() {
                (mask, false)
            } else {
                // Best effort: nearest regions, degraded fps.
                let mut mask = RegionMask::new();
                for r in nearest_regions_per_vendor(catalog, req) {
                    mask.set(r);
                }
                (mask, true)
            }
        }
    }
}

/// Nearest region of each vendor present in the catalog.
pub fn nearest_regions_per_vendor(catalog: &Catalog, req: &StreamRequest) -> Vec<usize> {
    let mut best: std::collections::BTreeMap<&'static str, (usize, f64)> =
        std::collections::BTreeMap::new();
    for (i, r) in catalog.regions.iter().enumerate() {
        let d = req.camera.location.distance_km(&r.location);
        let key = match r.vendor {
            crate::catalog::Vendor::Ec2 => "ec2",
            crate::catalog::Vendor::Azure => "azure",
        };
        let e = best.entry(key).or_insert((i, d));
        if d < e.1 {
            *e = (i, d);
        }
    }
    best.values().map(|&(i, _)| i).collect()
}

/// Run the stage through a persistent [`FrontCache`], incrementally when
/// the cache carries the previous slice's index.
///
/// `keys[i]` must be the stable identity of request `i` (from
/// [`stream_keys`]). Requests whose key and fingerprint both match the
/// previous run reuse their interned group directly; everything else runs
/// memoized eligibility + key interning. The grouping pass then assigns
/// first-seen group order over the whole slice, so the outcome is
/// **bit-identical to a cold full rebuild by construction** — reuse decides
/// only how much per-request work is skipped, never what is produced.
pub fn run_incremental(
    catalog: &Catalog,
    policy: LocationPolicy,
    requests: &[StreamRequest],
    keys: &[StreamKey],
    front: &mut FrontCache,
) -> EligibilityOutcome {
    debug_assert_eq!(requests.len(), keys.len());
    let mut out = EligibilityOutcome::default();
    let mut next: FxHashMap<StreamKey, (Fingerprint, GroupId)> =
        FxHashMap::with_capacity_and_hasher(requests.len(), Default::default());
    let mut gids: Vec<GroupId> = Vec::with_capacity(requests.len());
    for (req, &skey) in requests.iter().zip(keys) {
        let fp = fingerprint(req);
        let gid = match front.prev.as_ref().and_then(|p| p.get(&skey)) {
            Some(&(prev_fp, gid)) if prev_fp == fp => {
                out.unchanged += 1;
                gid
            }
            _ => {
                out.changed += 1;
                let memo_key = (fp.lat_bits, fp.lon_bits, canon_f64_bits(req.desired_fps));
                let (mask, degraded) = match front.elig.get(&memo_key) {
                    Some(&hit) => {
                        out.cache_hits += 1;
                        hit
                    }
                    None => {
                        out.cache_misses += 1;
                        let fresh = eligibility(catalog, policy, req);
                        front.elig.insert(memo_key, fresh);
                        fresh
                    }
                };
                front.arena.intern(GroupKey {
                    program: req.program,
                    fps_milli: (req.desired_fps * 1000.0).round() as u64,
                    res: req.camera.resolution,
                    mask,
                    degraded,
                    cost_milli: (req.feedback.cost_scale * 1000.0).round() as u64,
                    shed_tier: req.feedback.shed_tier,
                })
            }
        };
        next.insert(skey, (fp, gid));
        gids.push(gid);
    }

    // First-seen grouping over the whole slice (identical to a cold
    // rebuild); the arena id stands in for the full key, which is copied
    // out only once per distinct group.
    let mut index: FxHashMap<GroupId, usize> = FxHashMap::default();
    for (i, &gid) in gids.iter().enumerate() {
        if front.arena.key(gid).degraded {
            out.groups.degraded.push(i);
        }
        match index.get(&gid) {
            Some(&g) => out.groups.members[g].push(i),
            None => {
                index.insert(gid, out.groups.keys.len());
                out.groups.keys.push(*front.arena.key(gid));
                out.group_ids.push(gid);
                out.groups.members.push(vec![i]);
            }
        }
    }
    front.prev = Some(next);
    out
}

/// Run the stage statelessly (cold): eligibility (memoized through the
/// caller's `cache`) + grouping, with a throwaway arena and no
/// dirty-tracking. Exactly the incremental path with empty previous state.
pub fn run(
    catalog: &Catalog,
    policy: LocationPolicy,
    requests: &[StreamRequest],
    cache: &mut EligCache,
) -> EligibilityOutcome {
    let mut front = FrontCache::default();
    std::mem::swap(&mut front.elig, cache);
    let keys = stream_keys(requests);
    let out = run_incremental(catalog, policy, requests, &keys, &mut front);
    std::mem::swap(&mut front.elig, cache);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::camera_at;
    use crate::geo::cities;

    fn req(id: u64, city: crate::geo::GeoPoint, fps: f64) -> StreamRequest {
        StreamRequest::new(
            camera_at(id, "c", city, Resolution::VGA, 30.0),
            Program::Zf,
            fps,
        )
    }

    #[test]
    fn unrestricted_masks_everything() {
        let catalog = Catalog::builtin();
        let (mask, degraded) =
            eligibility(&catalog, LocationPolicy::Unrestricted, &req(0, cities::CHICAGO, 1.0));
        assert_eq!(mask.count(), catalog.regions.len());
        assert!(!degraded);
    }

    #[test]
    fn grouping_coalesces_identical_requests() {
        let catalog = Catalog::builtin();
        let requests = vec![
            req(0, cities::CHICAGO, 1.0),
            req(1, cities::CHICAGO, 1.0),
            req(2, cities::CHICAGO, 2.0),
        ];
        let mut cache = EligCache::default();
        let out = run(&catalog, LocationPolicy::RttFiltered, &requests, &mut cache);
        assert_eq!(out.groups.keys.len(), 2);
        assert_eq!(out.groups.members[0], vec![0, 1]);
        assert_eq!(out.groups.members[1], vec![2]);
        // Same-location same-fps requests hit the memo.
        assert_eq!((out.cache_hits, out.cache_misses), (1, 2));
        // A second run over the same workload is all hits.
        let again = run(&catalog, LocationPolicy::RttFiltered, &requests, &mut cache);
        assert_eq!((again.cache_hits, again.cache_misses), (3, 0));
        assert_eq!(again.groups.keys, out.groups.keys);
    }

    #[test]
    fn far_camera_at_high_fps_degrades_to_nearest() {
        let catalog = Catalog::builtin();
        let mut cache = EligCache::default();
        let requests = vec![req(0, cities::MEXICO_CITY, 60.0)];
        let out = run(&catalog, LocationPolicy::RttFiltered, &requests, &mut cache);
        assert_eq!(out.groups.degraded, vec![0]);
        assert!(out.groups.keys[0].degraded);
        assert!(out.groups.keys[0].mask.any(), "nearest fallback");
    }

    #[test]
    fn incremental_rerun_skips_unchanged_requests_bit_identically() {
        let catalog = Catalog::builtin();
        let requests = vec![
            req(0, cities::CHICAGO, 1.0),
            req(1, cities::NEW_YORK, 2.0),
            req(2, cities::TOKYO, 4.0),
        ];
        let keys = stream_keys(&requests);
        let mut front = FrontCache::default();
        let first =
            run_incremental(&catalog, LocationPolicy::RttFiltered, &requests, &keys, &mut front);
        assert_eq!((first.unchanged, first.changed), (0, 3));

        // Identical slice: everything rides the dirty-tracking index.
        let again =
            run_incremental(&catalog, LocationPolicy::RttFiltered, &requests, &keys, &mut front);
        assert_eq!((again.unchanged, again.changed), (3, 0));
        assert_eq!((again.cache_hits, again.cache_misses), (0, 0));
        assert_eq!(again.groups, first.groups);
        assert_eq!(again.group_ids, first.group_ids);

        // One camera changes rate: only that request re-runs, and the
        // outcome matches a cold rebuild of the new slice.
        let mut drifted = requests.clone();
        drifted[1].desired_fps = 3.0;
        let dkeys = stream_keys(&drifted);
        let warm =
            run_incremental(&catalog, LocationPolicy::RttFiltered, &drifted, &dkeys, &mut front);
        assert_eq!((warm.unchanged, warm.changed), (2, 1));
        let cold = run(&catalog, LocationPolicy::RttFiltered, &drifted, &mut EligCache::default());
        assert_eq!(warm.groups, cold.groups);
    }

    #[test]
    fn camera_move_invalidates_its_front_entry() {
        // 20 fps keeps the coverage circles regional (a few thousand km), so
        // a Chicago→Tokyo move genuinely changes the eligible-region mask.
        let catalog = Catalog::builtin();
        let mut requests = vec![req(0, cities::CHICAGO, 20.0), req(1, cities::CHICAGO, 20.0)];
        let keys = stream_keys(&requests);
        let mut front = FrontCache::default();
        run_incremental(&catalog, LocationPolicy::RttFiltered, &requests, &keys, &mut front);
        // Same stream key, new location: the fingerprint must force a
        // re-derive (a moved camera has a different coverage circle).
        requests[0].camera.location = cities::TOKYO;
        let keys = stream_keys(&requests);
        let out =
            run_incremental(&catalog, LocationPolicy::RttFiltered, &requests, &keys, &mut front);
        assert_eq!((out.unchanged, out.changed), (1, 1));
        let cold = run(&catalog, LocationPolicy::RttFiltered, &requests, &mut EligCache::default());
        assert_eq!(out.groups, cold.groups);
        assert_eq!(out.groups.keys.len(), 2, "moved camera must leave the Chicago group");
    }

    #[test]
    fn feedback_delta_dirties_exactly_the_observed_stream() {
        let catalog = Catalog::builtin();
        let requests = vec![req(0, cities::CHICAGO, 1.0), req(1, cities::CHICAGO, 1.0)];
        let keys = stream_keys(&requests);
        let mut front = FrontCache::default();
        let first =
            run_incremental(&catalog, LocationPolicy::RttFiltered, &requests, &keys, &mut front);
        assert_eq!(first.groups.keys.len(), 1, "identical requests share one group");

        // A published cost observation on one stream: only that stream
        // re-runs (feedback is in the fingerprint), the eligibility memo
        // still hits (coverage circles ignore feedback), and the group
        // splits (diverged cost must not share a demand vector).
        let mut drifted = requests.clone();
        drifted[1].feedback.cost_scale = 1.5;
        let out =
            run_incremental(&catalog, LocationPolicy::RttFiltered, &drifted, &keys, &mut front);
        assert_eq!((out.unchanged, out.changed), (1, 1));
        assert_eq!((out.cache_hits, out.cache_misses), (1, 0));
        assert_eq!(out.groups.keys.len(), 2);
        assert_eq!(out.groups.keys[1].cost_milli, 1500);

        // A degrade tier likewise fingerprints and groups apart.
        drifted[1].feedback = crate::cameras::DemandFeedback { cost_scale: 1.0, shed_tier: 1 };
        let out2 =
            run_incremental(&catalog, LocationPolicy::RttFiltered, &drifted, &keys, &mut front);
        assert_eq!((out2.unchanged, out2.changed), (1, 1));
        assert_eq!(out2.groups.keys[1].shed_tier, 1);
    }

    #[test]
    fn signed_zero_coordinates_share_one_memo_entry() {
        // Regression: raw `to_bits` keys treated -0.0 and 0.0 as distinct,
        // so cameras on the equator/meridian missed their own memo entries.
        let catalog = Catalog::builtin();
        let pos = req(0, crate::geo::GeoPoint::new(0.0, 51.0), 2.0);
        let neg = req(1, crate::geo::GeoPoint::new(-0.0, 51.0), 2.0);
        assert_ne!((-0.0f64).to_bits(), 0.0f64.to_bits(), "raw bits do differ");
        assert_eq!(canon_f64_bits(0.0), canon_f64_bits(-0.0));
        let mut cache = EligCache::default();
        let out = run(&catalog, LocationPolicy::RttFiltered, &[pos, neg], &mut cache);
        assert_eq!((out.cache_hits, out.cache_misses), (1, 1), "-0.0 must hit 0.0's entry");
        assert_eq!(out.groups.keys.len(), 1, "identical coordinates group together");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn arena_interns_each_key_once() {
        let mut arena = GroupArena::default();
        let a = GroupKey {
            program: Program::Zf,
            fps_milli: 1000,
            res: Resolution::VGA,
            mask: RegionMask::full(3),
            degraded: false,
            cost_milli: 1000,
            shed_tier: 0,
        };
        let mut b = a;
        b.fps_milli = 2000;
        let ia = arena.intern(a);
        let ib = arena.intern(b);
        assert_ne!(ia, ib);
        assert_eq!(arena.intern(a), ia);
        assert_eq!(arena.len(), 2);
        assert_eq!(*arena.key(ia), a);
    }
}
