//! Adaptive per-component solver budgets.
//!
//! The Solve stage decomposes every re-plan into independent per-region
//! subproblems. With static budgets each component gets the same
//! [`SolveOptions`] constants — which wastes budget on trivial metros and
//! starves the hard ones once deployments reach thousands of cameras per
//! city (Jain et al., "Scaling Video Analytics Systems to Large Camera
//! Deployments"). This module re-derives each component's budgets every
//! re-plan from its own solve telemetry plus a global pool:
//!
//! * a component whose last exact solve used far less than the static seed
//!   budget *donates* the difference between the seed and its predicted need
//!   (observed usage × a safety margin) into the pool,
//! * a component that fell back to a heuristic (budget wall) or could not
//!   prove optimality *requests* an escalated budget, granted from the pool
//!   (proportionally when the pool is oversubscribed),
//! * a component that keeps needing more than the seed keeps its
//!   history-derived need, so grants are sticky rather than oscillating,
//! * **no component is ever allocated less than the static seed budget** —
//!   the floor the property tests pin down. Donation reflects *predicted*
//!   slack, so total worst-case work stays bounded by roughly the static
//!   pool: donors were measured not to use what they give away.
//!
//! The same policy is applied independently to the three budget axes:
//! arc-flow graph nodes, joint-ILP variables, and branch-and-bound nodes.
//!
//! Since PR 5 the pool can also span *planning contexts*: the GCL portfolio
//! (`coordinator::portfolio`) evaluates three candidate strategies, and each
//! candidate's allocation publishes its leftover slack ([`AxisSlack`]) for
//! the others to draw on next round — the alternates' donated slack funds
//! the main exact solve. [`allocate_pooled`] takes that external share and
//! guarantees, in addition to the static floor, that every component's
//! pooled budget is **at least its isolated allocation** (the external pool
//! can only add, so pooled plans are never worse than isolated ones), and
//! that the published slack never exceeds what this round's own donors
//! actually left unclaimed.

use crate::packing::mcvbp::SolveOptions;

/// Row-count weight of the revised-simplex per-node cost model.
///
/// Under the dense tableau the Solve stage's node guard divided
/// `milp_node_scale` by the ILP's *variable* count: every pivot touched the
/// whole `rows × vars` tableau, so vars was the right latency proxy. The
/// revised core prices columns against a factorized basis instead, and
/// `benches/bench_solver.rs` (see the `calibration` section of
/// `BENCH_solver.json`) shows node cost on the wide-and-sparse arc-flow
/// ILPs (rows ≪ vars) tracking roughly `8 × rows` — FTRAN/BTRAN and the
/// eta file scale with the basis, not the tableau width — while on
/// near-square ILPs the dense-era vars proxy still binds first. The
/// weight stays conservative for the partial-pricing default
/// (`solve_lp_partial`): candidate-list repricing only lowers the
/// per-node column work below the full-Dantzig sweep this constant was
/// calibrated against, so budgets derived from it never starve a node.
pub const NODE_COST_ROWS_WEIGHT: usize = 8;

/// Calibrated per-node LP cost of an ILP with `vars` columns and `rows`
/// constraints under the revised simplex: `min(vars, 8 × rows)`, floored at
/// 1. Replaces the bare `vars` divisor in the Solve stage's node guard
/// (`max_nodes = min(max_nodes, milp_node_scale / milp_node_cost(..))`).
/// Because the value never exceeds `vars`, every node budget under the
/// revised core is at least what the dense model granted — budgets only
/// grow, so no previously exact component regresses to a heuristic.
pub fn milp_node_cost(vars: usize, rows: usize) -> usize {
    vars.max(1).min(NODE_COST_ROWS_WEIGHT.saturating_mul(rows).max(1))
}

/// Donated solver slack on the three budget axes, published by one
/// allocation round for other planning contexts to draw on (the
/// cross-candidate pool of `coordinator::portfolio`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AxisSlack {
    pub graph_nodes: usize,
    pub milp_vars: usize,
    pub milp_nodes: usize,
}

impl AxisSlack {
    pub fn is_zero(&self) -> bool {
        self.graph_nodes == 0 && self.milp_vars == 0 && self.milp_nodes == 0
    }

    /// Component-wise saturating sum.
    pub fn plus(&self, other: &AxisSlack) -> AxisSlack {
        AxisSlack {
            graph_nodes: self.graph_nodes.saturating_add(other.graph_nodes),
            milp_vars: self.milp_vars.saturating_add(other.milp_vars),
            milp_nodes: self.milp_nodes.saturating_add(other.milp_nodes),
        }
    }
}

/// Result of a pooled allocation round.
pub struct PooledAllocation {
    /// Per-component solver options, index-aligned with the history slice.
    pub opts: Vec<SolveOptions>,
    /// Arc-flow nodes each component drew from the *external* pool — the
    /// grant above what the isolated (external-free) allocation would have
    /// given it. Zero everywhere when `external` was zero.
    pub drawn_nodes: Vec<usize>,
    /// Leftover internal slack published back for the other candidates.
    pub published: AxisSlack,
}

/// Telemetry of one component's most recent solve, recorded by the Solve
/// stage into the `PlanContext` and consumed by [`allocate`] on the next
/// re-plan.
#[derive(Clone, Debug, Default)]
pub struct ComponentTelemetry {
    /// Arc-flow nodes built (uncompressed, cumulative over bin types).
    pub graph_nodes: usize,
    /// Joint-ILP variable count.
    pub milp_vars: usize,
    /// Branch-and-bound nodes expanded.
    pub milp_nodes: usize,
    /// The adopted packing came from the exact phase.
    pub exact: bool,
    /// ...with proven optimality.
    pub proven: bool,
    /// A structural budget (graph nodes / ILP variables) forced a fallback.
    pub budget_exhausted: bool,
    /// The budgets the solve ran under (escalation base on failure).
    pub graph_budget: usize,
    pub var_budget: usize,
    pub node_budget: usize,
}

impl ComponentTelemetry {
    /// A component is *hard* when its last attempt hit a wall: heuristic
    /// fallback, structural budget exhaustion, or an unproven exact phase.
    pub fn is_hard(&self) -> bool {
        self.budget_exhausted || !self.exact || !self.proven
    }
}

/// Safety margin over an exact solve's observed usage when predicting the
/// next re-plan's need.
const HEADROOM: usize = 2;
/// Escalation factor over the failed budget when a component was hard.
const ESCALATE: usize = 4;
/// Absolute ceiling on any escalation request, as a multiple of the static
/// seed budget. Without it a permanently hard component's request grows
/// geometrically (4× the previously *granted* budget each re-plan) and, via
/// proportional rationing, starves every recoverable requester of the pool.
const ESCALATE_CAP: usize = 64;

/// Grants above the static floor for one axis, given `slack` to distribute.
/// `history_complete` gates the degenerate self-escalation path: when every
/// known component is a requester and the pool is empty, bounded
/// self-escalation (≤ ESCALATE × static) replaces the pool so a hard lone
/// component is not pinned to the seed budget forever.
fn axis_grants(
    static_budget: usize,
    request: &[usize],
    slack: usize,
    history_complete: bool,
) -> Vec<usize> {
    let total_request: u128 = request.iter().map(|&r| r as u128).sum();
    let self_escalate =
        slack == 0 && history_complete && request.iter().all(|&r| r > 0);
    request
        .iter()
        .map(|&r| {
            if r == 0 {
                0
            } else if self_escalate {
                r.min(static_budget.saturating_mul(ESCALATE - 1))
            } else if total_request <= slack as u128 {
                r
            } else {
                // Oversubscribed pool: grant proportionally to the requests.
                (slack as u128 * r as u128 / total_request) as usize
            }
        })
        .collect()
}

/// One budget axis: floor every component at `static_budget`, collect the
/// predicted slack of easy components plus the `external` cross-candidate
/// share, grant it to the requesters. Returns per-component budgets, the
/// per-component external draw (grant above the isolated allocation), and
/// the leftover internal slack to publish.
fn allocate_axis_pooled(
    static_budget: usize,
    history: &[Option<&ComponentTelemetry>],
    usage: impl Fn(&ComponentTelemetry) -> usize,
    ran_under: impl Fn(&ComponentTelemetry) -> usize,
    external: usize,
) -> (Vec<usize>, Vec<usize>, usize) {
    let n = history.len();
    let mut request = vec![0usize; n]; // extra wanted above the static floor
    let mut slack = 0usize;
    for (i, t) in history.iter().enumerate() {
        match t {
            Some(t) if t.is_hard() => {
                // Escalate over whatever the failed attempt ran under,
                // capped so a hopeless component cannot ratchet forever.
                let want = ran_under(t)
                    .max(static_budget)
                    .saturating_mul(ESCALATE)
                    .min(static_budget.saturating_mul(ESCALATE_CAP));
                request[i] = want.saturating_sub(static_budget);
            }
            Some(t) => {
                // Sticky need for components that keep requiring a grant;
                // donation of the predicted slack otherwise.
                let need = usage(t).saturating_mul(HEADROOM);
                if need > static_budget {
                    request[i] = need - static_budget;
                } else {
                    slack += static_budget - need;
                }
            }
            None => {} // no history: the static seed, no donation
        }
    }
    let complete = history.iter().all(Option::is_some);
    let iso = axis_grants(static_budget, &request, slack, complete);
    // The pooled grants are the component-wise max of the isolated grants
    // and the grants a pool enlarged by `external` would give: the external
    // share can only ever add budget, so pooled allocation dominates
    // isolated allocation on every component (property-tested).
    let grants: Vec<usize> = if external == 0 {
        iso.clone()
    } else {
        let pooled = axis_grants(
            static_budget,
            &request,
            slack.saturating_add(external),
            complete,
        );
        iso.iter().zip(&pooled).map(|(&a, &b)| a.max(b)).collect()
    };
    let drawn: Vec<usize> = grants.iter().zip(&iso).map(|(&g, &i)| g - i).collect();
    // Publish only what this round's own donors left unclaimed — never the
    // external share (no double counting across candidates).
    let granted_total: usize = grants.iter().sum();
    let published = slack.saturating_sub(granted_total);
    let budgets = grants.iter().map(|&g| static_budget + g).collect();
    (budgets, drawn, published)
}

/// Derive each component's [`SolveOptions`] from the static seed options
/// and the components' solve history (`None` = never seen). The returned
/// vector is index-aligned with `history`.
pub fn allocate(
    static_opts: &SolveOptions,
    history: &[Option<&ComponentTelemetry>],
) -> Vec<SolveOptions> {
    allocate_pooled(static_opts, history, AxisSlack::default()).opts
}

/// [`allocate`] with an `external` cross-candidate pool share: the slack the
/// *other* portfolio candidates published last round is added to this
/// context's own donated pool before grants are rationed. With a zero
/// `external` this is exactly [`allocate`]. Every component still floors at
/// the static seed, and every pooled budget is at least the isolated one.
pub fn allocate_pooled(
    static_opts: &SolveOptions,
    history: &[Option<&ComponentTelemetry>],
    external: AxisSlack,
) -> PooledAllocation {
    let (graph, drawn_nodes, graph_pub) = allocate_axis_pooled(
        static_opts.max_graph_nodes,
        history,
        |t| t.graph_nodes,
        |t| t.graph_budget,
        external.graph_nodes,
    );
    let (vars, _, vars_pub) = allocate_axis_pooled(
        static_opts.max_milp_vars,
        history,
        |t| t.milp_vars,
        |t| t.var_budget,
        external.milp_vars,
    );
    let (nodes, _, nodes_pub) = allocate_axis_pooled(
        static_opts.milp.max_nodes,
        history,
        |t| t.milp_nodes,
        |t| t.node_budget,
        external.milp_nodes,
    );
    let opts = (0..history.len())
        .map(|i| {
            let mut o = static_opts.clone();
            o.max_graph_nodes = graph[i];
            o.max_milp_vars = vars[i];
            o.milp.max_nodes = nodes[i];
            // Scale the per-ILP node guard with the node grant so a granted
            // budget is not silently clamped back to the static ceiling.
            let scale_up = nodes[i].div_ceil(static_opts.milp.max_nodes.max(1)).max(1);
            o.milp_node_scale = static_opts.milp_node_scale.saturating_mul(scale_up);
            o
        })
        .collect();
    PooledAllocation {
        opts,
        drawn_nodes,
        published: AxisSlack {
            graph_nodes: graph_pub,
            milp_vars: vars_pub,
            milp_nodes: nodes_pub,
        },
    }
}

/// Cross-**shard** slack ledger — the arbiter-level analogue of the
/// portfolio's `SharedBudgetPool`. Each shard publishes the slack its own
/// pooled allocation left unclaimed (`PlanContext::pool_out`); when a shard
/// re-plans, the ledger hands it the sum of every *other* shard's last
/// published slack as the `external` input to [`allocate_pooled`]. A shard's
/// own entry is excluded (its own slack already feeds its in-context pool),
/// and a retired shard's donation is withdrawn with it.
#[derive(Debug, Default)]
pub struct ShardSlackLedger {
    donated: std::collections::BTreeMap<u32, AxisSlack>,
}

impl ShardSlackLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `shard`'s published slack, replacing its previous donation.
    pub fn publish(&mut self, shard: u32, slack: AxisSlack) {
        self.donated.insert(shard, slack);
    }

    /// Withdraw a departed shard's donation. Returns what it had published.
    pub fn retire(&mut self, shard: u32) -> Option<AxisSlack> {
        self.donated.remove(&shard)
    }

    /// The external pool share for `shard`: every other shard's last
    /// published slack, summed per axis.
    pub fn available_for(&self, shard: u32) -> AxisSlack {
        self.donated
            .iter()
            .filter(|(&s, _)| s != shard)
            .fold(AxisSlack::default(), |acc, (_, sl)| acc.plus(sl))
    }

    /// Number of shards currently holding a donation entry.
    pub fn donors(&self) -> usize {
        self.donated.len()
    }

    /// Sum of all donations (diagnostics; a shard never draws its own).
    pub fn total_donated(&self) -> AxisSlack {
        self.donated.values().fold(AxisSlack::default(), |acc, sl| acc.plus(sl))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn easy(graph_nodes: usize) -> ComponentTelemetry {
        ComponentTelemetry {
            graph_nodes,
            milp_vars: 10,
            milp_nodes: 5,
            exact: true,
            proven: true,
            budget_exhausted: false,
            graph_budget: 6_000,
            var_budget: 600,
            node_budget: 2_000,
        }
    }

    fn hard(graph_budget: usize) -> ComponentTelemetry {
        ComponentTelemetry {
            graph_nodes: graph_budget, // built up to the wall
            milp_vars: 0,
            milp_nodes: 0,
            exact: false,
            proven: false,
            budget_exhausted: true,
            graph_budget,
            var_budget: 600,
            node_budget: 2_000,
        }
    }

    #[test]
    fn no_history_means_static_budgets() {
        let opts = SolveOptions::default();
        let out = allocate(&opts, &[None, None]);
        for o in &out {
            assert_eq!(o.max_graph_nodes, opts.max_graph_nodes);
            assert_eq!(o.max_milp_vars, opts.max_milp_vars);
            assert_eq!(o.milp.max_nodes, opts.milp.max_nodes);
            assert_eq!(o.milp_node_scale, opts.milp_node_scale);
        }
    }

    #[test]
    fn donors_fund_the_hard_component() {
        let opts = SolveOptions::default();
        let donors = [easy(40), easy(60), easy(25)];
        let wall = hard(opts.max_graph_nodes);
        let history: Vec<Option<&ComponentTelemetry>> = vec![
            Some(&donors[0]),
            Some(&wall),
            Some(&donors[1]),
            Some(&donors[2]),
        ];
        let out = allocate(&opts, &history);
        // Every component keeps at least the static floor...
        for o in &out {
            assert!(o.max_graph_nodes >= opts.max_graph_nodes);
        }
        // ...and the hard one gets strictly more, up to ESCALATE× the
        // budget it failed under (pool permitting).
        assert!(out[1].max_graph_nodes > opts.max_graph_nodes, "{out:?}");
        assert!(out[1].max_graph_nodes <= opts.max_graph_nodes * ESCALATE);
    }

    #[test]
    fn grants_never_exceed_the_donated_slack() {
        let opts = SolveOptions::default();
        let donor = easy(2_900); // predicted need 5 800 of 6 000 → donates 200
        let walls = [hard(6_000), hard(6_000), hard(6_000)];
        let history: Vec<Option<&ComponentTelemetry>> = vec![
            Some(&donor),
            Some(&walls[0]),
            Some(&walls[1]),
            Some(&walls[2]),
        ];
        let out = allocate(&opts, &history);
        let granted: usize = out
            .iter()
            .map(|o| o.max_graph_nodes - opts.max_graph_nodes)
            .sum();
        assert!(granted <= 200, "oversubscribed pool must ration: {granted}");
        for o in &out {
            assert!(o.max_graph_nodes >= opts.max_graph_nodes, "floor violated");
        }
    }

    #[test]
    fn sustained_needs_stay_granted_after_success() {
        // A previously hard component that completed exactly under a grant
        // must not be dropped back to the static floor (oscillation) while
        // the pool still has the slack to fund its measured need.
        let opts = SolveOptions::default();
        let donors: Vec<ComponentTelemetry> = (0..7).map(|_| easy(40)).collect();
        let grown = ComponentTelemetry {
            graph_nodes: 20_000,
            exact: true,
            proven: true,
            budget_exhausted: false,
            graph_budget: 24_000,
            ..easy(0)
        };
        let mut history: Vec<Option<&ComponentTelemetry>> = donors.iter().map(Some).collect();
        history.push(Some(&grown));
        let out = allocate(&opts, &history);
        assert!(
            out[7].max_graph_nodes >= 20_000,
            "sticky grant lost: {}",
            out[7].max_graph_nodes
        );
    }

    #[test]
    fn escalation_requests_are_capped_even_with_a_deep_pool() {
        // A permanently hard component whose granted budget ratcheted high
        // must not request 4× it forever: the request is capped at
        // ESCALATE_CAP × static no matter how much slack the pool has.
        let opts = SolveOptions::default();
        let donors: Vec<ComponentTelemetry> = (0..100).map(|_| easy(10)).collect();
        let runaway = hard(opts.max_graph_nodes * 1_000);
        let mut history: Vec<Option<&ComponentTelemetry>> = donors.iter().map(Some).collect();
        history.push(Some(&runaway));
        let out = allocate(&opts, &history);
        assert_eq!(
            out[100].max_graph_nodes,
            opts.max_graph_nodes * ESCALATE_CAP,
            "runaway request must hit the cap exactly"
        );
    }

    #[test]
    fn lone_hard_component_self_escalates_boundedly() {
        let opts = SolveOptions::default();
        let wall = hard(opts.max_graph_nodes);
        let out = allocate(&opts, &[Some(&wall)]);
        assert!(out[0].max_graph_nodes > opts.max_graph_nodes);
        assert!(out[0].max_graph_nodes <= opts.max_graph_nodes * ESCALATE);
        // Re-running from the escalated budget stays at the cap — no
        // unbounded growth across re-plans.
        let wall2 = hard(out[0].max_graph_nodes);
        let out2 = allocate(&opts, &[Some(&wall2)]);
        assert_eq!(out2[0].max_graph_nodes, opts.max_graph_nodes * ESCALATE);
    }

    #[test]
    fn pooled_with_zero_external_is_exactly_the_isolated_allocation() {
        let opts = SolveOptions::default();
        let donor = easy(40);
        let wall = hard(opts.max_graph_nodes);
        let history: Vec<Option<&ComponentTelemetry>> =
            vec![Some(&donor), Some(&wall), None];
        let iso = allocate(&opts, &history);
        let pooled = allocate_pooled(&opts, &history, AxisSlack::default());
        for (a, b) in iso.iter().zip(&pooled.opts) {
            assert_eq!(a.max_graph_nodes, b.max_graph_nodes);
            assert_eq!(a.max_milp_vars, b.max_milp_vars);
            assert_eq!(a.milp.max_nodes, b.milp.max_nodes);
        }
        assert!(pooled.drawn_nodes.iter().all(|&d| d == 0));
    }

    #[test]
    fn external_pool_tops_up_an_oversubscribed_internal_pool() {
        // One donor, one wall: the wall's request dwarfs the internal slack,
        // so the isolated grant is the whole internal pool — the external
        // share adds on top, and the draw is attributed to the wall.
        let opts = SolveOptions::default();
        let donor = easy(40); // slack = 6000 - 80 = 5920
        let wall = hard(opts.max_graph_nodes); // request = 3 x 6000 = 18000
        let history: Vec<Option<&ComponentTelemetry>> = vec![Some(&donor), Some(&wall)];
        let external = AxisSlack { graph_nodes: 10_000, ..AxisSlack::default() };
        let iso = allocate(&opts, &history);
        let pooled = allocate_pooled(&opts, &history, external);
        assert_eq!(pooled.drawn_nodes[0], 0, "the donor draws nothing");
        assert_eq!(pooled.drawn_nodes[1], 10_000, "the wall drinks the whole share");
        assert_eq!(
            pooled.opts[1].max_graph_nodes,
            iso[1].max_graph_nodes + 10_000
        );
        // Everything internal was granted away: nothing left to publish.
        assert_eq!(pooled.published.graph_nodes, 0);
    }

    #[test]
    fn all_donor_round_publishes_the_full_internal_slack() {
        let opts = SolveOptions::default();
        let donors = [easy(40), easy(100)];
        let history: Vec<Option<&ComponentTelemetry>> =
            vec![Some(&donors[0]), Some(&donors[1])];
        let pooled = allocate_pooled(&opts, &history, AxisSlack::default());
        let want = (opts.max_graph_nodes - 80) + (opts.max_graph_nodes - 200);
        assert_eq!(pooled.published.graph_nodes, want);
        assert!(pooled.drawn_nodes.iter().all(|&d| d == 0));
    }

    #[test]
    fn external_pool_lifts_a_lone_component_past_its_bounded_self_grant() {
        // A lone hard component whose request fits under the ESCALATE x
        // static self-grant never needs the pool; once a second consecutive
        // failure pushes its request past that bound, only a real donated
        // pool (here: another candidate's) can fund the difference.
        let opts = SolveOptions::default();
        let b = opts.max_graph_nodes;
        let first_failure = hard(b);
        let external = AxisSlack { graph_nodes: 20 * b, ..AxisSlack::default() };
        let round1 = allocate_pooled(&opts, &[Some(&first_failure)], external);
        // request = 3B <= self-grant cap 3B: the pool adds nothing yet.
        assert_eq!(round1.drawn_nodes[0], 0);
        assert_eq!(round1.opts[0].max_graph_nodes, 4 * b);
        let second_failure = hard(4 * b); // want 16B, request 15B
        let round2 = allocate_pooled(&opts, &[Some(&second_failure)], external);
        assert_eq!(
            round2.opts[0].max_graph_nodes,
            b + 15 * b,
            "the external pool must fund the full request"
        );
        assert_eq!(round2.drawn_nodes[0], 15 * b - 3 * b);
    }

    #[test]
    fn axis_slack_plus_saturates() {
        let a = AxisSlack { graph_nodes: usize::MAX, milp_vars: 1, milp_nodes: 2 };
        let b = AxisSlack { graph_nodes: 10, milp_vars: 2, milp_nodes: 3 };
        let s = a.plus(&b);
        assert_eq!(s.graph_nodes, usize::MAX);
        assert_eq!((s.milp_vars, s.milp_nodes), (3, 5));
        assert!(!s.is_zero());
        assert!(AxisSlack::default().is_zero());
    }

    #[test]
    fn node_cost_never_exceeds_the_dense_vars_model() {
        // Wide-and-sparse arc-flow ILP: the row term binds (8 x 10 = 80).
        assert_eq!(milp_node_cost(1_000, 10), 80);
        // Near-square ILP: the dense-era vars proxy still binds.
        assert_eq!(milp_node_cost(50, 40), 50);
        // Degenerate shapes floor at 1 instead of dividing by zero.
        assert_eq!(milp_node_cost(0, 0), 1);
        assert_eq!(milp_node_cost(7, 0), 1);
        // The calibrated cost never exceeds the dense model's, so node
        // budgets derived from it can only grow.
        for (v, r) in [(1usize, 1usize), (600, 60), (10_000, 3), (3, 10_000)] {
            assert!(milp_node_cost(v, r) <= v.max(1));
        }
    }

    #[test]
    fn node_scale_grows_with_the_node_grant() {
        let opts = SolveOptions::default();
        let wall = ComponentTelemetry {
            exact: true,
            proven: false, // node-budget bound
            node_budget: opts.milp.max_nodes,
            graph_budget: opts.max_graph_nodes,
            var_budget: opts.max_milp_vars,
            ..Default::default()
        };
        let donor = easy(40);
        let history: Vec<Option<&ComponentTelemetry>> = vec![Some(&wall), Some(&donor)];
        let out = allocate(&opts, &history);
        assert!(out[0].milp.max_nodes > opts.milp.max_nodes);
        assert!(out[0].milp_node_scale > opts.milp_node_scale);
    }

    #[test]
    fn shard_ledger_excludes_the_drawing_shard() {
        let mut ledger = ShardSlackLedger::new();
        ledger.publish(0, AxisSlack { graph_nodes: 100, milp_vars: 10, milp_nodes: 5 });
        ledger.publish(3, AxisSlack { graph_nodes: 40, milp_vars: 4, milp_nodes: 2 });
        ledger.publish(7, AxisSlack { graph_nodes: 1, milp_vars: 1, milp_nodes: 1 });
        assert_eq!(ledger.donors(), 3);
        // Shard 0 draws only 3 + 7's slack — never its own.
        let ext = ledger.available_for(0);
        assert_eq!((ext.graph_nodes, ext.milp_vars, ext.milp_nodes), (41, 5, 3));
        // A shard with no entry draws everything.
        let all = ledger.available_for(99);
        assert_eq!(all, ledger.total_donated());
        assert_eq!(all.graph_nodes, 141);
    }

    #[test]
    fn shard_ledger_replaces_and_retires_donations() {
        let mut ledger = ShardSlackLedger::new();
        ledger.publish(1, AxisSlack { graph_nodes: 50, milp_vars: 5, milp_nodes: 5 });
        // Re-publishing replaces (no accumulation across rounds).
        ledger.publish(1, AxisSlack { graph_nodes: 20, milp_vars: 2, milp_nodes: 2 });
        ledger.publish(2, AxisSlack { graph_nodes: 30, milp_vars: 3, milp_nodes: 3 });
        assert_eq!(ledger.available_for(2).graph_nodes, 20);
        // Retiring a shard withdraws its donation from everyone's pool.
        let gone = ledger.retire(1).unwrap();
        assert_eq!(gone.graph_nodes, 20);
        assert_eq!(ledger.retire(1), None);
        assert_eq!(ledger.donors(), 1);
        assert!(ledger.available_for(2).is_zero());
    }
}
