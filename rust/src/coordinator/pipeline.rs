//! The staged planning pipeline: **Eligibility → ProblemBuild → Solve →
//! Expand**, with a [`PlanContext`] that persists intermediate artifacts
//! across re-plans.
//!
//! The paper's resource manager is dynamic — "its decisions may change over
//! time because the demands may vary" — so the hot path is not the cold
//! start but the *re-plan*: rush-hour rate changes, cameras joining and
//! leaving. Each stage produces a cacheable artifact keyed by exactly the
//! inputs it depends on:
//!
//! | stage        | artifact                     | cache key                          |
//! |--------------|------------------------------|------------------------------------|
//! | Eligibility  | region mask + degraded flag  | (camera location, fps)             |
//! | Eligibility  | group assignment per stream  | (stream key, fingerprint)          |
//! | ProblemBuild | bin list / demand vectors    | hardware filter / interned group   |
//! | Solve        | compressed arc-flow graphs   | (capacity grid, quantized items)   |
//! | Solve        | previous packing (incumbent) | interned-group translation         |
//! | Expand       | previous stream→slot assignment | stable stream keys              |
//!
//! Since PR 4 the front-end is **drift-proportional**: the context diffs
//! the incoming request slice against the previous one (stable
//! [`StreamKey`](crate::cameras::StreamKey) order + per-request
//! fingerprints) and re-runs eligibility
//! and grouping only for added/removed/changed requests; unchanged streams
//! reuse their interned [`GroupId`] directly, and the affected groups'
//! demand vectors come back out of the per-group memo. The result is
//! bit-identical to a cold full rebuild by construction (property-tested),
//! and a catalog/config signature change still falls back to the exact
//! full rebuild.
//!
//! On top of the caches the Solve stage decomposes the packing problem into
//! independent per-region-cluster subproblems (streams whose RTT circles
//! don't overlap can never share an instance) and solves them on a
//! persistent [`WorkerPool`](crate::util::pool::WorkerPool) reached through
//! the context's shareable [`PoolSlot`](crate::util::pool::PoolSlot) —
//! workers park between re-plans instead of paying thread spawn/teardown
//! each time, and the portfolio's three candidate contexts all solve on
//! one pool.
//! Decomposition is exact: no bin type is shared between components, so the
//! union of component optima is a global optimum. Plan costs are identical
//! to a monolithic solve whenever the monolithic exact phase would have
//! completed within its budgets (all the paper-scale scenarios); in the
//! budget-bound regime each component gets the full solver budget, so the
//! decomposed solve can only *improve* on the monolithic heuristic
//! fallback, never regress it.

use super::budget::{self, AxisSlack, ComponentTelemetry};
use super::eligibility::{
    self, canon_f64_bits, FrontCache, GroupId, GroupKey, GroupSet, RegionMask,
};
use super::expand::{self, PrevAssignment};
use super::{LocationPolicy, Plan, PlannerConfig, SolverKind};
use crate::cameras::{stream_keys, StreamRequest};
use crate::catalog::{Catalog, Dims, NUM_DIMS};
use crate::error::{Error, Result};
use crate::geo;
use crate::metrics::SolverMetrics;
use crate::packing::arcflow::GraphCache;
use crate::packing::mcvbp::{self, DeltaHints, SolveMethod, SolveOptions, SolveStats};
use crate::packing::{heuristic, BinType, ItemGroup, Packing, PackedBin, PackingProblem};
use crate::util::fxhash::FxHashMap;
use crate::util::pool::PoolSlot;
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::Arc;
use std::time::Instant;

/// Telemetry of one pipeline run (how much prior work was reused).
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    pub elig_cache_hits: usize,
    pub elig_cache_misses: usize,
    /// Requests whose group assignment was reused wholesale from the
    /// previous slice via the dirty-tracking index (no eligibility, key
    /// hashing, or grouping recompute at all).
    pub front_unchanged: usize,
    /// Requests that ran the per-request front-end this re-plan (added or
    /// changed since the previous slice — the workload drift).
    pub front_changed: usize,
    pub demand_cache_hits: usize,
    pub demand_cache_misses: usize,
    pub graph_cache_hits: usize,
    pub graph_cache_misses: usize,
    /// Per-region subproblems whose solution was reused verbatim because
    /// their inputs were bit-identical to a previous re-plan.
    pub solution_cache_hits: usize,
    pub solution_cache_misses: usize,
    /// Subproblems answered through the *near-match* memo path: a cached
    /// solve of the same structure within a bounded demand delta seeded the
    /// root LP basis and branching order (delta-solve reuse).
    pub delta_solve_hits: usize,
    /// Subproblems warmed through the *structural* near-match path — a
    /// cached exact solve whose structure differs by a bounded set of
    /// groups (vanished → ghost embedding, appeared → block-translated
    /// basis, possibly both in one re-plan). Counted separately from
    /// `delta_solve_hits`; each structural warm step is certified inside
    /// the solver and falls cold when it cannot be.
    pub structural_delta_hits: usize,
    /// Group-level breakdown of the structural path this run: vanished
    /// groups re-embedded as ghosts, and appeared groups bridged by
    /// block-basis translation, summed over all structural hits.
    pub structural_ghost_groups: usize,
    pub structural_appeared_groups: usize,
    /// True if a previous packing seeded this solve.
    pub warm_started: bool,
    /// Independent per-region subproblems the Solve stage decomposed into.
    pub components: usize,
    /// Subproblems dispatched to the persistent worker pool (0 = solved
    /// inline), bounded by the pool's worker count.
    pub solve_threads: usize,
    /// Components whose adopted packing came from the exact phase vs the
    /// heuristic fallback (memo hits count under their cached method).
    pub components_exact: usize,
    pub components_fallback: usize,
    /// Components whose exact phase also proved optimality.
    pub components_proven: usize,
    /// Node LPs warm-resumed from a cached/parent basis vs solved cold.
    pub lp_warm_resumes: usize,
    pub lp_cold_solves: usize,
    /// Simplex pivots whose min-ratio step was ~0 (degenerate), summed over
    /// every node LP this run — the stalling the two-tier pricing rule
    /// works to avoid.
    pub degenerate_pivots: u64,
    /// Extra arc-flow node budget granted above the static per-component
    /// seed by the adaptive allocator this run (the donated pool at work).
    pub budget_donated_nodes: usize,
    /// Of the donated grant, the arc-flow nodes drawn from the portfolio's
    /// *cross-candidate* pool — budget another candidate's allocation
    /// published that an isolated allocation could not have granted
    /// (`coordinator::portfolio`). Counts only components that actually
    /// solved this run, like `budget_donated_nodes`.
    pub budget_pooled_nodes: usize,
    /// Jobs this run dispatched to the persistent worker pool (0 = solved
    /// inline). The portfolio's three candidates share one pool, so the
    /// portfolio-level total is the sum across their contexts.
    pub pool_jobs: usize,
    /// Over-budget graph builds skipped via the failure watermark.
    pub graph_fail_fastpaths: usize,
    /// Expand label blocks where greedy demonstrably left kept-stream
    /// overlap on the table but the block exceeded
    /// [`expand::EXACT_MATCH_CAP`](super::expand::EXACT_MATCH_CAP), so the
    /// exact certification pass could not run. Nonzero means the sticky
    /// assignment may have moved more streams than necessary this re-plan.
    pub exact_cert_skipped: usize,
    /// Wall-clock of each pipeline stage this run, in milliseconds.
    pub elig_ms: f64,
    pub build_ms: f64,
    pub solve_ms: f64,
    pub expand_ms: f64,
}

impl PipelineStats {
    /// Fraction of cacheable lookups served from the context, in [0, 1].
    pub fn reuse_ratio(&self) -> f64 {
        let hits = self.front_unchanged
            + self.elig_cache_hits
            + self.demand_cache_hits
            + self.graph_cache_hits
            + self.solution_cache_hits;
        let total = hits
            + self.elig_cache_misses
            + self.demand_cache_misses
            + self.graph_cache_misses
            + self.solution_cache_misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Wall-clock of the front-end (Eligibility + ProblemBuild) this run,
    /// in milliseconds — the part PR 4 makes drift-proportional.
    pub fn front_end_ms(&self) -> f64 {
        self.elig_ms + self.build_ms
    }

    /// Fold `other` into `self`: counters and stage wall-clocks sum, the
    /// warm-start flag ORs. The fleet roll-up used by
    /// [`shard`](super::shard) when several per-shard pipelines report as
    /// one planning round.
    pub fn absorb(&mut self, other: &PipelineStats) {
        self.elig_cache_hits += other.elig_cache_hits;
        self.elig_cache_misses += other.elig_cache_misses;
        self.front_unchanged += other.front_unchanged;
        self.front_changed += other.front_changed;
        self.demand_cache_hits += other.demand_cache_hits;
        self.demand_cache_misses += other.demand_cache_misses;
        self.graph_cache_hits += other.graph_cache_hits;
        self.graph_cache_misses += other.graph_cache_misses;
        self.solution_cache_hits += other.solution_cache_hits;
        self.solution_cache_misses += other.solution_cache_misses;
        self.delta_solve_hits += other.delta_solve_hits;
        self.structural_delta_hits += other.structural_delta_hits;
        self.structural_ghost_groups += other.structural_ghost_groups;
        self.structural_appeared_groups += other.structural_appeared_groups;
        self.warm_started |= other.warm_started;
        self.components += other.components;
        self.solve_threads += other.solve_threads;
        self.components_exact += other.components_exact;
        self.components_fallback += other.components_fallback;
        self.components_proven += other.components_proven;
        self.lp_warm_resumes += other.lp_warm_resumes;
        self.lp_cold_solves += other.lp_cold_solves;
        self.degenerate_pivots += other.degenerate_pivots;
        self.budget_donated_nodes += other.budget_donated_nodes;
        self.budget_pooled_nodes += other.budget_pooled_nodes;
        self.pool_jobs += other.pool_jobs;
        self.graph_fail_fastpaths += other.graph_fail_fastpaths;
        self.exact_cert_skipped += other.exact_cert_skipped;
        self.elig_ms += other.elig_ms;
        self.build_ms += other.build_ms;
        self.solve_ms += other.solve_ms;
        self.expand_ms += other.expand_ms;
    }
}

fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

/// Demand vectors are memoized per interned group identity; degraded groups
/// also key on the representative camera's location (their delivered fps
/// depends on the camera→region RTT) and every group keys on the
/// representative's un-rounded *effective* fps and observed cost scale (the
/// group key only stores their rounded milli forms). Float bits are
/// canonicalized so signed zeros cannot split entries.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct DemandKey {
    gid: GroupId,
    rep_fps_bits: u64,
    rep_cost_bits: u64,
    rep_loc: Option<(u64, u64)>,
}

/// The previous run's solution, kept for warm-starting the next one.
#[derive(Clone, Debug)]
struct LastPlan {
    /// Interned group id per packed group, aligned with the packing's
    /// count vectors.
    ids: Vec<GroupId>,
    packing: Packing,
    num_bins: usize,
}

/// Bit-exact identity of a (sub)problem handed to the solver. Two problems
/// with equal keys are solved identically by the deterministic solver, so
/// the result of the first can be returned for the second verbatim.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct SolveKey {
    headroom: u64,
    /// Per bin type: (cost bits, capacity bits, has_gpu).
    bins: Vec<(u64, [u64; NUM_DIMS], bool)>,
    /// Per item group: (count, demand bits per bin type).
    items: Vec<(usize, Vec<Option<[u64; NUM_DIMS]>>)>,
}

fn dims_bits(d: &Dims) -> [u64; NUM_DIMS] {
    let mut out = [0u64; NUM_DIMS];
    for (o, v) in out.iter_mut().zip(d.as_array()) {
        *o = v.to_bits();
    }
    out
}

fn solve_key(problem: &PackingProblem) -> SolveKey {
    SolveKey {
        headroom: problem.headroom.to_bits(),
        bins: problem
            .bins
            .iter()
            .map(|b| (b.cost.to_bits(), dims_bits(&b.capacity), b.has_gpu))
            .collect(),
        items: problem
            .items
            .iter()
            .map(|it| {
                (
                    it.count,
                    it.demand_per_bin
                        .iter()
                        .map(|d| d.as_ref().map(dims_bits))
                        .collect(),
                )
            })
            .collect(),
    }
}

/// One memoized subproblem solution plus everything needed to (a) decide
/// whether it may be reused at this run's budgets and (b) warm-start a
/// near-identical subproblem (the delta path).
#[derive(Clone, Debug)]
struct CachedSolve {
    packing: Packing,
    method: SolveMethod,
    proven: bool,
    /// Warm re-entry state + per-group counts for the delta path.
    hints: DeltaHints,
    counts: Vec<usize>,
    /// Column-block layout of the exact solve's joint ILP (empty for
    /// heuristic results) + its structural column count: the inputs of the
    /// appeared-group basis translation on the structural delta path.
    blocks: Vec<mcvbp::VarBlock>,
    num_vars: usize,
}

/// Soft cap on memoized subproblem solutions; reaching it clears the memo.
const SOLUTION_CACHE_CAPACITY: usize = 2048;
/// Soft cap on the per-component telemetry map (components ≈ region
/// clusters, so this is generous).
const TELEMETRY_CAPACITY: usize = 4_096;

/// Soft caps on the per-request and per-group memos: cameras join, leave,
/// and change rates in long-running adaptive sessions, so these would grow
/// without bound otherwise. Entries are cheap to recompute after a clear.
const ELIG_CACHE_CAPACITY: usize = 65_536;
const DEMAND_CACHE_CAPACITY: usize = 16_384;
/// Soft cap on interned group keys. Clearing the arena invalidates every
/// stored [`GroupId`], so the demand memo, warm-start seed, and
/// dirty-tracking index are dropped with it.
const GROUP_ARENA_CAPACITY: usize = 65_536;

/// Persistent cross-re-plan state for one (catalog, planner-config) pair.
///
/// Dropping the context (or planning with a fresh one) gives exactly the
/// cold planner, and *identical consecutive* re-plans return identical
/// plans (the solution memo answers them verbatim — zero churn, stable
/// ids). Across *drifting* workloads the context can also change the
/// outcome for the better: per-component solver budgets adapt from the
/// recorded telemetry (a component that fell back under the static seed
/// budget re-solves exactly under a pool grant — cost can only improve,
/// since exact results are adopted only when they beat the heuristics), and
/// near-identical subproblems re-enter the solver warm from the delta memo
/// without ever giving up exactness. The Expand stage changes the output's
/// *shape* only: stream→instance assignments stick to the previous plan's
/// slots, so a re-plan moves only the packing diff instead of re-dealing
/// every stream.
#[derive(Default)]
pub struct PlanContext {
    /// Fingerprint of the (catalog, config) pair the caches are valid for;
    /// a mismatch clears everything (the exact full-rebuild fallback).
    signature: Option<u64>,
    /// Bin types (offerings × hardware filter) — workload-independent.
    bins: Option<Vec<BinType>>,
    /// Front-end state: eligibility memo, group-interning arena, and the
    /// previous slice's dirty-tracking index.
    front: FrontCache,
    demand: FxHashMap<DemandKey, Vec<Option<Dims>>>,
    graphs: Arc<GraphCache>,
    /// Memoized per-subproblem solutions (see [`SolveKey`]).
    solutions: FxHashMap<SolveKey, CachedSolve>,
    /// Structure-hash → key of the most recent *exact* solve with that
    /// structure: the near-match index behind the delta-solve path.
    delta_index: FxHashMap<u64, SolveKey>,
    /// Family-hash (headroom + bins only) → key of the most recent *exact*
    /// solve over those bins: the index behind the structural delta path.
    /// A new subproblem in the same family aligns its group sequence
    /// against the cached key (order-preserving LCS) to recover which
    /// groups vanished and which appeared — any bounded mix of both in one
    /// re-plan — in one probe, replacing the per-position minus-one-hash
    /// scan the one-group path used.
    family_index: FxHashMap<u64, SolveKey>,
    /// Per-component solve telemetry feeding the adaptive budget allocator
    /// ([`budget::allocate`]); keyed by the component's bin identity.
    telemetry: FxHashMap<u64, ComponentTelemetry>,
    last: Option<LastPlan>,
    /// The stream→slot assignment the next Expand matches against. Normally
    /// the previous plan's own; the portfolio overwrites it with the
    /// *winning* candidate's after every re-plan (`seed_assignment`), and it
    /// survives signature clears — it mirrors the deployed fleet, which a
    /// price or config change does not tear down.
    last_assign: Option<PrevAssignment>,
    /// Persistent solve workers: spawned lazily on the first parallel
    /// Solve, parked between re-plans, and carried across signature clears
    /// (threads are workload-independent). The slot is shareable — the
    /// portfolio installs one slot into all three candidate contexts so
    /// their parallel solves run on a single pool.
    pool: Arc<PoolSlot>,
    /// Slack the most recent budget allocation published for the
    /// portfolio's cross-candidate pool (`coordinator::portfolio`).
    pub(crate) pool_out: AxisSlack,
    /// Telemetry of the most recent run through this context.
    pub stats: PipelineStats,
    /// Cumulative cross-re-plan solver counters (never reset by re-plans).
    pub solver: SolverMetrics,
}

impl PlanContext {
    pub fn new() -> Self {
        PlanContext::default()
    }

    /// Clear cached artifacts if the catalog or config changed. Four
    /// things survive: the worker pool (threads are not workload state),
    /// the arc-flow graph cache (its key is the full capacity grid +
    /// quantized item list, so an entry a new catalog cannot reproduce is
    /// simply never looked up again and ages out — while graphs the new
    /// catalog *does* share come back for free, and the portfolio's shared
    /// cache keeps its identity across candidate-local signature clears),
    /// the previous assignment (it mirrors the *deployed fleet*, which a
    /// price update does not tear down — it is matched only by stable
    /// stream keys and bin labels, so entries a new catalog cannot
    /// reproduce simply never pair, while everything still deployed keeps
    /// its slot instead of being re-dealt), and the cumulative solver
    /// counters (they are documented as never resetting, and the portfolio
    /// roll-ups `pool_shared_jobs`/`budget_pooled_donated` must stay
    /// monotonic across the very price updates the flip scenarios exercise).
    fn ensure_for(&mut self, catalog: &Catalog, config: &PlannerConfig) {
        let sig = signature(catalog, config);
        if self.signature != Some(sig) {
            let pool = Arc::clone(&self.pool);
            let graphs = Arc::clone(&self.graphs);
            let last_assign = self.last_assign.take();
            let solver = std::mem::take(&mut self.solver);
            *self = PlanContext {
                signature: Some(sig),
                pool,
                graphs,
                last_assign,
                solver,
                ..PlanContext::default()
            };
        }
    }

    /// Forget the previous solution and assignment (keeps the pure-function
    /// caches).
    pub fn clear_warm_start(&mut self) {
        self.last = None;
        self.last_assign = None;
    }

    /// Per-component telemetry of the most recent solves, hardest (by
    /// arc-flow nodes built) first. Bench/diagnostic surface.
    pub fn component_telemetry(&self) -> Vec<ComponentTelemetry> {
        let mut v: Vec<ComponentTelemetry> = self.telemetry.values().cloned().collect();
        v.sort_by(|a, b| b.graph_nodes.cmp(&a.graph_nodes));
        v
    }

    /// Replace this context's worker-pool slot with a shared one
    /// (portfolio wiring — all candidates solve on one pool).
    pub(crate) fn share_pool(&mut self, slot: Arc<PoolSlot>) {
        self.pool = slot;
    }

    /// The worker-pool slot this context solves on (test-only surface: the
    /// portfolio's sharing tests assert slot identity across contexts).
    #[cfg(test)]
    pub(crate) fn pool_slot(&self) -> &Arc<PoolSlot> {
        &self.pool
    }

    /// Replace this context's arc-flow graph cache with a shared one
    /// (portfolio wiring — all candidates memoize compressed graphs in a
    /// single content-addressed cache, so a graph any candidate builds is
    /// a hit for the other two).
    pub(crate) fn share_graphs(&mut self, cache: Arc<GraphCache>) {
        self.graphs = cache;
    }

    /// The graph cache this context memoizes into (test-only surface: the
    /// portfolio's sharing tests assert cache identity across contexts).
    #[cfg(test)]
    pub(crate) fn graph_cache(&self) -> &Arc<GraphCache> {
        &self.graphs
    }

    /// The stream→slot assignment the next Expand will match against.
    pub(crate) fn assignment(&self) -> Option<&PrevAssignment> {
        self.last_assign.as_ref()
    }

    /// Seed the next Expand's matching target. The portfolio installs the
    /// *winning* candidate's assignment into every candidate context after
    /// each re-plan, so a later winner flip expands against the deployed
    /// fleet instead of restarting slots fresh.
    pub(crate) fn seed_assignment(&mut self, assign: PrevAssignment) {
        self.last_assign = Some(assign);
    }
}

// The portfolio context moved to `coordinator::portfolio` in PR 5 (it now
// owns shared runtime state, not just three independent contexts); the
// re-export keeps the long-standing `pipeline::ReplanContext` path working.
pub use super::portfolio::ReplanContext;

fn hash_f64<H: Hasher>(state: &mut H, v: f64) {
    v.to_bits().hash(state);
}

/// Fingerprint of everything the cached artifacts depend on. Also the
/// arbiter's catalog/config change detector ([`shard`](super::shard)): a
/// price or config flip moves this hash, which fans a dirty bit out to
/// every shard.
pub(crate) fn signature(catalog: &Catalog, config: &PlannerConfig) -> u64 {
    let mut h = DefaultHasher::new();
    let hw = match config.hardware {
        super::HardwareFilter::CpuOnly => 0u8,
        super::HardwareFilter::GpuOnly => 1,
        super::HardwareFilter::Both => 2,
    };
    let loc = match config.location {
        LocationPolicy::Unrestricted => 0u8,
        LocationPolicy::NearestOnly => 1,
        LocationPolicy::RttFiltered => 2,
    };
    let solver = match config.solver {
        SolverKind::Exact => 0u8,
        SolverKind::ArmvacGreedy => 1,
        SolverKind::Ffd => 2,
    };
    (hw, loc, solver).hash(&mut h);
    hash_f64(&mut h, config.headroom);
    config.solve_opts.quant.hash(&mut h);
    config.solve_opts.max_graph_nodes.hash(&mut h);
    config.solve_opts.max_milp_vars.hash(&mut h);
    config.solve_opts.exact.hash(&mut h);
    config.solve_opts.milp.max_nodes.hash(&mut h);
    config.solve_opts.milp_node_scale.hash(&mut h);
    config.parallel_regions.hash(&mut h);
    catalog.types.len().hash(&mut h);
    for t in &catalog.types {
        t.name.hash(&mut h);
        hash_f64(&mut h, t.gpu_speed);
        for v in t.capacity.as_array() {
            hash_f64(&mut h, v);
        }
    }
    catalog.regions.len().hash(&mut h);
    for r in &catalog.regions {
        r.id.hash(&mut h);
        // Vendor matters: NearestOnly eligibility picks the closest region
        // *per vendor*, so a vendor reassignment must invalidate the caches.
        (match r.vendor {
            crate::catalog::Vendor::Ec2 => 0u8,
            crate::catalog::Vendor::Azure => 1,
        })
        .hash(&mut h);
        hash_f64(&mut h, r.location.lat);
        hash_f64(&mut h, r.location.lon);
    }
    catalog.offerings.len().hash(&mut h);
    for o in &catalog.offerings {
        (o.type_idx, o.region_idx).hash(&mut h);
        hash_f64(&mut h, o.hourly_usd);
    }
    h.finish()
}

/// Enforce the per-context capacity caps before a run.
fn enforce_caps(ctx: &mut PlanContext) {
    if ctx.front.elig.len() > ELIG_CACHE_CAPACITY {
        ctx.front.elig.clear();
    }
    if ctx.front.arena.len() > GROUP_ARENA_CAPACITY {
        // Interned ids are about to dangle: drop everything keyed on them.
        ctx.front.clear_groups();
        ctx.demand.clear();
        ctx.last = None;
    }
    if ctx.demand.len() > DEMAND_CACHE_CAPACITY {
        ctx.demand.clear();
    }
    if ctx.telemetry.len() > TELEMETRY_CAPACITY {
        ctx.telemetry.clear();
    }
}

fn check_catalog_width(catalog: &Catalog) -> Result<()> {
    if catalog.regions.len() > RegionMask::CAPACITY {
        return Err(Error::config(format!(
            "catalog has {} regions; the planner supports at most {}",
            catalog.regions.len(),
            RegionMask::CAPACITY
        )));
    }
    Ok(())
}

/// Run the full pipeline through a persistent context.
pub fn plan_with_context(
    catalog: &Catalog,
    config: &PlannerConfig,
    requests: &[StreamRequest],
    ctx: &mut PlanContext,
) -> Result<Plan> {
    plan_with_pool(catalog, config, requests, ctx, AxisSlack::default())
}

/// [`plan_with_context`] with an external budget-pool share: `pool_in` is
/// the slack the *other* portfolio candidates published last round
/// (`coordinator::portfolio`), granted on top of this context's own donated
/// pool — never below the static floor, and exact-complete plan costs are
/// unaffected (budgets only decide whether the exact phase completes, not
/// what it finds). The slack this run publishes back is left in
/// `ctx.pool_out`.
pub(crate) fn plan_with_pool(
    catalog: &Catalog,
    config: &PlannerConfig,
    requests: &[StreamRequest],
    ctx: &mut PlanContext,
    pool_in: AxisSlack,
) -> Result<Plan> {
    if requests.is_empty() {
        return Err(Error::config("no stream requests"));
    }
    check_catalog_width(catalog)?;
    ctx.ensure_for(catalog, config);
    enforce_caps(ctx);
    let mut stats = PipelineStats::default();

    // Closed-loop telemetry: how many streams this re-plan provisions from
    // observed (not declared) demand, and how many are backpressure-shed.
    for r in requests {
        if !r.feedback.is_default() {
            ctx.solver.feedback_streams.inc();
        }
        if r.feedback.shed_tier > 0 {
            ctx.solver.degraded_tier_streams.inc();
        }
    }

    // Stage 1: Eligibility — incremental against the previous slice.
    let t_elig = Instant::now();
    let skeys = stream_keys(requests);
    let elig =
        eligibility::run_incremental(catalog, config.location, requests, &skeys, &mut ctx.front);
    stats.elig_ms = ms_since(t_elig);
    stats.elig_cache_hits = elig.cache_hits;
    stats.elig_cache_misses = elig.cache_misses;
    stats.front_unchanged = elig.unchanged;
    stats.front_changed = elig.changed;
    let groups = elig.groups;
    let gids = elig.group_ids;

    // Stage 2: ProblemBuild.
    let t_build = Instant::now();
    let problem = build_stage(catalog, config, requests, &groups, &gids, ctx, &mut stats)?;
    stats.build_ms = ms_since(t_build);

    // Warm-start seed: translate the previous packing onto this problem.
    let seeds = translate_seed(ctx.last.as_ref(), &gids, &problem);
    stats.warm_started = seeds.is_some();

    // Stage 3: Solve (decomposed per region cluster, adaptive budgets,
    // delta-aware memo, persistent worker pool).
    let t_solve = Instant::now();
    let (packing, method) =
        solve_stage(&problem, config, ctx, seeds.as_deref(), pool_in, &mut stats)?;
    packing.validate(&problem)?;
    stats.solve_ms = ms_since(t_solve);

    // Stage 4: Expand — sticky against the previous assignment.
    let t_expand = Instant::now();
    let instances = expand::run(
        &problem,
        &packing,
        &groups.members,
        &skeys,
        ctx.last_assign.as_ref(),
        &mut stats.exact_cert_skipped,
    )?;
    stats.expand_ms = ms_since(t_expand);

    let cost = packing.total_cost(&problem);
    let (non_gpu, gpu) = packing.count_by_gpu(&problem);
    ctx.last = Some(LastPlan {
        ids: gids,
        packing: packing.clone(),
        num_bins: problem.bins.len(),
    });
    ctx.last_assign = Some(PrevAssignment::capture(&instances, &skeys));
    ctx.stats = stats.clone();
    Ok(Plan {
        problem,
        packing,
        instances,
        cost_per_hour: cost,
        non_gpu,
        gpu,
        degraded: groups.degraded,
        method,
        region_locations: catalog.regions.iter().map(|r| r.location).collect(),
        pipeline: stats,
    })
}

/// Run only the front-end (Eligibility + ProblemBuild) through a persistent
/// context — incremental when the context carries previous state, a full
/// rebuild otherwise. Returns the stage artifacts; the property suite uses
/// this to check the incremental front-end is bit-identical to a cold
/// rebuild under churn.
pub fn front_end_with_context(
    catalog: &Catalog,
    config: &PlannerConfig,
    requests: &[StreamRequest],
    ctx: &mut PlanContext,
) -> Result<(GroupSet, PackingProblem)> {
    if requests.is_empty() {
        return Err(Error::config("no stream requests"));
    }
    check_catalog_width(catalog)?;
    ctx.ensure_for(catalog, config);
    enforce_caps(ctx);
    let mut stats = PipelineStats::default();
    let skeys = stream_keys(requests);
    let elig =
        eligibility::run_incremental(catalog, config.location, requests, &skeys, &mut ctx.front);
    let groups = elig.groups;
    let problem =
        build_stage(catalog, config, requests, &groups, &elig.group_ids, ctx, &mut stats)?;
    Ok((groups, problem))
}

/// Compatibility wrapper over Eligibility + ProblemBuild with a throwaway
/// context: the seed API's (problem, group members, degraded) triple.
pub fn build_problem(
    catalog: &Catalog,
    config: &PlannerConfig,
    requests: &[StreamRequest],
) -> Result<(PackingProblem, Vec<Vec<usize>>, Vec<usize>)> {
    let mut ctx = PlanContext::new();
    let (groups, problem) = front_end_with_context(catalog, config, requests, &mut ctx)?;
    Ok((problem, groups.members, groups.degraded))
}

/// Stage 2 — **ProblemBuild**: bins from the hardware filter (cached),
/// demand vectors per interned group (cached — an unchanged group's vector
/// is patched straight into the new problem without recompute).
fn build_stage(
    catalog: &Catalog,
    config: &PlannerConfig,
    requests: &[StreamRequest],
    groups: &GroupSet,
    gids: &[GroupId],
    ctx: &mut PlanContext,
    stats: &mut PipelineStats,
) -> Result<PackingProblem> {
    if ctx.bins.is_none() {
        ctx.bins = Some(build_bins(catalog, config)?);
    }
    let bins = ctx.bins.as_ref().unwrap().clone();

    let mut items = Vec::with_capacity(groups.keys.len());
    for ((key, mem), &gid) in groups.keys.iter().zip(&groups.members).zip(gids) {
        let rep = &requests[mem[0]];
        let dkey = DemandKey {
            gid,
            rep_fps_bits: canon_f64_bits(rep.effective_fps()),
            rep_cost_bits: canon_f64_bits(rep.feedback.cost_scale),
            rep_loc: key.degraded.then(|| {
                (
                    canon_f64_bits(rep.camera.location.lat),
                    canon_f64_bits(rep.camera.location.lon),
                )
            }),
        };
        let demand_per_bin = match ctx.demand.get(&dkey) {
            Some(d) => {
                stats.demand_cache_hits += 1;
                d.clone()
            }
            None => {
                stats.demand_cache_misses += 1;
                let d = compute_demand(catalog, key, rep, &bins);
                ctx.demand.insert(dkey, d.clone());
                d
            }
        };
        items.push(ItemGroup {
            label: format!("{}x{}", rep.label(), mem.len()),
            count: mem.len(),
            demand_per_bin,
        });
    }

    let mut problem = PackingProblem::new(items, bins);
    problem.headroom = config.headroom;
    Ok(problem)
}

/// Bin types: offerings passing the hardware filter.
fn build_bins(catalog: &Catalog, config: &PlannerConfig) -> Result<Vec<BinType>> {
    let bins: Vec<BinType> = catalog
        .offerings
        .iter()
        .filter(|o| {
            let has_gpu = catalog.types[o.type_idx].has_gpu();
            match config.hardware {
                super::HardwareFilter::CpuOnly => !has_gpu,
                super::HardwareFilter::GpuOnly => has_gpu,
                super::HardwareFilter::Both => true,
            }
        })
        .map(|o| {
            let ty = &catalog.types[o.type_idx];
            let rg = &catalog.regions[o.region_idx];
            BinType {
                label: format!("{}@{}", ty.name, rg.id),
                capacity: ty.capacity,
                cost: o.hourly_usd,
                type_idx: o.type_idx,
                region_idx: o.region_idx,
                has_gpu: ty.has_gpu(),
            }
        })
        .collect();
    if bins.is_empty() {
        return Err(Error::infeasible("no instance offerings pass the hardware filter"));
    }
    Ok(bins)
}

/// Demand vectors of one group across all bin types (the multiple-choice
/// aspect: CPU-path demand on CPU bins, GPU-path demand on GPU bins).
fn compute_demand(
    catalog: &Catalog,
    key: &GroupKey,
    rep: &StreamRequest,
    bins: &[BinType],
) -> Vec<Option<Dims>> {
    let profile = key.program.profile();
    // Closed-loop inputs: the backpressure tier sheds the provisioned rate
    // (`effective_fps`, tier 0 = declared bits exactly) and the observed
    // cost scale multiplies the compute term (scale 1.0 is bit-identical
    // to the profile, so a zero feedback delta re-plans bit-identically).
    let eff_fps = rep.effective_fps();
    let cost_scale = rep.feedback.cost_scale;
    bins.iter()
        .map(|b| {
            if !key.mask.get(b.region_idx) {
                return None;
            }
            // Delivered fps: capped by the region's RTT when the stream is
            // degraded (best-effort nearest region).
            let fps = if key.degraded {
                let rtt = rep
                    .camera
                    .location
                    .rtt_ms(&catalog.regions[b.region_idx].location);
                geo::fps_cap(rtt).min(eff_fps)
            } else {
                eff_fps
            };
            Some(if b.has_gpu {
                // Newer GPU generations (g3/p3-class) process the same
                // stream in proportionally less GPU time.
                let mut d = profile.demand_gpu_scaled(fps, key.res, cost_scale);
                d.gpus /= catalog.types[b.type_idx].gpu_speed;
                d
            } else {
                profile.demand_cpu_scaled(fps, key.res, cost_scale)
            })
        })
        .collect()
}

/// Translate the previous packing onto the new problem's group indices.
/// Groups are matched by interned [`GroupId`] equality (same arena, so id
/// equality is key equality); counts for vanished groups are dropped (their
/// streams left), counts above the new demand are clamped later by
/// `warm_start_fill`.
fn translate_seed(
    last: Option<&LastPlan>,
    gids: &[GroupId],
    problem: &PackingProblem,
) -> Option<Vec<PackedBin>> {
    let last = last?;
    if last.num_bins != problem.bins.len() {
        return None;
    }
    let new_index: FxHashMap<GroupId, usize> =
        gids.iter().enumerate().map(|(i, &g)| (g, i)).collect();
    let map: Vec<Option<usize>> =
        last.ids.iter().map(|g| new_index.get(g).copied()).collect();
    let mut seeds = Vec::with_capacity(last.packing.bins.len());
    for bin in &last.packing.bins {
        if bin.counts.len() != last.ids.len() {
            return None;
        }
        let mut counts = vec![0usize; gids.len()];
        let mut any = false;
        for (old_g, &c) in bin.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if let Some(new_g) = map[old_g] {
                counts[new_g] += c;
                any = true;
            }
        }
        if any {
            seeds.push(PackedBin { bin_type: bin.bin_type, counts });
        }
    }
    (!seeds.is_empty()).then_some(seeds)
}

/// An independent subproblem: bin types and groups that can only interact
/// with each other.
#[derive(Clone, Debug)]
struct Component {
    bins: Vec<usize>,
    groups: Vec<usize>,
}

fn uf_find(parent: &mut [usize], mut x: usize) -> usize {
    while parent[x] != x {
        parent[x] = parent[parent[x]]; // path halving
        x = parent[x];
    }
    x
}

fn uf_union(parent: &mut [usize], a: usize, b: usize) {
    let ra = uf_find(parent, a);
    let rb = uf_find(parent, b);
    if ra != rb {
        parent[ra.max(rb)] = ra.min(rb);
    }
}

/// Partition the problem into independent components: bin types are
/// connected iff some group can be placed in both. Groups with no
/// compatible bin become bin-less singleton components so the solver
/// reports the same infeasibility a monolithic solve would. The item↔bin
/// incidence walks fixed-width bitsets when the problem fits them
/// ([`PackingProblem::placeable_masks`]).
fn decompose(problem: &PackingProblem) -> Vec<Component> {
    let nb = problem.bins.len();
    let mut parent: Vec<usize> = (0..nb).collect();
    let masks = problem.placeable_masks();
    let first_placeable = |g: usize| -> Option<usize> {
        match &masks {
            Some(m) => m[g].ones().next(),
            None => (0..nb).find(|&t| problem.items[g].demand_per_bin[t].is_some()),
        }
    };
    for (g, item) in problem.items.iter().enumerate() {
        if item.count == 0 {
            continue;
        }
        let mut first: Option<usize> = None;
        let mut link = |t: usize, parent: &mut Vec<usize>| match first {
            None => first = Some(t),
            Some(f) => uf_union(parent, f, t),
        };
        match &masks {
            Some(m) => {
                for t in m[g].ones() {
                    link(t, &mut parent);
                }
            }
            None => {
                for t in 0..nb {
                    if item.demand_per_bin[t].is_some() {
                        link(t, &mut parent);
                    }
                }
            }
        }
    }

    let mut comp_of_root: FxHashMap<usize, usize> = FxHashMap::default();
    let mut comps: Vec<Component> = Vec::new();
    for t in 0..nb {
        let root = uf_find(&mut parent, t);
        let c = *comp_of_root.entry(root).or_insert_with(|| {
            comps.push(Component { bins: Vec::new(), groups: Vec::new() });
            comps.len() - 1
        });
        comps[c].bins.push(t);
    }
    for (g, item) in problem.items.iter().enumerate() {
        if item.count == 0 {
            continue;
        }
        match first_placeable(g) {
            Some(t) => {
                let root = uf_find(&mut parent, t);
                let c = comp_of_root[&root];
                comps[c].groups.push(g);
            }
            None => {
                // Unplaceable group: its own component, no bins.
                comps.push(Component { bins: Vec::new(), groups: vec![g] });
            }
        }
    }
    // Components without any group open no bins; drop them.
    comps.retain(|c| !c.groups.is_empty());
    comps
}

/// Restriction of the global problem to one component.
fn subproblem(problem: &PackingProblem, comp: &Component) -> PackingProblem {
    let bins: Vec<BinType> = comp.bins.iter().map(|&t| problem.bins[t].clone()).collect();
    let items: Vec<ItemGroup> = comp
        .groups
        .iter()
        .map(|&g| {
            let it = &problem.items[g];
            ItemGroup {
                label: it.label.clone(),
                count: it.count,
                demand_per_bin: comp.bins.iter().map(|&t| it.demand_per_bin[t]).collect(),
            }
        })
        .collect();
    let mut p = PackingProblem::new(items, bins);
    p.headroom = problem.headroom;
    p
}

/// Restriction of global warm-start seeds to one component.
fn sub_seeds(seeds: &[PackedBin], comp: &Component) -> Vec<PackedBin> {
    let local_bin: FxHashMap<usize, usize> =
        comp.bins.iter().enumerate().map(|(lt, &t)| (t, lt)).collect();
    seeds
        .iter()
        .filter_map(|b| {
            let lt = *local_bin.get(&b.bin_type)?;
            let counts: Vec<usize> = comp
                .groups
                .iter()
                .map(|&g| b.counts.get(g).copied().unwrap_or(0))
                .collect();
            counts
                .iter()
                .any(|&c| c > 0)
                .then_some(PackedBin { bin_type: lt, counts })
        })
        .collect()
}

/// Result of solving one (sub)problem. `stats` is present only for exact
/// solves (heuristic strategies have no solver telemetry); `proven` is
/// carried separately so memo hits keep their cached flag.
struct SubSolve {
    packing: Packing,
    method: SolveMethod,
    proven: bool,
    stats: Option<SolveStats>,
}

/// Solve one problem with the configured strategy, warm seeds, per-component
/// budgets, delta hints, and the shared graph cache.
fn solve_one(
    problem: &PackingProblem,
    config: &PlannerConfig,
    cache: &GraphCache,
    seeds: Option<&[PackedBin]>,
    opts: &SolveOptions,
    hints: Option<&DeltaHints>,
) -> Result<SubSolve> {
    let warm = seeds.and_then(|s| heuristic::warm_start_fill(problem, s).ok());
    match config.solver {
        SolverKind::Exact => {
            let (p, st) = mcvbp::solve_delta(problem, opts, Some(cache), warm.as_ref(), hints)?;
            Ok(SubSolve {
                packing: p,
                method: st.method,
                proven: st.method == SolveMethod::ExactArcFlow && st.proven_optimal,
                stats: Some(st),
            })
        }
        SolverKind::ArmvacGreedy => {
            let cold = heuristic::armvac_fill(problem)?;
            Ok(SubSolve {
                packing: cheaper(problem, cold, warm),
                method: SolveMethod::Heuristic,
                proven: false,
                stats: None,
            })
        }
        SolverKind::Ffd => {
            let cold = heuristic::first_fit_decreasing(problem)?;
            Ok(SubSolve {
                packing: cheaper(problem, cold, warm),
                method: SolveMethod::Heuristic,
                proven: false,
                stats: None,
            })
        }
    }
}

/// Prefer the warm packing only when strictly cheaper, so identical inputs
/// keep returning exactly the cold heuristic's result.
fn cheaper(problem: &PackingProblem, cold: Packing, warm: Option<Packing>) -> Packing {
    match warm {
        Some(w) if w.total_cost(problem) < cold.total_cost(problem) - 1e-12 => w,
        _ => cold,
    }
}

/// Stable identity of a component across re-plans: the sorted bin-type set
/// (instance type × region). Demand drift keeps the identity, so telemetry
/// recorded under one workload drives the budgets of the next.
fn component_id(problem: &PackingProblem, comp: &Component) -> u64 {
    let mut h = DefaultHasher::new();
    for &t in &comp.bins {
        let b = &problem.bins[t];
        (b.type_idx, b.region_idx).hash(&mut h);
    }
    comp.bins.len().hash(&mut h);
    h.finish()
}

/// Hash of a subproblem's *structure*: everything in its [`SolveKey`]
/// except the group counts. Two keys with equal structure hashes describe
/// the same bins, demand vectors, and group order — the precondition for
/// delta-solve reuse (their joint ILPs differ only in coverage RHS).
fn structure_hash(key: &SolveKey) -> u64 {
    let mut h = DefaultHasher::new();
    key.headroom.hash(&mut h);
    key.bins.hash(&mut h);
    key.items.len().hash(&mut h);
    for (_, demands) in &key.items {
        demands.hash(&mut h);
    }
    h.finish()
}

/// Near-match lookup: hints from the latest exact solve of the same
/// structure, provided the total demand delta is bounded (≤ max(2, 5% of
/// the subproblem's stream count) — beyond that a cold solve's own warm
/// start is as good).
fn delta_hints(
    solutions: &FxHashMap<SolveKey, CachedSolve>,
    delta_index: &FxHashMap<u64, SolveKey>,
    key: &SolveKey,
) -> Option<DeltaHints> {
    let prev_key = delta_index.get(&structure_hash(key))?;
    let prev = solutions.get(prev_key)?;
    if prev.method != SolveMethod::ExactArcFlow || prev.counts.len() != key.items.len() {
        return None;
    }
    let total: usize = key.items.iter().map(|(c, _)| *c).sum();
    let delta: usize = prev
        .counts
        .iter()
        .zip(key.items.iter().map(|(c, _)| *c))
        .map(|(&a, b)| a.abs_diff(b))
        .sum();
    (delta > 0 && delta <= (total / 20).max(2)).then(|| prev.hints.clone())
}

/// Hash of a subproblem's *family*: its headroom and bins only. Every
/// structure over the same bin set shares a family slot; the most recent
/// exact solve of the family is the structural-delta candidate.
fn family_hash(key: &SolveKey) -> u64 {
    let mut h = DefaultHasher::new();
    key.headroom.hash(&mut h);
    key.bins.hash(&mut h);
    h.finish()
}

/// Order-preserving alignment of two structures' group sequences: the
/// longest common subsequence over per-group demand-vector identity.
/// Returns matched `(prev_idx, new_idx)` pairs, ascending in both; the
/// unmatched remainders are the vanished (prev side) and appeared (new
/// side) groups of the structural delta.
fn align_groups(prev: &SolveKey, key: &SolveKey) -> Vec<(usize, usize)> {
    // Pre-hash each group's demand vector so a DP cell compares one word;
    // the full vectors break ties so a hash collision cannot mis-align.
    fn sigs(items: &[(usize, Vec<Option<[u64; NUM_DIMS]>>)]) -> Vec<u64> {
        items
            .iter()
            .map(|(_, d)| {
                let mut h = DefaultHasher::new();
                d.hash(&mut h);
                h.finish()
            })
            .collect()
    }
    let a = sigs(&prev.items);
    let b = sigs(&key.items);
    let eq = |i: usize, j: usize| a[i] == b[j] && prev.items[i].1 == key.items[j].1;
    // Suffix-LCS table: dp[i][j] = LCS length of a[i..] vs b[j..]. Sizes
    // are capped at STRUCTURAL_SCAN_LIMIT, so u16 lengths suffice.
    let mut dp = vec![vec![0u16; b.len() + 1]; a.len() + 1];
    for i in (0..a.len()).rev() {
        for j in (0..b.len()).rev() {
            dp[i][j] = if eq(i, j) {
                dp[i + 1][j + 1] + 1
            } else {
                dp[i + 1][j].max(dp[i][j + 1])
            };
        }
    }
    let mut out = Vec::with_capacity(usize::from(dp[0][0]));
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        if eq(i, j) {
            out.push((i, j));
            i += 1;
            j += 1;
        } else if dp[i + 1][j] >= dp[i][j + 1] {
            i += 1;
        } else {
            j += 1;
        }
    }
    out
}

/// Groups per side beyond which the structural alignment is skipped — the
/// LCS is O(groups²) and a subproblem that large re-plans through the
/// budget machinery anyway.
const STRUCTURAL_SCAN_LIMIT: usize = 256;

/// Vanished + appeared groups beyond which the structural path stands
/// down: each ghost pads the embedded ILP and each appeared group widens
/// the translation, so past a handful a cold solve's own warm start is as
/// good as a heavily patched basis.
const MAX_STRUCTURAL_GROUPS: usize = 4;

/// Structural near-match lookup, tried only after both the exact memo and
/// the counts-only delta index missed: hints for a subproblem that differs
/// from a cached exact solve by a bounded set of groups.
///
/// The family index names the most recent exact solve over the same bins;
/// [`align_groups`] recovers which of its groups *vanished* and which
/// groups *appeared*, in one pass that handles any bounded mix of both.
/// Vanished groups re-embed as zero-coverage *ghosts* so the solver
/// reconstructs the old column space ([`mcvbp::GhostGroup`]); with no
/// appeared groups the structural change then collapses to an RHS delta
/// and the cached basis re-enters directly. Appeared groups translate the
/// cached basis block-by-block into the wider (ghost-augmented) column
/// space ([`mcvbp::PrevLayout`]). Every path stays certified-or-cold
/// inside the solver: a hint that fails dual repair is discarded, never
/// adopted.
fn structural_hints(
    solutions: &FxHashMap<SolveKey, CachedSolve>,
    family_index: &FxHashMap<u64, SolveKey>,
    key: &SolveKey,
) -> Option<DeltaHints> {
    if key.items.len() > STRUCTURAL_SCAN_LIMIT {
        return None;
    }
    let prev_key = family_index.get(&family_hash(key))?;
    if prev_key.items.len() > STRUCTURAL_SCAN_LIMIT
        || prev_key.headroom != key.headroom
        || prev_key.bins != key.bins
    {
        return None;
    }
    let prev = solutions.get(prev_key)?;
    if prev.method != SolveMethod::ExactArcFlow {
        return None;
    }
    // Both directions start from the cached root basis (re-entered for
    // pure vanish, translated when groups appeared).
    let basis = prev.hints.root_basis.clone()?;
    let matched = align_groups(prev_key, key);
    let vanished = prev_key.items.len() - matched.len();
    let appeared = key.items.len() - matched.len();
    if vanished + appeared == 0 || vanished + appeared > MAX_STRUCTURAL_GROUPS {
        // Identical structure is the counts-only delta path's job, and
        // heavy churn solves better cold.
        return None;
    }
    // Count drift over the matched groups stays bounded like the
    // counts-only delta gate (zero drift allowed: the structure differs).
    let total: usize = key.items.iter().map(|(c, _)| *c).sum();
    let drift: usize = matched
        .iter()
        .map(|&(i, j)| prev_key.items[i].0.abs_diff(key.items[j].0))
        .sum();
    if drift > (total / 20).max(2) {
        return None;
    }
    // Merge-walk the alignment to assign *augmented* coordinates: the
    // augmented item list is this problem's groups with each vanished
    // group re-inserted as a ghost, laid out so that deleting the appeared
    // groups reproduces the previous problem's order exactly. Ghost
    // positions come out strictly ascending, as `solve_delta` requires.
    let mut ghosts = Vec::with_capacity(vanished);
    let mut new_groups = Vec::with_capacity(appeared);
    let (mut i, mut j, mut ap, mut m) = (0usize, 0usize, 0usize, 0usize);
    while i < prev_key.items.len() || j < key.items.len() {
        if m < matched.len() && matched[m] == (i, j) {
            i += 1;
            j += 1;
            m += 1;
        } else if i < prev_key.items.len() && (m >= matched.len() || i < matched[m].0) {
            let (count, demands) = &prev_key.items[i];
            if *count == 0 {
                // A count-0 group never shaped the cached solve's graphs;
                // embedding it would desync the layouts. Fall cold.
                return None;
            }
            ghosts.push(mcvbp::GhostGroup {
                position: ap,
                demand_bits: demands.clone(),
                count: *count,
            });
            i += 1;
        } else {
            new_groups.push(ap);
            j += 1;
        }
        ap += 1;
    }
    if new_groups.is_empty() {
        // Pure vanish: the ghost-augmented ILP is bit-identical to the
        // cached solve's, so its basis and branch order re-enter directly.
        return Some(DeltaHints {
            root_basis: Some(basis),
            branch_order: prev.hints.branch_order.clone(),
            ghosts,
            appeared: None,
        });
    }
    // Appeared groups in play (pure or mixed with ghosts): translate the
    // cached basis block-by-block. No root_basis / branch_order
    // passthrough — both index the previous solve's column space, which
    // the appeared groups shift. The slack-rank arithmetic needs every
    // group on both sides to own a coverage row (count > 0).
    if prev.blocks.is_empty()
        || prev.counts.iter().any(|&c| c == 0)
        || key.items.iter().any(|(c, _)| *c == 0)
    {
        return None;
    }
    Some(DeltaHints {
        root_basis: None,
        branch_order: Vec::new(),
        ghosts,
        appeared: Some(mcvbp::PrevLayout {
            basis,
            blocks: prev.blocks.clone(),
            num_vars: prev.num_vars,
            num_groups: prev_key.items.len(),
            new_groups,
        }),
    })
}

/// Post-solve bookkeeping of one subproblem that is not answered by the
/// memo: its memo key and the budgets it ran under (just the three telemetry
/// numbers — the full options live in the job).
struct Pending {
    ci: usize,
    key: SolveKey,
    graph_budget: usize,
    var_budget: usize,
    node_budget: usize,
}

/// Owned inputs of one dispatched solve (everything a pool worker needs;
/// the graph cache and config travel behind `Arc`s).
struct SolveJob {
    sub: PackingProblem,
    sub_seed: Option<Vec<PackedBin>>,
    opts: SolveOptions,
    hints: Option<DeltaHints>,
}

/// Stage 3 — **Solve**: decompose into independent per-region-cluster
/// subproblems, allocate each component's solver budgets from its history
/// plus the global pool, return memoized solutions for bit-identical
/// subproblems, warm-start near-identical ones from the delta memo, and
/// solve the rest on the context's persistent worker pool.
fn solve_stage(
    problem: &PackingProblem,
    config: &PlannerConfig,
    ctx: &mut PlanContext,
    seeds: Option<&[PackedBin]>,
    pool_in: AxisSlack,
    stats: &mut PipelineStats,
) -> Result<(Packing, SolveMethod)> {
    let comps = decompose(problem);
    stats.components = comps.len();
    let fail_fast0 = ctx.graphs.fail_fast_count();

    // Adaptive budgets: each component's SolveOptions from its telemetry
    // plus the donated pool (see `coordinator::budget`), topped up by the
    // cross-candidate share the portfolio collected from the other
    // contexts' allocations. Components without history run at the static
    // seed budgets — a cold context therefore solves exactly like the seed
    // planner.
    let comp_ids: Vec<u64> = comps.iter().map(|c| component_id(problem, c)).collect();
    let history: Vec<Option<&ComponentTelemetry>> =
        comp_ids.iter().map(|id| ctx.telemetry.get(id)).collect();
    let budget::PooledAllocation { opts: allocations, drawn_nodes, published } =
        budget::allocate_pooled(&config.solve_opts, &history, pool_in);
    ctx.pool_out = published;

    // Per-component inputs: the restricted problem, its memo key, budgets,
    // delta hints, and the translated warm seeds. Memo hits skip the solver
    // entirely — on a small-perturbation re-plan almost every region
    // cluster is bit-identical to the previous hour's.
    let mut resolved: Vec<Option<SubSolve>> = Vec::with_capacity(comps.len());
    let mut pending: Vec<Pending> = Vec::new();
    let mut jobs: Vec<SolveJob> = Vec::new();
    for (ci, comp) in comps.iter().enumerate() {
        let (sub, sub_seed) = if comps.len() == 1 {
            (problem.clone(), seeds.map(<[PackedBin]>::to_vec))
        } else {
            (subproblem(problem, comp), seeds.map(|s| sub_seeds(s, comp)))
        };
        let key = solve_key(&sub);
        let opts = allocations[ci].clone();
        // Bit-identical subproblems reuse the memoized result verbatim —
        // even a heuristic one. This keeps the documented invariant that
        // identical consecutive re-plans change nothing (zero churn, stable
        // ids); budget escalation kicks in the moment the subproblem
        // actually drifts, which is the regime the adaptive allocator is
        // for ("demands may vary").
        match ctx.solutions.get(&key) {
            Some(c) => {
                stats.solution_cache_hits += 1;
                resolved.push(Some(SubSolve {
                    packing: c.packing.clone(),
                    method: c.method,
                    proven: c.proven,
                    stats: None,
                }));
            }
            None => {
                stats.solution_cache_misses += 1;
                let mut hints = delta_hints(&ctx.solutions, &ctx.delta_index, &key);
                if hints.is_some() {
                    stats.delta_solve_hits += 1;
                } else {
                    // Same structure missed — try a bounded set of
                    // appeared and/or vanished groups (tracked by its own
                    // counters so the exact delta-path telemetry stays
                    // untouched).
                    hints = structural_hints(&ctx.solutions, &ctx.family_index, &key);
                    if let Some(h) = &hints {
                        stats.structural_delta_hits += 1;
                        stats.structural_ghost_groups += h.ghosts.len();
                        stats.structural_appeared_groups +=
                            h.appeared.as_ref().map_or(0, |p| p.new_groups.len());
                    }
                }
                resolved.push(None);
                pending.push(Pending {
                    ci,
                    key,
                    graph_budget: opts.max_graph_nodes,
                    var_budget: opts.max_milp_vars,
                    node_budget: opts.milp.max_nodes,
                });
                jobs.push(SolveJob { sub, sub_seed, opts, hints });
            }
        }
    }

    // Donated budget is reported for components that actually solve this
    // run — memo hits consume nothing, so a stable re-plan reports zero.
    // The cross-candidate draw follows the same rule.
    stats.budget_donated_nodes = pending
        .iter()
        .map(|p| p.graph_budget - config.solve_opts.max_graph_nodes)
        .sum();
    stats.budget_pooled_nodes = pending.iter().map(|p| drawn_nodes[p.ci]).sum();

    let results: Vec<Result<SubSolve>> = if config.parallel_regions && jobs.len() > 1 {
        // Dispatch to the persistent pool: jobs own their subproblem, the
        // graph cache and config ride behind Arcs, and results come back
        // indexed over a channel (a panicked job surfaces as a dropped
        // sender, mapped to a solver error below). The pool slot spawns the
        // workers on first use and may be shared across portfolio contexts.
        let pool = ctx.pool.get();
        stats.solve_threads = jobs.len().min(pool.threads());
        stats.pool_jobs = jobs.len();
        let cache = Arc::clone(&ctx.graphs);
        let cfg = Arc::new(config.clone());
        let n = jobs.len();
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Result<SubSolve>)>();
        for (j, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            let cache = Arc::clone(&cache);
            let cfg = Arc::clone(&cfg);
            pool.execute(move || {
                let r = solve_one(
                    &job.sub,
                    &cfg,
                    &cache,
                    job.sub_seed.as_deref(),
                    &job.opts,
                    job.hints.as_ref(),
                );
                let _ = tx.send((j, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<Result<SubSolve>>> =
            std::iter::repeat_with(|| None).take(n).collect();
        while let Ok((j, r)) = rx.recv() {
            slots[j] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.unwrap_or_else(|| Err(Error::solver("region solve worker panicked"))))
            .collect()
    } else {
        jobs.iter()
            .map(|job| {
                solve_one(
                    &job.sub,
                    config,
                    &ctx.graphs,
                    job.sub_seed.as_deref(),
                    &job.opts,
                    job.hints.as_ref(),
                )
            })
            .collect()
    };

    if ctx.solutions.len() + pending.len() > SOLUTION_CACHE_CAPACITY {
        ctx.solutions.clear();
        ctx.delta_index.clear();
        ctx.family_index.clear();
    }
    for (p, result) in pending.into_iter().zip(results) {
        let sub = result?;
        if let Some(st) = &sub.stats {
            // Record telemetry for the next re-plan's budget allocation.
            ctx.telemetry.insert(
                comp_ids[p.ci],
                ComponentTelemetry {
                    graph_nodes: st.graph_nodes_before,
                    milp_vars: st.milp_vars,
                    milp_nodes: st.milp_nodes,
                    exact: st.method == SolveMethod::ExactArcFlow,
                    proven: st.proven_optimal,
                    budget_exhausted: st.budget_exhausted,
                    graph_budget: p.graph_budget,
                    var_budget: p.var_budget,
                    node_budget: p.node_budget,
                },
            );
        }
        let hints = sub
            .stats
            .as_ref()
            .map(|st| DeltaHints {
                root_basis: st.root_basis.clone(),
                branch_order: st.branch_order.clone(),
                ghosts: Vec::new(),
                appeared: None,
            })
            .unwrap_or_default();
        let (blocks, num_vars) = sub
            .stats
            .as_ref()
            .map(|st| (st.var_blocks.clone(), st.milp_vars))
            .unwrap_or_default();
        if sub.method == SolveMethod::ExactArcFlow {
            ctx.delta_index.insert(structure_hash(&p.key), p.key.clone());
            // One family-index insert replaces the old per-position
            // minus-one-hash fan-out: the structural path re-derives the
            // vanished/appeared sets by alignment at probe time instead.
            ctx.family_index.insert(family_hash(&p.key), p.key.clone());
        }
        let counts: Vec<usize> = p.key.items.iter().map(|(c, _)| *c).collect();
        ctx.solutions.insert(
            p.key,
            CachedSolve {
                packing: sub.packing.clone(),
                method: sub.method,
                proven: sub.proven,
                hints,
                counts,
                blocks,
                num_vars,
            },
        );
        resolved[p.ci] = Some(sub);
    }

    // Aggregate per-component telemetry into the run stats + cumulative
    // solver counters, then merge the packings.
    let mut merged = Packing::default();
    let mut method = SolveMethod::ExactArcFlow;
    let mut single_result: Option<(Packing, SolveMethod)> = None;
    for (comp, slot) in comps.iter().zip(resolved) {
        let sub = slot.expect("every component resolved");
        if let Some(st) = &sub.stats {
            stats.graph_cache_hits += st.graph_cache_hits;
            stats.graph_cache_misses += st.graph_cache_misses;
            stats.lp_warm_resumes += st.lp_warm;
            stats.lp_cold_solves += st.lp_cold;
            stats.degenerate_pivots += st.degenerate_pivots;
            ctx.solver.bnb_nodes.add(st.milp_nodes as u64);
        }
        match sub.method {
            SolveMethod::ExactArcFlow => stats.components_exact += 1,
            SolveMethod::Heuristic => stats.components_fallback += 1,
        }
        if sub.proven {
            stats.components_proven += 1;
        }
        if sub.method == SolveMethod::Heuristic {
            method = SolveMethod::Heuristic;
        }
        if comps.len() == 1 {
            single_result = Some((sub.packing, sub.method));
            continue;
        }
        for b in sub.packing.bins {
            let mut counts = vec![0usize; problem.items.len()];
            for (lg, &c) in b.counts.iter().enumerate() {
                counts[comp.groups[lg]] = c;
            }
            merged.bins.push(PackedBin { bin_type: comp.bins[b.bin_type], counts });
        }
    }
    stats.graph_fail_fastpaths = ctx.graphs.fail_fast_count() - fail_fast0;
    ctx.solver.subproblems.add(comps.len() as u64);
    ctx.solver.exact_solves.add(stats.components_exact as u64);
    ctx.solver.heuristic_fallbacks.add(stats.components_fallback as u64);
    ctx.solver.memo_hits.add(stats.solution_cache_hits as u64);
    ctx.solver.delta_reuses.add(stats.delta_solve_hits as u64);
    ctx.solver.structural_reuses.add(stats.structural_delta_hits as u64);
    ctx.solver.degenerate_pivots.add(stats.degenerate_pivots);
    ctx.solver.lp_warm_resumes.add(stats.lp_warm_resumes as u64);
    ctx.solver.lp_cold_solves.add(stats.lp_cold_solves as u64);
    ctx.solver.budget_donated_nodes.add(stats.budget_donated_nodes as u64);
    ctx.solver.budget_pooled_donated.add(stats.budget_pooled_nodes as u64);
    ctx.solver.graph_fail_fastpaths.add(stats.graph_fail_fastpaths as u64);
    ctx.solver.pool_jobs.add(stats.pool_jobs as u64);
    if let Some(r) = single_result {
        return Ok(r);
    }
    Ok((merged, method))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::{camera_at, StreamRequest};
    use crate::coordinator::{Planner, PlannerConfig};
    use crate::geo::cities;
    use crate::profiles::{Program, Resolution};

    fn worldwide_requests() -> Vec<StreamRequest> {
        // Two far-apart clusters whose RTT circles cannot overlap.
        let mut reqs = Vec::new();
        for (i, city) in [cities::CHICAGO, cities::NEW_YORK].iter().enumerate() {
            reqs.push(StreamRequest::new(
                camera_at(i as u64, "us", *city, Resolution::VGA, 30.0),
                Program::Zf,
                15.0,
            ));
        }
        for (i, city) in [cities::TOKYO].iter().enumerate() {
            reqs.push(StreamRequest::new(
                camera_at(100 + i as u64, "asia", *city, Resolution::VGA, 30.0),
                Program::Zf,
                15.0,
            ));
        }
        reqs
    }

    #[test]
    fn rtt_disjoint_workload_decomposes() {
        let planner = Planner::new(crate::catalog::Catalog::builtin(), PlannerConfig::gcl());
        let (problem, _, _) = planner.build_problem(&worldwide_requests()).unwrap();
        let comps = decompose(&problem);
        assert!(comps.len() >= 2, "US and Japan clusters must split");
        // Every bin and every group lands in exactly one component.
        let mut bin_seen = vec![0usize; problem.bins.len()];
        let mut group_seen = vec![0usize; problem.items.len()];
        for c in &comps {
            for &t in &c.bins {
                bin_seen[t] += 1;
            }
            for &g in &c.groups {
                group_seen[g] += 1;
            }
        }
        assert!(bin_seen.iter().all(|&n| n <= 1));
        assert!(group_seen.iter().all(|&n| n == 1));
    }

    #[test]
    fn decomposed_plan_matches_monolithic_cost() {
        let catalog = crate::catalog::Catalog::builtin();
        let requests = worldwide_requests();
        let mut cfg = PlannerConfig::gcl();
        cfg.parallel_regions = true;
        let par = Planner::new(catalog.clone(), cfg.clone()).plan(&requests).unwrap();
        cfg.parallel_regions = false;
        let ser = Planner::new(catalog, cfg).plan(&requests).unwrap();
        assert!((par.cost_per_hour - ser.cost_per_hour).abs() < 1e-9);
        par.packing.validate(&par.problem).unwrap();
    }

    #[test]
    fn context_reuse_preserves_plan_and_reports_hits() {
        let catalog = crate::catalog::Catalog::builtin();
        let cfg = PlannerConfig::gcl();
        let requests = worldwide_requests();
        let mut ctx = PlanContext::new();
        let cold = plan_with_context(&catalog, &cfg, &requests, &mut ctx).unwrap();
        assert!(!ctx.stats.warm_started);
        assert_eq!(ctx.stats.front_unchanged, 0, "first plan has no previous slice");
        let warm = plan_with_context(&catalog, &cfg, &requests, &mut ctx).unwrap();
        assert!(ctx.stats.warm_started);
        assert_eq!(
            ctx.stats.front_unchanged,
            requests.len(),
            "identical re-plan must ride the dirty-tracking index: {:?}",
            ctx.stats
        );
        assert_eq!(ctx.stats.front_changed, 0);
        assert!(ctx.stats.demand_cache_hits > 0);
        assert!(
            (warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-9,
            "identical inputs must re-plan to the identical cost"
        );
        assert_eq!(warm.instances.len(), cold.instances.len());
    }

    #[test]
    fn warm_replan_keeps_slot_ids_and_assignments() {
        let catalog = crate::catalog::Catalog::builtin();
        let cfg = PlannerConfig::gcl();
        let requests = worldwide_requests();
        let mut ctx = PlanContext::new();
        let first = plan_with_context(&catalog, &cfg, &requests, &mut ctx).unwrap();
        let second = plan_with_context(&catalog, &cfg, &requests, &mut ctx).unwrap();
        assert_eq!(first.instances.len(), second.instances.len());
        for (a, b) in first.instances.iter().zip(&second.instances) {
            assert_eq!(a.slot_id, b.slot_id, "surviving slots keep their ids");
            assert_eq!(a.streams, b.streams, "sticky expand must not re-deal streams");
        }
    }

    #[test]
    fn context_clears_when_config_changes() {
        let catalog = crate::catalog::Catalog::builtin();
        let requests = worldwide_requests();
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &PlannerConfig::gcl(), &requests, &mut ctx).unwrap();
        // Different policy → caches must not leak over.
        let p = plan_with_context(&catalog, &PlannerConfig::nl(), &requests, &mut ctx).unwrap();
        assert!(!ctx.stats.warm_started, "stale warm start must be dropped");
        assert_eq!(ctx.stats.elig_cache_hits, 0);
        assert_eq!(ctx.stats.front_unchanged, 0, "dirty index must not survive a config change");
        p.packing.validate(&p.problem).unwrap();
    }

    #[test]
    fn solve_worker_pool_persists_across_replans() {
        let catalog = crate::catalog::Catalog::builtin();
        let cfg = PlannerConfig::gcl();
        let requests = worldwide_requests();
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &cfg, &requests, &mut ctx).unwrap();
        assert!(ctx.pool.spawned(), "parallel multi-component solve must spawn the pool");
        let first = Arc::as_ptr(&ctx.pool.get());
        assert!(ctx.stats.pool_jobs >= 2, "{:?}", ctx.stats);
        // A drifted re-plan re-solves on the same workers, and a config
        // change keeps them too (threads are not workload state).
        let mut drifted = requests.clone();
        drifted.push(StreamRequest::new(
            camera_at(7, "us2", cities::HOUSTON, Resolution::VGA, 30.0),
            Program::Zf,
            15.0,
        ));
        plan_with_context(&catalog, &cfg, &drifted, &mut ctx).unwrap();
        assert_eq!(Arc::as_ptr(&ctx.pool.get()), first);
        plan_with_context(&catalog, &PlannerConfig::armvac(), &drifted, &mut ctx).unwrap();
        assert_eq!(
            Arc::as_ptr(&ctx.pool.get()),
            first,
            "signature clear must keep the worker pool"
        );
    }

    #[test]
    fn assignment_survives_a_signature_clear() {
        // A price update clears every pure-function cache but must NOT
        // orphan the deployed fleet: the previous assignment is matched by
        // stable stream keys + bin labels only, so it stays valid across
        // catalog changes and keeps streams on their slots.
        let mut catalog = crate::catalog::Catalog::builtin()
            .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let cfg = PlannerConfig::st3();
        let requests: Vec<StreamRequest> = (0..4)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                    Program::Zf,
                    1.0,
                )
            })
            .collect();
        let mut ctx = PlanContext::new();
        let first = plan_with_context(&catalog, &cfg, &requests, &mut ctx).unwrap();
        // Perturb a price: same offerings, new signature.
        for o in &mut catalog.offerings {
            o.hourly_usd *= 1.01;
        }
        let second = plan_with_context(&catalog, &cfg, &requests, &mut ctx).unwrap();
        assert!(!ctx.stats.warm_started, "packing seed must not survive the clear");
        assert_eq!(first.instances.len(), second.instances.len());
        for (a, b) in first.instances.iter().zip(&second.instances) {
            assert_eq!(a.slot_id, b.slot_id, "slots must survive a price update");
            assert_eq!(a.streams, b.streams, "streams must stay on their slots");
        }
    }

    #[test]
    fn front_end_artifacts_match_plan_inputs() {
        let catalog = crate::catalog::Catalog::builtin();
        let cfg = PlannerConfig::gcl();
        let requests = worldwide_requests();
        let (groups, problem) =
            front_end_with_context(&catalog, &cfg, &requests, &mut PlanContext::new()).unwrap();
        let plan = plan_with_context(&catalog, &cfg, &requests, &mut PlanContext::new()).unwrap();
        assert_eq!(problem, plan.problem, "front-end artifacts must equal the planned problem");
        let members: usize = groups.members.iter().map(Vec::len).sum();
        assert_eq!(members, requests.len());
    }

    #[test]
    fn single_count_change_takes_the_delta_solve_path() {
        // Same structure (one Chicago group), one more camera: the solution
        // memo misses bit-exactly but the near-match index must hand the
        // solver its cached basis/branch order, and the warm plan must cost
        // exactly what a cold plan of the grown workload costs.
        let catalog = crate::catalog::Catalog::builtin()
            .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let cfg = PlannerConfig::st3();
        let mk = |n: usize| -> Vec<StreamRequest> {
            (0..n)
                .map(|i| {
                    StreamRequest::new(
                        camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                        Program::Zf,
                        1.0,
                    )
                })
                .collect()
        };
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &cfg, &mk(6), &mut ctx).unwrap();
        let warm = plan_with_context(&catalog, &cfg, &mk(7), &mut ctx).unwrap();
        assert_eq!(ctx.stats.delta_solve_hits, 1, "{:?}", ctx.stats);
        assert_eq!(ctx.solver.delta_reuses.get(), 1);
        assert_eq!(
            (ctx.stats.front_unchanged, ctx.stats.front_changed),
            (6, 1),
            "only the added camera runs the front-end"
        );
        let cold = plan_with_context(&catalog, &cfg, &mk(7), &mut PlanContext::new()).unwrap();
        assert!(
            (warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-9,
            "delta-solve warm {} != cold {}",
            warm.cost_per_hour,
            cold.cost_per_hour
        );
    }

    /// Two-resolution workload for the structural delta tests: `hd` HD720
    /// cameras (one group) plus `vga` VGA cameras (a second group), all in
    /// one region cluster.
    fn two_group_requests(hd: usize, vga: usize) -> Vec<StreamRequest> {
        let mut reqs: Vec<StreamRequest> = (0..hd)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                    Program::Zf,
                    1.0,
                )
            })
            .collect();
        reqs.extend((0..vga).map(|i| {
            StreamRequest::new(
                camera_at(100 + i as u64, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                Program::Zf,
                1.0,
            )
        }));
        reqs
    }

    #[test]
    fn group_vanishing_takes_the_structural_delta_path() {
        // Re-plan with one whole group gone: the exact-structure indexes
        // miss, but the family index finds the previous solve, the
        // alignment reports one vanished group, and the solver re-enters
        // it through the ghost embedding. The cost must equal a cold
        // plan's and the counts-only delta telemetry must not move.
        let catalog = crate::catalog::Catalog::builtin()
            .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let cfg = PlannerConfig::st3();
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &cfg, &two_group_requests(4, 3), &mut ctx).unwrap();
        let warm = plan_with_context(&catalog, &cfg, &two_group_requests(4, 0), &mut ctx).unwrap();
        assert_eq!(ctx.stats.structural_delta_hits, 1, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.delta_solve_hits, 0, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.structural_ghost_groups, 1, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.structural_appeared_groups, 0, "{:?}", ctx.stats);
        assert_eq!(ctx.solver.structural_reuses.get(), 1);
        let cold =
            plan_with_context(&catalog, &cfg, &two_group_requests(4, 0), &mut PlanContext::new())
                .unwrap();
        assert!(
            (warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-9,
            "vanished-group warm {} != cold {}",
            warm.cost_per_hour,
            cold.cost_per_hour
        );
    }

    #[test]
    fn group_appearing_takes_the_structural_delta_path() {
        // The reverse drift: a whole new group joins. The family index
        // finds the previous solve, the alignment reports one appeared
        // group, and its basis arrives block-translated into the wider
        // column space. Certified-or-cold: cost must equal a cold plan's.
        let catalog = crate::catalog::Catalog::builtin()
            .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let cfg = PlannerConfig::st3();
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &cfg, &two_group_requests(4, 0), &mut ctx).unwrap();
        let warm = plan_with_context(&catalog, &cfg, &two_group_requests(4, 3), &mut ctx).unwrap();
        assert_eq!(ctx.stats.structural_delta_hits, 1, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.delta_solve_hits, 0, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.structural_ghost_groups, 0, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.structural_appeared_groups, 1, "{:?}", ctx.stats);
        let cold =
            plan_with_context(&catalog, &cfg, &two_group_requests(4, 3), &mut PlanContext::new())
                .unwrap();
        assert!(
            (warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-9,
            "appeared-group warm {} != cold {}",
            warm.cost_per_hour,
            cold.cost_per_hour
        );
    }

    #[test]
    fn mixed_vanish_and_appear_takes_the_structural_delta_path() {
        // One group swaps for another in a single re-plan (VGA out, XGA
        // in): the alignment reports one vanished AND one appeared group,
        // the vanished one re-embeds as a ghost, and the cached basis
        // translates into the ghost-augmented column space — one certified
        // structural delta solve instead of a cold one. Cost parity with a
        // cold plan is the exactness pin.
        let catalog = crate::catalog::Catalog::builtin()
            .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let cfg = PlannerConfig::st3();
        let swap = |vga: usize, xga: usize| -> Vec<StreamRequest> {
            let mut reqs = two_group_requests(4, vga);
            reqs.extend((0..xga).map(|i| {
                StreamRequest::new(
                    camera_at(200 + i as u64, "Chicago", cities::CHICAGO, Resolution::XGA, 30.0),
                    Program::Zf,
                    1.0,
                )
            }));
            reqs
        };
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &cfg, &swap(3, 0), &mut ctx).unwrap();
        let warm = plan_with_context(&catalog, &cfg, &swap(0, 3), &mut ctx).unwrap();
        assert_eq!(ctx.stats.structural_delta_hits, 1, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.delta_solve_hits, 0, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.structural_ghost_groups, 1, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.structural_appeared_groups, 1, "{:?}", ctx.stats);
        assert_eq!(ctx.solver.structural_reuses.get(), 1);
        let cold = plan_with_context(&catalog, &cfg, &swap(0, 3), &mut PlanContext::new()).unwrap();
        assert!(
            (warm.cost_per_hour - cold.cost_per_hour).abs() < 1e-9,
            "mixed vanish+appear warm {} != cold {}",
            warm.cost_per_hour,
            cold.cost_per_hour
        );
    }

    #[test]
    fn component_accounting_covers_every_subproblem() {
        let catalog = crate::catalog::Catalog::builtin();
        let cfg = PlannerConfig::gcl();
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &cfg, &worldwide_requests(), &mut ctx).unwrap();
        let s = &ctx.stats;
        assert!(s.components >= 2);
        assert_eq!(
            s.components_exact + s.components_fallback,
            s.components,
            "every component is exact or fallback: {s:?}"
        );
        assert_eq!(s.components_proven, s.components, "paper-scale solves must prove");
        // Telemetry recorded for each component, at the static seed budgets
        // (first plan: no history, so no grants).
        assert_eq!(ctx.component_telemetry().len(), s.components);
        assert_eq!(s.budget_donated_nodes, 0);
        assert_eq!(ctx.solver.subproblems.get(), s.components as u64);
    }

    #[test]
    fn budget_escalates_after_a_fallback_when_the_workload_drifts() {
        // Force a budget-bound fallback, then re-plan a drifted workload
        // through the same context: the allocator must escalate the
        // component's budgets (visible as donated/granted nodes and in the
        // recorded telemetry), while an *identical* re-plan keeps riding
        // the memo for stability.
        let catalog = crate::catalog::Catalog::builtin()
            .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let mut cfg = PlannerConfig::st3();
        cfg.solve_opts.max_graph_nodes = 2; // nothing real builds under this
        let mk = |n: usize| -> Vec<StreamRequest> {
            (0..n)
                .map(|i| {
                    StreamRequest::new(
                        camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                        Program::Zf,
                        1.0,
                    )
                })
                .collect()
        };
        let mut ctx = PlanContext::new();
        let first = plan_with_context(&catalog, &cfg, &mk(5), &mut ctx).unwrap();
        assert_eq!(ctx.stats.components_fallback, 1, "{:?}", ctx.stats);
        assert_eq!(ctx.stats.budget_donated_nodes, 0, "no history yet");
        let telem = ctx.component_telemetry();
        assert!(telem[0].budget_exhausted && telem[0].graph_budget == 2);

        // Identical re-plan: memo hit, nothing re-solved (stability).
        plan_with_context(&catalog, &cfg, &mk(5), &mut ctx).unwrap();
        assert_eq!(ctx.stats.solution_cache_hits, 1, "{:?}", ctx.stats);

        // Drifted re-plan: escalated budgets applied to the fresh solve.
        let drifted = plan_with_context(&catalog, &cfg, &mk(6), &mut ctx).unwrap();
        assert!(ctx.stats.budget_donated_nodes > 0, "{:?}", ctx.stats);
        let telem = ctx.component_telemetry();
        assert!(
            telem[0].graph_budget > 2,
            "drifted re-plan must run under the escalated budget: {:?}",
            telem[0]
        );
        assert!(first.cost_per_hour > 0.0 && drifted.cost_per_hour > 0.0);
    }

    #[test]
    fn warm_replan_tracks_workload_growth() {
        let catalog = crate::catalog::Catalog::builtin()
            .restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let cfg = PlannerConfig::st3();
        let mk = |n: usize| -> Vec<StreamRequest> {
            (0..n)
                .map(|i| {
                    StreamRequest::new(
                        camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                        Program::Zf,
                        2.0,
                    )
                })
                .collect()
        };
        let mut ctx = PlanContext::new();
        plan_with_context(&catalog, &cfg, &mk(4), &mut ctx).unwrap();
        let grown = plan_with_context(&catalog, &cfg, &mk(6), &mut ctx).unwrap();
        let cold = plan_with_context(&catalog, &cfg, &mk(6), &mut PlanContext::new()).unwrap();
        assert!(
            (grown.cost_per_hour - cold.cost_per_hour).abs() < 1e-9,
            "warm growth plan must cost the same as a cold plan"
        );
        let assigned: usize = grown.instances.iter().map(|i| i.streams.len()).sum();
        assert_eq!(assigned, 6);
    }
}
