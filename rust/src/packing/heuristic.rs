//! Greedy packers: first-fit-decreasing (FFD) over cost-efficiency-ranked
//! bins, the ARMVAC fill rule ("pick the lowest-cost eligible instance,
//! fill it with as many streams as fit, repeat"), and a warm-start fill that
//! repairs a previous packing against a perturbed problem.
//!
//! These provide (a) warm-start incumbents for the exact branch-and-bound
//! solver, (b) the behaviour of the paper's baseline resource managers,
//! (c) a fallback when an instance is too large for exact solving, and
//! (d) the incremental re-plan seed used by `coordinator::pipeline`.

use super::{BinType, ItemGroup, Packing, PackedBin, PackingProblem};
use crate::catalog::Dims;
use crate::error::{Error, Result};

/// Normalized "size" of a demand vector w.r.t. a capacity: the max dimension
/// fraction. Items that demand a scarce dimension rank large.
fn norm_size(demand: &Dims, cap: &Dims) -> f64 {
    demand.max_utilization(cap)
}

/// Component-wise max of all bin types' effective capacities — the global
/// reference scale that makes packed volumes comparable across bin types.
fn reference_capacity(problem: &PackingProblem) -> Dims {
    let mut r = Dims::default();
    for t in 0..problem.bins.len() {
        let c = problem.effective_capacity(t);
        r = Dims::new(
            r.vcpus.max(c.vcpus),
            r.mem_gib.max(c.mem_gib),
            r.gpus.max(c.gpus),
            r.gpu_mem_gib.max(c.gpu_mem_gib),
        );
    }
    r
}

/// Simulate greedily filling ONE bin of type `t` from `remaining` counts,
/// starting from an already-used `used0` footprint (zero for a fresh bin).
/// Returns (counts per group, packed volume normalized by `reference`).
fn fill_one_bin_from(
    problem: &PackingProblem,
    t: usize,
    remaining: &[usize],
    reference: &Dims,
    used0: Dims,
) -> (Vec<usize>, f64) {
    let cap = problem.effective_capacity(t);
    // Order groups by decreasing normalized size in this bin.
    let mut order: Vec<usize> = (0..problem.items.len())
        .filter(|&g| remaining[g] > 0 && problem.compatible(g, t))
        .collect();
    order.sort_by(|&a, &b| {
        let sa = norm_size(&problem.items[a].demand_per_bin[t].unwrap(), &cap);
        let sb = norm_size(&problem.items[b].demand_per_bin[t].unwrap(), &cap);
        sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut counts = vec![0usize; problem.items.len()];
    let mut used = used0;
    let mut volume = 0.0;
    for &g in &order {
        let d = problem.items[g].demand_per_bin[t].unwrap();
        for _ in 0..remaining[g] {
            let next = used.add(&d);
            if next.fits_in(&cap) {
                used = next;
                counts[g] += 1;
                volume += norm_size(&d, reference);
            } else {
                break;
            }
        }
    }
    (counts, volume)
}

fn fill_one_bin(
    problem: &PackingProblem,
    t: usize,
    remaining: &[usize],
    reference: &Dims,
) -> (Vec<usize>, f64) {
    fill_one_bin_from(problem, t, remaining, reference, Dims::default())
}

/// The FFD inner loop as a continuation: pack every count left in
/// `remaining` into fresh bins appended to `packing`.
fn ffd_fill(
    problem: &PackingProblem,
    remaining: &mut [usize],
    packing: &mut Packing,
) -> Result<()> {
    let reference = reference_capacity(problem);
    while remaining.iter().any(|&c| c > 0) {
        let mut best: Option<(usize, Vec<usize>, f64)> = None; // (t, counts, score)
        for t in 0..problem.bins.len() {
            let (counts, volume) = fill_one_bin(problem, t, remaining, &reference);
            if volume <= 0.0 {
                continue;
            }
            let score = problem.bins[t].cost / volume;
            if best.as_ref().is_none_or(|(_, _, s)| score < *s) {
                best = Some((t, counts, score));
            }
        }
        let (t, counts, _) = best.ok_or_else(|| {
            Error::infeasible("remaining streams fit in no instance type")
        })?;
        for (g, &c) in counts.iter().enumerate() {
            remaining[g] -= c;
        }
        packing.bins.push(PackedBin { bin_type: t, counts });
    }
    Ok(())
}

/// First-fit-decreasing over cost-efficiency: repeatedly open the bin type
/// with the best (cost / packed-volume) ratio for the remaining items.
pub fn first_fit_decreasing(problem: &PackingProblem) -> Result<Packing> {
    problem.check_feasible_items()?;
    let mut remaining: Vec<usize> = problem.items.iter().map(|g| g.count).collect();
    let mut packing = Packing::default();
    ffd_fill(problem, &mut remaining, &mut packing)?;
    packing.validate(problem)?;
    Ok(packing)
}

/// Warm-start fill: rebuild a packing for `problem` starting from the bins
/// of a previous solution (already translated to this problem's group/bin
/// indices by the caller).
///
/// Each seed bin is admitted with its counts clamped to the still-unpacked
/// demand and its incompatible placements dropped; bins that no longer fit
/// the (possibly changed) demand vectors are discarded. Leftover demand is
/// then topped up into the admitted bins' spare capacity and finally packed
/// into fresh bins with the FFD rule. On an unchanged problem this
/// reproduces the seed packing exactly — the property the incremental
/// re-planner relies on.
pub fn warm_start_fill(problem: &PackingProblem, seeds: &[PackedBin]) -> Result<Packing> {
    problem.check_feasible_items()?;
    let reference = reference_capacity(problem);
    let mut remaining: Vec<usize> = problem.items.iter().map(|g| g.count).collect();
    let mut packing = Packing::default();

    // Pass 1: admit seed bins (clamped to unpacked demand, capacity-checked).
    // Admission must finish before any top-up, otherwise spare capacity in an
    // early bin would steal items destined for a later seed bin and an
    // unchanged problem would not round-trip.
    let mut admitted: Vec<Dims> = Vec::new(); // per admitted bin: used footprint
    for seed in seeds {
        if seed.bin_type >= problem.bins.len() || seed.counts.len() != problem.items.len() {
            continue;
        }
        let t = seed.bin_type;
        let cap = problem.effective_capacity(t);
        let mut counts = vec![0usize; problem.items.len()];
        let mut used = Dims::default();
        for (g, &c) in seed.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let Some(d) = problem.items[g].demand_per_bin[t] else {
                continue;
            };
            let mut take = c.min(remaining[g]);
            while take > 0 {
                let next = used.add(&d.scale(take as f64));
                if next.fits_in(&cap) {
                    used = next;
                    counts[g] = take;
                    break;
                }
                take -= 1;
            }
        }
        if counts.iter().all(|&c| c == 0) {
            continue;
        }
        for (g, &c) in counts.iter().enumerate() {
            remaining[g] -= c;
        }
        packing.bins.push(PackedBin { bin_type: t, counts });
        admitted.push(used);
    }

    // Pass 2: top up admitted bins' spare capacity with leftover demand.
    for (bin_idx, used) in admitted.into_iter().enumerate() {
        if remaining.iter().all(|&c| c == 0) {
            break;
        }
        let t = packing.bins[bin_idx].bin_type;
        let (extra, _) = fill_one_bin_from(problem, t, &remaining, &reference, used);
        for (g, &c) in extra.iter().enumerate() {
            packing.bins[bin_idx].counts[g] += c;
            remaining[g] -= c;
        }
    }

    // Pass 3: whatever is left opens fresh bins under the FFD rule.
    ffd_fill(problem, &mut remaining, &mut packing)?;
    packing.validate(problem)?;
    Ok(packing)
}

/// The ARMVAC fill rule (Mohan et al. \[6\], \[8\]): select the *lowest-cost*
/// eligible instance type, send as many streams to it as fit, repeat.
/// (Cheapest-first rather than efficiency-first: this is exactly the
/// behaviour the paper says underperforms in the 1–20 fps band.)
pub fn armvac_fill(problem: &PackingProblem) -> Result<Packing> {
    problem.check_feasible_items()?;
    let mut remaining: Vec<usize> = problem.items.iter().map(|g| g.count).collect();
    let mut packing = Packing::default();

    // Bin types sorted by absolute hourly cost, cheapest first.
    let mut order: Vec<usize> = (0..problem.bins.len()).collect();
    order.sort_by(|&a, &b| {
        problem.bins[a]
            .cost
            .partial_cmp(&problem.bins[b].cost)
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    let reference = reference_capacity(problem);
    while remaining.iter().any(|&c| c > 0) {
        let mut progressed = false;
        for &t in &order {
            let (counts, volume) = fill_one_bin(problem, t, &remaining, &reference);
            if volume > 0.0 {
                for (g, &c) in counts.iter().enumerate() {
                    remaining[g] -= c;
                }
                packing.bins.push(PackedBin { bin_type: t, counts });
                progressed = true;
                break;
            }
        }
        if !progressed {
            return Err(Error::infeasible(
                "ARMVAC: remaining streams fit in no instance type",
            ));
        }
    }
    packing.validate(problem)?;
    Ok(packing)
}

/// Helper for tests/benches: single-bin-kind problem builder.
pub fn simple_problem(
    item_sizes: &[(f64, f64, usize)], // (cpu, mem, count)
    bins: &[(f64, f64, f64)],         // (cpu cap, mem cap, cost)
) -> PackingProblem {
    let bin_types: Vec<BinType> = bins
        .iter()
        .enumerate()
        .map(|(i, &(c, m, cost))| BinType {
            label: format!("bin{i}"),
            capacity: Dims::new(c, m, 0.0, 0.0),
            cost,
            type_idx: i,
            region_idx: 0,
            has_gpu: false,
        })
        .collect();
    let items = item_sizes
        .iter()
        .enumerate()
        .map(|(i, &(c, m, count))| ItemGroup {
            label: format!("item{i}"),
            count,
            demand_per_bin: vec![Some(Dims::new(c, m, 0.0, 0.0)); bin_types.len()],
        })
        .collect();
    PackingProblem::new(items, bin_types)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffd_packs_everything() {
        let p = simple_problem(
            &[(2.0, 1.0, 5), (3.0, 2.0, 3)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.8)],
        );
        let packing = first_fit_decreasing(&p).unwrap();
        packing.validate(&p).unwrap();
        assert_eq!(
            packing.bins.iter().map(|b| b.num_streams()).sum::<usize>(),
            8
        );
    }

    #[test]
    fn ffd_prefers_cost_efficient_bin() {
        // Big bin is cheaper per unit: 16 cores for 1.5 vs 8 cores for 1.0.
        let p = simple_problem(&[(1.0, 0.5, 12)], &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.5)]);
        let packing = first_fit_decreasing(&p).unwrap();
        // 12 items of 1 core: 90% of 16 = 14.4 -> one big bin suffices.
        assert_eq!(packing.num_bins(), 1);
        assert_eq!(packing.bins[0].bin_type, 1);
    }

    #[test]
    fn armvac_prefers_cheapest_bin() {
        // Same instance: ARMVAC opens the cheap small bin first.
        let p = simple_problem(&[(1.0, 0.5, 12)], &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.5)]);
        let packing = armvac_fill(&p).unwrap();
        assert_eq!(packing.bins[0].bin_type, 0);
        // 7 items fit in 7.2 cores; needs 2 bins of the small type.
        assert_eq!(packing.num_bins(), 2);
        // ARMVAC cost (2.0) exceeds FFD cost (1.5): the paper's 1–20 fps gap.
        let ffd = first_fit_decreasing(&p).unwrap();
        assert!(packing.total_cost(&p) > ffd.total_cost(&p));
    }

    #[test]
    fn infeasible_when_item_too_big() {
        let p = simple_problem(&[(100.0, 1.0, 1)], &[(8.0, 15.0, 1.0)]);
        assert!(first_fit_decreasing(&p).is_err());
        assert!(armvac_fill(&p).is_err());
    }

    #[test]
    fn headroom_respected() {
        // One item of exactly 7.3 cores does NOT fit an 8-core bin at 90%.
        let p = simple_problem(&[(7.3, 1.0, 1)], &[(8.0, 15.0, 1.0)]);
        assert!(first_fit_decreasing(&p).is_err());
        // 7.1 does.
        let p = simple_problem(&[(7.1, 1.0, 1)], &[(8.0, 15.0, 1.0)]);
        assert!(first_fit_decreasing(&p).is_ok());
    }

    #[test]
    fn warm_start_round_trips_unchanged_problem() {
        let p = simple_problem(
            &[(2.0, 1.0, 5), (3.0, 2.0, 3)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.8)],
        );
        let cold = first_fit_decreasing(&p).unwrap();
        let warm = warm_start_fill(&p, &cold.bins).unwrap();
        assert_eq!(warm, cold, "unchanged problem must reproduce the seed");
    }

    #[test]
    fn warm_start_absorbs_small_growth_without_extra_bins() {
        // 10 one-core items fill a 16-core bin to 10/14.4; one more item must
        // slot into the same bin on re-plan.
        let p0 = simple_problem(&[(1.0, 0.5, 10)], &[(16.0, 30.0, 1.5)]);
        let seed = first_fit_decreasing(&p0).unwrap();
        assert_eq!(seed.num_bins(), 1);
        let p1 = simple_problem(&[(1.0, 0.5, 11)], &[(16.0, 30.0, 1.5)]);
        let warm = warm_start_fill(&p1, &seed.bins).unwrap();
        warm.validate(&p1).unwrap();
        assert_eq!(warm.num_bins(), 1, "growth should be absorbed via top-up");
    }

    #[test]
    fn warm_start_drops_shrunk_demand() {
        let p0 = simple_problem(&[(2.0, 1.0, 6)], &[(8.0, 15.0, 1.0)]);
        let seed = first_fit_decreasing(&p0).unwrap();
        let p1 = simple_problem(&[(2.0, 1.0, 2)], &[(8.0, 15.0, 1.0)]);
        let warm = warm_start_fill(&p1, &seed.bins).unwrap();
        warm.validate(&p1).unwrap();
        assert_eq!(
            warm.bins.iter().map(|b| b.num_streams()).sum::<usize>(),
            2
        );
    }

    #[test]
    fn warm_start_with_stale_seed_shapes_is_ignored() {
        // Seeds from an incompatible problem (wrong counts length / bin index)
        // must be skipped, not crash.
        let p = simple_problem(&[(2.0, 1.0, 3)], &[(8.0, 15.0, 1.0)]);
        let stale = vec![
            PackedBin { bin_type: 7, counts: vec![3] },
            PackedBin { bin_type: 0, counts: vec![1, 1] },
        ];
        let warm = warm_start_fill(&p, &stale).unwrap();
        warm.validate(&p).unwrap();
    }

    #[test]
    fn property_warm_start_valid_on_perturbed_problems() {
        use crate::util::Rng;
        let mut rng = Rng::new(77);
        for _ in 0..30 {
            let n_groups = 1 + rng.index(3);
            let items: Vec<(f64, f64, usize)> = (0..n_groups)
                .map(|_| {
                    (
                        rng.range_f64(0.3, 5.0),
                        rng.range_f64(0.3, 8.0),
                        1 + rng.index(6),
                    )
                })
                .collect();
            let bins = [(8.0, 15.0, 1.0), (16.0, 30.0, 1.8)];
            let p0 = simple_problem(&items, &bins);
            let Ok(seed) = first_fit_decreasing(&p0) else {
                continue;
            };
            // Perturb counts by ±1.
            let perturbed: Vec<(f64, f64, usize)> = items
                .iter()
                .map(|&(c, m, n)| {
                    let n2 = match rng.index(3) {
                        0 => n + 1,
                        1 => n.saturating_sub(1).max(1),
                        _ => n,
                    };
                    (c, m, n2)
                })
                .collect();
            let p1 = simple_problem(&perturbed, &bins);
            let warm = warm_start_fill(&p1, &seed.bins).unwrap();
            warm.validate(&p1).unwrap();
            assert!(warm.peak_utilization(&p1) <= p1.headroom + 1e-9);
        }
    }

    #[test]
    fn property_ffd_never_overfills() {
        use crate::util::Rng;
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let n_groups = 1 + rng.index(4);
            let items: Vec<(f64, f64, usize)> = (0..n_groups)
                .map(|_| {
                    (
                        rng.range_f64(0.2, 6.0),
                        rng.range_f64(0.2, 10.0),
                        1 + rng.index(6),
                    )
                })
                .collect();
            let p = simple_problem(
                &items,
                &[(8.0, 15.0, 1.0), (36.0, 60.0, 4.0), (16.0, 30.0, 2.1)],
            );
            if let Ok(packing) = first_fit_decreasing(&p) {
                packing.validate(&p).unwrap();
                assert!(packing.peak_utilization(&p) <= p.headroom + 1e-9);
            }
        }
    }
}
