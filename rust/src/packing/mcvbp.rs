//! Exact multiple-choice vector bin packing: one arc-flow graph per bin type
//! (Brandão & Pedroso's multiple-choice method \[10\] — "a graph is constructed
//! for each truck type, and then solved using the Gurobi solver"), assembled
//! into a joint min-cost integer flow and solved by branch-and-bound.
//!
//! Demands are quantized (rounded *up*) onto a per-bin grid, so any packing
//! valid on the quantized instance is valid on the original. An FFD packing
//! of the quantized instance provides the incumbent; the exact solve can only
//! improve it.

use super::arcflow::{self, GraphCache, QuantItem};
use super::heuristic;
use super::{ItemGroup, Packing, PackedBin, PackingProblem};
use crate::catalog::{Dims, NUM_DIMS};
use crate::coordinator::budget::milp_node_cost;
use crate::error::{Error, Result};
use crate::solver::{complete_basis, solve_milp, Lp, Milp, MilpOptions, Op};
use crate::util::fxhash::FxBuildHasher;
use std::hash::BuildHasher;

/// Exact-solve configuration.
///
/// The `Default` values are the *static seed budgets*: what one subproblem
/// gets with no solve history. The staged planner's adaptive allocator
/// (`coordinator::budget`) re-derives per-component budgets from telemetry
/// each re-plan, flooring at these values — so the defaults are the
/// guaranteed minimum, not a hard ceiling.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Quantization levels per dimension (grid = effective capacity / quant).
    pub quant: i64,
    /// Cumulative arc-flow node budget across a solve's bin types;
    /// exceeded -> heuristic fallback.
    pub max_graph_nodes: usize,
    /// Joint-ILP variable budget; exceeded -> heuristic fallback.
    pub max_milp_vars: usize,
    /// Branch-and-bound limits.
    pub milp: MilpOptions,
    /// Numerator of the per-ILP node guard: the effective branch-and-bound
    /// node budget is `min(milp.max_nodes, max(50, milp_node_scale / vars))`
    /// so planning latency stays bounded on large ILPs ("resource decisions
    /// quickly, during runtime"). Scaled up alongside `milp.max_nodes` by
    /// the adaptive allocator.
    pub milp_node_scale: usize,
    /// If false, skip the exact phase entirely (best-of heuristics).
    pub exact: bool,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            quant: 60,
            max_graph_nodes: 6_000,
            max_milp_vars: 600,
            milp: MilpOptions { max_nodes: 2_000, ..Default::default() },
            milp_node_scale: 200_000,
            exact: true,
        }
    }
}

/// How the returned packing was obtained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveMethod {
    Heuristic,
    ExactArcFlow,
}

/// Diagnostics for benches and EXPERIMENTS.md.
#[derive(Clone, Debug)]
pub struct SolveStats {
    pub method: SolveMethod,
    pub ffd_cost: f64,
    pub final_cost: f64,
    pub milp_nodes: usize,
    pub graph_nodes_before: usize,
    pub graph_arcs_before: usize,
    pub graph_nodes_after: usize,
    pub graph_arcs_after: usize,
    pub milp_vars: usize,
    pub milp_constraints: usize,
    /// Arc-flow graphs reused from / inserted into a [`GraphCache`].
    pub graph_cache_hits: usize,
    pub graph_cache_misses: usize,
    /// True if a warm-start incumbent participated in this solve.
    pub warm_started: bool,
    /// True when branch-and-bound proved optimality of the exact phase.
    pub proven_optimal: bool,
    /// True when a structural budget (graph nodes / ILP variables) forced
    /// the heuristic fallback — the signal the adaptive budget allocator
    /// escalates on.
    pub budget_exhausted: bool,
    /// Node LPs re-entered warm from a parent/cached basis vs solved cold.
    pub lp_warm: usize,
    pub lp_cold: usize,
    /// Root-relaxation basis + first-branch order, cached by the planner's
    /// solution memo to warm-start near-identical future subproblems.
    pub root_basis: Option<Vec<usize>>,
    pub branch_order: Vec<usize>,
    /// Simplex pivots whose min-ratio step was ~0 (stalling), summed over
    /// every node LP of the exact phase.
    pub degenerate_pivots: u64,
    /// Per-bin-type layout of the joint ILP's columns/rows, recorded so a
    /// later re-plan whose structure gained groups can translate the
    /// surviving blocks of this solve's basis (see [`DeltaHints::appeared`]).
    pub var_blocks: Vec<VarBlock>,
    /// Vanished groups re-embedded as ghosts in this solve (0 = no
    /// ghost-embedding took place).
    pub structural_ghosts: usize,
    /// Appeared groups bridged by block-basis translation in this solve
    /// (0 = no translation took place or it could not be certified).
    pub structural_appeared: usize,
}

/// One bin type's slice of the joint ILP: its arc variables and its flow
/// conservation rows. `graph_hash` is a content hash of the type's quantized
/// item list (the arc-flow graph key), so two solves agree on a block iff
/// the type's compatible item multiset — and hence its graph, arcs, and
/// conservation rows — is bit-identical.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VarBlock {
    pub bin_type: usize,
    pub graph_hash: u64,
    pub var_offset: usize,
    pub num_arcs: usize,
    pub row_offset: usize,
    pub num_rows: usize,
}

/// A vanished item group, re-inserted as a *ghost* so the new subproblem's
/// arc-flow graphs (and ILP columns) stay bit-identical to the previous
/// solve's. The ghost's coverage demand is zero — its arcs can only waste
/// capacity, never satisfy anything — so the embedded ILP's optimum equals
/// the plain one's, while the structural delta collapses to a pure RHS
/// delta the certified [`resume_from_basis`](crate::solver::simplex::
/// resume_from_basis) path already repairs.
#[derive(Clone, Debug)]
pub struct GhostGroup {
    /// Index in the *augmented* item list where the group re-inserts:
    /// ghosts apply in ascending `position` order, each position counted
    /// after all lower-positioned ghosts have been inserted. With no
    /// appeared groups in play this is exactly the group's index in the
    /// previous problem's item list.
    pub position: usize,
    /// Per-bin demand vectors, bit-preserved (`f64::to_bits` per dim;
    /// `None` = incompatible with that bin type).
    pub demand_bits: Vec<Option<[u64; NUM_DIMS]>>,
    /// The count the previous solve saw (caps the graph multiplicity).
    pub count: usize,
}

/// The previous solve's basis and block layout, for the *appeared*-group
/// structural delta: bin types the new groups cannot use keep bit-identical
/// graphs, so their basis columns translate 1:1 into the new column space;
/// the rest are dropped and re-derived by
/// [`complete_basis`](crate::solver::simplex::complete_basis).
#[derive(Clone, Debug)]
pub struct PrevLayout {
    /// Root basis of the previous solve, in its own column space.
    pub basis: Vec<usize>,
    /// Its block layout ([`SolveStats::var_blocks`]).
    pub blocks: Vec<VarBlock>,
    /// Its structural variable count (slack columns start here).
    pub num_vars: usize,
    /// Its item-group count (coverage-row slacks; the cut slack follows).
    pub num_groups: usize,
    /// Indices in *this* solve's (ghost-augmented) item list of the groups
    /// the previous solve lacked, strictly ascending.
    pub new_groups: Vec<usize>,
}

/// Cached warm re-entry state from a previous solve of a *structurally
/// identical* subproblem (same bins and per-bin demand vectors; only group
/// counts may differ). The root LP re-enters the simplex via
/// [`resume_from_basis`](crate::solver::simplex::resume_from_basis) and the
/// branching order replays in `bnb`. Hints only ever accelerate: every warm
/// step is certified by the solver, and anything uncertifiable falls back
/// to the cold path inside the same budgets.
#[derive(Clone, Debug, Default)]
pub struct DeltaHints {
    pub root_basis: Option<Vec<usize>>,
    pub branch_order: Vec<usize>,
    /// Vanished-group embeddings, strictly ascending by `position`: each
    /// re-inserts its group with zero coverage. Ghosts alone make the ILP
    /// structure match the previous solve's exactly (use `root_basis`);
    /// combined with `appeared` they reduce a mixed vanish+appear re-plan
    /// to a pure appeared-group translation.
    pub ghosts: Vec<GhostGroup>,
    /// Appeared-group translation: the previous solve's basis + layout,
    /// used only when `root_basis` is absent (the two paths are exclusive).
    pub appeared: Option<PrevLayout>,
}

/// Quantize each item's demand up to the bin-type grid; `None` stays `None`,
/// and demands that cannot fit become `None` (incompatible).
fn quantize_problem(problem: &PackingProblem, quant: i64) -> PackingProblem {
    let mut q = problem.clone();
    for t in 0..problem.bins.len() {
        let eff = problem.effective_capacity(t);
        let caps = eff.as_array();
        for item in q.items.iter_mut() {
            if let Some(d) = item.demand_per_bin[t] {
                let mut qd = [0.0f64; NUM_DIMS];
                let mut ok = true;
                for (i, (dv, cv)) in d.as_array().iter().zip(caps.iter()).enumerate() {
                    if *dv <= 0.0 {
                        qd[i] = 0.0;
                        continue;
                    }
                    if *cv <= 0.0 {
                        ok = false;
                        break;
                    }
                    let unit = cv / quant as f64;
                    let cells = (dv / unit).ceil();
                    if cells > quant as f64 {
                        ok = false;
                        break;
                    }
                    qd[i] = cells * unit;
                }
                item.demand_per_bin[t] = if ok { Some(Dims::from_array(qd)) } else { None };
            }
        }
    }
    q
}

/// Integer cell counts of a quantized demand on bin `t`'s grid.
fn cells(problem: &PackingProblem, t: usize, d: &Dims, quant: i64) -> Vec<i64> {
    let eff = problem.effective_capacity(t);
    d.as_array()
        .iter()
        .zip(eff.as_array())
        .map(|(dv, cv)| {
            if *dv <= 0.0 || cv <= 0.0 {
                0
            } else {
                ((dv / (cv / quant as f64)).round()) as i64
            }
        })
        .collect()
}

/// Solve the MCVBP. Returns the packing plus diagnostics.
pub fn solve(problem: &PackingProblem, opts: &SolveOptions) -> Result<(Packing, SolveStats)> {
    solve_with(problem, opts, None, None)
}

/// Solve the MCVBP with optional cross-replan state:
///
/// * `cache` — a [`GraphCache`] of compressed arc-flow graphs; bin types
///   whose compatible item set is unchanged since the last re-plan reuse
///   their graph instead of rebuilding it,
/// * `incumbent` — a previous packing (translated to this problem's
///   indices). If it validates it competes as a final candidate, and its
///   quantized cost tightens the ILP's incumbent cut so branch-and-bound
///   starts from the old plan's cost rather than the cold FFD bound.
///
/// With `cache = None, incumbent = None` this is exactly the cold solve; on
/// identical inputs the warm solve returns the same cost (the cached graphs
/// are bit-identical and the incumbent can only match, never beat, the
/// optimum the cold solve found).
pub fn solve_with(
    problem: &PackingProblem,
    opts: &SolveOptions,
    cache: Option<&GraphCache>,
    incumbent: Option<&Packing>,
) -> Result<(Packing, SolveStats)> {
    solve_delta(problem, opts, cache, incumbent, None)
}

/// [`solve_with`], additionally re-entering the solver from cached
/// [`DeltaHints`] when a structurally identical subproblem was solved
/// before (the near-match memo path). Incompatible hints are ignored, so
/// this is never less exact than the cold solve under the same budgets.
pub fn solve_delta(
    problem: &PackingProblem,
    opts: &SolveOptions,
    cache: Option<&GraphCache>,
    incumbent: Option<&Packing>,
    hints: Option<&DeltaHints>,
) -> Result<(Packing, SolveStats)> {
    // Quantize once; all phases work on the conservative instance so the
    // result is valid for the original problem.
    let qp = quantize_problem(problem, opts.quant);
    qp.check_feasible_items()?;

    // Heuristic candidates: FFD on the quantized instance (safe incumbent
    // for the exact phase), plus FFD and ARMVAC-fill on the original problem
    // (the round-up can cost a slot per bin, so the unquantized packings are
    // sometimes strictly better). All are valid for the original problem.
    // A warm-start incumbent that still validates joins the contest.
    let ffd = heuristic::first_fit_decreasing(&qp)?;
    let ffd_cost = ffd.total_cost(&qp);
    let valid_incumbent =
        incumbent.filter(|inc| inc.validate(problem).is_ok());
    let mut best_heuristic = ffd.clone();
    let mut best_heuristic_cost = ffd_cost;
    for cand in [
        heuristic::first_fit_decreasing(problem).ok(),
        heuristic::armvac_fill(problem).ok(),
        valid_incumbent.cloned(),
    ]
    .into_iter()
    .flatten()
    {
        let c = cand.total_cost(problem);
        if c < best_heuristic_cost {
            best_heuristic = cand;
            best_heuristic_cost = c;
        }
    }

    let mut stats = SolveStats {
        method: SolveMethod::Heuristic,
        ffd_cost: best_heuristic_cost,
        final_cost: best_heuristic_cost,
        milp_nodes: 0,
        graph_nodes_before: 0,
        graph_arcs_before: 0,
        graph_nodes_after: 0,
        graph_arcs_after: 0,
        milp_vars: 0,
        milp_constraints: 0,
        graph_cache_hits: 0,
        graph_cache_misses: 0,
        warm_started: valid_incumbent.is_some(),
        proven_optimal: false,
        budget_exhausted: false,
        lp_warm: 0,
        lp_cold: 0,
        root_basis: None,
        branch_order: Vec::new(),
        degenerate_pivots: 0,
        var_blocks: Vec::new(),
        structural_ghosts: 0,
        structural_appeared: 0,
    };
    if !opts.exact {
        return Ok((best_heuristic, stats));
    }

    // Vanished-group embedding: when the caller says this problem is the
    // previous one minus a bounded set of groups, re-insert each as a ghost
    // (original demands, original count, zero coverage). With no appeared
    // groups in play, every bin type's quantized item list — and hence its
    // arc-flow graph and ILP columns — is then bit-identical to the previous
    // solve's, and the cached basis re-enters through the certified
    // RHS-repair path; with appeared groups alongside, the embedding reduces
    // the mixed delta to a pure appeared-group translation. Malformed hints
    // are dropped here; an uncertifiable basis falls cold inside the solver.
    let ghosts: &[GhostGroup] = match hints {
        Some(h)
            if !h.ghosts.is_empty()
                && h.ghosts.iter().enumerate().all(|(i, g)| {
                    g.count > 0
                        && g.demand_bits.len() == qp.bins.len()
                        && g.position <= qp.items.len() + i
                        && (i == 0 || h.ghosts[i - 1].position < g.position)
                }) =>
        {
            &h.ghosts
        }
        _ => &[],
    };
    // Augmented positions of the ghosts, ascending (binary-searchable).
    let ghost_positions: Vec<usize> = ghosts.iter().map(|g| g.position).collect();
    let xqp_owned;
    let xqp: &PackingProblem = if ghosts.is_empty() {
        &qp
    } else {
        let mut aug = problem.clone();
        for g in ghosts {
            aug.items.insert(
                g.position,
                ItemGroup {
                    label: "__ghost__".into(),
                    count: g.count,
                    demand_per_bin: g
                        .demand_bits
                        .iter()
                        .map(|d| d.map(|bits| Dims::from_array(bits.map(f64::from_bits))))
                        .collect(),
                },
            );
        }
        // Quantization is per-item, so the non-ghost items land exactly
        // where the plain `qp` has them.
        xqp_owned = quantize_problem(&aug, opts.quant);
        stats.structural_ghosts = ghosts.len();
        &xqp_owned
    };

    // Build one arc-flow graph per bin type over its compatible item groups.
    // A *cumulative* node budget bounds total build work: when the joint ILP
    // would be too large to solve anyway (see max_milp_vars), bail out to the
    // heuristic before burning time constructing hundreds of graphs. Cache
    // hits charge their original (uncompressed) node count against the same
    // budget so a warm solve takes exactly the structural decisions a cold
    // solve would — only faster.
    let mut graphs = Vec::with_capacity(xqp.bins.len());
    // Content hash of each built type's quantized item list — the block
    // identity two structurally adjacent solves agree on (see [`VarBlock`]).
    let mut graph_hashes = vec![0u64; xqp.bins.len()];
    let mut remaining_nodes = opts.max_graph_nodes;
    // Item↔bin compatibility as fixed-width bitsets (falls back to the
    // direct scan on problems too wide for the mask).
    let cmasks = xqp.compatible_masks();
    for t in 0..xqp.bins.len() {
        // Map: local item index -> global group index.
        let groups: Vec<usize> = (0..xqp.items.len())
            .filter(|&g| {
                xqp.items[g].count > 0
                    && match &cmasks {
                        Some(m) => m[g].get(t),
                        None => xqp.compatible(g, t),
                    }
            })
            .collect();
        if groups.is_empty() {
            graphs.push(None);
            continue;
        }
        let cap = vec![opts.quant; NUM_DIMS];
        let items: Vec<QuantItem> = groups
            .iter()
            .map(|&g| {
                let sizes = cells(xqp, t, &xqp.items[g].demand_per_bin[t].unwrap(), opts.quant);
                // Per-bin multiplicity cap: more copies of a group than fit
                // one bin can never appear on a single source→sink path, so
                // clamping the demanded count here leaves the path set
                // unchanged while making the graph — and its cache key —
                // insensitive to count drift beyond the cap. That key
                // stability is what lets the delta-solve path reuse bases
                // across re-plans whose only change is a demand count.
                let max_mult = sizes
                    .iter()
                    .filter(|&&s| s > 0)
                    .map(|&s| (opts.quant / s).max(1) as usize)
                    .min()
                    .unwrap_or(xqp.items[g].count);
                QuantItem { sizes, count: xqp.items[g].count.min(max_mult) }
            })
            .collect();
        graph_hashes[t] = FxBuildHasher::default().hash_one(
            items
                .iter()
                .map(|it| (it.sizes.clone(), it.count))
                .collect::<Vec<(Vec<i64>, usize)>>(),
        );
        let built = match cache {
            Some(c) => match c.get_or_build(&cap, &items, remaining_nodes) {
                Ok((entry, hit)) => {
                    // Mirror the cold build's budget check: a cached graph a
                    // fresh build could not have afforded is treated as the
                    // same budget exhaustion.
                    if hit && entry.1.nodes_before > remaining_nodes + 1 {
                        None
                    } else {
                        if hit {
                            stats.graph_cache_hits += 1;
                        } else {
                            stats.graph_cache_misses += 1;
                        }
                        Some((entry.0.clone(), entry.1))
                    }
                }
                Err(_) => None,
            },
            None => match arcflow::build(&cap, &items, remaining_nodes) {
                Ok(g) => {
                    let (cg, cs) = arcflow::compress(&g);
                    Some((cg, cs))
                }
                Err(_) => None,
            },
        };
        match built {
            Some((cg, cs)) => {
                remaining_nodes = remaining_nodes.saturating_sub(cs.nodes_before);
                stats.graph_nodes_before += cs.nodes_before;
                stats.graph_arcs_before += cs.arcs_before;
                stats.graph_nodes_after += cg.num_nodes;
                stats.graph_arcs_after += cg.arcs.len();
                graphs.push(Some((cg, groups)));
            }
            None => {
                // Cumulative state budget exhausted: heuristic fallback.
                stats.budget_exhausted = true;
                return Ok((best_heuristic, stats));
            }
        }
    }

    // Assemble the joint min-cost integer flow.
    // Variables: one per arc (all graphs), integral.
    let mut var_arc: Vec<(usize, usize)> = Vec::new(); // (bin type, arc idx)
    let mut var_offset = vec![0usize; xqp.bins.len() + 1];
    for (t, g) in graphs.iter().enumerate() {
        var_offset[t] = var_arc.len();
        if let Some((graph, _)) = g {
            for a in 0..graph.arcs.len() {
                var_arc.push((t, a));
            }
        }
    }
    var_offset[xqp.bins.len()] = var_arc.len();
    let num_vars = var_arc.len();
    if num_vars == 0 || num_vars > opts.max_milp_vars {
        stats.budget_exhausted = num_vars > opts.max_milp_vars;
        return Ok((best_heuristic, stats));
    }

    let mut lp = Lp::new(num_vars);
    // Objective: bin cost on arcs leaving the source.
    for (v, &(t, a)) in var_arc.iter().enumerate() {
        let (graph, _) = graphs[t].as_ref().unwrap();
        if graph.arcs[a].from == graph.source {
            lp.set_objective(v, xqp.bins[t].cost);
        }
    }
    // Conservation at internal nodes, recording each bin type's block of
    // columns and rows for the appeared-group translation of a later solve.
    let mut var_blocks: Vec<VarBlock> = Vec::new();
    for (t, g) in graphs.iter().enumerate() {
        let Some((graph, _)) = g else { continue };
        let row_offset = lp.constraints.len();
        for node in 0..graph.num_nodes {
            if node == graph.source || node == graph.sink {
                continue;
            }
            let mut coeffs: Vec<(usize, f64)> = Vec::new();
            for (a, arc) in graph.arcs.iter().enumerate() {
                let v = var_offset[t] + a;
                if arc.to == node {
                    coeffs.push((v, 1.0));
                }
                if arc.from == node {
                    coeffs.push((v, -1.0));
                }
            }
            if !coeffs.is_empty() {
                lp.add_constraint(coeffs, Op::Eq, 0.0);
            }
        }
        var_blocks.push(VarBlock {
            bin_type: t,
            graph_hash: graph_hashes[t],
            var_offset: var_offset[t],
            num_arcs: graph.arcs.len(),
            row_offset,
            num_rows: lp.constraints.len() - row_offset,
        });
    }
    // Demand coverage per item group. A ghost group keeps its row (the
    // previous solve's basis expects it) but demands nothing: its arcs may
    // carry flow, yet covering zero can never change the optimum.
    for (g_idx, item) in xqp.items.iter().enumerate() {
        if item.count == 0 {
            continue;
        }
        let mut coeffs: Vec<(usize, f64)> = Vec::new();
        for (t, g) in graphs.iter().enumerate() {
            let Some((graph, groups)) = g else { continue };
            let Some(local) = groups.iter().position(|&x| x == g_idx) else {
                continue;
            };
            for (a, arc) in graph.arcs.iter().enumerate() {
                if arc.item == Some(local) {
                    coeffs.push((var_offset[t] + a, 1.0));
                }
            }
        }
        let is_ghost = ghost_positions.binary_search(&g_idx).is_ok();
        if coeffs.is_empty() {
            if is_ghost {
                // The ghost touches no graph (it was incompatible with the
                // budgeted types this round): no row. The resulting row
                // mismatch simply decertifies the resume — still exact.
                continue;
            }
            return Err(Error::infeasible(format!(
                "stream group '{}' unplaceable in exact phase",
                item.label
            )));
        }
        let rhs = if is_ghost { 0.0 } else { item.count as f64 };
        lp.add_constraint(coeffs, Op::Ge, rhs);
    }
    // Incumbent cut: never exceed the best bound known to be feasible on the
    // quantized instance — the FFD cost, tightened by a warm-start incumbent
    // when one validates against the quantized problem.
    let cut_rhs = match valid_incumbent.filter(|inc| inc.validate(&qp).is_ok()) {
        Some(inc) => ffd_cost.min(inc.total_cost(&qp)),
        None => ffd_cost,
    };
    {
        let coeffs: Vec<(usize, f64)> = var_arc
            .iter()
            .enumerate()
            .filter_map(|(v, &(t, a))| {
                let (graph, _) = graphs[t].as_ref().unwrap();
                (graph.arcs[a].from == graph.source).then_some((v, qp.bins[t].cost))
            })
            .collect();
        lp.add_constraint(coeffs, Op::Le, cut_rhs + 1e-6);
    }

    stats.milp_vars = num_vars;
    stats.milp_constraints = lp.constraints.len();

    let milp = Milp { lp, integer_vars: (0..num_vars).collect() };
    // Branch on source arcs first (they decide how many bins of each type
    // open), and scale the node budget down for large ILPs so planning
    // latency stays bounded ("resource decisions quickly, during runtime").
    let mut milp_opts = opts.milp.clone();
    milp_opts.priority_vars = var_arc
        .iter()
        .enumerate()
        .filter_map(|(v, &(t, a))| {
            let (graph, _) = graphs[t].as_ref().unwrap();
            (graph.arcs[a].from == graph.source).then_some(v)
        })
        .collect();
    // Calibrated node guard: the dense tableau priced every pivot against
    // the full `rows × vars` tableau, so `vars` was the divisor; the revised
    // core's per-node cost is `min(vars, 8·rows)` (bench_solver-derived, see
    // `coordinator::budget::milp_node_cost`), which never exceeds the dense
    // model — node budgets can only grow under the revised simplex.
    milp_opts.max_nodes = milp_opts
        .max_nodes
        .min((opts.milp_node_scale / milp_node_cost(num_vars, stats.milp_constraints)).max(50));
    // Delta-solve hints: replay a structurally identical previous solve's
    // branching order and re-enter from its root basis. Out-of-range hints
    // (the structure changed after all) are dropped here or certified away
    // inside the solver — either way the search stays exact.
    if let Some(h) = hints {
        if h.branch_order.iter().all(|&v| v < num_vars) {
            milp_opts.replay_order = h.branch_order.clone();
        }
        milp_opts.root_basis = h.root_basis.clone();
        // Appeared-group translation: carry the surviving blocks of the
        // previous basis into this (possibly ghost-augmented) column space
        // and let `complete_basis` re-derive the rest. Only meaningful
        // without an exact-structure basis (the two warm paths are
        // exclusive), and only when every group has a coverage row
        // (count > 0), which the slack-rank arithmetic below relies on.
        // Ghosts compose: `new_groups` are indices into the augmented list.
        if milp_opts.root_basis.is_none() {
            if let Some(prev) = h.appeared.as_ref() {
                if xqp.items.iter().all(|it| it.count > 0) {
                    if let Some(partial) = translate_block_basis(
                        prev,
                        &var_blocks,
                        num_vars,
                        xqp.items.len(),
                    ) {
                        milp_opts.root_basis = complete_basis(&milp.lp, &partial);
                        if milp_opts.root_basis.is_some() {
                            stats.structural_appeared = prev.new_groups.len();
                        }
                    }
                }
            }
        }
    }
    let sol = match solve_milp(&milp, &milp_opts) {
        Ok(s) => s,
        Err(_) => return Ok((best_heuristic, stats)), // exact phase failed
    };
    stats.milp_nodes = sol.nodes;
    stats.proven_optimal = sol.proven_optimal;
    stats.lp_warm = sol.lp_warm;
    stats.lp_cold = sol.lp_cold;
    stats.degenerate_pivots = sol.lp_stats.degenerate_pivots;
    if ghost_positions.is_empty() {
        stats.root_basis = sol.root_basis.clone();
        stats.branch_order = sol.branch_order.clone();
        stats.var_blocks = var_blocks;
    }
    // (A ghost-embedded solve publishes no warm hints: its basis, branch
    // order, and blocks live in the embedded column space, which a later
    // plain solve of this structure does not share.)

    // Decompose flows into source->sink paths per graph -> bins.
    let mut packing = Packing::default();
    for (t, g) in graphs.iter().enumerate() {
        let Some((graph, groups)) = g else { continue };
        let mut flow: Vec<i64> = (0..graph.arcs.len())
            .map(|a| sol.x[var_offset[t] + a].round() as i64)
            .collect();
        let mut out_arcs: Vec<Vec<usize>> = vec![Vec::new(); graph.num_nodes];
        for (a, arc) in graph.arcs.iter().enumerate() {
            out_arcs[arc.from].push(a);
        }
        loop {
            // Start a new path if any source arc still carries flow.
            let Some(&start) = out_arcs[graph.source].iter().find(|&&a| flow[a] > 0) else {
                break;
            };
            let mut counts = vec![0usize; xqp.items.len()];
            let mut a = start;
            let mut guard = 0;
            loop {
                flow[a] -= 1;
                if let Some(local) = graph.arcs[a].item {
                    counts[groups[local]] += 1;
                }
                let node = graph.arcs[a].to;
                if node == graph.sink {
                    break;
                }
                a = match out_arcs[node].iter().find(|&&x| flow[x] > 0) {
                    Some(&x) => x,
                    None => {
                        return Err(Error::solver(
                            "flow decomposition stuck (conservation violated)",
                        ))
                    }
                };
                guard += 1;
                if guard > graph.arcs.len() * (problem.total_items() + 2) {
                    return Err(Error::solver("flow decomposition cycle"));
                }
            }
            if counts.iter().any(|&c| c > 0) {
                packing.bins.push(PackedBin { bin_type: t, counts });
            }
        }
    }

    // Strip the ghosts before validating: their flows (zero-coverage
    // padding) map to nothing in the real problem, and removing them only
    // frees capacity, so the stripped packing stays feasible. Removal runs
    // descending so earlier positions stay valid as later ones vacate.
    if !ghost_positions.is_empty() {
        for b in packing.bins.iter_mut() {
            for &gi in ghost_positions.iter().rev() {
                b.counts.remove(gi);
            }
        }
        packing.bins.retain(|b| b.num_streams() > 0);
    }

    // Trim over-coverage (Ge slack) and drop empty bins.
    let mut placed = vec![0usize; qp.items.len()];
    for b in &packing.bins {
        for (g, &c) in b.counts.iter().enumerate() {
            placed[g] += c;
        }
    }
    for g in 0..qp.items.len() {
        let mut extra = placed[g].saturating_sub(qp.items[g].count);
        if extra == 0 {
            continue;
        }
        for b in packing.bins.iter_mut() {
            while extra > 0 && b.counts[g] > 0 {
                b.counts[g] -= 1;
                extra -= 1;
            }
        }
    }
    packing.bins.retain(|b| b.num_streams() > 0);

    packing.validate(&qp)?;
    let exact_cost = packing.total_cost(&qp);

    if exact_cost <= best_heuristic_cost + 1e-9 {
        stats.method = SolveMethod::ExactArcFlow;
        stats.final_cost = exact_cost;
        Ok((packing, stats))
    } else {
        Ok((best_heuristic, stats))
    }
}

/// Translate a previous solve's basis into the current ILP's column space
/// for the appeared-group delta. Structural columns translate through
/// matching [`VarBlock`]s (same bin type, same graph content); columns of
/// changed blocks are *dropped* — `complete_basis` re-derives them — and
/// slack columns re-rank around the inserted groups (any bounded set, not
/// just one). Returns `None` when the layouts cannot correspond (the hint
/// was stale), which sends the solve down the cold path.
fn translate_block_basis(
    prev: &PrevLayout,
    blocks: &[VarBlock],
    num_vars: usize,
    num_groups: usize,
) -> Option<Vec<usize>> {
    let inserted = &prev.new_groups;
    if inserted.is_empty()
        || prev.num_groups + inserted.len() != num_groups
        || inserted.windows(2).any(|w| w[0] >= w[1])
        || *inserted.last()? >= num_groups
    {
        return None;
    }
    // Surviving groups occupy the complement of the inserted positions, in
    // order: old coverage-row rank k re-ranks to `old_to_new[k]`.
    let mut old_to_new = Vec::with_capacity(prev.num_groups);
    let mut next_ins = 0usize;
    for g in 0..num_groups {
        if next_ins < inserted.len() && inserted[next_ins] == g {
            next_ins += 1;
        } else {
            old_to_new.push(g);
        }
    }
    let mut out = Vec::with_capacity(prev.basis.len());
    for &v in &prev.basis {
        if v < prev.num_vars {
            let pb = prev
                .blocks
                .iter()
                .find(|b| b.var_offset <= v && v < b.var_offset + b.num_arcs)?;
            let Some(nb) = blocks.iter().find(|b| {
                b.bin_type == pb.bin_type
                    && b.graph_hash == pb.graph_hash
                    && b.num_arcs == pb.num_arcs
            }) else {
                // This bin type's graph absorbed a new group: its arc
                // space changed, so the old column has no referent here.
                continue;
            };
            out.push(nb.var_offset + (v - pb.var_offset));
        } else {
            // Slack columns: coverage rows in group order, then the
            // incumbent cut. Surviving groups re-rank past the insertions.
            let k = v - prev.num_vars;
            if k < prev.num_groups {
                out.push(num_vars + old_to_new[k]);
            } else if k == prev.num_groups {
                out.push(num_vars + num_groups);
            } else {
                return None; // column outside the recognized layout
            }
        }
    }
    (!out.is_empty()).then_some(out)
}

// ---------------------------------------------------------------------------
// Temporal capacity axis — deferred backfill packed into hour-indexed slack
// ---------------------------------------------------------------------------
//
// The live MCVBP above answers "which bins, right now". Deferred backfill
// (`cameras::scenarios::BackfillQuery`) adds a time axis: work is a budget of
// unit-hours with a deadline, and capacity is an hour-indexed grid of lanes —
// the slack live bins leave unused (already paid for), spot instances
// (cheap, but their usable capacity is discounted by the pool's revocation
// rate), and plain on-demand instances (the baseline the certified gate in
// `coordinator::spot` compares against). The packer is a deterministic
// earliest-deadline-first greedy: items either schedule completely before
// their deadline or are shed whole — a shed item never holds capacity.
// Revocations re-enter through [`rehome_backfill`], the temporal analogue of
// the ghost path: revoked lanes are zero-capacity from the revocation hour
// on, and only the placements stranded on them move.

/// Where a temporal lane's capacity comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneKind {
    /// Headroom a live on-demand bin leaves unused — already paid for, so
    /// occupied hours bill nothing.
    LiveSlack,
    /// A spot instance: cheap, revocable; `usable` is risk-discounted.
    Spot,
    /// An on-demand instance opened purely for backfill.
    OnDemand,
}

/// One hour-indexed capacity lane of the temporal packing axis.
#[derive(Clone, Debug, PartialEq)]
pub struct TemporalLane {
    /// Bin identity ("type@region"), mirroring [`BinType`]'s label.
    pub label: String,
    pub kind: LaneKind,
    /// Usable capacity per occupied hour. Spot lanes carry
    /// `capacity × headroom × (1 − preemption rate)` — the expected fraction
    /// of the hour the instance actually survives.
    pub usable: Dims,
    /// Price of one occupied hour (0 for live slack).
    pub hourly_cost: f64,
    /// First hour the lane exists (lanes opened mid-trace start late).
    pub from_hour: usize,
}

/// One backfill job, quantized into unit-hours of work: scanning one hour of
/// stored footage at the query's sampling rate is one unit, and units are
/// independent footage segments — they may run in any order and in parallel.
#[derive(Clone, Debug, PartialEq)]
pub struct BackfillItem {
    pub id: u64,
    /// Demand of one unit for one hour.
    pub demand: Dims,
    /// Remaining unit-hours of work.
    pub units: usize,
    /// Every unit must land in an hour strictly below this.
    pub deadline_hour: usize,
    /// Non-preemptible items never pack onto [`LaneKind::Spot`] lanes.
    pub preemptible: bool,
}

/// One placed unit-hour: one unit of item `item` runs on `lane` during
/// `hour`. Multiple units (of any items) may share a lane-hour as long as
/// their summed demand fits the lane's usable capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BackfillPlacement {
    pub item: u64,
    pub lane: usize,
    pub hour: usize,
}

/// A backfill schedule over the temporal axis.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BackfillSchedule {
    /// Placed unit-hours, in deterministic (EDF item, placement) order.
    pub placements: Vec<BackfillPlacement>,
    /// Items shed whole: their deadline was infeasible under the offered
    /// capacity. Shedding is explicit — a shed id holds no placements.
    pub shed: Vec<u64>,
    /// Σ `hourly_cost` over *occupied* paid lane-hours: slack hours are
    /// free, and a paid lane-hour bills once however many units share it.
    pub cost: f64,
}

impl BackfillSchedule {
    /// Recompute `cost` from the placements (used after schedule surgery).
    fn rebill(&mut self, lanes: &[TemporalLane]) {
        let mut cells: Vec<(usize, usize)> =
            self.placements.iter().map(|p| (p.lane, p.hour)).collect();
        cells.sort_unstable();
        cells.dedup();
        self.cost = cells.iter().map(|&(l, _)| lanes[l].hourly_cost).sum();
    }
}

/// Hour-indexed occupancy of the lane grid during packing.
#[derive(Clone)]
struct LaneGrid {
    /// `used[l][h]`: demand already placed on lane `l` during hour `h`.
    used: Vec<Vec<Dims>>,
    /// `open[l][h]`: whether the paid lane-hour is already billed.
    open: Vec<Vec<bool>>,
}

impl LaneGrid {
    fn new(lanes: &[TemporalLane], horizon: usize) -> LaneGrid {
        LaneGrid {
            used: vec![vec![Dims::ZERO; horizon]; lanes.len()],
            open: vec![vec![false; horizon]; lanes.len()],
        }
    }

    /// Marginal cost of placing one more unit on (lane, hour): zero when the
    /// lane is free or the lane-hour is already billed.
    fn marginal(&self, lanes: &[TemporalLane], l: usize, h: usize) -> f64 {
        if self.open[l][h] {
            0.0
        } else {
            lanes[l].hourly_cost
        }
    }

    fn fits(&self, lanes: &[TemporalLane], item: &BackfillItem, l: usize, h: usize) -> bool {
        let lane = &lanes[l];
        if h < lane.from_hour {
            return false;
        }
        if lane.kind == LaneKind::Spot && !item.preemptible {
            return false;
        }
        self.used[l][h].add(&item.demand).fits_in(&lane.usable)
    }

    fn place(&mut self, item: &BackfillItem, l: usize, h: usize) {
        self.used[l][h] = self.used[l][h].add(&item.demand);
        self.open[l][h] = true;
    }
}

/// Place every unit of `item` into the grid between `from_hour` (inclusive)
/// and its deadline (exclusive, capped at `horizon`). Each unit takes the
/// cheapest feasible cell, ties broken by (hour, lane) — so free slack and
/// already-billed lane-hours absorb work before a new paid hour opens, and
/// the placement order is deterministic. Returns the placements, or `None`
/// if any unit cannot be placed (the item must then be shed whole).
fn place_item(
    lanes: &[TemporalLane],
    grid: &mut LaneGrid,
    item: &BackfillItem,
    from_hour: usize,
    horizon: usize,
) -> Option<Vec<BackfillPlacement>> {
    let end = item.deadline_hour.min(horizon);
    let mut placed = Vec::with_capacity(item.units);
    for _ in 0..item.units {
        let mut best: Option<(f64, usize, usize)> = None;
        for h in from_hour..end {
            for l in 0..lanes.len() {
                if !grid.fits(lanes, item, l, h) {
                    continue;
                }
                let cost = grid.marginal(lanes, l, h);
                let cand = (cost, h, l);
                if best.is_none_or(|b| cand.0 < b.0 || (cand.0 == b.0 && (h, l) < (b.1, b.2))) {
                    best = Some(cand);
                }
            }
        }
        let (_, h, l) = best?;
        grid.place(item, l, h);
        placed.push(BackfillPlacement { item: item.id, lane: l, hour: h });
    }
    Some(placed)
}

/// Pack backfill items into the temporal lane grid, earliest deadline first
/// (ties by id). Each item is placed atomically on a scratch overlay: either
/// every unit lands before the deadline and the overlay commits, or the item
/// is shed whole and holds nothing. Deterministic — same inputs, same
/// schedule, bit for bit.
pub fn pack_backfill(
    lanes: &[TemporalLane],
    items: &[BackfillItem],
    horizon: usize,
) -> BackfillSchedule {
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by_key(|&i| (items[i].deadline_hour, items[i].id));
    let mut grid = LaneGrid::new(lanes, horizon);
    let mut schedule = BackfillSchedule::default();
    for &i in &order {
        let item = &items[i];
        if item.units == 0 {
            continue;
        }
        // Tentative placement: clone-on-attempt keeps shed items capacity-free.
        let mut scratch = grid.clone();
        match place_item(lanes, &mut scratch, item, 0, horizon) {
            Some(mut placed) => {
                grid = scratch;
                schedule.placements.append(&mut placed);
            }
            None => schedule.shed.push(item.id),
        }
    }
    schedule.rebill(lanes);
    schedule
}

/// Absorb a revocation as a *structural delta* on the temporal axis: lanes
/// in `revoked` are ghost-zeroed from `hour` on (their history stands — work
/// already executed is sunk and stays in the schedule), and only the
/// placements stranded on them are re-homed. Every placement of an untouched
/// item survives bit-identically; stranded items re-place their lost units
/// EDF into the remaining grid, and an item whose deadline no longer fits is
/// shed explicitly — its pending (hour ≥ `hour`) placements are withdrawn,
/// its completed ones stand.
///
/// Returns the repaired schedule and the ids of the items that moved
/// (re-homed or shed).
pub fn rehome_backfill(
    lanes: &[TemporalLane],
    items: &[BackfillItem],
    schedule: &BackfillSchedule,
    revoked: &[usize],
    hour: usize,
    horizon: usize,
) -> (BackfillSchedule, Vec<u64>) {
    let is_revoked = |l: usize| revoked.contains(&l);
    // Partition: placements that stand vs stranded unit-hours per item.
    let mut kept: Vec<BackfillPlacement> = Vec::with_capacity(schedule.placements.len());
    let mut stranded: Vec<(u64, usize)> = Vec::new(); // (item id, lost units)
    for p in &schedule.placements {
        if p.hour >= hour && is_revoked(p.lane) {
            match stranded.iter_mut().find(|(id, _)| *id == p.item) {
                Some((_, n)) => *n += 1,
                None => stranded.push((p.item, 1)),
            }
        } else {
            kept.push(*p);
        }
    }
    if stranded.is_empty() {
        let mut out = schedule.clone();
        out.rebill(lanes);
        return (out, Vec::new());
    }
    stranded.sort_by_key(|&(id, _)| {
        (items.iter().find(|it| it.id == id).map_or(usize::MAX, |it| it.deadline_hour), id)
    });
    // Rebuild occupancy from the kept placements; revoked lanes are
    // ghost-zeroed from `hour` by a from_hour/usable mask on lookup.
    let masked: Vec<TemporalLane> = lanes
        .iter()
        .enumerate()
        .map(|(l, lane)| {
            let mut lane = lane.clone();
            if is_revoked(l) {
                // Zero capacity from the revocation hour on: from_hour can't
                // express "until", so mask by shrinking usable to zero and
                // re-adding kept history below (kept cells on revoked lanes
                // are all pre-`hour` and never re-packed into).
                lane.usable = Dims::ZERO;
            }
            lane
        })
        .collect();
    let mut grid = LaneGrid::new(&masked, horizon);
    for p in &kept {
        if let Some(item) = items.iter().find(|it| it.id == p.item) {
            grid.used[p.lane][p.hour] = grid.used[p.lane][p.hour].add(&item.demand);
        }
        grid.open[p.lane][p.hour] = true;
    }
    let mut moved: Vec<u64> = Vec::new();
    let mut shed: Vec<u64> = schedule.shed.clone();
    for &(id, lost) in &stranded {
        let Some(item) = items.iter().find(|it| it.id == id) else { continue };
        moved.push(id);
        let remnant = BackfillItem { units: lost, ..item.clone() };
        let mut scratch = grid.clone();
        match place_item(&masked, &mut scratch, &remnant, hour, horizon) {
            Some(mut placed) => {
                grid = scratch;
                kept.append(&mut placed);
            }
            None => {
                // Deadline infeasible after the storm: shed explicitly.
                // Withdraw the item's pending placements; history stands.
                kept.retain(|p| p.item != id || p.hour < hour);
                shed.push(id);
            }
        }
    }
    let mut out = BackfillSchedule { placements: kept, shed, cost: 0.0 };
    out.rebill(lanes);
    (out, moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::heuristic::simple_problem;
    use crate::packing::{BinType, ItemGroup};

    #[test]
    fn exact_matches_ffd_on_trivial() {
        let p = simple_problem(&[(2.0, 1.0, 3)], &[(8.0, 15.0, 1.0)]);
        let (packing, stats) = solve(&p, &SolveOptions::default()).unwrap();
        packing.validate(&p).unwrap();
        assert_eq!(packing.num_bins(), 1);
        assert!(stats.final_cost <= stats.ffd_cost + 1e-9);
    }

    #[test]
    fn exact_beats_greedy_where_it_should() {
        // 3 items of 3 cores; bins: 8-core@1.0, 12-core@1.15.
        // Greedy-by-efficiency opens the 12-core (3 items = 9 <= 10.8): cost
        // 1.15, which is also optimal — sanity that exact agrees.
        let p = simple_problem(&[(3.0, 1.0, 3)], &[(8.0, 15.0, 1.0), (12.0, 20.0, 1.15)]);
        let (packing, _) = solve(&p, &SolveOptions::default()).unwrap();
        assert!((packing.total_cost(&p) - 1.15).abs() < 1e-9);
    }

    #[test]
    fn exact_fixes_ffd_suboptimality() {
        // The Fig-3 S1 pattern in miniature: one "CPU" bin fits only one item
        // (score looks good), but a single "GPU" bin holds all items cheaper.
        // items: CPU demand 6 cores, GPU demand 0.2 gpu.
        let bins = vec![
            BinType {
                label: "cpu".into(),
                capacity: Dims::new(8.0, 15.0, 0.0, 0.0),
                cost: 0.419,
                type_idx: 0,
                region_idx: 0,
                has_gpu: false,
            },
            BinType {
                label: "gpu".into(),
                capacity: Dims::new(8.0, 15.0, 1.0, 4.0),
                cost: 0.65,
                type_idx: 1,
                region_idx: 0,
                has_gpu: true,
            },
        ];
        let items = vec![ItemGroup {
            label: "stream".into(),
            count: 4,
            demand_per_bin: vec![
                Some(Dims::new(6.0, 1.0, 0.0, 0.0)),
                Some(Dims::new(0.2, 0.5, 0.2, 0.7)),
            ],
        }];
        let p = PackingProblem::new(items, bins);
        let ffd = heuristic::first_fit_decreasing(&p).unwrap();
        // FFD picks cpu bins one by one: 4 x 0.419 = 1.676.
        assert!((ffd.total_cost(&p) - 1.676).abs() < 1e-9);
        let (packing, stats) = solve(&p, &SolveOptions::default()).unwrap();
        assert_eq!(stats.method, SolveMethod::ExactArcFlow);
        assert!((packing.total_cost(&p) - 0.65).abs() < 1e-9, "exact should pick 1 GPU bin");
        packing.validate(&p).unwrap();
    }

    #[test]
    fn infeasible_reported_as_fail() {
        let p = simple_problem(&[(100.0, 1.0, 1)], &[(8.0, 15.0, 1.0)]);
        assert!(solve(&p, &SolveOptions::default()).is_err());
    }

    #[test]
    fn quantization_is_conservative() {
        // An item at exactly the effective capacity still fits (rounding up
        // to the full grid), one epsilon above does not.
        let p = simple_problem(&[(7.2, 1.0, 1)], &[(8.0, 15.0, 1.0)]);
        let (packing, _) = solve(&p, &SolveOptions::default()).unwrap();
        packing.validate(&p).unwrap();
        let p2 = simple_problem(&[(7.21, 1.0, 1)], &[(8.0, 15.0, 1.0)]);
        assert!(solve(&p2, &SolveOptions::default()).is_err());
    }

    #[test]
    fn multi_choice_demand_vectors() {
        // Item demands differ per bin: 4 cores on cpu-bin, 0.5 gpu on gpu-bin.
        // Optimal: all 3 on one gpu bin (cost 1.0) vs 2 cpu bins (1.6)?
        // gpu capacity 2 gpus * 0.9 = 1.8 -> 3 x 0.5 = 1.5 fits. cpu: 7.2/4 =
        // 1 each -> 3 bins = 2.4. Exact must choose gpu.
        let bins = vec![
            BinType {
                label: "cpu".into(),
                capacity: Dims::new(8.0, 16.0, 0.0, 0.0),
                cost: 0.8,
                type_idx: 0,
                region_idx: 0,
                has_gpu: false,
            },
            BinType {
                label: "gpu".into(),
                capacity: Dims::new(4.0, 16.0, 2.0, 8.0),
                cost: 1.0,
                type_idx: 1,
                region_idx: 0,
                has_gpu: true,
            },
        ];
        let items = vec![ItemGroup {
            label: "s".into(),
            count: 3,
            demand_per_bin: vec![
                Some(Dims::new(4.0, 1.0, 0.0, 0.0)),
                Some(Dims::new(0.2, 1.0, 0.5, 1.0)),
            ],
        }];
        let p = PackingProblem::new(items, bins);
        let (packing, _) = solve(&p, &SolveOptions::default()).unwrap();
        assert!((packing.total_cost(&p) - 1.0).abs() < 1e-9);
        let (non_gpu, gpu) = packing.count_by_gpu(&p);
        assert_eq!((non_gpu, gpu), (0, 1));
    }

    #[test]
    fn warm_solve_matches_cold_solve_on_identical_inputs() {
        use crate::packing::arcflow::GraphCache;
        let p = simple_problem(
            &[(2.0, 1.0, 4), (3.0, 2.0, 2)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let opts = SolveOptions::default();
        let (cold, cold_stats) = solve(&p, &opts).unwrap();
        let cache = GraphCache::new();
        // First warm call populates the cache; second reuses it and seeds the
        // incumbent with the cold result.
        let (w1, s1) = solve_with(&p, &opts, Some(&cache), None).unwrap();
        assert_eq!(s1.graph_cache_hits, 0);
        assert!((w1.total_cost(&p) - cold.total_cost(&p)).abs() < 1e-9);
        let (w2, s2) = solve_with(&p, &opts, Some(&cache), Some(&cold)).unwrap();
        assert!(s2.graph_cache_hits > 0, "second solve must reuse graphs");
        assert!(s2.warm_started);
        assert!((w2.total_cost(&p) - cold.total_cost(&p)).abs() < 1e-9);
        assert_eq!(s2.method, cold_stats.method);
        w2.validate(&p).unwrap();
    }

    #[test]
    fn graph_cache_key_is_count_insensitive_beyond_the_per_bin_cap() {
        use crate::packing::arcflow::GraphCache;
        // 2-core items in an 8-core bin: at most 3 fit one bin, so counts 10
        // and 12 must produce the same capped graph (and cache key).
        let p10 = simple_problem(&[(2.0, 1.0, 10)], &[(8.0, 15.0, 1.0)]);
        let p12 = simple_problem(&[(2.0, 1.0, 12)], &[(8.0, 15.0, 1.0)]);
        let opts = SolveOptions::default();
        let cache = GraphCache::new();
        let (s10, st10) = solve_with(&p10, &opts, Some(&cache), None).unwrap();
        assert_eq!(st10.graph_cache_hits, 0);
        let (s12, st12) = solve_with(&p12, &opts, Some(&cache), None).unwrap();
        assert!(
            st12.graph_cache_hits > 0,
            "count drift beyond the per-bin cap must reuse the cached graph"
        );
        s10.validate(&p10).unwrap();
        s12.validate(&p12).unwrap();
    }

    #[test]
    fn delta_hints_accelerate_without_changing_the_answer() {
        // Solve once, then re-solve single-count perturbations warm from the
        // first solve's root basis + branching order: costs must match the
        // cold solves exactly (the exactness guard falls back internally
        // whenever a warm step cannot be certified).
        let opts = SolveOptions::default();
        let base = simple_problem(
            &[(2.0, 1.0, 5), (3.0, 2.0, 3), (1.5, 0.8, 4)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let (_, st) = solve(&base, &opts).unwrap();
        assert!(st.proven_optimal, "seed solve must prove optimality");
        let hints = DeltaHints {
            root_basis: st.root_basis.clone(),
            branch_order: st.branch_order.clone(),
            ..DeltaHints::default()
        };
        for counts in [[6, 3, 4], [5, 2, 4], [4, 3, 5]] {
            let p = simple_problem(
                &[
                    (2.0, 1.0, counts[0]),
                    (3.0, 2.0, counts[1]),
                    (1.5, 0.8, counts[2]),
                ],
                &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
            );
            let (cold, cold_st) = solve(&p, &opts).unwrap();
            let (warm, warm_st) = solve_delta(&p, &opts, None, None, Some(&hints)).unwrap();
            assert!(cold_st.proven_optimal && warm_st.proven_optimal);
            assert!(
                (warm.total_cost(&p) - cold.total_cost(&p)).abs() < 1e-9,
                "counts {counts:?}: warm {} != cold {}",
                warm.total_cost(&p),
                cold.total_cost(&p)
            );
            warm.validate(&p).unwrap();
        }
    }

    #[test]
    fn ghost_embedding_matches_the_cold_solve() {
        // Solve a 3-group problem, then drop the middle group and re-solve
        // with a ghost hint: the embedded ILP is the previous one with the
        // ghost's coverage zeroed, so the cached basis re-enters through
        // the certified RHS-repair path — and the answer must equal cold.
        let opts = SolveOptions::default();
        let prev = simple_problem(
            &[(2.0, 1.0, 5), (3.0, 2.0, 3), (1.5, 0.8, 4)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let (_, st) = solve(&prev, &opts).unwrap();
        assert!(st.proven_optimal, "seed solve must prove optimality");
        let now = simple_problem(
            &[(2.0, 1.0, 5), (1.5, 0.8, 4)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let hints = DeltaHints {
            root_basis: st.root_basis.clone(),
            branch_order: st.branch_order.clone(),
            ghosts: vec![GhostGroup {
                position: 1,
                demand_bits: prev.items[1]
                    .demand_per_bin
                    .iter()
                    .map(|d| d.map(|dims| dims.as_array().map(f64::to_bits)))
                    .collect(),
                count: prev.items[1].count,
            }],
            appeared: None,
        };
        let (cold, cold_st) = solve(&now, &opts).unwrap();
        let (warm, warm_st) = solve_delta(&now, &opts, None, None, Some(&hints)).unwrap();
        assert!(cold_st.proven_optimal && warm_st.proven_optimal);
        assert!(
            (warm.total_cost(&now) - cold.total_cost(&now)).abs() < 1e-9,
            "ghost warm {} != cold {}",
            warm.total_cost(&now),
            cold.total_cost(&now)
        );
        warm.validate(&now).unwrap();
        // Ghost solves publish no warm hints: their column space includes
        // the ghost's arcs, which a later plain solve does not share.
        assert!(warm_st.root_basis.is_none());
        assert!(warm_st.var_blocks.is_empty());
    }

    #[test]
    fn appeared_group_translation_matches_the_cold_solve() {
        let opts = SolveOptions::default();
        let prev = simple_problem(
            &[(2.0, 1.0, 5), (1.5, 0.8, 4)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let (_, st) = solve(&prev, &opts).unwrap();
        assert!(st.proven_optimal);
        assert!(!st.var_blocks.is_empty(), "exact solves must record their layout");
        let now = simple_problem(
            &[(2.0, 1.0, 5), (3.0, 2.0, 3), (1.5, 0.8, 4)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let Some(basis) = st.root_basis.clone() else {
            return; // no root basis recorded: nothing to translate
        };
        let hints = DeltaHints {
            root_basis: None,
            branch_order: Vec::new(),
            ghosts: Vec::new(),
            appeared: Some(PrevLayout {
                basis,
                blocks: st.var_blocks.clone(),
                num_vars: st.milp_vars,
                num_groups: prev.items.len(),
                new_groups: vec![1],
            }),
        };
        let (cold, cold_st) = solve(&now, &opts).unwrap();
        let (warm, warm_st) = solve_delta(&now, &opts, None, None, Some(&hints)).unwrap();
        assert!(cold_st.proven_optimal && warm_st.proven_optimal);
        assert!(
            (warm.total_cost(&now) - cold.total_cost(&now)).abs() < 1e-9,
            "translated warm {} != cold {}",
            warm.total_cost(&now),
            cold.total_cost(&now)
        );
        warm.validate(&now).unwrap();
    }

    #[test]
    fn translate_block_basis_maps_blocks_and_slacks() {
        let pb = VarBlock {
            bin_type: 0,
            graph_hash: 7,
            var_offset: 0,
            num_arcs: 4,
            row_offset: 0,
            num_rows: 2,
        };
        let pb2 = VarBlock {
            bin_type: 1,
            graph_hash: 9,
            var_offset: 4,
            num_arcs: 3,
            row_offset: 2,
            num_rows: 2,
        };
        // Previous layout: 7 structural columns, 2 groups; the basis holds
        // one column per block plus all three slacks (group 0, group 1, cut).
        let prev = PrevLayout {
            basis: vec![1, 5, 7, 8, 9],
            blocks: vec![pb, pb2],
            num_vars: 7,
            num_groups: 2,
            new_groups: vec![1],
        };
        // Current layout: type 0 unchanged, type 1 absorbed the new group
        // (different hash), 10 structural columns, 3 groups.
        let nb = pb; // type 0's block carries over verbatim
        let nb2 = VarBlock {
            bin_type: 1,
            graph_hash: 11,
            var_offset: 4,
            num_arcs: 6,
            row_offset: 2,
            num_rows: 3,
        };
        let out = translate_block_basis(&prev, &[nb, nb2], 10, 3).unwrap();
        // Column 1 survives in block 0; column 5 (changed block) is dropped;
        // group 0's slack keeps rank 0, group 1's shifts past the inserted
        // group to rank 2, and the cut slack goes last.
        assert_eq!(out, vec![1, 10, 12, 13]);
        // A layout that cannot correspond to this problem is rejected.
        assert!(translate_block_basis(&prev, &[nb], 10, 2).is_none());

        // Two inserted groups: surviving ranks re-rank through the
        // complement (inserts at 1 and 3 -> old ranks 0,1 become 0,2).
        let prev2 = PrevLayout { new_groups: vec![1, 3], ..prev.clone() };
        let out2 = translate_block_basis(&prev2, &[nb, nb2], 10, 4).unwrap();
        assert_eq!(out2, vec![1, 10, 12, 14]);
        // Unsorted or out-of-range insertion lists are stale hints.
        let bad = PrevLayout { new_groups: vec![3, 1], ..prev.clone() };
        assert!(translate_block_basis(&bad, &[nb, nb2], 10, 4).is_none());
        let oob = PrevLayout { new_groups: vec![1, 4], ..prev.clone() };
        assert!(translate_block_basis(&oob, &[nb, nb2], 10, 4).is_none());
    }

    #[test]
    fn multi_vanish_ghost_embedding_matches_the_cold_solve() {
        // Drop TWO groups at once: both re-insert as ghosts, the embedded
        // ILP is bit-identical to the previous solve's, and the cached
        // basis re-enters through the certified RHS-repair path.
        let opts = SolveOptions::default();
        let prev = simple_problem(
            &[(2.0, 1.0, 5), (3.0, 2.0, 3), (1.5, 0.8, 4), (2.5, 1.2, 2)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let (_, st) = solve(&prev, &opts).unwrap();
        assert!(st.proven_optimal, "seed solve must prove optimality");
        // Groups 1 and 3 vanish.
        let now = simple_problem(
            &[(2.0, 1.0, 5), (1.5, 0.8, 4)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let ghost_of = |g: usize| GhostGroup {
            position: g,
            demand_bits: prev.items[g]
                .demand_per_bin
                .iter()
                .map(|d| d.map(|dims| dims.as_array().map(f64::to_bits)))
                .collect(),
            count: prev.items[g].count,
        };
        let hints = DeltaHints {
            root_basis: st.root_basis.clone(),
            branch_order: st.branch_order.clone(),
            ghosts: vec![ghost_of(1), ghost_of(3)],
            appeared: None,
        };
        let (cold, cold_st) = solve(&now, &opts).unwrap();
        let (warm, warm_st) = solve_delta(&now, &opts, None, None, Some(&hints)).unwrap();
        assert!(cold_st.proven_optimal && warm_st.proven_optimal);
        assert_eq!(warm_st.structural_ghosts, 2);
        assert!(
            (warm.total_cost(&now) - cold.total_cost(&now)).abs() < 1e-9,
            "multi-ghost warm {} != cold {}",
            warm.total_cost(&now),
            cold.total_cost(&now)
        );
        warm.validate(&now).unwrap();
    }

    #[test]
    fn mixed_vanish_and_appear_matches_the_cold_solve() {
        // One group vanishes AND one appears in the same re-plan: the
        // vanished group re-inserts as a ghost, reducing the delta to a
        // pure appeared-group translation over the augmented item list.
        let opts = SolveOptions::default();
        let prev = simple_problem(
            &[(2.0, 1.0, 5), (3.0, 2.0, 3), (1.5, 0.8, 4)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let (_, st) = solve(&prev, &opts).unwrap();
        assert!(st.proven_optimal);
        let Some(basis) = st.root_basis.clone() else {
            return; // no root basis recorded: nothing to translate
        };
        // Group 1 (3.0-core) vanished; a 2.5-core group appeared in its
        // place. Augmented list: [old0, ghost(old1), appeared, old2] — the
        // ghost re-inserts at 1, the appeared group sits at 2.
        let now = simple_problem(
            &[(2.0, 1.0, 5), (2.5, 1.2, 2), (1.5, 0.8, 4)],
            &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7)],
        );
        let hints = DeltaHints {
            root_basis: None,
            branch_order: Vec::new(),
            ghosts: vec![GhostGroup {
                position: 1,
                demand_bits: prev.items[1]
                    .demand_per_bin
                    .iter()
                    .map(|d| d.map(|dims| dims.as_array().map(f64::to_bits)))
                    .collect(),
                count: prev.items[1].count,
            }],
            appeared: Some(PrevLayout {
                basis,
                blocks: st.var_blocks.clone(),
                num_vars: st.milp_vars,
                num_groups: prev.items.len(),
                new_groups: vec![2],
            }),
        };
        let (cold, cold_st) = solve(&now, &opts).unwrap();
        let (warm, warm_st) = solve_delta(&now, &opts, None, None, Some(&hints)).unwrap();
        assert!(cold_st.proven_optimal && warm_st.proven_optimal);
        assert_eq!(warm_st.structural_ghosts, 1);
        assert!(
            (warm.total_cost(&now) - cold.total_cost(&now)).abs() < 1e-9,
            "mixed warm {} != cold {}",
            warm.total_cost(&now),
            cold.total_cost(&now)
        );
        warm.validate(&now).unwrap();
        // Ghost-embedded solves publish no warm hints.
        assert!(warm_st.root_basis.is_none());
        assert!(warm_st.var_blocks.is_empty());
    }

    #[test]
    fn property_exact_never_worse_than_ffd() {
        use crate::util::Rng;
        let mut rng = Rng::new(31);
        for round in 0..15 {
            let n_groups = 1 + rng.index(3);
            let items: Vec<(f64, f64, usize)> = (0..n_groups)
                .map(|_| {
                    (
                        rng.range_f64(0.5, 6.0),
                        rng.range_f64(0.5, 8.0),
                        1 + rng.index(4),
                    )
                })
                .collect();
            let p = simple_problem(
                &items,
                &[(8.0, 15.0, 1.0), (16.0, 30.0, 1.7), (36.0, 60.0, 3.4)],
            );
            let Ok((packing, stats)) = solve(&p, &SolveOptions::default()) else {
                continue;
            };
            packing.validate(&p).unwrap();
            assert!(
                stats.final_cost <= stats.ffd_cost + 1e-9,
                "round {round}: exact {} > ffd {}",
                stats.final_cost,
                stats.ffd_cost
            );
        }
    }

    fn slack_lane(cpu: f64) -> TemporalLane {
        TemporalLane {
            label: "cpu@r".into(),
            kind: LaneKind::LiveSlack,
            usable: Dims::new(cpu, 2.0 * cpu, 0.0, 0.0),
            hourly_cost: 0.0,
            from_hour: 0,
        }
    }

    fn spot_lane(cpu: f64, cost: f64) -> TemporalLane {
        TemporalLane {
            label: "cpu@r".into(),
            kind: LaneKind::Spot,
            usable: Dims::new(cpu, 2.0 * cpu, 0.0, 0.0),
            hourly_cost: cost,
            from_hour: 0,
        }
    }

    fn unit_item(id: u64, units: usize, deadline: usize) -> BackfillItem {
        BackfillItem {
            id,
            demand: Dims::new(1.0, 1.0, 0.0, 0.0),
            units,
            deadline_hour: deadline,
            preemptible: true,
        }
    }

    #[test]
    fn backfill_prefers_free_slack_before_opening_paid_hours() {
        // 4 units fit entirely into the free slack lane (2/hour × 2 hours);
        // the spot lane must stay unbilled.
        let lanes = vec![slack_lane(2.0), spot_lane(8.0, 0.14)];
        let items = vec![unit_item(1, 4, 4)];
        let s = pack_backfill(&lanes, &items, 24);
        assert!(s.shed.is_empty());
        assert_eq!(s.placements.len(), 4);
        assert!(s.placements.iter().all(|p| p.lane == 0));
        assert_eq!(s.cost, 0.0);
    }

    #[test]
    fn backfill_bills_paid_lane_hours_once() {
        // 6 units, no slack: a 3-wide spot lane fills 2 hours — cost is two
        // lane-hours, not six unit placements.
        let lanes = vec![spot_lane(3.0, 0.5)];
        let items = vec![unit_item(1, 6, 8)];
        let s = pack_backfill(&lanes, &items, 24);
        assert!(s.shed.is_empty());
        assert_eq!(s.placements.len(), 6);
        assert!((s.cost - 1.0).abs() < 1e-12, "two billed hours at 0.5: {}", s.cost);
    }

    #[test]
    fn infeasible_deadline_sheds_whole_item_and_holds_no_capacity() {
        // Item 1 needs 5 units before hour 2 on a 2-wide lane (max 4) —
        // shed. Item 2's 4 units must then still fit (no half-placed ghost).
        let lanes = vec![spot_lane(2.0, 0.3)];
        let items = vec![unit_item(1, 5, 2), unit_item(2, 4, 2)];
        let s = pack_backfill(&lanes, &items, 24);
        assert_eq!(s.shed, vec![1]);
        assert!(s.placements.iter().all(|p| p.item == 2));
        assert_eq!(s.placements.len(), 4);
    }

    #[test]
    fn non_preemptible_items_never_land_on_spot() {
        let lanes = vec![spot_lane(8.0, 0.2), slack_lane(1.0)];
        let mut item = unit_item(7, 3, 12);
        item.preemptible = false;
        let s = pack_backfill(&lanes, &[item], 24);
        assert!(s.shed.is_empty());
        assert!(s.placements.iter().all(|p| p.lane == 1), "{:?}", s.placements);
    }

    #[test]
    fn rehome_moves_only_stranded_items_and_rebills() {
        // Two spot lanes; item 1 lands on lane 0, item 2 on lane 0/1 mix is
        // avoided by capacity: lane 0 takes 2/hour, so EDF puts item 1
        // (deadline 4) and item 2 (deadline 8) across both lanes.
        let lanes = vec![spot_lane(1.0, 0.2), spot_lane(1.0, 0.2)];
        let items = vec![unit_item(1, 2, 4), unit_item(2, 2, 8)];
        let s = pack_backfill(&lanes, &items, 24);
        assert!(s.shed.is_empty());
        // Revoke lane 0 from hour 0: every unit on lane 0 is stranded.
        let (r, moved) = rehome_backfill(&lanes, &items, &s, &[0], 0, 24);
        assert!(r.shed.is_empty(), "lane 1 alone still meets both deadlines");
        assert!(r.placements.iter().all(|p| p.lane == 1));
        // Untouched placements (those already on lane 1) survive verbatim.
        for p in s.placements.iter().filter(|p| p.lane == 1) {
            assert!(r.placements.contains(p), "surviving placement moved: {p:?}");
        }
        let stranded: Vec<u64> =
            s.placements.iter().filter(|p| p.lane == 0).map(|p| p.item).collect();
        assert!(moved.iter().all(|id| stranded.contains(id)));
        assert!(!moved.is_empty());
    }

    #[test]
    fn rehome_without_revocations_is_bit_identical() {
        let lanes = vec![slack_lane(2.0), spot_lane(2.0, 0.4)];
        let items = vec![unit_item(1, 5, 6), unit_item(2, 3, 4)];
        let s = pack_backfill(&lanes, &items, 24);
        let (r, moved) = rehome_backfill(&lanes, &items, &s, &[], 3, 24);
        assert!(moved.is_empty());
        assert_eq!(r, s, "zero-revocation rehome must be a bit-identical no-op");
    }

    #[test]
    fn rehome_sheds_when_the_deadline_no_longer_fits() {
        // One 1-wide spot lane, item needs 3 units by hour 3 — exactly
        // feasible. Revoking the lane's hours from hour 1 strands 2 units
        // with nowhere to go: the item is shed, its pending placements
        // withdrawn, and the executed hour-0 unit stands as sunk work.
        let lanes = vec![spot_lane(1.0, 0.25)];
        let items = vec![unit_item(9, 3, 3)];
        let s = pack_backfill(&lanes, &items, 24);
        assert!(s.shed.is_empty());
        assert_eq!(s.placements.len(), 3);
        let (r, moved) = rehome_backfill(&lanes, &items, &s, &[0], 1, 24);
        assert_eq!(moved, vec![9]);
        assert_eq!(r.shed, vec![9]);
        assert_eq!(r.placements.len(), 1, "only the executed hour survives");
        assert_eq!(r.placements[0].hour, 0);
    }
}
