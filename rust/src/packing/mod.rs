//! Multi-dimensional multiple-choice vector bin packing (MCVBP).
//!
//! The paper's core formulation (sidebar + Fig 2): analysis streams are
//! "boxes" with a 4-dimensional resource demand; cloud instance types are
//! "trucks" with capacities and hourly costs; the *multiple-choice* aspect is
//! twofold — several truck types exist, **and** a stream's demand vector
//! depends on the truck it lands in (CPU demand on CPU boxes, GPU demand on
//! GPU boxes, per Kaseb et al. \[7\]).
//!
//! * [`heuristic`] — first-fit-decreasing style greedy packer (warm starts,
//!   large instances, the ARMVAC fill rule),
//! * [`arcflow`] — the Brandão–Pedroso arc-flow graph with compression,
//! * [`mcvbp`] — the exact solver: one arc-flow graph per bin type, a joint
//!   min-cost integer flow solved by branch-and-bound (the Gurobi role).

pub mod arcflow;
pub mod heuristic;
pub mod mcvbp;

use crate::catalog::Dims;
use crate::error::{Error, Result};

pub use crate::util::bitset::BinMask;

/// The paper's 90% rule: "when any dimension is more than 90% utilized, the
/// performance starts to degrade. Thus, the method keeps the utilization of
/// each dimension below 90%."
pub const DEFAULT_HEADROOM: f64 = 0.90;

/// A group of identical streams (same program, fps, resolution, and
/// location-eligibility), with a per-bin-type demand vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ItemGroup {
    pub label: String,
    pub count: usize,
    /// `demand_per_bin[t]` = demand vector when placed in bin type `t`;
    /// `None` = this item may not be placed in that bin type (wrong hardware
    /// or outside the RTT circle).
    pub demand_per_bin: Vec<Option<Dims>>,
}

/// A bin type: one instance type at one location, at an hourly cost.
#[derive(Clone, Debug, PartialEq)]
pub struct BinType {
    pub label: String,
    pub capacity: Dims,
    pub cost: f64,
    /// Opaque back-references for the coordinator (catalog indices).
    pub type_idx: usize,
    pub region_idx: usize,
    pub has_gpu: bool,
}

/// The packing instance.
#[derive(Clone, Debug, PartialEq)]
pub struct PackingProblem {
    pub items: Vec<ItemGroup>,
    pub bins: Vec<BinType>,
    /// Per-dimension utilization cap (paper: 0.90).
    pub headroom: f64,
}

impl PackingProblem {
    pub fn new(items: Vec<ItemGroup>, bins: Vec<BinType>) -> Self {
        PackingProblem { items, bins, headroom: DEFAULT_HEADROOM }
    }

    /// Usable capacity of bin type `t` after the 90% rule.
    pub fn effective_capacity(&self, t: usize) -> Dims {
        self.bins[t].capacity.scale(self.headroom)
    }

    /// Total stream count.
    pub fn total_items(&self) -> usize {
        self.items.iter().map(|g| g.count).sum()
    }

    /// True iff item group `g` can ever be placed in bin type `t`.
    pub fn compatible(&self, g: usize, t: usize) -> bool {
        match &self.items[g].demand_per_bin[t] {
            Some(d) => d.fits_in(&self.effective_capacity(t)),
            None => false,
        }
    }

    /// Per item group, the bin types it may ever be packed into
    /// (`demand_per_bin[t].is_some()`) as a fixed-width [`BinMask`] —
    /// `None` when the problem has more bin types than the mask can index
    /// (callers fall back to scanning the demand options).
    pub fn placeable_masks(&self) -> Option<Vec<BinMask>> {
        if self.bins.len() > BinMask::CAPACITY {
            return None;
        }
        Some(
            self.items
                .iter()
                .map(|it| {
                    let mut m = BinMask::new();
                    for (t, d) in it.demand_per_bin.iter().enumerate() {
                        if d.is_some() {
                            m.set(t);
                        }
                    }
                    m
                })
                .collect(),
        )
    }

    /// Like [`PackingProblem::placeable_masks`], additionally requiring the
    /// demand to fit the headroom-scaled capacity
    /// ([`PackingProblem::compatible`]).
    pub fn compatible_masks(&self) -> Option<Vec<BinMask>> {
        if self.bins.len() > BinMask::CAPACITY {
            return None;
        }
        Some(
            (0..self.items.len())
                .map(|g| {
                    let mut m = BinMask::new();
                    for t in 0..self.bins.len() {
                        if self.compatible(g, t) {
                            m.set(t);
                        }
                    }
                    m
                })
                .collect(),
        )
    }

    /// Quick infeasibility check: every item group must fit *somewhere*.
    pub fn check_feasible_items(&self) -> Result<()> {
        for (g, item) in self.items.iter().enumerate() {
            if item.count == 0 {
                continue;
            }
            if !(0..self.bins.len()).any(|t| self.compatible(g, t)) {
                return Err(Error::infeasible(format!(
                    "stream group '{}' fits in no available instance type",
                    item.label
                )));
            }
        }
        Ok(())
    }
}

/// One provisioned bin: a bin type plus per-item-group counts.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedBin {
    pub bin_type: usize,
    /// `counts[g]` = number of streams of item group g placed here.
    pub counts: Vec<usize>,
}

impl PackedBin {
    pub fn total_demand(&self, problem: &PackingProblem) -> Dims {
        let mut total = Dims::default();
        for (g, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                let d = problem.items[g].demand_per_bin[self.bin_type]
                    .expect("packed incompatible item");
                total = total.add(&d.scale(c as f64));
            }
        }
        total
    }

    pub fn num_streams(&self) -> usize {
        self.counts.iter().sum()
    }
}

/// A complete packing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Packing {
    pub bins: Vec<PackedBin>,
}

impl Packing {
    pub fn total_cost(&self, problem: &PackingProblem) -> f64 {
        self.bins.iter().map(|b| problem.bins[b.bin_type].cost).sum()
    }

    pub fn num_bins(&self) -> usize {
        self.bins.len()
    }

    /// Bins split by hardware class — the Fig-3 table columns.
    pub fn count_by_gpu(&self, problem: &PackingProblem) -> (usize, usize) {
        let gpu = self
            .bins
            .iter()
            .filter(|b| problem.bins[b.bin_type].has_gpu)
            .count();
        (self.bins.len() - gpu, gpu)
    }

    /// Verify capacity limits and exact demand coverage.
    pub fn validate(&self, problem: &PackingProblem) -> Result<()> {
        let mut placed = vec![0usize; problem.items.len()];
        for (i, bin) in self.bins.iter().enumerate() {
            if bin.counts.len() != problem.items.len() {
                return Err(Error::config(format!("bin {i}: counts length mismatch")));
            }
            for (g, &c) in bin.counts.iter().enumerate() {
                if c > 0 && problem.items[g].demand_per_bin[bin.bin_type].is_none() {
                    return Err(Error::config(format!(
                        "bin {i}: item '{}' incompatible with bin type '{}'",
                        problem.items[g].label, problem.bins[bin.bin_type].label
                    )));
                }
                placed[g] += c;
            }
            let demand = bin.total_demand(problem);
            let cap = problem.effective_capacity(bin.bin_type);
            if !demand.fits_in(&cap) {
                return Err(Error::config(format!(
                    "bin {i} ('{}') over capacity: demand {demand:?} > cap {cap:?}",
                    problem.bins[bin.bin_type].label
                )));
            }
        }
        for (g, item) in problem.items.iter().enumerate() {
            if placed[g] != item.count {
                return Err(Error::config(format!(
                    "item '{}': placed {} of {}",
                    item.label, placed[g], item.count
                )));
            }
        }
        Ok(())
    }

    /// Max per-dimension utilization over all bins (vs *raw* capacity) —
    /// must stay below the headroom by construction.
    pub fn peak_utilization(&self, problem: &PackingProblem) -> f64 {
        self.bins
            .iter()
            .map(|b| {
                b.total_demand(problem)
                    .max_utilization(&problem.bins[b.bin_type].capacity)
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cpu_bin(cost: f64) -> BinType {
        BinType {
            label: format!("cpu@{cost}"),
            capacity: Dims::new(8.0, 15.0, 0.0, 0.0),
            cost,
            type_idx: 0,
            region_idx: 0,
            has_gpu: false,
        }
    }

    fn item(label: &str, count: usize, cpu: f64, mem: f64) -> ItemGroup {
        ItemGroup {
            label: label.into(),
            count,
            demand_per_bin: vec![Some(Dims::new(cpu, mem, 0.0, 0.0))],
        }
    }

    #[test]
    fn effective_capacity_applies_headroom() {
        let p = PackingProblem::new(vec![], vec![cpu_bin(1.0)]);
        let eff = p.effective_capacity(0);
        assert!((eff.vcpus - 7.2).abs() < 1e-12);
        assert!((eff.mem_gib - 13.5).abs() < 1e-12);
    }

    #[test]
    fn validate_catches_overflow() {
        let p = PackingProblem::new(vec![item("a", 2, 4.0, 1.0)], vec![cpu_bin(1.0)]);
        let packing = Packing {
            bins: vec![PackedBin { bin_type: 0, counts: vec![2] }], // 8.0 > 7.2
        };
        assert!(packing.validate(&p).is_err());
    }

    #[test]
    fn validate_catches_missing_items() {
        let p = PackingProblem::new(vec![item("a", 2, 3.0, 1.0)], vec![cpu_bin(1.0)]);
        let packing = Packing {
            bins: vec![PackedBin { bin_type: 0, counts: vec![1] }],
        };
        assert!(packing.validate(&p).is_err());
    }

    #[test]
    fn validate_accepts_good_packing() {
        let p = PackingProblem::new(vec![item("a", 2, 3.0, 1.0)], vec![cpu_bin(1.0)]);
        let packing = Packing {
            bins: vec![PackedBin { bin_type: 0, counts: vec![2] }],
        };
        packing.validate(&p).unwrap();
        assert_eq!(packing.total_cost(&p), 1.0);
        assert!(packing.peak_utilization(&p) <= DEFAULT_HEADROOM + 1e-9);
    }

    #[test]
    fn masks_mirror_the_scan_predicates() {
        let mut both = item("a", 2, 3.0, 1.0);
        both.demand_per_bin = vec![Some(Dims::new(3.0, 1.0, 0.0, 0.0)); 2];
        let mut second_only = item("g", 1, 1.0, 1.0);
        second_only.demand_per_bin = vec![None, Some(Dims::new(1.0, 1.0, 0.0, 0.0))];
        let mut oversized = item("big", 1, 100.0, 1.0);
        oversized.demand_per_bin = vec![Some(Dims::new(100.0, 1.0, 0.0, 0.0)), None];
        let p = PackingProblem::new(
            vec![both, second_only, oversized],
            vec![cpu_bin(1.0), cpu_bin(2.0)],
        );
        let placeable = p.placeable_masks().unwrap();
        let compatible = p.compatible_masks().unwrap();
        for g in 0..p.items.len() {
            for t in 0..p.bins.len() {
                assert_eq!(placeable[g].get(t), p.items[g].demand_per_bin[t].is_some());
                assert_eq!(compatible[g].get(t), p.compatible(g, t));
            }
        }
        // The oversized item is placeable (a demand exists) but never
        // compatible (it cannot fit the headroom capacity).
        assert!(placeable[2].any());
        assert!(!compatible[2].any());
    }

    #[test]
    fn infeasible_item_detected() {
        let p = PackingProblem::new(vec![item("huge", 1, 100.0, 1.0)], vec![cpu_bin(1.0)]);
        assert!(p.check_feasible_items().is_err());
    }

    #[test]
    fn incompatible_item_not_placeable() {
        let mut it = item("gpu-only", 1, 1.0, 1.0);
        it.demand_per_bin = vec![None];
        let p = PackingProblem::new(vec![it], vec![cpu_bin(1.0)]);
        assert!(p.check_feasible_items().is_err());
    }
}
