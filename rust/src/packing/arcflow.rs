//! Arc-flow graph for vector bin packing (Brandão & Pedroso \[9\], \[10\]).
//!
//! As in the paper's sidebar: for one bin ("truck") type, nodes represent
//! partial fill states; placing a box of item type `g` is an arc; any path
//! source→sink is a feasible single-bin packing. Item types are added in a
//! fixed order, each up to its demand — "First, box A is added as many times
//! as the demand requires without over-filling the truck. Then, box B ...".
//!
//! After construction the graph is **compressed**: nodes with identical
//! outgoing behaviour are merged (partition refinement / bisimulation), the
//! multi-dimensional analogue of Brandão–Pedroso level merging. The
//! compressed graph has the same set of source→sink item-label paths but far
//! fewer nodes/arcs — "this in turn will result in time saved when solving
//! the graph".

use crate::error::{Error, Result};
use crate::util::fxhash::{FxHashMap, FxHashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
// `Arc` is this module's graph-arc struct; the shared pointer is aliased.
use std::sync::{Arc as SharedArc, Mutex};

/// A quantized item type: integer sizes per dimension + demanded count.
#[derive(Clone, Debug)]
pub struct QuantItem {
    pub sizes: Vec<i64>,
    pub count: usize,
}

/// An arc. `item == None` marks the "finish" arc to the sink (loss arc).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Arc {
    pub from: usize,
    pub to: usize,
    pub item: Option<usize>,
}

/// The arc-flow graph of one bin type.
#[derive(Clone, Debug)]
pub struct ArcFlow {
    pub num_nodes: usize,
    pub source: usize,
    pub sink: usize,
    pub arcs: Vec<Arc>,
}

/// Compression statistics (reported by `bench_packing --sidebar`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionStats {
    pub nodes_before: usize,
    pub arcs_before: usize,
    pub nodes_after: usize,
    pub arcs_after: usize,
}

impl CompressionStats {
    pub fn node_ratio(&self) -> f64 {
        self.nodes_after as f64 / self.nodes_before.max(1) as f64
    }
    pub fn arc_ratio(&self) -> f64 {
        self.arcs_after as f64 / self.arcs_before.max(1) as f64
    }
}

/// Build the arc-flow graph for a bin with integer capacity `cap` over
/// `items` (in the given order). Fails if the state space exceeds
/// `max_nodes` (callers fall back to the heuristic packer).
pub fn build(cap: &[i64], items: &[QuantItem], max_nodes: usize) -> Result<ArcFlow> {
    let dims = cap.len();
    for it in items {
        if it.sizes.len() != dims {
            return Err(Error::config("item dimensionality mismatch"));
        }
    }

    // State: (usage vector, last item group, count of that group used).
    type State = (Vec<i64>, usize, usize);
    let mut index: FxHashMap<State, usize> = FxHashMap::default();
    let mut states: Vec<State> = Vec::new();
    let mut arcs: Vec<Arc> = Vec::new();

    let source_state: State = (vec![0; dims], usize::MAX, 0);
    index.insert(source_state.clone(), 0);
    states.push(source_state);

    let fits = |usage: &[i64], sizes: &[i64]| -> bool {
        usage.iter().zip(sizes).zip(cap).all(|((u, s), c)| u + s <= *c)
    };

    let mut frontier = vec![0usize];
    while let Some(u) = frontier.pop() {
        let (usage, g, k) = states[u].clone();
        // Next placements: more of group g (if any left), or the first
        // placement of any later group.
        let start_group = if g == usize::MAX { 0 } else { g };
        for (g2, item) in items.iter().enumerate().skip(start_group) {
            if item.count == 0 {
                continue;
            }
            let k2 = if g2 == g { k + 1 } else { 1 };
            if k2 > item.count || !fits(&usage, &item.sizes) {
                continue;
            }
            let mut usage2 = usage.clone();
            let mut ok = true;
            for (u2, s) in usage2.iter_mut().zip(&item.sizes) {
                *u2 += s;
            }
            for (u2, c) in usage2.iter().zip(cap) {
                if u2 > c {
                    ok = false;
                    break;
                }
            }
            if !ok {
                continue;
            }
            let st: State = (usage2, g2, k2);
            let v = match index.get(&st) {
                Some(&v) => v,
                None => {
                    let v = states.len();
                    if v >= max_nodes {
                        return Err(Error::solver(format!(
                            "arc-flow state space exceeds {max_nodes} nodes"
                        )));
                    }
                    index.insert(st.clone(), v);
                    states.push(st);
                    frontier.push(v);
                    v
                }
            };
            arcs.push(Arc { from: u, to: v, item: Some(g2) });
        }
    }

    // Finish arcs: every state (including the empty source, representing an
    // unopened bin slot — removed below for source) can close the bin.
    let sink = states.len();
    for u in 0..states.len() {
        arcs.push(Arc { from: u, to: sink, item: None });
    }
    // Drop the source->sink loss arc: an empty bin is never opened.
    arcs.retain(|a| !(a.from == 0 && a.to == sink && a.item.is_none()));

    Ok(ArcFlow { num_nodes: sink + 1, source: 0, sink, arcs })
}

/// Merge nodes with identical outgoing behaviour (partition refinement).
/// Preserves the multiset of source→sink item-label paths.
pub fn compress(g: &ArcFlow) -> (ArcFlow, CompressionStats) {
    let before = CompressionStats {
        nodes_before: g.num_nodes,
        arcs_before: g.arcs.len(),
        nodes_after: 0,
        arcs_after: 0,
    };

    // Initial partition: {sink}, {source}, {everything else}.
    let mut class = vec![1usize; g.num_nodes];
    class[g.sink] = 0;
    class[g.source] = 2;

    let mut out: Vec<Vec<(Option<usize>, usize)>> = vec![Vec::new(); g.num_nodes];
    for a in &g.arcs {
        out[a.from].push((a.item, a.to));
    }

    loop {
        // Signature: sorted (item, class-of-target) pairs.
        let mut sig_index: FxHashMap<(usize, Vec<(Option<usize>, usize)>), usize> =
            FxHashMap::default();
        let mut new_class = vec![0usize; g.num_nodes];
        let mut next = 0usize;
        for u in 0..g.num_nodes {
            let mut sig: Vec<(Option<usize>, usize)> =
                out[u].iter().map(|&(item, v)| (item, class[v])).collect();
            sig.sort_unstable();
            sig.dedup();
            let key = (class[u], sig);
            let c = *sig_index.entry(key).or_insert_with(|| {
                let c = next;
                next += 1;
                c
            });
            new_class[u] = c;
        }
        if new_class == class {
            break;
        }
        class = new_class;
    }

    // Rebuild: representative node per class.
    let num_classes = class.iter().max().unwrap() + 1;
    let mut new_arcs: Vec<Arc> = Vec::new();
    let mut seen: FxHashSet<(usize, usize, Option<usize>)> = FxHashSet::default();
    for a in &g.arcs {
        let key = (class[a.from], class[a.to], a.item);
        if seen.insert(key) {
            new_arcs.push(Arc { from: class[a.from], to: class[a.to], item: a.item });
        }
    }

    let compressed = ArcFlow {
        num_nodes: num_classes,
        source: class[g.source],
        sink: class[g.sink],
        arcs: new_arcs,
    };
    let stats = CompressionStats {
        nodes_after: compressed.num_nodes,
        arcs_after: compressed.arcs.len(),
        ..before
    };
    (compressed, stats)
}

/// Exact cache key for a bin type's arc-flow graph: the graph is fully
/// determined by the (ordered) quantized item list and the integer capacity.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct GraphKey {
    cap: Vec<i64>,
    items: Vec<(Vec<i64>, usize)>,
}

/// Cross-replan cache of compressed arc-flow graphs.
///
/// Re-planning with a lightly perturbed workload leaves most bin types'
/// compatible item sets untouched, so their graphs can be reused verbatim.
/// The cache is `Sync`: lookups take a short lock, builds run outside it so
/// parallel per-region solves don't serialize on graph construction (a
/// duplicate concurrent build of the same key is possible but harmless).
///
/// Failed builds are remembered too: the cache keeps, per key, the highest
/// node budget known to be insufficient (the *failure watermark*). A
/// repeated over-budget subproblem then fails fast instead of re-enumerating
/// the same state space to the same failure on every re-plan; a later call
/// with a larger budget still rebuilds, and a success clears the watermark.
#[derive(Default)]
pub struct GraphCache {
    map: Mutex<FxHashMap<GraphKey, SharedArc<(ArcFlow, CompressionStats)>>>,
    /// Key → highest `max_nodes` that is known to be insufficient.
    failed: Mutex<FxHashMap<GraphKey, usize>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    fail_fast: AtomicUsize,
}

/// Soft cap on cached graphs; reaching it clears the cache (simple, bounded).
const GRAPH_CACHE_CAPACITY: usize = 512;

impl GraphCache {
    pub fn new() -> Self {
        GraphCache::default()
    }

    /// (hits, misses) since creation.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Over-budget builds short-circuited by the failure watermark.
    pub fn fail_fast_count(&self) -> usize {
        self.fail_fast.load(Ordering::Relaxed)
    }

    /// Return the compressed graph for `(cap, items)` plus whether it was a
    /// cache hit, building (and caching) it on a miss. A budget failure
    /// records its watermark so retries at or below it fail fast; a retry
    /// with a larger budget rebuilds (and, on success, clears it).
    pub fn get_or_build(
        &self,
        cap: &[i64],
        items: &[QuantItem],
        max_nodes: usize,
    ) -> Result<(SharedArc<(ArcFlow, CompressionStats)>, bool)> {
        let key = GraphKey {
            cap: cap.to_vec(),
            items: items.iter().map(|it| (it.sizes.clone(), it.count)).collect(),
        };
        if let Some(hit) = self.map.lock().unwrap().get(&key).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((hit, true));
        }
        if let Some(&w) = self.failed.lock().unwrap().get(&key) {
            if max_nodes <= w {
                self.fail_fast.fetch_add(1, Ordering::Relaxed);
                return Err(Error::solver(format!(
                    "arc-flow state space exceeds {max_nodes} nodes \
                     (cached failure watermark {w})"
                )));
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        match build(cap, items, max_nodes) {
            Ok(g) => {
                let (cg, stats) = compress(&g);
                let entry = SharedArc::new((cg, stats));
                self.failed.lock().unwrap().remove(&key);
                let mut map = self.map.lock().unwrap();
                if map.len() >= GRAPH_CACHE_CAPACITY {
                    map.clear();
                }
                map.insert(key, entry.clone());
                Ok((entry, false))
            }
            Err(e) => {
                // Only budget failures are watermarked; config errors (e.g.
                // dimension mismatch) are cheap to rediscover and should not
                // occupy cache space.
                if matches!(e, Error::Solver(_)) {
                    let mut failed = self.failed.lock().unwrap();
                    if failed.len() >= GRAPH_CACHE_CAPACITY {
                        failed.clear();
                    }
                    let w = failed.entry(key).or_insert(0);
                    *w = (*w).max(max_nodes);
                }
                Err(e)
            }
        }
    }
}

/// Enumerate all distinct source→sink paths as item-count vectors
/// (test/diagnostic helper; exponential in general, fine for sidebar-scale).
pub fn enumerate_packings(g: &ArcFlow, num_items: usize) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<(Option<usize>, usize)>> = vec![Vec::new(); g.num_nodes];
    for a in &g.arcs {
        out[a.from].push((a.item, a.to));
    }
    let mut results = Vec::new();
    let mut stack = vec![(g.source, vec![0usize; num_items])];
    while let Some((u, counts)) = stack.pop() {
        if u == g.sink {
            if counts.iter().any(|&c| c > 0) {
                results.push(counts);
            }
            continue;
        }
        for &(item, v) in &out[u] {
            let mut c2 = counts.clone();
            if let Some(i) = item {
                c2[i] += 1;
            }
            stack.push((v, c2));
        }
    }
    results.sort();
    results.dedup();
    results
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's sidebar instance: truck (7,3); A (5,1)×1, B (3,1)×1,
    /// C (2,1)×2.
    fn sidebar() -> (Vec<i64>, Vec<QuantItem>) {
        (
            vec![7, 3],
            vec![
                QuantItem { sizes: vec![5, 1], count: 1 },
                QuantItem { sizes: vec![3, 1], count: 1 },
                QuantItem { sizes: vec![2, 1], count: 2 },
            ],
        )
    }

    #[test]
    fn sidebar_graph_builds() {
        let (cap, items) = sidebar();
        let g = build(&cap, &items, 10_000).unwrap();
        assert!(g.num_nodes > 2);
        assert!(g.arcs.iter().any(|a| a.item == Some(0)));
        assert!(g.arcs.iter().any(|a| a.item.is_none()));
    }

    #[test]
    fn sidebar_packings_are_exactly_the_feasible_ones() {
        let (cap, items) = sidebar();
        let g = build(&cap, &items, 10_000).unwrap();
        let packs = enumerate_packings(&g, 3);
        // Feasibility oracle: 5a + 3b + 2c <= 7 and a + b + c <= 3, bounded
        // by demands (a<=1, b<=1, c<=2).
        let mut expected = Vec::new();
        for a in 0..=1usize {
            for b in 0..=1usize {
                for c in 0..=2usize {
                    if a + b + c == 0 {
                        continue;
                    }
                    if 5 * a + 3 * b + 2 * c <= 7 && a + b + c <= 3 {
                        expected.push(vec![a, b, c]);
                    }
                }
            }
        }
        expected.sort();
        assert_eq!(packs, expected);
        // Max boxes in one truck = 3 (B + 2C), as in the sidebar narrative.
        let max_boxes = packs.iter().map(|p| p.iter().sum::<usize>()).max().unwrap();
        assert_eq!(max_boxes, 3);
    }

    #[test]
    fn sidebar_compression_shrinks_and_preserves_paths() {
        let (cap, items) = sidebar();
        let g = build(&cap, &items, 10_000).unwrap();
        let before = enumerate_packings(&g, 3);
        let (cg, stats) = compress(&g);
        let after = enumerate_packings(&cg, 3);
        assert_eq!(before, after, "compression must preserve packings");
        assert!(stats.nodes_after <= stats.nodes_before);
        assert!(stats.arcs_after <= stats.arcs_before);
        assert!(stats.nodes_after < stats.nodes_before, "expected real merging");
    }

    #[test]
    fn item_order_canonicalization_no_permuted_duplicates() {
        // Two identical items: placing them is order-canonical, so the graph
        // has exactly one path with count 2 (not two permutations).
        let cap = vec![4];
        let items = vec![QuantItem { sizes: vec![2], count: 2 }];
        let g = build(&cap, &items, 1000).unwrap();
        let packs = enumerate_packings(&g, 1);
        assert_eq!(packs, vec![vec![1], vec![2]]);
    }

    #[test]
    fn oversize_item_produces_no_arc() {
        let cap = vec![3];
        let items = vec![QuantItem { sizes: vec![5], count: 1 }];
        let g = build(&cap, &items, 1000).unwrap();
        assert!(enumerate_packings(&g, 1).is_empty());
    }

    #[test]
    fn max_nodes_guard_trips() {
        // Many distinct small items in 3 dims -> big state space.
        let cap = vec![50, 50, 50];
        let items: Vec<QuantItem> = (1..=10)
            .map(|i| QuantItem { sizes: vec![i, 11 - i, (i % 3) + 1], count: 5 })
            .collect();
        assert!(build(&cap, &items, 50).is_err());
    }

    #[test]
    fn graph_cache_hits_on_identical_inputs() {
        let (cap, items) = sidebar();
        let cache = GraphCache::new();
        let (g1, hit1) = cache.get_or_build(&cap, &items, 10_000).unwrap();
        let (g2, hit2) = cache.get_or_build(&cap, &items, 10_000).unwrap();
        assert!(!hit1 && hit2);
        assert!(SharedArc::ptr_eq(&g1, &g2), "second lookup must hit the cache");
        assert_eq!(cache.stats(), (1, 1));
        // A different capacity is a different key.
        let other_cap = vec![8, 3];
        let (g3, hit3) = cache.get_or_build(&other_cap, &items, 10_000).unwrap();
        assert!(!hit3);
        assert!(!SharedArc::ptr_eq(&g1, &g3));
        assert_eq!(cache.stats(), (1, 2));
        // Cached graph enumerates the same packings as a fresh build.
        let fresh = build(&cap, &items, 10_000).unwrap();
        assert_eq!(
            enumerate_packings(&g1.0, 3),
            enumerate_packings(&compress(&fresh).0, 3)
        );
    }

    #[test]
    fn failure_watermark_stops_repeat_rebuilds() {
        // A state space that cannot fit in 50 nodes (see max_nodes_guard).
        let cap = vec![50, 50, 50];
        let items: Vec<QuantItem> = (1..=10)
            .map(|i| QuantItem { sizes: vec![i, 11 - i, (i % 3) + 1], count: 5 })
            .collect();
        let cache = GraphCache::new();
        assert!(cache.get_or_build(&cap, &items, 50).is_err());
        let misses_after_first = cache.stats().1;
        // Same (or lower) budget: fails fast without re-enumerating states.
        assert!(cache.get_or_build(&cap, &items, 50).is_err());
        assert!(cache.get_or_build(&cap, &items, 30).is_err());
        assert_eq!(cache.stats().1, misses_after_first, "watermark must skip rebuilds");
        assert_eq!(cache.fail_fast_count(), 2);
        // A larger budget rebuilds; success clears the watermark so the
        // entry is a plain cache hit afterwards.
        let (_, hit) = cache.get_or_build(&cap, &items, 1_000_000).unwrap();
        assert!(!hit);
        let (_, hit2) = cache.get_or_build(&cap, &items, 50).unwrap();
        assert!(hit2, "successful build must serve later lookups");
        assert_eq!(cache.fail_fast_count(), 2);
    }

    #[test]
    fn property_every_path_fits_capacity() {
        use crate::util::Rng;
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let dims = 1 + rng.index(3);
            let cap: Vec<i64> = (0..dims).map(|_| 4 + rng.index(8) as i64).collect();
            let n_items = 1 + rng.index(3);
            let items: Vec<QuantItem> = (0..n_items)
                .map(|_| QuantItem {
                    sizes: (0..dims).map(|_| 1 + rng.index(5) as i64).collect(),
                    count: 1 + rng.index(3),
                })
                .collect();
            let g = match build(&cap, &items, 20_000) {
                Ok(g) => g,
                Err(_) => continue,
            };
            for pack in enumerate_packings(&g, n_items) {
                for d in 0..dims {
                    let used: i64 = pack
                        .iter()
                        .zip(&items)
                        .map(|(&c, it)| c as i64 * it.sizes[d])
                        .sum();
                    assert!(used <= cap[d], "pack {pack:?} violates dim {d}");
                }
                for (c, it) in pack.iter().zip(&items) {
                    assert!(*c <= it.count);
                }
            }
        }
    }
}
