//! Serving metrics: counters, gauges, and latency histograms.
//!
//! Thread-safe (the serving layer is multi-threaded); histograms use
//! logarithmic buckets (HDR-style) so p99 of microsecond-to-second latencies
//! stays accurate without unbounded memory.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
    /// Zero the counter (window/reset semantics; needs only `&self`).
    pub fn reset(&self) {
        self.value.store(0, Ordering::Relaxed);
    }
}

/// Instantaneous gauge (bit-cast f64).
#[derive(Default, Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log-bucketed latency histogram: buckets at `MIN_US * GROWTH^i`.
#[derive(Debug)]
pub struct Histogram {
    /// Bucket upper bounds in microseconds.
    bounds_us: Vec<f64>,
    counts: Vec<AtomicU64>,
    /// Sum and max accumulate in integer *nanoseconds*: accumulating
    /// truncated microseconds biased `mean_us` low (sub-microsecond samples
    /// vanished entirely).
    sum_ns: AtomicU64,
    count: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 1 µs .. ~200 s with 1.35x growth: 64 buckets cover it comfortably.
        let mut bounds = Vec::new();
        let mut b = 1.0f64;
        while b < 2.0e8 {
            bounds.push(b);
            b *= 1.35;
        }
        let n = bounds.len();
        Histogram {
            bounds_us: bounds,
            counts: (0..=n).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record_us(&self, us: f64) {
        let idx = self
            .bounds_us
            .partition_point(|&b| b < us)
            .min(self.counts.len() - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        let ns = (us.max(0.0) * 1e3).round() as u64;
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    /// Zero every bucket and the sum/count/max accumulators (window/reset
    /// semantics; needs only `&self`). Concurrent `record_us` calls may land
    /// on either side of the reset, matching [`Counter::reset`].
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.sum_ns.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return f64::NAN;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e3 / c as f64
    }

    pub fn max_us(&self) -> f64 {
        self.max_ns.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Approximate percentile (bucket upper bound), q in [0, 100].
    pub fn percentile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = ((q / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    self.max_us()
                };
            }
        }
        self.max_us()
    }
}

/// Cross-re-plan solver telemetry, accumulated by the planning pipeline
/// (the planner-side counterpart of [`ServingMetrics`]). Owned by a
/// `PlanContext`, so counters aggregate over every re-plan through that
/// context — the adaptive budget allocator's raw material is the
/// per-component snapshot; these are the fleet-level roll-up.
#[derive(Default)]
pub struct SolverMetrics {
    /// Subproblems entering the Solve stage (memo hits included).
    pub subproblems: Counter,
    /// Components whose adopted packing came from the exact phase vs the
    /// heuristic fallback.
    pub exact_solves: Counter,
    pub heuristic_fallbacks: Counter,
    /// Bit-exact solution-memo hits and near-match (delta) reuses.
    pub memo_hits: Counter,
    pub delta_reuses: Counter,
    /// Structural near-match reuses: a cached exact solve one group away
    /// (appeared/vanished) seeded the solver. Separate from `delta_reuses`,
    /// which counts only same-structure (counts-only) warm starts.
    pub structural_reuses: Counter,
    /// Node LPs warm-resumed from a cached/parent basis vs solved cold.
    pub lp_warm_resumes: Counter,
    pub lp_cold_solves: Counter,
    /// Simplex pivots whose min-ratio was ~0 (the basis changed but the
    /// point did not move) — the degeneracy the two-tier Dantzig pricing
    /// works to avoid; summed over every node LP.
    pub degenerate_pivots: Counter,
    /// Branch-and-bound nodes expanded.
    pub bnb_nodes: Counter,
    /// Extra arc-flow node budget granted above the static seed by the
    /// adaptive allocator (sum over re-plans).
    pub budget_donated_nodes: Counter,
    /// Arc-flow node budget drawn from the portfolio's *cross-candidate*
    /// donated pool — grants beyond what this context's own isolated
    /// allocation would have given (`coordinator::portfolio`).
    pub budget_pooled_donated: Counter,
    /// Over-budget graph builds short-circuited by the failure watermark.
    pub graph_fail_fastpaths: Counter,
    /// Subproblems dispatched to the persistent worker pool. The portfolio
    /// shares one pool across its three candidate contexts, so
    /// `ReplanContext::pool_shared_jobs` sums this counter over all of them.
    pub pool_jobs: Counter,
    /// Streams whose re-plan provisioned from observed (serving-feedback)
    /// demand rather than the declared profile — i.e. their
    /// `DemandFeedback` differed from the default at plan time
    /// (`server::feedback` closed the loop for them).
    pub feedback_streams: Counter,
    /// Streams provisioned at a backpressure degrade tier (> 0): the
    /// controller shed them to a lower fps tier before frames dropped.
    pub degraded_tier_streams: Counter,
}

impl SolverMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "subproblems={} exact={} fallback={} memo={} delta={} structural={} lp_warm={} \
             lp_cold={} degen_pivots={} bnb_nodes={} donated_nodes={} pooled_nodes={} \
             fail_fast={} pool_jobs={} feedback_streams={} degraded_tiers={}",
            self.subproblems.get(),
            self.exact_solves.get(),
            self.heuristic_fallbacks.get(),
            self.memo_hits.get(),
            self.delta_reuses.get(),
            self.structural_reuses.get(),
            self.lp_warm_resumes.get(),
            self.lp_cold_solves.get(),
            self.degenerate_pivots.get(),
            self.bnb_nodes.get(),
            self.budget_donated_nodes.get(),
            self.budget_pooled_donated.get(),
            self.graph_fail_fastpaths.get(),
            self.pool_jobs.get(),
            self.feedback_streams.get(),
            self.degraded_tier_streams.get(),
        )
    }

    /// [`SolverMetrics::summary`] labelled with the reporting scope — the
    /// per-shard form used when several planners roll up into one sink
    /// (`coordinator::shard`), so interleaved counter lines stay
    /// attributable to the shard that produced them.
    pub fn summary_for(&self, scope: &str) -> String {
        format!("shard={scope} {}", self.summary())
    }

    /// Add every counter from `other` into `self` — the roll-up primitive
    /// behind fleet-level summaries. Counters are atomic, so absorbing
    /// needs only `&self`.
    pub fn absorb(&self, other: &SolverMetrics) {
        self.subproblems.add(other.subproblems.get());
        self.exact_solves.add(other.exact_solves.get());
        self.heuristic_fallbacks.add(other.heuristic_fallbacks.get());
        self.memo_hits.add(other.memo_hits.get());
        self.delta_reuses.add(other.delta_reuses.get());
        self.structural_reuses.add(other.structural_reuses.get());
        self.lp_warm_resumes.add(other.lp_warm_resumes.get());
        self.lp_cold_solves.add(other.lp_cold_solves.get());
        self.degenerate_pivots.add(other.degenerate_pivots.get());
        self.bnb_nodes.add(other.bnb_nodes.get());
        self.budget_donated_nodes.add(other.budget_donated_nodes.get());
        self.budget_pooled_donated.add(other.budget_pooled_donated.get());
        self.graph_fail_fastpaths.add(other.graph_fail_fastpaths.get());
        self.pool_jobs.add(other.pool_jobs.get());
        self.feedback_streams.add(other.feedback_streams.get());
        self.degraded_tier_streams.add(other.degraded_tier_streams.get());
    }
}

/// A snapshot of the windowable serving counters. Doubles as a *delta*:
/// `take_window` returns the counter increments since the previous window,
/// which is what the feedback controller consumes (observed per-window
/// throughput, not lifetime totals).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsWindow {
    pub frames_in: u64,
    pub frames_analyzed: u64,
    pub frames_dropped: u64,
    pub batches: u64,
    /// Instantaneous queue depth at snapshot time — a gauge, so deltas keep
    /// the *latest* value rather than subtracting.
    pub queue_depth: f64,
}

impl MetricsWindow {
    /// Counter increments from `prev` to `self`; `queue_depth` keeps the
    /// newer reading. Saturating, so a reset between snapshots yields zeros
    /// instead of wrapping.
    pub fn delta_since(&self, prev: &MetricsWindow) -> MetricsWindow {
        MetricsWindow {
            frames_in: self.frames_in.saturating_sub(prev.frames_in),
            frames_analyzed: self.frames_analyzed.saturating_sub(prev.frames_analyzed),
            frames_dropped: self.frames_dropped.saturating_sub(prev.frames_dropped),
            batches: self.batches.saturating_sub(prev.batches),
            queue_depth: self.queue_depth,
        }
    }

    /// Dropped / (analyzed + dropped); 0.0 when no frames completed either
    /// way (an idle window is not a lossy window).
    pub fn drop_rate(&self) -> f64 {
        let total = self.frames_analyzed + self.frames_dropped;
        if total == 0 {
            0.0
        } else {
            self.frames_dropped as f64 / total as f64
        }
    }
}

/// A named set of serving metrics.
#[derive(Default)]
pub struct ServingMetrics {
    pub frames_in: Counter,
    pub frames_analyzed: Counter,
    pub frames_dropped: Counter,
    pub batches: Counter,
    pub detections: Counter,
    pub queue_depth: Gauge,
    pub batch_latency: Histogram,
    pub e2e_latency: Histogram,
    pub infer_latency: Histogram,
    pub batch_sizes: Mutex<Vec<usize>>,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch_size(&self, n: usize) {
        self.batches.inc();
        self.batch_sizes.lock().unwrap().push(n);
    }

    pub fn mean_batch_size(&self) -> f64 {
        let v = self.batch_sizes.lock().unwrap();
        if v.is_empty() {
            return f64::NAN;
        }
        v.iter().sum::<usize>() as f64 / v.len() as f64
    }

    /// Point-in-time snapshot of the windowable counters.
    pub fn snapshot(&self) -> MetricsWindow {
        MetricsWindow {
            frames_in: self.frames_in.get(),
            frames_analyzed: self.frames_analyzed.get(),
            frames_dropped: self.frames_dropped.get(),
            batches: self.batches.get(),
            queue_depth: self.queue_depth.get(),
        }
    }

    /// Per-window delta: increments since `last`, which is advanced to the
    /// current snapshot. Call once per observation window; the counters
    /// themselves keep accumulating (lifetime totals stay intact).
    pub fn take_window(&self, last: &mut MetricsWindow) -> MetricsWindow {
        let now = self.snapshot();
        let delta = now.delta_since(last);
        *last = now;
        delta
    }

    /// Zero every counter, gauge, histogram, and the batch-size log.
    pub fn reset(&self) {
        self.frames_in.reset();
        self.frames_analyzed.reset();
        self.frames_dropped.reset();
        self.batches.reset();
        self.detections.reset();
        self.queue_depth.set(0.0);
        self.batch_latency.reset();
        self.e2e_latency.reset();
        self.infer_latency.reset();
        self.batch_sizes.lock().unwrap().clear();
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "frames_in={} analyzed={} dropped={} batches={} mean_batch={:.2} \
             e2e_p50={:.1}ms e2e_p99={:.1}ms infer_mean={:.1}ms",
            self.frames_in.get(),
            self.frames_analyzed.get(),
            self.frames_dropped.get(),
            self.batches.get(),
            self.mean_batch_size(),
            self.e2e_latency.percentile_us(50.0) / 1e3,
            self.e2e_latency.percentile_us(99.0) / 1e3,
            self.infer_latency.mean_us() / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64 * 100.0); // 100us .. 100ms
        }
        let p50 = h.percentile_us(50.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p99);
        // Log buckets: within 35% of the true value.
        assert!((p50 / 50_000.0) < 1.4 && (p50 / 50_000.0) > 0.7, "p50={p50}");
        assert!((p99 / 99_000.0) < 1.4 && (p99 / 99_000.0) > 0.7, "p99={p99}");
    }

    #[test]
    fn histogram_mean_and_max() {
        let h = Histogram::new();
        h.record_us(100.0);
        h.record_us(300.0);
        assert_eq!(h.count(), 2);
        assert!((h.mean_us() - 200.0).abs() < 1.0);
        assert_eq!(h.max_us(), 300.0);
    }

    #[test]
    fn fractional_microseconds_are_not_truncated() {
        // Regression: sums accumulated `us as u64`, so sub-microsecond
        // samples contributed 0 and every sample lost its fraction.
        let h = Histogram::new();
        for _ in 0..4 {
            h.record_us(0.25);
        }
        assert!((h.mean_us() - 0.25).abs() < 1e-9, "mean={}", h.mean_us());
        assert!((h.max_us() - 0.25).abs() < 1e-9);
        h.record_us(1.5);
        assert!((h.mean_us() - (4.0 * 0.25 + 1.5) / 5.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let h = Histogram::new();
        assert!(h.percentile_us(50.0).is_nan());
        assert!(h.mean_us().is_nan());
    }

    #[test]
    fn histogram_thread_safety() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.record_us((t * 1000 + i) as f64);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn solver_metrics_accumulate_and_render() {
        let m = SolverMetrics::new();
        m.subproblems.add(6);
        m.exact_solves.add(5);
        m.heuristic_fallbacks.inc();
        m.delta_reuses.add(2);
        m.budget_donated_nodes.add(12_000);
        m.budget_pooled_donated.add(3_000);
        m.pool_jobs.add(9);
        m.degenerate_pivots.add(4);
        m.structural_reuses.add(3);
        let s = m.summary();
        assert!(s.contains("subproblems=6"));
        assert!(s.contains("degen_pivots=4"));
        assert!(s.contains("fallback=1"));
        assert!(s.contains("delta=2"));
        assert!(s.contains("structural=3"));
        assert!(s.contains("donated_nodes=12000"));
        assert!(s.contains("pooled_nodes=3000"));
        assert!(s.contains("pool_jobs=9"));
    }

    #[test]
    fn solver_metrics_scoped_summary_and_rollup() {
        let a = SolverMetrics::new();
        a.subproblems.add(2);
        a.bnb_nodes.add(10);
        let b = SolverMetrics::new();
        b.subproblems.add(3);
        b.memo_hits.add(1);
        // The scoped form is the plain summary behind a shard label, so
        // existing token parsers (`contains("delta=")`) still work on it.
        let s = a.summary_for("us-east-1");
        assert!(s.starts_with("shard=us-east-1 "));
        assert!(s.contains("subproblems=2"));
        assert_eq!(&s[s.find(' ').unwrap() + 1..], a.summary());
        let total = SolverMetrics::new();
        total.absorb(&a);
        total.absorb(&b);
        assert_eq!(total.subproblems.get(), 5);
        assert_eq!(total.bnb_nodes.get(), 10);
        assert_eq!(total.memo_hits.get(), 1);
        // Absorbing reads `other` without resetting it.
        assert_eq!(a.subproblems.get(), 2);
    }

    #[test]
    fn solver_metrics_render_feedback_counters() {
        let m = SolverMetrics::new();
        m.feedback_streams.add(7);
        m.degraded_tier_streams.add(2);
        let s = m.summary();
        assert!(s.contains("feedback_streams=7"), "{s}");
        assert!(s.contains("degraded_tiers=2"), "{s}");
        let total = SolverMetrics::new();
        total.absorb(&m);
        total.absorb(&m);
        assert_eq!(total.feedback_streams.get(), 14);
        assert_eq!(total.degraded_tier_streams.get(), 4);
    }

    #[test]
    fn serving_metrics_window_deltas_do_not_disturb_totals() {
        let m = ServingMetrics::new();
        let mut last = MetricsWindow::default();

        m.frames_in.add(10);
        m.frames_analyzed.add(8);
        m.frames_dropped.add(2);
        m.queue_depth.set(5.0);
        let w1 = m.take_window(&mut last);
        assert_eq!(w1.frames_in, 10);
        assert_eq!(w1.frames_analyzed, 8);
        assert_eq!(w1.frames_dropped, 2);
        assert_eq!(w1.queue_depth, 5.0);
        assert!((w1.drop_rate() - 0.2).abs() < 1e-12);

        // Second window sees only the increments, not lifetime totals.
        m.frames_in.add(4);
        m.frames_analyzed.add(4);
        m.queue_depth.set(1.0);
        let w2 = m.take_window(&mut last);
        assert_eq!(w2.frames_in, 4);
        assert_eq!(w2.frames_analyzed, 4);
        assert_eq!(w2.frames_dropped, 0);
        assert_eq!(w2.queue_depth, 1.0);
        assert_eq!(w2.drop_rate(), 0.0);

        // Lifetime counters keep accumulating across take_window calls.
        assert_eq!(m.frames_in.get(), 14);
        assert_eq!(m.frames_dropped.get(), 2);

        // An idle window is not lossy, and an all-drop window is fully lossy.
        let idle = m.take_window(&mut last);
        assert_eq!(idle, MetricsWindow { queue_depth: 1.0, ..MetricsWindow::default() });
        assert_eq!(idle.drop_rate(), 0.0);
        m.frames_in.add(3);
        m.frames_dropped.add(3);
        let lossy = m.take_window(&mut last);
        assert_eq!(lossy.drop_rate(), 1.0);
    }

    #[test]
    fn serving_metrics_reset_clears_everything() {
        let m = ServingMetrics::new();
        m.frames_in.add(5);
        m.frames_dropped.add(1);
        m.queue_depth.set(9.0);
        m.record_batch_size(4);
        m.e2e_latency.record_us(500.0);
        m.reset();
        assert_eq!(m.frames_in.get(), 0);
        assert_eq!(m.frames_dropped.get(), 0);
        assert_eq!(m.batches.get(), 0);
        assert_eq!(m.queue_depth.get(), 0.0);
        assert_eq!(m.e2e_latency.count(), 0);
        assert!(m.e2e_latency.mean_us().is_nan());
        assert!(m.e2e_latency.percentile_us(50.0).is_nan());
        assert!(m.mean_batch_size().is_nan());
        // A reset between snapshots saturates to zero rather than wrapping.
        let mut last = MetricsWindow { frames_in: 100, ..MetricsWindow::default() };
        let w = m.take_window(&mut last);
        assert_eq!(w.frames_in, 0);
    }

    #[test]
    fn serving_metrics_summary_renders() {
        let m = ServingMetrics::new();
        m.frames_in.add(10);
        m.frames_analyzed.add(9);
        m.record_batch_size(3);
        m.e2e_latency.record_us(1500.0);
        let s = m.summary();
        assert!(s.contains("frames_in=10"));
        assert!(s.contains("mean_batch=3.00"));
    }
}
