//! Compile-check stand-in for the vendored `xla` crate.
//!
//! The real PJRT bindings (`xla` / xla_extension 0.5.1) are vendored outside
//! this repository and wired in with `--cfg camflow_vendored_xla` plus a
//! path dependency (see `Cargo.toml`). Without them, this stub provides the
//! exact API surface `engine.rs` uses, so CI can type-check the `pjrt`
//! feature on every PR — the gated runtime/serving layer can no longer rot
//! silently. Every constructor fails at runtime with a clear message; no
//! stubbed computation ever returns fabricated results.

use std::fmt;

/// Error mirroring the vendored crate's error type (`Display` only).
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

type XlaResult<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "xla runtime not vendored: build with --cfg camflow_vendored_xla and the \
         vendored xla crate to execute models"
            .to_string(),
    )
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<Self> {
        Err(unavailable())
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<Self> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> XlaResult<PjRtBuffer> {
        Err(unavailable())
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> XlaResult<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(unavailable())
    }
}
