//! PJRT runtime: load AOT artifacts (HLO text + parameter blobs) and execute
//! the analysis programs from the Rust request path.
//!
//! Python is **never** involved here — `make artifacts` ran once at build
//! time; this module loads `artifacts/manifest.json`, compiles each HLO
//! module on the PJRT CPU client, pre-uploads the parameter buffers, and
//! serves `infer()` calls.

pub mod engine;
pub mod manifest;
#[cfg(not(camflow_vendored_xla))]
mod xla_stub;

pub use engine::{Detections, Engine};
pub use manifest::{Manifest, ModelEntry};
