//! `artifacts/manifest.json` loader (produced by `python -m compile.aot`).

use crate::error::{Error, Result};
use crate::util::json::{self, Value};
use std::path::{Path, PathBuf};

/// One AOT-compiled model variant.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub batch: usize,
    pub hlo_path: PathBuf,
    pub params_path: PathBuf,
    pub param_shapes: Vec<Vec<usize>>,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub flops_per_frame: f64,
}

impl ModelEntry {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }
    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
    pub fn param_len(&self) -> usize {
        self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// The artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub input_size: usize,
    pub num_classes: usize,
    pub num_anchors: usize,
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and resolve artifact paths.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::config(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let v = json::parse(&text)?;
        Self::from_value(&v, dir)
    }

    pub fn from_value(v: &Value, dir: &Path) -> Result<Manifest> {
        let version = v.get_usize("version")?;
        if version != 1 {
            return Err(Error::config(format!("unsupported manifest version {version}")));
        }
        let parse_shape = |val: &Value| -> Result<Vec<usize>> {
            val.as_arr()
                .ok_or_else(|| Error::config("shape is not an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| Error::config("bad shape dim")))
                .collect()
        };
        let mut models = Vec::new();
        for m in v.get_arr("models")? {
            let param_shapes = m
                .get_arr("param_shapes")?
                .iter()
                .map(&parse_shape)
                .collect::<Result<Vec<_>>>()?;
            models.push(ModelEntry {
                name: m.get_str("name")?.to_string(),
                batch: m.get_usize("batch")?,
                hlo_path: dir.join(m.get_str("hlo")?),
                params_path: dir.join(m.get_str("params_bin")?),
                param_shapes,
                input_shape: parse_shape(m.get("input_shape")?)?,
                output_shape: parse_shape(m.get("output_shape")?)?,
                flops_per_frame: m.get_f64("flops_per_frame")?,
            });
        }
        if models.is_empty() {
            return Err(Error::config("manifest has no models"));
        }
        Ok(Manifest {
            input_size: v.get_usize("input_size")?,
            num_classes: v.get_usize("num_classes")?,
            num_anchors: v.get_usize("num_anchors")?,
            models,
        })
    }

    pub fn find(&self, name: &str, batch: usize) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name && m.batch == batch)
    }

    /// Available batch sizes for a model, ascending.
    pub fn batches_for(&self, name: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .models
            .iter()
            .filter(|m| m.name == name)
            .map(|m| m.batch)
            .collect();
        b.sort_unstable();
        b
    }

    /// Smallest available batch >= n, else the largest available.
    pub fn batch_for(&self, name: &str, n: usize) -> Option<usize> {
        let batches = self.batches_for(name);
        batches.iter().copied().find(|&b| b >= n).or(batches.last().copied())
    }
}

/// Load a params .bin (little-endian f32 concat) and split per shape.
pub fn load_params(entry: &ModelEntry) -> Result<Vec<Vec<f32>>> {
    let raw = std::fs::read(&entry.params_path)?;
    if raw.len() % 4 != 0 {
        return Err(Error::config("params bin length not a multiple of 4"));
    }
    let floats: Vec<f32> = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    if floats.len() != entry.param_len() {
        return Err(Error::config(format!(
            "params bin has {} floats, manifest expects {}",
            floats.len(),
            entry.param_len()
        )));
    }
    let mut out = Vec::with_capacity(entry.param_shapes.len());
    let mut off = 0;
    for shape in &entry.param_shapes {
        let n: usize = shape.iter().product();
        out.push(floats[off..off + n].to_vec());
        off += n;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert_eq!(m.input_size, 64);
        assert!(m.find("vgg16", 1).is_some());
        assert!(m.find("zf", 1).is_some());
        for e in &m.models {
            assert!(e.hlo_path.exists(), "{:?}", e.hlo_path);
            assert!(e.params_path.exists());
            assert_eq!(e.input_shape[0], e.batch);
        }
    }

    #[test]
    fn params_blob_round_trips() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        let e = m.find("zf", 1).unwrap();
        let params = load_params(e).unwrap();
        assert_eq!(params.len(), e.param_shapes.len());
        for (p, s) in params.iter().zip(&e.param_shapes) {
            assert_eq!(p.len(), s.iter().product::<usize>());
            assert!(p.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn batch_selection() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert_eq!(m.batches_for("vgg16"), vec![1, 4, 8]);
        assert_eq!(m.batch_for("vgg16", 1), Some(1));
        assert_eq!(m.batch_for("vgg16", 3), Some(4));
        assert_eq!(m.batch_for("vgg16", 100), Some(8));
        assert_eq!(m.batch_for("nonexistent", 1), None);
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
