//! The inference engine: PJRT CPU client + compiled executables + pre-staged
//! parameter buffers.
//!
//! HLO **text** is the interchange format (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id incompatibility between jax ≥ 0.5
//! and xla_extension 0.5.1.

use super::manifest::{load_params, Manifest, ModelEntry};
use crate::error::{Error, Result};
use std::collections::HashMap;

// Without the vendored xla crate the engine compiles against a stub with the
// same API surface (constructors fail at runtime). CI type-checks the pjrt
// feature through this path; `--cfg camflow_vendored_xla` selects the real
// crate.
#[cfg(not(camflow_vendored_xla))]
use super::xla_stub as xla;

/// Raw detections for a batch: `(batch, cells*anchors, 5 + classes)`.
#[derive(Clone, Debug)]
pub struct Detections {
    pub data: Vec<f32>,
    pub shape: [usize; 3],
}

impl Detections {
    /// Objectness score (index 4) of cell `c` in frame `b`.
    pub fn objectness(&self, b: usize, c: usize) -> f32 {
        let stride = self.shape[2];
        self.data[(b * self.shape[1] + c) * stride + 4]
    }

    /// Count of cells whose objectness exceeds `thresh` for frame `b`.
    pub fn count_above(&self, b: usize, thresh: f32) -> usize {
        (0..self.shape[1])
            .filter(|&c| self.objectness(b, c) > thresh)
            .count()
    }
}

struct LoadedModel {
    entry: ModelEntry,
    exe: xla::PjRtLoadedExecutable,
    param_bufs: Vec<xla::PjRtBuffer>,
}

/// The engine. NOT `Sync` (PJRT wrappers hold raw pointers); the serving
/// layer gives each executor thread its own engine.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    models: HashMap<(String, usize), LoadedModel>,
}

impl Engine {
    /// Load every model variant in the manifest.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Self::load_filtered(artifacts_dir, None)
    }

    /// Load only selected (name, batch) variants (None = all). Loading fewer
    /// variants cuts XLA compile time at startup.
    pub fn load_filtered(
        artifacts_dir: impl AsRef<std::path::Path>,
        keep: Option<&[(&str, usize)]>,
    ) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PJRT client: {e}")))?;
        let mut models = HashMap::new();
        for entry in &manifest.models {
            if let Some(keep) = keep {
                if !keep.iter().any(|(n, b)| *n == entry.name && *b == entry.batch) {
                    continue;
                }
            }
            let model = Self::load_model(&client, entry)?;
            models.insert((entry.name.clone(), entry.batch), model);
        }
        if models.is_empty() {
            return Err(Error::config("no model variants loaded"));
        }
        Ok(Engine { client, manifest, models })
    }

    fn load_model(client: &xla::PjRtClient, entry: &ModelEntry) -> Result<LoadedModel> {
        let hlo_path = entry
            .hlo_path
            .to_str()
            .ok_or_else(|| Error::config("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .map_err(|e| Error::runtime(format!("parse {hlo_path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {}: {e}", entry.name)))?;
        // Pre-stage parameters on the device once.
        let params = load_params(entry)?;
        let mut param_bufs = Vec::with_capacity(params.len());
        for (data, shape) in params.iter().zip(&entry.param_shapes) {
            let buf = client
                .buffer_from_host_buffer::<f32>(data, shape, None)
                .map_err(|e| Error::runtime(format!("stage params: {e}")))?;
            param_bufs.push(buf);
        }
        Ok(LoadedModel { entry: entry.clone(), exe, param_bufs })
    }

    pub fn has(&self, name: &str, batch: usize) -> bool {
        self.models.contains_key(&(name.to_string(), batch))
    }

    pub fn loaded_variants(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Run one batch. `frames` must contain exactly `batch × 64 × 64 × 3`
    /// f32 values in [0, 1], NHWC.
    pub fn infer(&self, name: &str, batch: usize, frames: &[f32]) -> Result<Detections> {
        let model = self
            .models
            .get(&(name.to_string(), batch))
            .ok_or_else(|| Error::runtime(format!("model {name} b{batch} not loaded")))?;
        let entry = &model.entry;
        if frames.len() != entry.input_len() {
            return Err(Error::runtime(format!(
                "input has {} floats, {} b{batch} expects {}",
                frames.len(),
                name,
                entry.input_len()
            )));
        }
        let input = self
            .client
            .buffer_from_host_buffer::<f32>(frames, &entry.input_shape, None)
            .map_err(|e| Error::runtime(format!("stage input: {e}")))?;
        let mut args: Vec<&xla::PjRtBuffer> = model.param_bufs.iter().collect();
        args.push(&input);
        let result = model
            .exe
            .execute_b(&args)
            .map_err(|e| Error::runtime(format!("execute: {e}")))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("fetch result: {e}")))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = literal
            .to_tuple1()
            .map_err(|e| Error::runtime(format!("untuple: {e}")))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| Error::runtime(format!("read result: {e}")))?;
        if data.len() != entry.output_len() {
            return Err(Error::runtime(format!(
                "output has {} floats, expected {}",
                data.len(),
                entry.output_len()
            )));
        }
        Ok(Detections {
            data,
            shape: [entry.output_shape[0], entry.output_shape[1], entry.output_shape[2]],
        })
    }

    /// Pad a short frame set up to `batch` frames (repeating the last frame)
    /// and run it; returns detections for the first `n` frames only.
    pub fn infer_padded(
        &self,
        name: &str,
        batch: usize,
        frames: &[f32],
        n: usize,
    ) -> Result<Detections> {
        let per_frame = {
            let entry = self
                .manifest
                .find(name, batch)
                .ok_or_else(|| Error::runtime(format!("unknown model {name} b{batch}")))?;
            entry.input_len() / entry.batch
        };
        if n == 0 || frames.len() != n * per_frame {
            return Err(Error::runtime("bad frame count for infer_padded"));
        }
        let mut padded = frames.to_vec();
        let last = frames[frames.len() - per_frame..].to_vec();
        for _ in n..batch {
            padded.extend_from_slice(&last);
        }
        let mut det = self.infer(name, batch, &padded)?;
        det.shape[0] = n;
        det.data.truncate(n * det.shape[1] * det.shape[2]);
        Ok(det)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Engine {
        Engine::load_filtered(artifacts_dir(), Some(&[("zf", 1), ("zf", 4), ("vgg16", 1)]))
            .expect("engine load")
    }

    fn frame(seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..64 * 64 * 3).map(|_| rng.f32()).collect()
    }

    #[test]
    fn infer_shapes_and_finiteness() {
        let e = engine();
        let det = e.infer("zf", 1, &frame(1)).unwrap();
        assert_eq!(det.shape, [1, 128, 9]); // 8x8 cells x 2 anchors, 5+4
        assert_eq!(det.data.len(), 128 * 9);
        assert!(det.data.iter().all(|v| v.is_finite()));

        let v = e.infer("vgg16", 1, &frame(2)).unwrap();
        assert_eq!(v.shape, [1, 128, 9]);
    }

    #[test]
    fn inference_is_deterministic() {
        let e = engine();
        let f = frame(3);
        let a = e.infer("zf", 1, &f).unwrap();
        let b = e.infer("zf", 1, &f).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn batched_matches_single() {
        let e = engine();
        let f0 = frame(10);
        let f1 = frame(11);
        let mut batch = f0.clone();
        batch.extend_from_slice(&f1);
        batch.extend_from_slice(&f0);
        batch.extend_from_slice(&f1);
        let b = e.infer("zf", 4, &batch).unwrap();
        let s0 = e.infer("zf", 1, &f0).unwrap();
        let s1 = e.infer("zf", 1, &f1).unwrap();
        let stride = 128 * 9;
        for (i, single) in [&s0, &s1, &s0, &s1].iter().enumerate() {
            for j in 0..stride {
                let d = (b.data[i * stride + j] - single.data[j]).abs();
                assert!(d < 1e-4, "frame {i} elem {j}: {d}");
            }
        }
    }

    #[test]
    fn infer_padded_truncates() {
        let e = engine();
        let mut frames = frame(20);
        frames.extend_from_slice(&frame(21));
        let det = e.infer_padded("zf", 4, &frames, 2).unwrap();
        assert_eq!(det.shape[0], 2);
        assert_eq!(det.data.len(), 2 * 128 * 9);
    }

    #[test]
    fn wrong_input_size_rejected() {
        let e = engine();
        assert!(e.infer("zf", 1, &[0.0; 10]).is_err());
        assert!(e.infer("zf", 9, &frame(1)).is_err());
        assert!(e.infer("nope", 1, &frame(1)).is_err());
    }

    #[test]
    fn detections_accessors() {
        let e = engine();
        let det = e.infer("zf", 1, &frame(5)).unwrap();
        let n_hot = det.count_above(0, 0.0);
        assert!(n_hot <= 128);
        let _ = det.objectness(0, 0);
    }
}
