//! Camera database + analysis workloads (the CAM² substrate).
//!
//! The paper's CAM² platform maintains a database of public network cameras
//! (geographic location, frame rate, resolution, snapshot vs video). That
//! data is not redistributable, so this module synthesizes an equivalent
//! database over real city coordinates — the resource manager consumes only
//! the (location, fps, resolution, program) tuple either way.

pub mod scenarios;

use crate::geo::{cities, GeoPoint};
use crate::profiles::{Program, Resolution};
use crate::util::Rng;

/// Video vs snapshot cameras (CAM² supports both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CameraMode {
    Video,
    Snapshot,
}

/// One network camera.
#[derive(Clone, Debug)]
pub struct Camera {
    pub id: u64,
    pub city: String,
    pub location: GeoPoint,
    pub resolution: Resolution,
    /// The camera's native capture rate (fps); analyses may request less.
    pub native_fps: f64,
    pub mode: CameraMode,
}

/// Serving-loop feedback attached to a request by the feedback controller
/// ([`crate::server::feedback`]): the planner's view of *observed* demand.
///
/// The default value is the open-loop contract — demand straight from the
/// offline profiles at the declared fps — and every key/hash downstream
/// (fingerprints, group keys, shard drift signatures) folds these fields in,
/// so publishing a changed observation dirties exactly the affected streams
/// while a zero-delta re-plan stays bit-identical to the declared plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DemandFeedback {
    /// Measured compute cost per frame relative to the offline profile
    /// (EWMA, quantized by the controller before publishing). 1.0 = trust
    /// the profile. Scales only the compute term of the demand vector
    /// ([`crate::profiles::ProgramProfile::demand_cpu_scaled`]).
    pub cost_scale: f64,
    /// Backpressure degrade tier: each tier halves the served rate, so the
    /// effective fps is `desired_fps / 2^shed_tier`. Tier 0 serves the
    /// declared contract. Bounded by the controller's `max_tier`, so a
    /// stream is degraded, never dropped.
    pub shed_tier: u8,
}

impl Default for DemandFeedback {
    fn default() -> Self {
        DemandFeedback { cost_scale: 1.0, shed_tier: 0 }
    }
}

impl DemandFeedback {
    /// True iff this is the open-loop default (no observation published).
    pub fn is_default(&self) -> bool {
        self.cost_scale == 1.0 && self.shed_tier == 0
    }
}

/// An analysis request: run `program` on `camera`'s stream at `desired_fps`.
/// This is the paper's unit of work — the "box" of the packing problem.
#[derive(Clone, Debug)]
pub struct StreamRequest {
    pub camera: Camera,
    pub program: Program,
    pub desired_fps: f64,
    /// Closed-loop observed-demand adjustment (defaults to open-loop).
    /// Deliberately **not** part of [`StreamKey`]: feedback changes demand,
    /// not stream identity, so sticky Expand keeps a degraded stream on its
    /// slot.
    pub feedback: DemandFeedback,
}

impl StreamRequest {
    pub fn new(camera: Camera, program: Program, desired_fps: f64) -> Self {
        assert!(desired_fps > 0.0, "desired_fps must be positive");
        StreamRequest { camera, program, desired_fps, feedback: DemandFeedback::default() }
    }

    /// The fps the planner should actually provision for: the declared rate
    /// shed by the feedback tier. Tier 0 returns `desired_fps` exactly (the
    /// same bits — zero feedback delta must re-plan bit-identically).
    pub fn effective_fps(&self) -> f64 {
        if self.feedback.shed_tier == 0 {
            self.desired_fps
        } else {
            self.desired_fps / f64::from(1u32 << self.feedback.shed_tier.min(30))
        }
    }

    /// Short human label, e.g. "ZF@8.00fps/Tokyo".
    pub fn label(&self) -> String {
        format!(
            "{}@{:.2}fps/{}",
            self.program.name(),
            self.desired_fps,
            self.camera.city
        )
    }
}

/// Stable identity of one stream across re-plans: the full request tuple,
/// not just (camera, program) — the same camera can run the same program at
/// two fps tiers concurrently, and those are distinct streams with distinct
/// host assignments. `occurrence` disambiguates exact duplicates of the
/// whole tuple, so a request slice always yields pairwise-distinct keys.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamKey {
    pub camera_id: u64,
    pub program: &'static str,
    /// Desired fps bit pattern (exact, not rounded: a rate change is a new
    /// stream contract, and its demand vector changes with it).
    pub fps_bits: u64,
    /// Index among requests with an identical (camera, program, fps) tuple,
    /// in slice order.
    pub occurrence: u32,
}

/// Keys for a request slice, aligned by index. Deterministic in slice order.
pub fn stream_keys(requests: &[StreamRequest]) -> Vec<StreamKey> {
    let mut seen: crate::util::FxHashMap<(u64, &'static str, u64), u32> =
        crate::util::FxHashMap::default();
    requests
        .iter()
        .map(|r| {
            let tuple = (r.camera.id, r.program.name(), r.desired_fps.to_bits());
            let occurrence = seen.entry(tuple).or_insert(0);
            let key = StreamKey {
                camera_id: tuple.0,
                program: tuple.1,
                fps_bits: tuple.2,
                occurrence: *occurrence,
            };
            *occurrence += 1;
            key
        })
        .collect()
}

/// The synthetic camera database.
#[derive(Clone, Debug, Default)]
pub struct CameraDb {
    cameras: Vec<Camera>,
}

impl CameraDb {
    pub fn new() -> Self {
        Self::default()
    }

    /// Generate `n` cameras spread over the built-in world cities with
    /// jittered positions and realistic resolution / frame-rate mixes.
    pub fn synthetic(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let resolutions = [
            Resolution::VGA,
            Resolution::XGA,
            Resolution::HD720,
            Resolution::HD900,
            Resolution::FHD,
        ];
        let cameras = (0..n)
            .map(|i| {
                let (city, base) = *rng.choose(cities::ALL);
                // Jitter within ~30 km of the city center.
                let lat = base.lat + rng.normal() * 0.15;
                let lon = base.lon + rng.normal() * 0.15;
                let mode = if rng.bool(0.7) { CameraMode::Video } else { CameraMode::Snapshot };
                let native_fps = match mode {
                    CameraMode::Video => *rng.choose(&[8.0, 15.0, 25.0, 30.0]),
                    CameraMode::Snapshot => rng.range_f64(0.2, 1.0),
                };
                Camera {
                    id: i as u64,
                    city: city.to_string(),
                    location: GeoPoint::new(lat, lon),
                    resolution: *rng.choose(&resolutions),
                    native_fps,
                    mode,
                }
            })
            .collect();
        CameraDb { cameras }
    }

    pub fn push(&mut self, cam: Camera) {
        self.cameras.push(cam);
    }

    pub fn cameras(&self) -> &[Camera] {
        &self.cameras
    }

    pub fn len(&self) -> usize {
        self.cameras.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cameras.is_empty()
    }

    /// Cameras within `radius_km` of a point.
    pub fn near(&self, p: &GeoPoint, radius_km: f64) -> Vec<&Camera> {
        self.cameras
            .iter()
            .filter(|c| c.location.distance_km(p) <= radius_km)
            .collect()
    }

    /// Build an analysis workload: each camera gets `program` at
    /// min(desired_fps, native_fps).
    pub fn workload(&self, program: Program, desired_fps: f64) -> Vec<StreamRequest> {
        self.cameras
            .iter()
            .map(|c| StreamRequest::new(c.clone(), program, desired_fps.min(c.native_fps)))
            .collect()
    }
}

/// Convenience constructor for scenario tables.
pub fn camera_at(id: u64, city: &str, location: GeoPoint, resolution: Resolution, native_fps: f64) -> Camera {
    Camera {
        id,
        city: city.to_string(),
        location,
        resolution,
        native_fps,
        mode: CameraMode::Video,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_db_deterministic() {
        let a = CameraDb::synthetic(20, 7);
        let b = CameraDb::synthetic(20, 7);
        assert_eq!(a.len(), 20);
        for (x, y) in a.cameras().iter().zip(b.cameras()) {
            assert_eq!(x.city, y.city);
            assert_eq!(x.location, y.location);
            assert_eq!(x.resolution, y.resolution);
        }
    }

    #[test]
    fn synthetic_db_has_variety() {
        let db = CameraDb::synthetic(100, 3);
        let cities: std::collections::HashSet<_> =
            db.cameras().iter().map(|c| c.city.clone()).collect();
        assert!(cities.len() > 5);
        let has_video = db.cameras().iter().any(|c| c.mode == CameraMode::Video);
        let has_snap = db.cameras().iter().any(|c| c.mode == CameraMode::Snapshot);
        assert!(has_video && has_snap);
    }

    #[test]
    fn near_filters_by_distance() {
        let db = CameraDb::synthetic(200, 11);
        let near = db.near(&cities::TOKYO, 100.0);
        for c in &near {
            assert!(c.location.distance_km(&cities::TOKYO) <= 100.0);
        }
        let far = db.near(&cities::TOKYO, 20000.0);
        assert_eq!(far.len(), 200);
    }

    #[test]
    fn workload_caps_at_native_fps() {
        let mut db = CameraDb::new();
        db.push(camera_at(0, "X", cities::LONDON, Resolution::VGA, 5.0));
        let w = db.workload(Program::Zf, 30.0);
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].desired_fps, 5.0);
    }

    #[test]
    #[should_panic]
    fn zero_fps_request_rejected() {
        let cam = camera_at(0, "X", cities::LONDON, Resolution::VGA, 5.0);
        let _ = StreamRequest::new(cam, Program::Zf, 0.0);
    }

    #[test]
    fn label_format() {
        let cam = camera_at(0, "Tokyo", cities::TOKYO, Resolution::VGA, 30.0);
        let r = StreamRequest::new(cam, Program::Zf, 8.0);
        assert_eq!(r.label(), "ZF@8.00fps/Tokyo");
    }

    #[test]
    fn effective_fps_halves_per_tier_and_tier_zero_is_exact() {
        let cam = camera_at(0, "Tokyo", cities::TOKYO, Resolution::VGA, 30.0);
        let mut r = StreamRequest::new(cam, Program::Zf, 0.5);
        assert!(r.feedback.is_default());
        assert_eq!(r.effective_fps().to_bits(), 0.5f64.to_bits());
        r.feedback.shed_tier = 1;
        assert_eq!(r.effective_fps(), 0.25);
        r.feedback.shed_tier = 3;
        assert_eq!(r.effective_fps(), 0.0625);
        assert!(r.effective_fps() > 0.0, "degrade must never reach zero fps");
    }

    #[test]
    fn stream_keys_distinguish_fps_tiers_and_duplicates() {
        let cam = camera_at(0, "Tokyo", cities::TOKYO, Resolution::VGA, 30.0);
        let requests = vec![
            StreamRequest::new(cam.clone(), Program::Zf, 1.0),
            StreamRequest::new(cam.clone(), Program::Zf, 8.0), // same camera+program, other tier
            StreamRequest::new(cam, Program::Zf, 1.0),         // exact duplicate of [0]
        ];
        let keys = stream_keys(&requests);
        assert_eq!(keys.len(), 3);
        assert_ne!(keys[0], keys[1], "fps tiers are distinct streams");
        assert_ne!(keys[0], keys[2], "duplicates get distinct occurrences");
        assert_eq!(keys[0].occurrence, 0);
        assert_eq!(keys[2].occurrence, 1);
        // Keys are order-stable: recomputing yields the same alignment.
        assert_eq!(keys, stream_keys(&requests));
    }
}
