//! The paper's evaluation scenarios, expressed as data.
//!
//! * Fig 3 — three scenarios over the CAM² ten-camera testbed: combinations
//!   of VGG16 / ZF at different frame rates and camera counts, evaluated
//!   against the Fig-3 instance pool (the $0.419 c4.2xlarge-class CPU box and
//!   the $0.650 g2.2xlarge GPU box in us-east-2).
//! * Fig 4 — six cameras geographically distributed in America, Europe, and
//!   Asia/Oceania, used for the location-coverage experiment.
//! * Fig 6 — a worldwide workload sweep used to compare NL / ARMVAC / GCL.
//! * Backfill — deferred-analytics queries over stored footage (the
//!   zero-streaming-cameras workload family from PAPERS.md): diurnal-burst
//!   and flash-crowd arrival generators for the spot-market planner.

use super::{camera_at, Camera, StreamRequest};
use crate::geo::cities;
use crate::profiles::{Program, Resolution};
use crate::util::Rng;

/// One Fig-3 scenario: a named set of stream requests.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub requests: Vec<StreamRequest>,
}

/// Expected Fig-3 row for validation: (#non-GPU, #GPU, hourly cost) or Fail.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ExpectedOutcome {
    Selected { non_gpu: usize, gpu: usize, hourly_cost: f64 },
    Fail,
}

/// Fig 3, Scenario 1: VGG16 @0.25 fps ×1 camera + ZF @0.55 fps ×3 cameras.
/// Cameras are 1600x900 street cameras (the CAM² testbed mixes resolutions;
/// resolution per scenario is part of the Fig-3 calibration — DESIGN.md).
pub fn fig3_scenario1() -> Scenario {
    let res = Resolution::HD900;
    let mut requests = vec![StreamRequest::new(
        camera_at(100, "New York", cities::NEW_YORK, res, 30.0),
        Program::Vgg16,
        0.25,
    )];
    for (i, city, loc) in [
        (101u64, "Chicago", cities::CHICAGO),
        (102, "Houston", cities::HOUSTON),
        (103, "West Lafayette", cities::WEST_LAFAYETTE),
    ] {
        requests.push(StreamRequest::new(
            camera_at(i, city, loc, res, 30.0),
            Program::Zf,
            0.55,
        ));
    }
    Scenario { name: "Scenario 1".into(), requests }
}

/// Fig 3, Scenario 2: VGG16 @0.20 ×1 + ZF @0.50 ×1 (1024x768 cameras).
pub fn fig3_scenario2() -> Scenario {
    let res = Resolution::XGA;
    Scenario {
        name: "Scenario 2".into(),
        requests: vec![
            StreamRequest::new(
                camera_at(200, "New York", cities::NEW_YORK, res, 30.0),
                Program::Vgg16,
                0.20,
            ),
            StreamRequest::new(
                camera_at(201, "Chicago", cities::CHICAGO, res, 30.0),
                Program::Zf,
                0.50,
            ),
        ],
    }
}

/// Fig 3, Scenario 3: VGG16 @0.20 ×2 + ZF @8.00 ×10 (1280x720 cameras).
pub fn fig3_scenario3() -> Scenario {
    let res = Resolution::HD720;
    let mut requests = Vec::new();
    for i in 0..2u64 {
        requests.push(StreamRequest::new(
            camera_at(300 + i, "New York", cities::NEW_YORK, res, 30.0),
            Program::Vgg16,
            0.20,
        ));
    }
    for i in 0..10u64 {
        requests.push(StreamRequest::new(
            camera_at(310 + i, "Chicago", cities::CHICAGO, res, 30.0),
            Program::Zf,
            8.0,
        ));
    }
    Scenario { name: "Scenario 3".into(), requests }
}

pub fn fig3_scenarios() -> Vec<Scenario> {
    vec![fig3_scenario1(), fig3_scenario2(), fig3_scenario3()]
}

/// The paper's Fig-3 table, used by tests and the bench to validate output.
/// Rows are (scenario, strategy) -> expected outcome; savings are derived.
pub fn fig3_expected() -> [[ExpectedOutcome; 3]; 3] {
    use ExpectedOutcome::*;
    [
        // Scenario 1: ST1, ST2, ST3
        [
            Selected { non_gpu: 4, gpu: 0, hourly_cost: 1.676 },
            Selected { non_gpu: 0, gpu: 1, hourly_cost: 0.650 },
            Selected { non_gpu: 0, gpu: 1, hourly_cost: 0.650 },
        ],
        // Scenario 2
        [
            Selected { non_gpu: 1, gpu: 0, hourly_cost: 0.419 },
            Selected { non_gpu: 0, gpu: 1, hourly_cost: 0.650 },
            Selected { non_gpu: 1, gpu: 0, hourly_cost: 0.419 },
        ],
        // Scenario 3
        [
            Fail,
            Selected { non_gpu: 0, gpu: 11, hourly_cost: 7.150 },
            Selected { non_gpu: 1, gpu: 10, hourly_cost: 6.919 },
        ],
    ]
}

/// Fig 4: six cameras distributed across America, Europe, Asia, Oceania.
pub fn fig4_cameras() -> Vec<Camera> {
    vec![
        camera_at(400, "New York", cities::NEW_YORK, Resolution::VGA, 30.0),
        camera_at(401, "Los Angeles", cities::LOS_ANGELES, Resolution::VGA, 30.0),
        camera_at(402, "Sao Paulo", cities::SAO_PAULO, Resolution::VGA, 30.0),
        camera_at(403, "London", cities::LONDON, Resolution::VGA, 30.0),
        camera_at(404, "Tokyo", cities::TOKYO, Resolution::VGA, 30.0),
        camera_at(405, "Sydney", cities::SYDNEY, Resolution::VGA, 30.0),
    ]
}

/// Fig 6 workload: `n` cameras weighted toward expensive-region metros
/// (São Paulo, Tokyo, Sydney, Hong Kong) so location choice matters, running
/// a VGG16/ZF mix. All requests share `target_fps` (the sweep variable).
pub fn fig6_workload(n: usize, target_fps: f64, seed: u64) -> Vec<StreamRequest> {
    let mut rng = Rng::new(seed);
    // (city, location, weight): expensive regions get more cameras.
    let sites = [
        ("Sao Paulo", cities::SAO_PAULO, 4.0),
        ("Tokyo", cities::TOKYO, 4.0),
        ("Sydney", cities::SYDNEY, 3.0),
        ("Hong Kong", cities::HONG_KONG, 2.0),
        ("Seoul", cities::SEOUL, 2.0),
        ("London", cities::LONDON, 2.0),
        ("Paris", cities::PARIS, 1.0),
        ("New York", cities::NEW_YORK, 1.0),
        ("Chicago", cities::CHICAGO, 1.0),
        ("Mexico City", cities::MEXICO_CITY, 1.0),
    ];
    let total_w: f64 = sites.iter().map(|s| s.2).sum();
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let mut pick = rng.f64() * total_w;
        let mut site = &sites[0];
        for s in &sites {
            if pick < s.2 {
                site = s;
                break;
            }
            pick -= s.2;
        }
        let program = if rng.bool(0.5) { Program::Vgg16 } else { Program::Zf };
        let res = *rng.choose(&[Resolution::VGA, Resolution::XGA, Resolution::HD720]);
        let cam = camera_at(500 + i as u64, site.0, site.1, res, 30.0);
        requests.push(StreamRequest::new(cam, program, target_fps));
    }
    requests
}

/// A deferred-analytics query: scan `span_hours` of `camera`'s stored
/// footage with `program`, sampling frames at `scan_fps`, with results due
/// `deadline_hours` after the query arrives. Unlike a [`StreamRequest`] the
/// work is latency-tolerant: footage segments are independent, so the
/// planner may run them in any order, in parallel, and — when `preemptible`
/// — on revocable spot capacity.
#[derive(Clone, Debug)]
pub struct BackfillQuery {
    pub id: u64,
    pub camera: Camera,
    pub program: Program,
    /// Stored-footage span to scan, in hours.
    pub span_hours: f64,
    /// Frame sampling rate over the stored footage (fps), the same knob as
    /// a live stream's desired fps — it sets the per-unit demand vector.
    pub scan_fps: f64,
    /// Hours from arrival until results are due.
    pub deadline_hours: f64,
    /// Hour index (from trace start) at which the query arrives.
    pub arrival_hour: usize,
    /// False pins the query to non-revocable (slack / on-demand) capacity.
    pub preemptible: bool,
}

/// Diurnal-burst backfill arrivals over a 24-hour trace: overnight-buffered
/// footage lands as a morning query burst (hours 6–10) with a smaller
/// evening review burst (hours 18–22), scattered low-rate stragglers in
/// between. Deadlines are loose (4–12 h) and most queries are preemptible —
/// the workload spot markets are priced for. Deterministic in `seed`.
pub fn diurnal_backfill(n: usize, seed: u64) -> Vec<BackfillQuery> {
    let mut rng = Rng::new(seed);
    let mut queries = Vec::with_capacity(n);
    for i in 0..n {
        let arrival_hour = if rng.bool(0.55) {
            6 + rng.index(5) // morning burst: 6..=10
        } else if rng.bool(0.6) {
            18 + rng.index(5) // evening burst: 18..=22
        } else {
            rng.index(24) // stragglers
        };
        let res = *rng.choose(&[Resolution::VGA, Resolution::XGA, Resolution::HD720]);
        let cam = camera_at(9000 + i as u64, "Chicago", cities::CHICAGO, res, 30.0);
        let program = if rng.bool(0.25) { Program::Vgg16 } else { Program::Zf };
        queries.push(BackfillQuery {
            id: i as u64,
            camera: cam,
            program,
            span_hours: 1.0 + rng.index(8) as f64,
            scan_fps: rng.range_f64(0.2, 1.0),
            deadline_hours: 4.0 + rng.index(9) as f64,
            arrival_hour,
            preemptible: rng.bool(0.8),
        });
    }
    queries
}

/// Flash-crowd backfill: an incident at `event_hour` triggers a dense burst
/// of tight-deadline queries re-scanning the hours of footage leading up to
/// it. Deadlines are 1–3 h and fewer queries tolerate preemption — the
/// adversarial case for deadline-feasibility checking and explicit shedding.
/// Deterministic in `seed`.
pub fn flash_crowd_backfill(n: usize, event_hour: usize, seed: u64) -> Vec<BackfillQuery> {
    let mut rng = Rng::new(seed);
    let mut queries = Vec::with_capacity(n);
    for i in 0..n {
        let res = *rng.choose(&[Resolution::XGA, Resolution::HD720]);
        let cam = camera_at(9500 + i as u64, "New York", cities::NEW_YORK, res, 30.0);
        queries.push(BackfillQuery {
            id: 10_000 + i as u64,
            camera: cam,
            program: if rng.bool(0.5) { Program::Vgg16 } else { Program::Zf },
            span_hours: 2.0 + rng.index(5) as f64,
            scan_fps: rng.range_f64(0.5, 2.0),
            deadline_hours: 1.0 + rng.index(3) as f64,
            arrival_hour: event_hour + rng.index(2),
            preemptible: rng.bool(0.6),
        });
    }
    queries
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_scenario_shapes() {
        let s = fig3_scenarios();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].requests.len(), 4); // 1 VGG + 3 ZF
        assert_eq!(s[1].requests.len(), 2);
        assert_eq!(s[2].requests.len(), 12); // 2 VGG + 10 ZF
    }

    #[test]
    fn fig3_scenario_programs_and_rates() {
        let s1 = fig3_scenario1();
        assert_eq!(s1.requests[0].program, Program::Vgg16);
        assert_eq!(s1.requests[0].desired_fps, 0.25);
        assert!(s1.requests[1..].iter().all(|r| r.program == Program::Zf));
        assert!(s1.requests[1..].iter().all(|r| r.desired_fps == 0.55));

        let s3 = fig3_scenario3();
        let zf8 = s3
            .requests
            .iter()
            .filter(|r| r.program == Program::Zf && r.desired_fps == 8.0)
            .count();
        assert_eq!(zf8, 10);
    }

    #[test]
    fn fig4_six_cameras_three_continents() {
        let cams = fig4_cameras();
        assert_eq!(cams.len(), 6);
        // America (lon < -30), Europe (-30..60), Asia/Oceania (> 60).
        assert!(cams.iter().any(|c| c.location.lon < -30.0));
        assert!(cams.iter().any(|c| (-30.0..60.0).contains(&c.location.lon)));
        assert!(cams.iter().any(|c| c.location.lon > 60.0));
    }

    #[test]
    fn fig6_workload_deterministic_and_sized() {
        let a = fig6_workload(50, 4.0, 1);
        let b = fig6_workload(50, 4.0, 1);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.camera.city, y.camera.city);
            assert_eq!(x.program, y.program);
        }
        assert!(a.iter().all(|r| r.desired_fps == 4.0));
        // Both programs present.
        assert!(a.iter().any(|r| r.program == Program::Vgg16));
        assert!(a.iter().any(|r| r.program == Program::Zf));
    }

    #[test]
    fn diurnal_backfill_deterministic_bursty_and_mostly_preemptible() {
        let a = diurnal_backfill(120, 7);
        let b = diurnal_backfill(120, 7);
        assert_eq!(a.len(), 120);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_hour, y.arrival_hour);
            assert_eq!(x.span_hours, y.span_hours);
            assert_eq!(x.preemptible, y.preemptible);
        }
        assert!(a.iter().all(|q| q.arrival_hour < 24));
        assert!(a.iter().all(|q| q.span_hours >= 1.0 && q.deadline_hours >= 4.0));
        let morning = a.iter().filter(|q| (6..=10).contains(&q.arrival_hour)).count();
        assert!(morning * 2 > a.len(), "morning burst dominates: {morning}/120");
        let preemptible = a.iter().filter(|q| q.preemptible).count();
        assert!(preemptible * 2 > a.len(), "most queries tolerate preemption");
    }

    #[test]
    fn flash_crowd_backfill_is_tight_and_clustered() {
        let q = flash_crowd_backfill(40, 13, 3);
        assert_eq!(q.len(), 40);
        assert!(q.iter().all(|x| x.arrival_hour == 13 || x.arrival_hour == 14));
        assert!(q.iter().all(|x| (1.0..=3.0).contains(&x.deadline_hours)));
        assert!(q.iter().any(|x| !x.preemptible) && q.iter().any(|x| x.preemptible));
    }

    #[test]
    fn fig3_expected_cost_identity() {
        // 4 x 0.419 = 1.676 and 11 x 0.650 = 7.150, as in the paper.
        assert!((4.0_f64 * 0.419 - 1.676).abs() < 1e-9);
        assert!((11.0_f64 * 0.650 - 7.150).abs() < 1e-9);
        assert!((0.419_f64 + 10.0 * 0.650 - 6.919).abs() < 1e-9);
    }
}
