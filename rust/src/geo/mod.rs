//! Geography + network model: distances, RTT, and the frame-rate/RTT coupling.
//!
//! The paper (following Chen et al. \[5\]) observes that the achievable frame
//! rate of a camera→instance stream drops as the network round-trip time
//! grows: frames are fetched request/response, so the fetch loop completes at
//! most ~1/RTT iterations per second (plus protocol overhead). We model:
//!
//! * distance: haversine great-circle km,
//! * RTT: `RTT_ms = BASE + distance_km * MS_PER_100KM / 100` — a straight-line
//!   fiber model with a routing-inflation factor, calibrated so NY↔London
//!   (~5 570 km) lands near the observed ~75 ms,
//! * frame-rate cap: `fps_max(RTT) = FPS_K / RTT_ms` (Chen et al.'s inverse
//!   relationship), hence a *desired* fps implies a *maximum acceptable RTT*
//!   `rtt_budget(fps) = FPS_K / fps` and therefore a coverage circle around
//!   each camera (Fig 4).

/// Fixed per-hop/protocol RTT overhead (ms).
pub const RTT_BASE_MS: f64 = 2.0;
/// RTT milliseconds added per 100 km of great-circle distance. Speed of light
/// in fiber is ~100 km/ms one-way (0.5 ms RTT per 100 km); 1.3 ms per 100 km
/// RTT accounts for routing inflation (~1.3x straight-line).
pub const RTT_MS_PER_100KM: f64 = 1.3;
/// Frame-rate constant: fps_max * RTT_ms ≈ FPS_K (Chen et al. \[5\] shape;
/// the runtime pipelines a handful of parallel fetches per stream, so the
/// achievable rate is several frames per round trip).
pub const FPS_K: f64 = 1200.0;

/// Mean Earth radius (km).
const EARTH_RADIUS_KM: f64 = 6371.0;

/// A point on the globe (degrees).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GeoPoint {
    pub lat: f64,
    pub lon: f64,
}

impl GeoPoint {
    pub const fn new(lat: f64, lon: f64) -> Self {
        GeoPoint { lat, lon }
    }

    /// Great-circle distance in km (haversine).
    pub fn distance_km(&self, other: &GeoPoint) -> f64 {
        let (la1, lo1) = (self.lat.to_radians(), self.lon.to_radians());
        let (la2, lo2) = (other.lat.to_radians(), other.lon.to_radians());
        let dlat = la2 - la1;
        let dlon = lo2 - lo1;
        let a = (dlat / 2.0).sin().powi(2) + la1.cos() * la2.cos() * (dlon / 2.0).sin().powi(2);
        2.0 * EARTH_RADIUS_KM * a.sqrt().asin()
    }

    /// Modeled round-trip time to another point (ms).
    pub fn rtt_ms(&self, other: &GeoPoint) -> f64 {
        rtt_for_distance_km(self.distance_km(other))
    }
}

/// RTT (ms) for a given great-circle distance.
pub fn rtt_for_distance_km(d_km: f64) -> f64 {
    RTT_BASE_MS + d_km * RTT_MS_PER_100KM / 100.0
}

/// Maximum achievable frame rate over a link with the given RTT.
pub fn fps_cap(rtt_ms: f64) -> f64 {
    FPS_K / rtt_ms.max(RTT_BASE_MS)
}

/// Maximum acceptable RTT (ms) for a desired frame rate — the Fig-4 circle.
pub fn rtt_budget_ms(fps: f64) -> f64 {
    assert!(fps > 0.0, "fps must be positive");
    FPS_K / fps
}

/// Radius (km) of the Fig-4 coverage circle for a desired frame rate:
/// the farthest an instance may be while still sustaining `fps`.
pub fn coverage_radius_km(fps: f64) -> f64 {
    let budget = rtt_budget_ms(fps);
    if budget <= RTT_BASE_MS {
        return 0.0;
    }
    (budget - RTT_BASE_MS) * 100.0 / RTT_MS_PER_100KM
}

/// True iff an instance at `site` can serve a camera at `cam` at `fps`.
pub fn reachable(cam: &GeoPoint, site: &GeoPoint, fps: f64) -> bool {
    cam.rtt_ms(site) <= rtt_budget_ms(fps) + 1e-9
}

/// Well-known city coordinates used by scenarios, tests, and benches.
pub mod cities {
    use super::GeoPoint;

    pub const NEW_YORK: GeoPoint = GeoPoint::new(40.71, -74.01);
    pub const LOS_ANGELES: GeoPoint = GeoPoint::new(34.05, -118.24);
    pub const CHICAGO: GeoPoint = GeoPoint::new(41.88, -87.63);
    pub const HOUSTON: GeoPoint = GeoPoint::new(29.76, -95.37);
    pub const WEST_LAFAYETTE: GeoPoint = GeoPoint::new(40.43, -86.91);
    pub const SAO_PAULO: GeoPoint = GeoPoint::new(-23.55, -46.63);
    pub const LONDON: GeoPoint = GeoPoint::new(51.51, -0.13);
    pub const PARIS: GeoPoint = GeoPoint::new(48.86, 2.35);
    pub const BERLIN: GeoPoint = GeoPoint::new(52.52, 13.41);
    pub const MADRID: GeoPoint = GeoPoint::new(40.42, -3.70);
    pub const ROME: GeoPoint = GeoPoint::new(41.90, 12.50);
    pub const MOSCOW: GeoPoint = GeoPoint::new(55.76, 37.62);
    pub const CAIRO: GeoPoint = GeoPoint::new(30.04, 31.24);
    pub const MUMBAI: GeoPoint = GeoPoint::new(19.08, 72.88);
    pub const SINGAPORE: GeoPoint = GeoPoint::new(1.35, 103.82);
    pub const HONG_KONG: GeoPoint = GeoPoint::new(22.32, 114.17);
    pub const TOKYO: GeoPoint = GeoPoint::new(35.68, 139.69);
    pub const SEOUL: GeoPoint = GeoPoint::new(37.57, 126.98);
    pub const SYDNEY: GeoPoint = GeoPoint::new(-33.87, 151.21);
    pub const MEXICO_CITY: GeoPoint = GeoPoint::new(19.43, -99.13);

    pub const ALL: &[(&str, GeoPoint)] = &[
        ("New York", NEW_YORK),
        ("Los Angeles", LOS_ANGELES),
        ("Chicago", CHICAGO),
        ("Houston", HOUSTON),
        ("West Lafayette", WEST_LAFAYETTE),
        ("Sao Paulo", SAO_PAULO),
        ("London", LONDON),
        ("Paris", PARIS),
        ("Berlin", BERLIN),
        ("Madrid", MADRID),
        ("Rome", ROME),
        ("Moscow", MOSCOW),
        ("Cairo", CAIRO),
        ("Mumbai", MUMBAI),
        ("Singapore", SINGAPORE),
        ("Hong Kong", HONG_KONG),
        ("Tokyo", TOKYO),
        ("Seoul", SEOUL),
        ("Sydney", SYDNEY),
        ("Mexico City", MEXICO_CITY),
    ];
}

#[cfg(test)]
mod tests {
    use super::cities::*;
    use super::*;

    #[test]
    fn haversine_known_distances() {
        // NY <-> London ~5 570 km; NY <-> LA ~3 940 km; London <-> Paris ~344 km.
        let d = NEW_YORK.distance_km(&LONDON);
        assert!((d - 5570.0).abs() < 60.0, "NY-London {d}");
        let d = NEW_YORK.distance_km(&LOS_ANGELES);
        assert!((d - 3940.0).abs() < 60.0, "NY-LA {d}");
        let d = LONDON.distance_km(&PARIS);
        assert!((d - 344.0).abs() < 15.0, "London-Paris {d}");
    }

    #[test]
    fn distance_symmetric_and_zero_on_self() {
        let d1 = TOKYO.distance_km(&SYDNEY);
        let d2 = SYDNEY.distance_km(&TOKYO);
        assert!((d1 - d2).abs() < 1e-9);
        assert!(TOKYO.distance_km(&TOKYO) < 1e-9);
    }

    #[test]
    fn rtt_ny_london_realistic() {
        let rtt = NEW_YORK.rtt_ms(&LONDON);
        assert!((60.0..100.0).contains(&rtt), "rtt={rtt}");
    }

    #[test]
    fn fps_cap_decreases_with_rtt() {
        assert!(fps_cap(10.0) > fps_cap(50.0));
        assert!(fps_cap(50.0) > fps_cap(200.0));
    }

    #[test]
    fn rtt_budget_inverse_of_fps_cap() {
        for fps in [0.5, 1.0, 5.0, 20.0] {
            let budget = rtt_budget_ms(fps);
            assert!((fps_cap(budget) - fps).abs() < 1e-9);
        }
    }

    #[test]
    fn coverage_circle_shrinks_with_fps() {
        // Fig 4: higher desired fps -> smaller circle.
        let r_high = coverage_radius_km(20.0);
        let r_low = coverage_radius_km(3.0);
        assert!(r_high < r_low);
        assert!(r_high > 0.0);
    }

    #[test]
    fn reachable_respects_circle() {
        // At 20 fps budget is 20 ms -> radius ~1 385 km: NY cannot reach London.
        assert!(!reachable(&NEW_YORK, &LONDON, 20.0));
        // At 1 fps budget is 400 ms -> everywhere on Earth reachable.
        assert!(reachable(&NEW_YORK, &SYDNEY, 1.0));
        // Nearby always reachable at moderate rates.
        assert!(reachable(&LONDON, &PARIS, 20.0));
    }

    #[test]
    fn fig4_circle_radii_bracket_the_regimes() {
        // At 30 fps the circle is continental-scale (~3 000 km): London
        // cannot reach an instance in Virginia. At 2 fps the circle spans
        // most of the planet.
        let r_high = coverage_radius_km(30.0);
        assert!(r_high < LONDON.distance_km(&NEW_YORK));
        let r_low = coverage_radius_km(2.0);
        assert!(r_low > TOKYO.distance_km(&NEW_YORK));
    }
}
