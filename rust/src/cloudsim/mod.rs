//! Simulated cloud provider (the AWS/Azure stand-in).
//!
//! The paper's experiments provision real EC2 instances; this simulator
//! reproduces the parts the resource manager interacts with: provisioning
//! with boot latency, hourly billing, per-dimension load/utilization
//! tracking, and the >90%-utilization performance degradation the 90% rule
//! guards against. Driven by `bench_adaptive`, `examples/adaptive_day`, and
//! the serving layer.

use crate::catalog::{Catalog, Dims};
use crate::coordinator::{Plan, SlotId};
use crate::error::{Error, Result};
use crate::util::Rng;

/// Boot latency of a fresh instance (seconds). EC2-era instances took on the
/// order of a minute to become available.
pub const DEFAULT_BOOT_DELAY_S: f64 = 60.0;

/// Reclaim notice for a revoked spot instance (seconds): the provider gives
/// two minutes of warning before pulling a spot instance, and the simulator
/// keeps billing (and the instance keeps working) until the notice expires.
pub const SPOT_WARNING_S: f64 = 120.0;

/// Throughput factor once any dimension exceeds the degradation threshold
/// (the paper: "when any dimension is more than 90% utilized, the
/// performance starts to degrade").
pub const DEGRADATION_THRESHOLD: f64 = 0.90;

/// Instance id.
pub type InstanceId = u64;

/// One simulated instance.
#[derive(Clone, Debug)]
pub struct SimInstance {
    pub id: InstanceId,
    pub type_idx: usize,
    pub region_idx: usize,
    pub label: String,
    pub hourly_usd: f64,
    pub launched_at: f64,
    pub ready_at: f64,
    pub terminated_at: Option<f64>,
    /// True for spot-market instances: billed at the catalog's spot quote
    /// and revocable by the provider ([`CloudSim::revoke`]).
    pub is_spot: bool,
    /// Pending revocation deadline (absolute sim time): the instance dies
    /// when the clock reaches it. `None` while the instance is unrevoked.
    pub revoke_at: Option<f64>,
    /// Current resource load (set by the serving layer / plan application).
    pub load: Dims,
    pub capacity: Dims,
}

impl SimInstance {
    pub fn alive(&self) -> bool {
        self.terminated_at.is_none()
    }

    pub fn ready(&self, now: f64) -> bool {
        self.alive() && now >= self.ready_at
    }

    pub fn utilization(&self) -> f64 {
        let u = self.load.max_utilization(&self.capacity);
        if u.is_finite() {
            u
        } else {
            1.0
        }
    }

    /// Effective throughput multiplier: 1.0 below the threshold, then a
    /// linear penalty down to 0.5 at 100% (saturating).
    pub fn degradation_factor(&self) -> f64 {
        let u = self.utilization();
        if u <= DEGRADATION_THRESHOLD {
            1.0
        } else {
            let over = ((u - DEGRADATION_THRESHOLD) / (1.0 - DEGRADATION_THRESHOLD)).min(1.0);
            1.0 - 0.5 * over
        }
    }
}

/// The simulator.
pub struct CloudSim {
    pub catalog: Catalog,
    pub boot_delay_s: f64,
    clock_s: f64,
    next_id: InstanceId,
    instances: Vec<SimInstance>,
    /// id → index into `instances`: long-running adaptive simulations
    /// accumulate an unbounded terminated-instance history, so per-id
    /// lookups must not scan it.
    by_id: std::collections::BTreeMap<InstanceId, usize>,
    /// Plan slot → provisioned instance, remembered across `apply_plan`
    /// calls so a surviving planned slot keeps its physical instance.
    bindings: std::collections::BTreeMap<SlotId, InstanceId>,
    /// Slots owned by each shard's most recently applied plan
    /// (`apply_shard_plan`), so shard-scoped reconciliation bounds its
    /// same-label claims and terminations to that shard's own fleet.
    shard_slots: std::collections::BTreeMap<u32, Vec<SlotId>>,
    accrued_usd: f64,
}

impl CloudSim {
    pub fn new(catalog: Catalog) -> Self {
        CloudSim {
            catalog,
            boot_delay_s: DEFAULT_BOOT_DELAY_S,
            clock_s: 0.0,
            next_id: 0,
            instances: Vec::new(),
            by_id: std::collections::BTreeMap::new(),
            bindings: std::collections::BTreeMap::new(),
            shard_slots: std::collections::BTreeMap::new(),
            accrued_usd: 0.0,
        }
    }

    pub fn now(&self) -> f64 {
        self.clock_s
    }

    /// Advance the clock, accruing cost for every alive instance
    /// (billing is linear $/hour, as the paper's hourly prices). An
    /// instance whose revocation deadline falls inside the step is billed
    /// only up to the deadline, then terminated at exactly that time.
    pub fn advance(&mut self, dt_s: f64) {
        assert!(dt_s >= 0.0);
        let now = self.clock_s;
        let end = now + dt_s;
        let mut accrued = 0.0;
        for inst in &mut self.instances {
            if !inst.alive() {
                continue;
            }
            match inst.revoke_at {
                Some(t) if t <= end => {
                    accrued += inst.hourly_usd * (t - now).max(0.0) / 3600.0;
                    inst.terminated_at = Some(t);
                    inst.load = Dims::default();
                }
                _ => accrued += inst.hourly_usd * dt_s / 3600.0,
            }
        }
        self.accrued_usd += accrued;
        self.clock_s = end;
    }

    /// Provision an instance of `type_idx` in `region_idx` at the
    /// on-demand price.
    pub fn provision(&mut self, type_idx: usize, region_idx: usize) -> Result<InstanceId> {
        let price = self
            .catalog
            .price(type_idx, region_idx)
            .ok_or_else(|| {
                Error::config(format!(
                    "no offering for type {type_idx} in region {region_idx}"
                ))
            })?;
        Ok(self.provision_with(type_idx, region_idx, price, false))
    }

    /// Provision a **spot** instance of `type_idx` in `region_idx`, billed
    /// at the catalog's spot quote. Fails when the offering carries no spot
    /// pool. The instance runs like any other until the provider revokes it
    /// ([`CloudSim::revoke`]).
    pub fn provision_spot(&mut self, type_idx: usize, region_idx: usize) -> Result<InstanceId> {
        let price = self.catalog.spot_price(type_idx, region_idx).ok_or_else(|| {
            Error::config(format!(
                "no spot pool for type {type_idx} in region {region_idx}"
            ))
        })?;
        Ok(self.provision_with(type_idx, region_idx, price, true))
    }

    fn provision_with(
        &mut self,
        type_idx: usize,
        region_idx: usize,
        hourly_usd: f64,
        is_spot: bool,
    ) -> InstanceId {
        let ty = &self.catalog.types[type_idx];
        let rg = &self.catalog.regions[region_idx];
        let id = self.next_id;
        self.next_id += 1;
        self.by_id.insert(id, self.instances.len());
        self.instances.push(SimInstance {
            id,
            type_idx,
            region_idx,
            label: format!("{}@{}", ty.name, rg.id),
            hourly_usd,
            launched_at: self.clock_s,
            ready_at: self.clock_s + self.boot_delay_s,
            terminated_at: None,
            is_spot,
            revoke_at: None,
            load: Dims::default(),
            capacity: ty.capacity,
        });
        id
    }

    /// The provider reclaims a spot instance: it keeps running (and
    /// billing) for `warning_s` more seconds, then terminates during the
    /// [`advance`](CloudSim::advance) step that crosses the deadline.
    /// Revoking an already-revoked instance keeps the earlier deadline;
    /// revoking an on-demand instance is an error (terminate those).
    pub fn revoke(&mut self, id: InstanceId, warning_s: f64) -> Result<()> {
        let now = self.clock_s;
        let inst = self.get_alive_mut(id)?;
        if !inst.is_spot {
            return Err(Error::config(format!(
                "instance {id} is on-demand; revocation is a spot-market event"
            )));
        }
        let at = now + warning_s.max(0.0);
        inst.revoke_at = Some(inst.revoke_at.map_or(at, |prev| prev.min(at)));
        Ok(())
    }

    /// The instance with `id` iff it is alive.
    fn get_alive_mut(&mut self, id: InstanceId) -> Result<&mut SimInstance> {
        let idx = self.by_id.get(&id).copied();
        match idx {
            Some(i) if self.instances[i].alive() => Ok(&mut self.instances[i]),
            _ => Err(Error::config(format!("instance {id} not alive"))),
        }
    }

    pub fn terminate(&mut self, id: InstanceId) -> Result<()> {
        let now = self.clock_s;
        let inst = self.get_alive_mut(id)?;
        inst.terminated_at = Some(now);
        inst.load = Dims::default();
        Ok(())
    }

    pub fn set_load(&mut self, id: InstanceId, load: Dims) -> Result<()> {
        let inst = self.get_alive_mut(id)?;
        inst.load = load;
        Ok(())
    }

    pub fn get(&self, id: InstanceId) -> Option<&SimInstance> {
        self.by_id.get(&id).map(|&idx| &self.instances[idx])
    }

    pub fn alive(&self) -> Vec<&SimInstance> {
        self.instances.iter().filter(|i| i.alive()).collect()
    }

    pub fn accrued_usd(&self) -> f64 {
        self.accrued_usd
    }

    /// Hourly burn rate of the current fleet.
    pub fn hourly_rate(&self) -> f64 {
        self.instances
            .iter()
            .filter(|i| i.alive())
            .map(|i| i.hourly_usd)
            .sum()
    }

    /// Mean [`SimInstance::utilization`] over the alive fleet (0.0 when
    /// empty). Meaningful after loads were set — by the serving layer or
    /// by [`set_plan_loads`](CloudSim::set_plan_loads).
    pub fn fleet_utilization(&self) -> f64 {
        let alive: Vec<_> = self.instances.iter().filter(|i| i.alive()).collect();
        if alive.is_empty() {
            return 0.0;
        }
        alive.iter().map(|i| i.utilization()).sum::<f64>() / alive.len() as f64
    }

    /// Set each plan-bound instance's load gauge from the workload it
    /// hosts, at the *feedback-adjusted* demand: delivered fps
    /// ([`Plan::delivered_fps`], which honours degrade tiers) and the
    /// published `cost_scale` — so after a closed-loop re-plan the fleet's
    /// utilization reflects observed demand, not the declared profile. The
    /// plan must have been applied first (`apply_plan` binds slots).
    pub fn set_plan_loads(
        &mut self,
        plan: &Plan,
        requests: &[crate::cameras::StreamRequest],
    ) -> Result<()> {
        let fps = plan.delivered_fps(requests);
        for inst in &plan.instances {
            let id = *self
                .bindings
                .get(&inst.slot_id)
                .ok_or_else(|| Error::config(format!("slot {} not bound", inst.slot_id)))?;
            let mut load = Dims::default();
            for &s in &inst.streams {
                let r = &requests[s];
                let p = r.program.profile();
                let d = if inst.has_gpu {
                    let mut d =
                        p.demand_gpu_scaled(fps[s], r.camera.resolution, r.feedback.cost_scale);
                    d.gpus /= self.catalog.types[inst.type_idx].gpu_speed;
                    d
                } else {
                    p.demand_cpu_scaled(fps[s], r.camera.resolution, r.feedback.cost_scale)
                };
                load = load.add(&d);
            }
            self.set_load(id, load)?;
        }
        Ok(())
    }

    /// Reconcile the fleet with a plan: keep surviving instances, terminate
    /// surplus ones, provision the rest. Returns ids aligned with
    /// `plan.instances` order.
    ///
    /// Matching is **id-stable**: a planned slot that was bound to a
    /// physical instance by a previous `apply_plan` keeps that instance
    /// (same [`SlotId`], same label, still alive). Unbound planned
    /// instances then claim remaining same-label instances oldest-id-first
    /// — a deterministic FIFO, so applying the same plan twice yields the
    /// same ids (the old LIFO label pool could permute them).
    ///
    /// Binding is keyed purely by slot id + label, never by plan order or
    /// by which planner produced the plan — so a portfolio winner flip
    /// whose plan carries the same slots (seeded continuity,
    /// `coordinator::portfolio`) reuses the same physical instances with
    /// zero provisioning.
    pub fn apply_plan(&mut self, plan: &Plan) -> Result<Vec<InstanceId>> {
        let mut assigned: Vec<Option<InstanceId>> = vec![None; plan.instances.len()];
        let mut claimed: std::collections::BTreeSet<InstanceId> =
            std::collections::BTreeSet::new();
        // Pass 1: stable slot bindings.
        for (pi, planned) in plan.instances.iter().enumerate() {
            if let Some(&id) = self.bindings.get(&planned.slot_id) {
                let matches = self
                    .get(id)
                    .is_some_and(|inst| inst.alive() && inst.label == planned.label);
                if matches && claimed.insert(id) {
                    assigned[pi] = Some(id);
                }
            }
        }
        // Pass 2: same-label claims, oldest id first (`instances` is in
        // provision order, so per-label queues come out id-ascending). Spot
        // instances are invisible here: the live plan may never claim
        // revocable capacity, and the global apply must not terminate the
        // backfill layer's spot fleet as "surplus".
        let mut pool: std::collections::BTreeMap<&str, std::collections::VecDeque<InstanceId>> =
            std::collections::BTreeMap::new();
        for inst in self
            .instances
            .iter()
            .filter(|i| i.alive() && !i.is_spot && !claimed.contains(&i.id))
        {
            pool.entry(inst.label.as_str()).or_default().push_back(inst.id);
        }
        for (pi, planned) in plan.instances.iter().enumerate() {
            if assigned[pi].is_none() {
                if let Some(id) = pool.get_mut(planned.label.as_str()).and_then(|v| v.pop_front())
                {
                    claimed.insert(id);
                    assigned[pi] = Some(id);
                }
            }
        }
        // Terminate unclaimed leftovers.
        let leftovers: Vec<InstanceId> = pool.values().flatten().copied().collect();
        for id in leftovers {
            self.terminate(id)?;
        }
        // Provision the gaps and rebind slots. A *global* apply owns the
        // whole fleet, so it also resets any per-shard slot tracking — the
        // two reconciliation modes do not mix within one binding epoch.
        let ids: Vec<InstanceId> = plan
            .instances
            .iter()
            .zip(assigned)
            .map(|(planned, slot)| match slot {
                Some(id) => Ok(id),
                None => self.provision(planned.type_idx, planned.region_idx),
            })
            .collect::<Result<_>>()?;
        self.bindings.clear();
        self.shard_slots.clear();
        for (planned, &id) in plan.instances.iter().zip(&ids) {
            self.bindings.insert(planned.slot_id, id);
        }
        // Set loads from the plan's packing.
        let loads: Vec<Dims> = plan
            .packing
            .bins
            .iter()
            .map(|b| b.total_demand(&plan.problem))
            .collect();
        for (id, load) in ids.iter().zip(loads) {
            self.set_load(*id, load)?;
        }
        Ok(ids)
    }

    /// Shard-scoped [`apply_plan`](CloudSim::apply_plan): reconcile `plan`
    /// against only the fleet `shard`'s previous shard-scoped apply owns.
    /// Slot bindings still match globally (slot ids are process-unique, so
    /// a surviving slot reclaims its instance no matter which epoch bound
    /// it), but the same-label FIFO and the surplus terminations are
    /// restricted to the shard's own instances — another shard's fleet is
    /// never claimed or terminated, which is what lets the sharded planner
    /// apply per-shard plans in any order and only for dirty shards.
    pub fn apply_shard_plan(&mut self, shard: u32, plan: &Plan) -> Result<Vec<InstanceId>> {
        let prev_slots: Vec<SlotId> = self.shard_slots.get(&shard).cloned().unwrap_or_default();
        let owned: std::collections::BTreeSet<InstanceId> = prev_slots
            .iter()
            .filter_map(|s| self.bindings.get(s).copied())
            .filter(|&id| self.get(id).is_some_and(SimInstance::alive))
            .collect();
        let mut assigned: Vec<Option<InstanceId>> = vec![None; plan.instances.len()];
        let mut claimed: std::collections::BTreeSet<InstanceId> =
            std::collections::BTreeSet::new();
        // Pass 1: stable slot bindings (global — see above).
        for (pi, planned) in plan.instances.iter().enumerate() {
            if let Some(&id) = self.bindings.get(&planned.slot_id) {
                let matches = self
                    .get(id)
                    .is_some_and(|inst| inst.alive() && inst.label == planned.label);
                if matches && claimed.insert(id) {
                    assigned[pi] = Some(id);
                }
            }
        }
        // Pass 2: same-label claims, oldest id first — shard-owned only.
        let mut pool: std::collections::BTreeMap<&str, std::collections::VecDeque<InstanceId>> =
            std::collections::BTreeMap::new();
        for inst in self
            .instances
            .iter()
            .filter(|i| i.alive() && owned.contains(&i.id) && !claimed.contains(&i.id))
        {
            pool.entry(inst.label.as_str()).or_default().push_back(inst.id);
        }
        for (pi, planned) in plan.instances.iter().enumerate() {
            if assigned[pi].is_none() {
                if let Some(id) = pool.get_mut(planned.label.as_str()).and_then(|v| v.pop_front())
                {
                    claimed.insert(id);
                    assigned[pi] = Some(id);
                }
            }
        }
        // Terminate the shard's own unclaimed leftovers — nobody else's.
        let leftovers: Vec<InstanceId> = pool.values().flatten().copied().collect();
        for id in leftovers {
            self.terminate(id)?;
        }
        // Provision the gaps, rebind only this shard's slots.
        let ids: Vec<InstanceId> = plan
            .instances
            .iter()
            .zip(assigned)
            .map(|(planned, slot)| match slot {
                Some(id) => Ok(id),
                None => self.provision(planned.type_idx, planned.region_idx),
            })
            .collect::<Result<_>>()?;
        for s in &prev_slots {
            self.bindings.remove(s);
        }
        for (planned, &id) in plan.instances.iter().zip(&ids) {
            self.bindings.insert(planned.slot_id, id);
        }
        self.shard_slots
            .insert(shard, plan.instances.iter().map(|p| p.slot_id).collect());
        let loads: Vec<Dims> = plan
            .packing
            .bins
            .iter()
            .map(|b| b.total_demand(&plan.problem))
            .collect();
        for (id, load) in ids.iter().zip(loads) {
            self.set_load(*id, load)?;
        }
        Ok(ids)
    }

    /// Terminate every instance bound to `shard`'s slots and forget the
    /// shard (a metro leaving the workload). Returns how many instances
    /// were terminated. Idempotent: an unknown shard retires zero.
    pub fn retire_shard(&mut self, shard: u32) -> Result<usize> {
        let slots = self.shard_slots.remove(&shard).unwrap_or_default();
        let mut terminated = 0usize;
        for s in slots {
            if let Some(id) = self.bindings.remove(&s) {
                if self.get(id).is_some_and(SimInstance::alive) {
                    self.terminate(id)?;
                    terminated += 1;
                }
            }
        }
        Ok(terminated)
    }
}

/// Deterministic seeded preemption-storm injector.
///
/// Each [`step`](PreemptionInjector::step) visits every alive, not yet
/// revoked spot instance in id order and revokes it with probability
/// `quoted_rate × intensity × dt/3600` (clamped to 1), issuing the standard
/// [`SPOT_WARNING_S`] reclaim notice. Exactly one rng draw per visited
/// instance, in a deterministic order — the same seed over the same fleet
/// history replays the same storm, which is what lets the spot bench gate
/// on exact deadline-miss and cost numbers.
pub struct PreemptionInjector {
    rng: Rng,
    /// Multiplier on each instance's quoted preemption rate: 1.0 replays
    /// the market's baseline churn, larger values model storms.
    pub intensity: f64,
}

impl PreemptionInjector {
    pub fn new(seed: u64, intensity: f64) -> Self {
        PreemptionInjector { rng: Rng::new(seed), intensity }
    }

    /// Run one injection round covering the next `dt_s` seconds of sim
    /// time (call it *before* the matching [`CloudSim::advance`]). Returns
    /// the ids revoked this round.
    pub fn step(&mut self, sim: &mut CloudSim, dt_s: f64) -> Vec<InstanceId> {
        let candidates: Vec<(InstanceId, f64)> = sim
            .alive()
            .iter()
            .filter(|i| i.is_spot && i.revoke_at.is_none())
            .filter_map(|i| {
                sim.catalog
                    .spot_quote(i.type_idx, i.region_idx)
                    .map(|q| (i.id, q.preemption_rate_per_hour))
            })
            .collect();
        let mut revoked = Vec::new();
        for (id, rate) in candidates {
            let p = (rate * self.intensity * dt_s / 3600.0).clamp(0.0, 1.0);
            if self.rng.bool(p) {
                sim.revoke(id, SPOT_WARNING_S).expect("candidate was alive spot");
                revoked.push(id);
            }
        }
        revoked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cameras::{camera_at, StreamRequest};
    use crate::coordinator::{Planner, PlannerConfig};
    use crate::geo::cities;
    use crate::profiles::{Program, Resolution};

    fn sim() -> CloudSim {
        CloudSim::new(Catalog::builtin())
    }

    #[test]
    fn billing_is_linear_in_time() {
        let mut s = sim();
        let t = s.catalog.type_by_name("c4.2xlarge").unwrap();
        let r = s.catalog.region_by_id("us-east-1").unwrap();
        s.provision(t, r).unwrap();
        s.advance(3600.0);
        assert!((s.accrued_usd() - 0.398).abs() < 1e-9);
        s.advance(1800.0);
        assert!((s.accrued_usd() - 0.398 * 1.5).abs() < 1e-9);
    }

    #[test]
    fn terminated_instances_stop_billing() {
        let mut s = sim();
        let t = s.catalog.type_by_name("c4.2xlarge").unwrap();
        let r = s.catalog.region_by_id("us-east-1").unwrap();
        let id = s.provision(t, r).unwrap();
        s.advance(3600.0);
        s.terminate(id).unwrap();
        let before = s.accrued_usd();
        s.advance(3600.0);
        assert_eq!(s.accrued_usd(), before);
        assert!(s.terminate(id).is_err(), "double-terminate must fail");
    }

    #[test]
    fn boot_delay_respected() {
        let mut s = sim();
        let t = s.catalog.type_by_name("c4.2xlarge").unwrap();
        let r = s.catalog.region_by_id("us-east-1").unwrap();
        let id = s.provision(t, r).unwrap();
        assert!(!s.get(id).unwrap().ready(s.now()));
        s.advance(DEFAULT_BOOT_DELAY_S + 1.0);
        assert!(s.get(id).unwrap().ready(s.now()));
    }

    #[test]
    fn degradation_kicks_in_above_threshold() {
        let mut s = sim();
        let t = s.catalog.type_by_name("c4.2xlarge").unwrap();
        let r = s.catalog.region_by_id("us-east-1").unwrap();
        let id = s.provision(t, r).unwrap();
        s.set_load(id, Dims::new(4.0, 4.0, 0.0, 0.0)).unwrap(); // 50%
        assert_eq!(s.get(id).unwrap().degradation_factor(), 1.0);
        s.set_load(id, Dims::new(7.6, 4.0, 0.0, 0.0)).unwrap(); // 95%
        let f = s.get(id).unwrap().degradation_factor();
        assert!(f < 1.0 && f >= 0.5, "factor={f}");
    }

    #[test]
    fn apply_plan_reconciles_fleet() {
        let catalog =
            Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let planner = Planner::new(catalog.clone(), PlannerConfig::st3());
        let mut s = CloudSim::new(catalog);

        let mk = |fps: f64, n: usize| -> Vec<StreamRequest> {
            (0..n)
                .map(|i| {
                    StreamRequest::new(
                        camera_at(i as u64, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                        Program::Zf,
                        fps,
                    )
                })
                .collect()
        };

        let plan_low = planner.plan(&mk(0.5, 4)).unwrap();
        let ids1 = s.apply_plan(&plan_low).unwrap();
        assert_eq!(ids1.len(), plan_low.instances.len());
        let n1 = s.alive().len();

        // Rush hour: more/different instances.
        let plan_high = planner.plan(&mk(8.0, 4)).unwrap();
        s.apply_plan(&plan_high).unwrap();
        assert_eq!(s.alive().len(), plan_high.instances.len());
        assert!(s.alive().len() >= n1);

        // Back to calm: surplus terminated.
        let ids3 = s.apply_plan(&plan_low).unwrap();
        assert_eq!(s.alive().len(), plan_low.instances.len());
        assert_eq!(ids3.len(), plan_low.instances.len());
        // Hourly rate matches the plan's cost.
        assert!((s.hourly_rate() - plan_low.cost_per_hour).abs() < 1e-9);
    }

    #[test]
    fn plan_loads_track_feedback_adjusted_demand() {
        // CPU-only so the vcpus dimension (the one cost_scale scales)
        // dominates utilization.
        let catalog = Catalog::builtin().restrict(Some(&["c4.2xlarge"]), Some(&["us-east-2"]));
        let planner = Planner::new(catalog.clone(), PlannerConfig::st1());
        let mut s = CloudSim::new(catalog);
        let requests = vec![
            StreamRequest::new(
                camera_at(0, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                Program::Zf,
                2.0,
            ),
            StreamRequest::new(
                camera_at(1, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                Program::Zf,
                2.0,
            ),
        ];
        let plan = planner.plan(&requests).unwrap();
        assert!(s.fleet_utilization() == 0.0, "empty fleet");
        // Loads require bound slots.
        assert!(s.set_plan_loads(&plan, &requests).is_err());
        s.apply_plan(&plan).unwrap();
        s.set_plan_loads(&plan, &requests).unwrap();
        let declared = s.fleet_utilization();
        assert!(declared > 0.0 && declared <= 1.0 + 1e-9, "util={declared}");
        // Observed demand at half the declared compute: utilization falls.
        let mut observed = requests.clone();
        for r in &mut observed {
            r.feedback.cost_scale = 0.5;
        }
        s.set_plan_loads(&plan, &observed).unwrap();
        let adjusted = s.fleet_utilization();
        assert!(adjusted < declared, "{adjusted} vs {declared}");
    }

    #[test]
    fn reapplying_the_same_plan_keeps_instance_ids() {
        // Regression: the old LIFO label pool could permute which physical
        // instance backed which planned slot across identical applications.
        let catalog =
            Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let planner = Planner::new(catalog.clone(), PlannerConfig::st3());
        let mut s = CloudSim::new(catalog);
        let requests: Vec<StreamRequest> = (0..6)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                    Program::Zf,
                    1.0,
                )
            })
            .collect();
        let plan = planner.plan(&requests).unwrap();
        let ids1 = s.apply_plan(&plan).unwrap();
        let alive_before: Vec<InstanceId> = s.alive().iter().map(|i| i.id).collect();
        let ids2 = s.apply_plan(&plan).unwrap();
        assert_eq!(ids1, ids2, "identical plan must keep identical instance ids");
        let alive_after: Vec<InstanceId> = s.alive().iter().map(|i| i.id).collect();
        assert_eq!(alive_before, alive_after, "no provision/terminate on a no-op apply");

        // An identical workload re-planned from scratch (fresh slot ids)
        // still reuses the fleet via the deterministic label FIFO.
        let replanned = planner.plan(&requests).unwrap();
        let ids3 = s.apply_plan(&replanned).unwrap();
        assert_eq!(ids1, ids3, "re-planned identical plan must reuse the same instances");
    }

    #[test]
    fn shard_scoped_apply_touches_only_the_shards_fleet() {
        let catalog =
            Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let planner = Planner::new(catalog.clone(), PlannerConfig::st3());
        let mut s = CloudSim::new(catalog);
        let mk = |base: u64, fps: f64, n: usize| -> Vec<StreamRequest> {
            (0..n as u64)
                .map(|i| {
                    StreamRequest::new(
                        camera_at(base + i, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                        Program::Zf,
                        fps,
                    )
                })
                .collect()
        };
        let plan_a = planner.plan(&mk(0, 8.0, 6)).unwrap();
        let plan_b = planner.plan(&mk(100, 8.0, 6)).unwrap();
        let ids_a = s.apply_shard_plan(1, &plan_a).unwrap();
        let ids_b = s.apply_shard_plan(2, &plan_b).unwrap();
        assert_eq!(s.alive().len(), ids_a.len() + ids_b.len());

        // Identical re-apply of shard 1 is a no-op with stable ids.
        let ids_a2 = s.apply_shard_plan(1, &plan_a).unwrap();
        assert_eq!(ids_a, ids_a2, "re-applying a shard plan must keep its instances");
        assert_eq!(s.alive().len(), ids_a.len() + ids_b.len());

        // Shard 1 shrinks: its surplus terminates, shard 2 stays whole.
        let small = planner.plan(&mk(0, 8.0, 2)).unwrap();
        let ids_small = s.apply_shard_plan(1, &small).unwrap();
        assert!(ids_small.len() < ids_a.len(), "shrink scenario must drop instances");
        assert!(
            ids_small.iter().all(|id| ids_a.contains(id)),
            "the shrunk shard reuses its own fleet"
        );
        for &id in &ids_b {
            assert!(s.get(id).unwrap().alive(), "shard 2 instance {id} was touched");
        }
        assert_eq!(s.alive().len(), ids_small.len() + ids_b.len());

        // Retiring shard 2 terminates exactly its fleet.
        let n = s.retire_shard(2).unwrap();
        assert_eq!(n, ids_b.len());
        assert!(ids_b.iter().all(|&id| !s.get(id).unwrap().alive()));
        assert!(ids_small.iter().all(|&id| s.get(id).unwrap().alive()));
        assert_eq!(s.retire_shard(2).unwrap(), 0, "retire is idempotent");
        assert!((s.hourly_rate() - small.cost_per_hour).abs() < 1e-9);
    }

    #[test]
    fn spot_billing_runs_at_the_quote_until_the_revocation_deadline() {
        let mut s = sim();
        let t = s.catalog.type_by_name("c4.2xlarge").unwrap();
        let r = s.catalog.region_by_id("us-east-1").unwrap();
        let id = s.provision_spot(t, r).unwrap();
        assert!(s.get(id).unwrap().is_spot);
        // us-east-1 on-demand $0.398, spot fraction 0.34 → $0.1353.
        s.advance(3600.0);
        assert!((s.accrued_usd() - 0.1353).abs() < 1e-9);
        // Reclaim notice: two more minutes of billed runtime, then death
        // at exactly the deadline inside the crossing advance().
        s.revoke(id, SPOT_WARNING_S).unwrap();
        assert!(s.get(id).unwrap().alive(), "warning window keeps it running");
        s.advance(3600.0);
        let expect = 0.1353 * (1.0 + SPOT_WARNING_S / 3600.0);
        assert!((s.accrued_usd() - expect).abs() < 1e-9);
        let inst = s.get(id).unwrap();
        assert!(!inst.alive());
        assert_eq!(inst.terminated_at, Some(3600.0 + SPOT_WARNING_S));
        let before = s.accrued_usd();
        s.advance(3600.0);
        assert_eq!(s.accrued_usd(), before, "revoked instances stop billing");
    }

    #[test]
    fn revocation_is_a_spot_only_event_and_keeps_the_earliest_deadline() {
        let mut s = sim();
        let t = s.catalog.type_by_name("c4.2xlarge").unwrap();
        let r = s.catalog.region_by_id("us-east-1").unwrap();
        let od = s.provision(t, r).unwrap();
        assert!(s.revoke(od, SPOT_WARNING_S).is_err(), "on-demand terminates, never revokes");
        let sp = s.provision_spot(t, r).unwrap();
        s.revoke(sp, 300.0).unwrap();
        s.revoke(sp, SPOT_WARNING_S).unwrap(); // tighter notice wins
        s.revoke(sp, 900.0).unwrap(); // a later notice cannot extend the deadline
        assert_eq!(s.get(sp).unwrap().revoke_at, Some(SPOT_WARNING_S));
        s.advance(SPOT_WARNING_S + 1.0);
        assert!(!s.get(sp).unwrap().alive());
        assert!(s.get(od).unwrap().alive());
    }

    #[test]
    fn live_reconciliation_never_claims_or_terminates_the_spot_fleet() {
        let catalog = Catalog::builtin().restrict(Some(&["c4.2xlarge"]), Some(&["us-east-2"]));
        let planner = Planner::new(catalog.clone(), PlannerConfig::st1());
        let mut s = CloudSim::new(catalog);
        // A spot instance wearing the exact label the live fleet will use.
        let spot_id = s.provision_spot(0, 0).unwrap();
        let requests: Vec<StreamRequest> = (0..2)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
                    Program::Zf,
                    2.0,
                )
            })
            .collect();
        let plan = planner.plan(&requests).unwrap();
        let ids = s.apply_plan(&plan).unwrap();
        assert!(!ids.contains(&spot_id), "a live slot claimed a spot instance");
        assert!(s.get(spot_id).unwrap().alive(), "global apply terminated the spot fleet");
        // A second reconciliation pass leaves it untouched too.
        let ids2 = s.apply_plan(&plan).unwrap();
        assert_eq!(ids, ids2);
        assert!(s.get(spot_id).unwrap().alive());
    }

    #[test]
    fn preemption_injector_replays_identically_and_only_touches_spot() {
        let catalog = Catalog::builtin().restrict(Some(&["c4.2xlarge"]), Some(&["us-east-2"]));
        let run = |seed: u64| -> (Vec<InstanceId>, Vec<InstanceId>) {
            let mut s = CloudSim::new(catalog.clone());
            let od = s.provision(0, 0).unwrap();
            let spots: Vec<InstanceId> =
                (0..12).map(|_| s.provision_spot(0, 0).unwrap()).collect();
            // c4.2xlarge quotes 0.04 revocations/hour; intensity 10 makes a
            // 0.4-per-step storm over hourly steps.
            let mut inj = PreemptionInjector::new(seed, 10.0);
            let mut revoked = Vec::new();
            for _ in 0..6 {
                revoked.extend(inj.step(&mut s, 3600.0));
                s.advance(3600.0);
            }
            assert!(s.get(od).unwrap().alive(), "the storm revoked an on-demand instance");
            for &id in &revoked {
                assert!(!s.get(id).unwrap().alive(), "revoked {id} outlived its notice");
            }
            (spots, revoked)
        };
        let (spots, a) = run(7);
        let (_, b) = run(7);
        assert_eq!(a, b, "same seed must replay the same storm");
        assert!(!a.is_empty(), "a 0.4-per-step storm over 12 instances revokes someone");
        assert!(a.iter().all(|id| spots.contains(id)));
        let mut dedup = a.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), a.len(), "an instance is revoked at most once");
    }

    #[test]
    fn slot_bindings_follow_slots_not_plan_order() {
        // A winner flip hands the simulator a plan produced by a different
        // candidate: same slots (seeded continuity), possibly in a
        // different instance order. Reconciliation must follow the slot
        // ids, not positions — zero provision/terminate either way.
        let catalog =
            Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
        let planner = Planner::new(catalog.clone(), PlannerConfig::st3());
        let mut s = CloudSim::new(catalog);
        let requests: Vec<StreamRequest> = (0..6)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                    Program::Zf,
                    1.0,
                )
            })
            .collect();
        let plan = planner.plan(&requests).unwrap();
        assert!(plan.instances.len() >= 2, "need multiple instances to permute");
        let ids1 = s.apply_plan(&plan).unwrap();
        let alive1 = s.alive().len();

        // The "flipped winner's" plan: identical slots, reversed order
        // (instances and packing bins stay index-aligned).
        let mut flipped = plan.clone();
        flipped.instances.reverse();
        flipped.packing.bins.reverse();
        let ids2 = s.apply_plan(&flipped).unwrap();
        let mut ids2_rev = ids2.clone();
        ids2_rev.reverse();
        assert_eq!(ids1, ids2_rev, "each slot must keep its physical instance");
        assert_eq!(s.alive().len(), alive1, "no provision/terminate on the flip");
        assert!((s.hourly_rate() - plan.cost_per_hour).abs() < 1e-9);
    }
}
