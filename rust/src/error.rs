//! Crate-wide error type.

use thiserror::Error;

/// Unified error for all camflow subsystems.
#[derive(Error, Debug)]
pub enum Error {
    /// Bin-packing / planning found no feasible assignment (the paper's
    /// "Fail" rows in Fig 3: e.g. CPU-only strategy at 8 fps ZF).
    #[error("infeasible: {0}")]
    Infeasible(String),

    /// Malformed configuration, scenario, or manifest.
    #[error("config error: {0}")]
    Config(String),

    /// JSON parse/serialize failure.
    #[error("json error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    /// LP/MILP solver failure (unbounded, iteration limit, numerical).
    #[error("solver error: {0}")]
    Solver(String),

    /// PJRT runtime failure (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Serving-layer failure (channel closed, worker died).
    #[error("serving error: {0}")]
    Serving(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Convenience constructor used across modules.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn infeasible(msg: impl Into<String>) -> Self {
        Error::Infeasible(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn solver(msg: impl Into<String>) -> Self {
        Error::Solver(msg.into())
    }
    pub fn serving(msg: impl Into<String>) -> Self {
        Error::Serving(msg.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
