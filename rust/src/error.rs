//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the build image has
//! no network access, so the crate stays dependency-free by default.

use std::fmt;

/// Unified error for all camflow subsystems.
#[derive(Debug)]
pub enum Error {
    /// Bin-packing / planning found no feasible assignment (the paper's
    /// "Fail" rows in Fig 3: e.g. CPU-only strategy at 8 fps ZF).
    Infeasible(String),

    /// Malformed configuration, scenario, or manifest.
    Config(String),

    /// JSON parse/serialize failure.
    Json { offset: usize, message: String },

    /// LP/MILP solver failure (unbounded, iteration limit, numerical).
    Solver(String),

    /// PJRT runtime failure (artifact load, compile, execute).
    Runtime(String),

    /// Serving-layer failure (channel closed, worker died).
    Serving(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Infeasible(m) => write!(f, "infeasible: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json { offset, message } => {
                write!(f, "json error at byte {offset}: {message}")
            }
            Error::Solver(m) => write!(f, "solver error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Serving(m) => write!(f, "serving error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Convenience constructor used across modules.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn infeasible(msg: impl Into<String>) -> Self {
        Error::Infeasible(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn solver(msg: impl Into<String>) -> Self {
        Error::Solver(msg.into())
    }
    pub fn serving(msg: impl Into<String>) -> Self {
        Error::Serving(msg.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
