//! Analysis-program resource profiles.
//!
//! The resource manager never executes a network to make decisions — it uses
//! per-program *demand vectors* measured offline (in the paper: profiled on
//! EC2; here: calibrated so the packer reproduces the paper's Fig-3 decision
//! table exactly, see DESIGN.md §Calibration).
//!
//! Model (per stream):
//! * compute scales with pixel rate: `fps × megapixels` (the paper: "If an
//!   image has more pixels, more computation is needed"),
//! * every stream pays a decode/fetch CPU tax on whichever host runs it,
//! * a stream placed on a GPU instance demands GPU-seconds and GPU memory
//!   instead of CPU-seconds (Kaseb's 4-dimensional formulation \[7\]).
//!
//! GPU acceleration ("up to 16×" in the paper) is an *achieved-frame-rate*
//! ratio, not a resource ratio: at the paper's top rate (8 fps, VGA) the ZF
//! program reaches 8 fps on GPU vs 0.5 fps on one CPU core — 16×; at 0.2 fps
//! both paths meet the rate and the improvement is < 5%. `effective_speedup`
//! reproduces this curve.

use crate::catalog::Dims;

/// The two analysis programs of the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Program {
    Vgg16,
    Zf,
}

impl Program {
    pub const ALL: [Program; 2] = [Program::Vgg16, Program::Zf];

    pub fn name(&self) -> &'static str {
        match self {
            Program::Vgg16 => "VGG16",
            Program::Zf => "ZF",
        }
    }

    /// Artifact model name in `artifacts/manifest.json`.
    pub fn artifact_name(&self) -> &'static str {
        match self {
            Program::Vgg16 => "vgg16",
            Program::Zf => "zf",
        }
    }

    pub fn profile(&self) -> &'static ProgramProfile {
        match self {
            Program::Vgg16 => &VGG16_PROFILE,
            Program::Zf => &ZF_PROFILE,
        }
    }
}

impl std::str::FromStr for Program {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "vgg16" | "vgg" | "vgg-16" => Ok(Program::Vgg16),
            "zf" => Ok(Program::Zf),
            other => Err(format!("unknown program '{other}'")),
        }
    }
}

/// A camera frame resolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Resolution {
    pub width: u32,
    pub height: u32,
}

impl Resolution {
    pub const VGA: Resolution = Resolution { width: 640, height: 480 };
    pub const XGA: Resolution = Resolution { width: 1024, height: 768 };
    pub const HD720: Resolution = Resolution { width: 1280, height: 720 };
    pub const HD900: Resolution = Resolution { width: 1600, height: 900 };
    pub const FHD: Resolution = Resolution { width: 1920, height: 1080 };

    pub fn megapixels(&self) -> f64 {
        (self.width as f64 * self.height as f64) / 1.0e6
    }
}

impl std::fmt::Display for Resolution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.width, self.height)
    }
}

/// Offline-profiled constants for one analysis program.
#[derive(Clone, Debug)]
pub struct ProgramProfile {
    /// CPU-seconds per frame per megapixel (single-core equivalent).
    pub cpu_sec_per_mpix_frame: f64,
    /// GPU-seconds per frame per megapixel.
    pub gpu_sec_per_mpix_frame: f64,
    /// Host memory (GiB) for model + buffers when running on CPU.
    pub host_mem_gib: f64,
    /// Host memory (GiB) when the compute runs on the GPU.
    pub gpu_host_mem_gib: f64,
    /// GPU memory (GiB) for model + activations.
    pub gpu_mem_gib: f64,
    /// Fetch/decode CPU tax: `base + per_fps * fps` cores on any host.
    pub decode_vcpus_base: f64,
    pub decode_vcpus_per_fps: f64,
    /// Frame-buffer memory per fps (GiB).
    pub mem_gib_per_fps: f64,
}

/// Calibrated so that Fig 3's nine table rows reproduce exactly (DESIGN.md).
pub static VGG16_PROFILE: ProgramProfile = ProgramProfile {
    cpu_sec_per_mpix_frame: 15.5,
    gpu_sec_per_mpix_frame: 0.75,
    host_mem_gib: 1.5,
    gpu_host_mem_gib: 0.75,
    gpu_mem_gib: 1.2,
    decode_vcpus_base: 0.1,
    decode_vcpus_per_fps: 0.05,
    mem_gib_per_fps: 0.05,
};

pub static ZF_PROFILE: ProgramProfile = ProgramProfile {
    cpu_sec_per_mpix_frame: 6.5,
    gpu_sec_per_mpix_frame: 0.11,
    host_mem_gib: 1.0,
    gpu_host_mem_gib: 0.5,
    gpu_mem_gib: 0.7,
    decode_vcpus_base: 0.1,
    decode_vcpus_per_fps: 0.05,
    mem_gib_per_fps: 0.05,
};

impl ProgramProfile {
    /// Demand vector when the stream runs on a CPU-only placement.
    pub fn demand_cpu(&self, fps: f64, res: Resolution) -> Dims {
        self.demand_cpu_scaled(fps, res, 1.0)
    }

    /// [`demand_cpu`](ProgramProfile::demand_cpu) with the *compute* term
    /// multiplied by `cost_scale` — the serving feedback loop's measured
    /// cost-per-frame relative to this offline profile
    /// ([`crate::cameras::DemandFeedback::cost_scale`]). Decode tax and
    /// memory are fetch-side and stay unscaled. `cost_scale = 1.0` is
    /// bit-identical to the unscaled vector.
    pub fn demand_cpu_scaled(&self, fps: f64, res: Resolution, cost_scale: f64) -> Dims {
        let mpix = res.megapixels();
        Dims::new(
            fps * self.cpu_sec_per_mpix_frame * mpix * cost_scale
                + self.decode_vcpus_base
                + self.decode_vcpus_per_fps * fps,
            self.host_mem_gib + self.mem_gib_per_fps * fps,
            0.0,
            0.0,
        )
    }

    /// Demand vector when the stream runs on a GPU placement.
    pub fn demand_gpu(&self, fps: f64, res: Resolution) -> Dims {
        self.demand_gpu_scaled(fps, res, 1.0)
    }

    /// [`demand_gpu`](ProgramProfile::demand_gpu) with the GPU compute term
    /// scaled by the feedback loop's measured `cost_scale` (see
    /// [`demand_cpu_scaled`](ProgramProfile::demand_cpu_scaled)).
    pub fn demand_gpu_scaled(&self, fps: f64, res: Resolution, cost_scale: f64) -> Dims {
        let mpix = res.megapixels();
        Dims::new(
            self.decode_vcpus_base + self.decode_vcpus_per_fps * fps,
            self.gpu_host_mem_gib + self.mem_gib_per_fps * fps,
            fps * self.gpu_sec_per_mpix_frame * mpix * cost_scale,
            self.gpu_mem_gib,
        )
    }

    /// Achieved frame rate on one CPU core (frames processed sequentially).
    pub fn achieved_fps_cpu(&self, arrival_fps: f64, res: Resolution) -> f64 {
        arrival_fps.min(1.0 / (self.cpu_sec_per_mpix_frame * res.megapixels()))
    }

    /// Achieved frame rate on one GPU.
    pub fn achieved_fps_gpu(&self, arrival_fps: f64, res: Resolution) -> f64 {
        arrival_fps.min(1.0 / (self.gpu_sec_per_mpix_frame * res.megapixels()))
    }

    /// The paper's "GPU acceleration" metric: achieved-fps ratio at a given
    /// arrival rate. ≈16× for ZF at 8 fps VGA; ≈1.0 (<5% gain) at 0.2 fps.
    pub fn effective_speedup(&self, arrival_fps: f64, res: Resolution) -> f64 {
        self.achieved_fps_gpu(arrival_fps, res) / self.achieved_fps_cpu(arrival_fps, res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zf_speedup_is_16x_at_8fps_vga() {
        // The paper: "At the highest frame rates, GPUs can accelerate these
        // two analysis programs up to 16 times."
        let s = ZF_PROFILE.effective_speedup(8.0, Resolution::VGA);
        assert!((s - 16.0).abs() < 0.2, "speedup={s}");
    }

    #[test]
    fn speedup_below_5pct_at_lowest_rates() {
        // "At the lowest frame rates, the improvement falls below 5%."
        for prog in Program::ALL {
            let s = prog.profile().effective_speedup(0.2, Resolution::VGA);
            assert!(s < 1.05, "{}: speedup={s}", prog.name());
        }
    }

    #[test]
    fn vgg_heavier_than_zf() {
        let v = VGG16_PROFILE.demand_cpu(1.0, Resolution::VGA);
        let z = ZF_PROFILE.demand_cpu(1.0, Resolution::VGA);
        assert!(v.vcpus > z.vcpus);
        assert!(v.mem_gib > z.mem_gib);
    }

    #[test]
    fn cpu_demand_scales_with_fps_and_pixels() {
        let p = &ZF_PROFILE;
        let d1 = p.demand_cpu(1.0, Resolution::VGA);
        let d2 = p.demand_cpu(2.0, Resolution::VGA);
        let d3 = p.demand_cpu(1.0, Resolution::FHD);
        assert!(d2.vcpus > d1.vcpus);
        assert!(d3.vcpus > d1.vcpus);
        // Compute part is linear in fps (decode tax aside).
        let compute1 = d1.vcpus - 0.1 - 0.05;
        let compute2 = d2.vcpus - 0.1 - 0.10;
        assert!((compute2 / compute1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_demand_has_no_heavy_cpu_component() {
        let d = VGG16_PROFILE.demand_gpu(8.0, Resolution::FHD);
        assert!(d.vcpus < 1.0); // just the decode tax
        assert!(d.gpus > 0.0);
        assert!(d.gpu_mem_gib > 0.0);
    }

    #[test]
    fn zf_8fps_720p_fits_one_gpu_not_two() {
        // The S3 geometry: one ZF@8fps 720p stream consumes most of one GPU
        // (≤ 0.9 usable) so exactly one fits per g2-class instance.
        let d = ZF_PROFILE.demand_gpu(8.0, Resolution::HD720);
        assert!(d.gpus <= 0.9, "gpus={}", d.gpus);
        assert!(2.0 * d.gpus > 0.9, "two must not fit");
    }

    #[test]
    fn program_parse() {
        assert_eq!("vgg16".parse::<Program>().unwrap(), Program::Vgg16);
        assert_eq!("ZF".parse::<Program>().unwrap(), Program::Zf);
        assert!("yolo".parse::<Program>().is_err());
    }

    #[test]
    fn unit_cost_scale_is_bit_identical_and_scaling_moves_only_compute() {
        for prog in Program::ALL {
            let p = prog.profile();
            for (d, s) in [
                (p.demand_cpu(3.0, Resolution::HD720), p.demand_cpu_scaled(3.0, Resolution::HD720, 1.0)),
                (p.demand_gpu(3.0, Resolution::HD720), p.demand_gpu_scaled(3.0, Resolution::HD720, 1.0)),
            ] {
                assert_eq!(d.as_array().map(f64::to_bits), s.as_array().map(f64::to_bits));
            }
            let heavy = p.demand_cpu_scaled(3.0, Resolution::HD720, 2.0);
            let base = p.demand_cpu(3.0, Resolution::HD720);
            assert!(heavy.vcpus > base.vcpus, "{}", prog.name());
            assert_eq!(heavy.mem_gib, base.mem_gib, "memory must not scale");
            let g_heavy = p.demand_gpu_scaled(3.0, Resolution::HD720, 2.0);
            let g_base = p.demand_gpu(3.0, Resolution::HD720);
            assert!(g_heavy.gpus > g_base.gpus);
            assert_eq!(g_heavy.vcpus, g_base.vcpus, "decode tax must not scale");
        }
    }

    #[test]
    fn resolution_megapixels() {
        assert!((Resolution::VGA.megapixels() - 0.3072).abs() < 1e-9);
        assert!((Resolution::FHD.megapixels() - 2.0736).abs() < 1e-9);
    }
}
