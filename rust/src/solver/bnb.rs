//! Best-first branch-and-bound MILP over the simplex LP relaxation.
//!
//! Branching adds simple bound rows (`x_i ≤ ⌊v⌋` / `x_i ≥ ⌈v⌉`) to the parent
//! LP; nodes are explored best-bound-first. An optional warm-start incumbent
//! (e.g. an FFD packing) prunes from the start — the same role heuristic
//! solutions play in the paper's Gurobi branch-and-cut runs.
//!
//! Node LPs re-enter the simplex warm: each node carries its parent's
//! optimal basis, extended by the new branch row's slack column, and
//! [`resume_from_basis`] repairs the single infeasible row with a short
//! dual-simplex pass instead of a cold two-phase solve. A previous solve of
//! a structurally identical MILP can additionally seed the *root* basis and
//! replay its branching order (`MilpOptions::{root_basis, replay_order}`) —
//! the delta-solve path used by the planner's near-match solution memo. All
//! warm re-entries are certified by the simplex layer; any uncertified node
//! falls back to a cold LP solve, so the search is exactly as correct as the
//! all-cold one.

use super::simplex::{
    resume_from_basis_with_stats, solve_lp_partial_with_stats, Lp, LpOutcome, LpStats, Op, Resume,
};
use crate::error::{Error, Result};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A mixed-integer program: `lp` plus integrality on `integer_vars`.
#[derive(Clone, Debug)]
pub struct Milp {
    pub lp: Lp,
    pub integer_vars: Vec<usize>,
}

/// Search limits / tolerances.
#[derive(Clone, Debug)]
pub struct MilpOptions {
    /// Max branch-and-bound nodes before giving up with the incumbent.
    pub max_nodes: usize,
    /// Integrality tolerance.
    pub int_tol: f64,
    /// Optional warm-start incumbent (x must be feasible & integral).
    pub warm_start: Option<(Vec<f64>, f64)>,
    /// Stop when the gap between incumbent and best bound is below this.
    pub rel_gap: f64,
    /// Variables to branch on first while fractional (e.g. the per-bin-type
    /// "number of bins" arcs in the arc-flow ILP — branching there decides
    /// the macro structure before micro flow routing).
    pub priority_vars: Vec<usize>,
    /// Delta-solve replay: branch on these variables first, in this order,
    /// while fractional — the first-branch order of a previous solve of a
    /// structurally identical MILP steers the search down the same path.
    /// Takes precedence over `priority_vars`; out-of-range entries are
    /// ignored.
    pub replay_order: Vec<usize>,
    /// Optimal basis of a structurally identical MILP's root relaxation;
    /// warm-starts the root node LP (dual simplex absorbs RHS deltas). An
    /// incompatible basis is silently ignored (the root solves cold).
    pub root_basis: Option<Vec<usize>>,
}

impl Default for MilpOptions {
    fn default() -> Self {
        MilpOptions {
            max_nodes: 50_000,
            int_tol: 1e-6,
            warm_start: None,
            rel_gap: 1e-9,
            priority_vars: Vec::new(),
            replay_order: Vec::new(),
            root_basis: None,
        }
    }
}

/// Result of the search.
#[derive(Clone, Debug)]
pub struct MilpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    /// Number of B&B nodes explored.
    pub nodes: usize,
    /// True if optimality was proven (node limit not hit).
    pub proven_optimal: bool,
    /// The root relaxation's optimal basis (artificial-free), for
    /// delta-solve caching; `None` when the root LP was pruned or its basis
    /// kept an artificial column.
    pub root_basis: Option<Vec<usize>>,
    /// Integer variables branched on, in first-branch order (the replay
    /// hint for a future structurally identical solve).
    pub branch_order: Vec<usize>,
    /// Node LPs re-entered warm from a parent/cached basis vs solved cold.
    pub lp_warm: usize,
    pub lp_cold: usize,
    /// Aggregate simplex counters across every node LP (warm and cold):
    /// pivots, degenerate pivots, FTRAN/BTRAN ops, refactorizations.
    pub lp_stats: LpStats,
}

struct Node {
    bound: f64,
    /// Extra bound rows: (var, op, rhs).
    extra: Vec<(usize, Op, f64)>,
    /// The parent node's optimal basis (warm re-entry seed), extended by
    /// the new branch row's slack column at solve time.
    basis: Option<Vec<usize>>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    // Min-heap on bound via reversed comparison (BinaryHeap is a max-heap).
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .bound
            .partial_cmp(&self.bound)
            .unwrap_or(Ordering::Equal)
    }
}

fn most_fractional(x: &[f64], int_vars: &[usize], tol: f64) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64, f64)> = None; // (var, value, dist-from-half)
    for &i in int_vars {
        let v = x[i];
        let frac = v - v.floor();
        if frac > tol && frac < 1.0 - tol {
            let score = (frac - 0.5).abs();
            if best.is_none_or(|(_, _, s)| score < s) {
                best = Some((i, v, score));
            }
        }
    }
    best.map(|(i, v, _)| (i, v))
}

/// First variable in `order` (the replay hint) that is still fractional.
fn first_fractional(x: &[f64], order: &[usize], tol: f64) -> Option<(usize, f64)> {
    order
        .iter()
        .filter(|&&i| i < x.len())
        .map(|&i| (i, x[i]))
        .find(|&(_, v)| {
            let frac = v - v.floor();
            frac > tol && frac < 1.0 - tol
        })
}

/// Solve a node LP warm from `basis` when possible. The basis is either the
/// node's own row count (a cached root basis) or one short (a parent basis;
/// the appended branch row's slack column completes it). Returns `None`
/// whenever the simplex layer cannot certify the warm result.
fn try_warm(lp: &Lp, basis: &[usize], stats: &mut LpStats) -> Option<LpOutcome> {
    let m = lp.constraints.len();
    let candidate: Vec<usize> = if basis.len() == m {
        basis.to_vec()
    } else if basis.len() + 1 == m {
        let num_slack = lp.constraints.iter().filter(|c| c.op != Op::Eq).count();
        let mut b = basis.to_vec();
        // Branch rows are Le/Ge, so the appended row owns the last slack.
        b.push(lp.num_vars + num_slack - 1);
        b
    } else {
        return None;
    };
    match resume_from_basis_with_stats(lp, &candidate, stats) {
        Ok(Resume::Solved(o)) => Some(o),
        _ => None,
    }
}

/// Solve `min c·x` with integrality. Returns `Error::Infeasible` if no
/// integral solution exists (and none was warm-started).
pub fn solve_milp(milp: &Milp, opts: &MilpOptions) -> Result<MilpSolution> {
    let mut incumbent: Option<(Vec<f64>, f64)> = opts.warm_start.clone();
    let mut nodes_explored = 0usize;
    let mut root_basis_out: Option<Vec<usize>> = None;
    let mut branch_order: Vec<usize> = Vec::new();
    let mut lp_warm = 0usize;
    let mut lp_cold = 0usize;
    let mut lp_stats = LpStats::default();

    let root = Node {
        bound: f64::NEG_INFINITY,
        extra: Vec::new(),
        basis: opts.root_basis.clone(),
    };
    let mut heap = BinaryHeap::new();
    heap.push(root);
    let mut proven = true;

    while let Some(node) = heap.pop() {
        // Bound-based pruning against the incumbent.
        if let Some((_, inc_obj)) = &incumbent {
            if node.bound > *inc_obj - opts.rel_gap * inc_obj.abs().max(1.0) {
                continue;
            }
        }
        if nodes_explored >= opts.max_nodes {
            proven = false;
            break;
        }
        nodes_explored += 1;

        // Build the node LP = base + branch bound rows.
        let mut lp = milp.lp.clone();
        for &(var, op, rhs) in &node.extra {
            lp.add_constraint(vec![(var, 1.0)], op, rhs);
        }
        // Warm re-entry from the parent/cached basis; cold solve whenever
        // the simplex layer cannot certify the warm result.
        let outcome = match node.basis.as_deref().and_then(|b| try_warm(&lp, b, &mut lp_stats)) {
            Some(o) => {
                lp_warm += 1;
                o
            }
            None => {
                // Cold node LPs take the candidate-list partial-pricing
                // mode: the optimum *cost* is pivot-path independent (the
                // final full sweep certifies it), and node LPs are the
                // search's hot path. The bit-parity pins stay on
                // `solve_lp`'s full-Dantzig mode.
                lp_cold += 1;
                solve_lp_partial_with_stats(&lp, &mut lp_stats)?
            }
        };
        let sol = match outcome {
            LpOutcome::Optimal(s) => s,
            LpOutcome::Infeasible => continue,
            LpOutcome::Unbounded => {
                return Err(Error::solver("MILP relaxation unbounded"));
            }
        };
        if node.extra.is_empty() {
            root_basis_out = sol.basis.clone();
        }
        if let Some((_, inc_obj)) = &incumbent {
            if sol.objective > *inc_obj - opts.rel_gap * inc_obj.abs().max(1.0) {
                continue;
            }
        }

        let branch_var = first_fractional(&sol.x, &opts.replay_order, opts.int_tol)
            .or_else(|| most_fractional(&sol.x, &opts.priority_vars, opts.int_tol))
            .or_else(|| most_fractional(&sol.x, &milp.integer_vars, opts.int_tol));
        match branch_var {
            None => {
                // Integral: candidate incumbent.
                let obj = sol.objective;
                if incumbent.as_ref().is_none_or(|(_, b)| obj < *b) {
                    incumbent = Some((sol.x, obj));
                }
            }
            Some((var, val)) => {
                if !branch_order.contains(&var) {
                    branch_order.push(var);
                }
                let mut lo = node.extra.clone();
                lo.push((var, Op::Le, val.floor()));
                let mut hi = node.extra;
                hi.push((var, Op::Ge, val.ceil()));
                heap.push(Node { bound: sol.objective, extra: lo, basis: sol.basis.clone() });
                heap.push(Node { bound: sol.objective, extra: hi, basis: sol.basis });
            }
        }
    }

    match incumbent {
        Some((x, objective)) => Ok(MilpSolution {
            // Snap near-integral values.
            x: x.iter()
                .enumerate()
                .map(|(i, &v)| {
                    if milp.integer_vars.contains(&i) {
                        v.round()
                    } else {
                        v
                    }
                })
                .collect(),
            objective,
            nodes: nodes_explored,
            proven_optimal: proven,
            root_basis: root_basis_out,
            branch_order,
            lp_warm,
            lp_cold,
            lp_stats,
        }),
        None => Err(Error::infeasible("MILP has no integral solution")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn milp(num_vars: usize) -> Milp {
        Milp { lp: Lp::new(num_vars), integer_vars: (0..num_vars).collect() }
    }

    #[test]
    fn knapsack_small() {
        // max 10a + 6b + 4c s.t. a+b+c<=2 (integer, binary-ish via <=1 rows)
        let mut m = milp(3);
        m.lp.set_objective(0, -10.0);
        m.lp.set_objective(1, -6.0);
        m.lp.set_objective(2, -4.0);
        m.lp.add_constraint(vec![(0, 1.0), (1, 1.0), (2, 1.0)], Op::Le, 2.0);
        for v in 0..3 {
            m.lp.add_constraint(vec![(v, 1.0)], Op::Le, 1.0);
        }
        let s = solve_milp(&m, &MilpOptions::default()).unwrap();
        assert!((s.objective + 16.0).abs() < 1e-6);
        assert_eq!(s.x[0], 1.0);
        assert_eq!(s.x[1], 1.0);
        assert_eq!(s.x[2], 0.0);
        assert!(s.proven_optimal);
    }

    #[test]
    fn integer_rounding_matters() {
        // min z1 + z2 s.t. z1 + z2 >= 1.5 -> LP 1.5, MILP 2.
        let mut m = milp(2);
        m.lp.set_objective(0, 1.0);
        m.lp.set_objective(1, 1.0);
        m.lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Ge, 1.5);
        let s = solve_milp(&m, &MilpOptions::default()).unwrap();
        assert!((s.objective - 2.0).abs() < 1e-6);
    }

    #[test]
    fn bin_packing_integral() {
        // Cover 10 units: bin A (cap 2, cost 1), bin B (cap 5, cost 1.8).
        // LP: 2xB = 3.6; MILP: 2xB = 3.6 (already integral).
        let mut m = milp(2);
        m.lp.set_objective(0, 1.0);
        m.lp.set_objective(1, 1.8);
        m.lp.add_constraint(vec![(0, 2.0), (1, 5.0)], Op::Ge, 10.0);
        let s = solve_milp(&m, &MilpOptions::default()).unwrap();
        assert!((s.objective - 3.6).abs() < 1e-6);
        assert_eq!(s.x[1], 2.0);
    }

    #[test]
    fn bin_packing_fractional_lp_integral_fix() {
        // Cover 11 units with bin B (cap 5, cost 1.8) only: LP 2.2 bins=3.96,
        // MILP 3 bins = 5.4.
        let mut m = milp(1);
        m.lp.set_objective(0, 1.8);
        m.lp.add_constraint(vec![(0, 5.0)], Op::Ge, 11.0);
        let s = solve_milp(&m, &MilpOptions::default()).unwrap();
        assert!((s.objective - 5.4).abs() < 1e-6);
        assert_eq!(s.x[0], 3.0);
    }

    #[test]
    fn infeasible_reported() {
        let mut m = milp(1);
        m.lp.set_objective(0, 1.0);
        m.lp.add_constraint(vec![(0, 1.0)], Op::Ge, 2.0);
        m.lp.add_constraint(vec![(0, 1.0)], Op::Le, 1.0);
        assert!(solve_milp(&m, &MilpOptions::default()).is_err());
    }

    #[test]
    fn warm_start_used_when_node_limit_zero() {
        let mut m = milp(1);
        m.lp.set_objective(0, 1.0);
        m.lp.add_constraint(vec![(0, 1.0)], Op::Ge, 3.0);
        let opts = MilpOptions {
            max_nodes: 0,
            warm_start: Some((vec![5.0], 5.0)),
            ..Default::default()
        };
        let s = solve_milp(&m, &opts).unwrap();
        assert_eq!(s.objective, 5.0);
        assert!(!s.proven_optimal);
    }

    #[test]
    fn warm_start_improved_upon() {
        let mut m = milp(1);
        m.lp.set_objective(0, 1.0);
        m.lp.add_constraint(vec![(0, 1.0)], Op::Ge, 3.0);
        let opts = MilpOptions {
            warm_start: Some((vec![10.0], 10.0)),
            ..Default::default()
        };
        let s = solve_milp(&m, &opts).unwrap();
        assert_eq!(s.objective, 3.0);
    }

    #[test]
    fn node_lps_resume_warm_from_parent_bases() {
        // A MILP that must branch: children re-enter the simplex from the
        // parent basis, so warm LP solves dominate once branching starts.
        let mut m = milp(2);
        m.lp.set_objective(0, 1.0);
        m.lp.set_objective(1, 1.1);
        m.lp.add_constraint(vec![(0, 2.0), (1, 3.0)], Op::Ge, 7.5);
        let s = solve_milp(&m, &MilpOptions::default()).unwrap();
        assert!(s.proven_optimal);
        assert!(s.nodes > 1, "expected branching, got {} nodes", s.nodes);
        assert!(s.lp_warm > 0, "no node LP resumed warm: {s:?}");
        assert_eq!(s.lp_warm + s.lp_cold, s.nodes);
        assert!(!s.branch_order.is_empty());
    }

    #[test]
    fn delta_resolve_with_hints_matches_cold() {
        // Same structure, different RHS (a demand count moved): seeding the
        // cached root basis + branching order must reproduce the cold
        // optimum exactly.
        let build = |rhs: f64| {
            let mut m = milp(3);
            m.lp.set_objective(0, 1.0);
            m.lp.set_objective(1, 1.8);
            m.lp.set_objective(2, 2.9);
            m.lp.add_constraint(vec![(0, 2.0), (1, 5.0), (2, 9.0)], Op::Ge, rhs);
            m.lp.add_constraint(vec![(0, 1.0), (1, 1.0), (2, 1.0)], Op::Le, 50.0);
            m
        };
        let first = solve_milp(&build(23.0), &MilpOptions::default()).unwrap();
        assert!(first.proven_optimal);
        for rhs in [21.0, 24.0, 31.0] {
            let m2 = build(rhs);
            let cold = solve_milp(&m2, &MilpOptions::default()).unwrap();
            let warm_opts = MilpOptions {
                root_basis: first.root_basis.clone(),
                replay_order: first.branch_order.clone(),
                ..Default::default()
            };
            let warm = solve_milp(&m2, &warm_opts).unwrap();
            assert!(warm.proven_optimal && cold.proven_optimal);
            assert!(
                (warm.objective - cold.objective).abs() < 1e-9,
                "rhs={rhs}: warm {} != cold {}",
                warm.objective,
                cold.objective
            );
        }
    }

    #[test]
    fn bogus_hints_never_change_the_answer() {
        let mut m = milp(2);
        m.lp.set_objective(0, 1.0);
        m.lp.set_objective(1, 1.0);
        m.lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Ge, 1.5);
        let cold = solve_milp(&m, &MilpOptions::default()).unwrap();
        let opts = MilpOptions {
            root_basis: Some(vec![0, 0, 7, 99]), // wrong length & duplicates
            replay_order: vec![42, 17],          // out of range
            ..Default::default()
        };
        let warm = solve_milp(&m, &opts).unwrap();
        assert!((warm.objective - cold.objective).abs() < 1e-9);
        assert!(warm.proven_optimal);
    }

    #[test]
    fn property_milp_at_least_lp() {
        // For random covering problems, MILP objective >= LP objective.
        use crate::solver::simplex::{solve_lp, LpOutcome};
        use crate::util::Rng;
        let mut rng = Rng::new(77);
        for _ in 0..20 {
            let n = 4 + rng.index(4);
            let mut m = milp(n);
            for j in 0..n {
                m.lp.set_objective(j, rng.range_f64(1.0, 3.0));
            }
            for _ in 0..3 {
                let coeffs: Vec<(usize, f64)> =
                    (0..n).map(|j| (j, rng.range_f64(0.5, 2.0))).collect();
                m.lp.add_constraint(coeffs, Op::Ge, rng.range_f64(1.0, 6.0));
            }
            let lp_obj = match solve_lp(&m.lp).unwrap() {
                LpOutcome::Optimal(s) => s.objective,
                _ => continue,
            };
            let s = solve_milp(&m, &MilpOptions::default()).unwrap();
            assert!(s.objective >= lp_obj - 1e-6);
            // Integrality holds.
            for &i in &m.integer_vars {
                assert!((s.x[i] - s.x[i].round()).abs() < 1e-6);
            }
        }
    }
}
