//! Dense two-phase primal simplex, plus a warm re-entry path.
//!
//! Solves `min c·x  s.t.  A x (≤|≥|=) b,  x ≥ 0`. Suited to the small/medium
//! dense LPs produced by the packing formulations (≤ a few thousand
//! variables). Uses Dantzig pricing with a Bland's-rule fallback to guarantee
//! termination under degeneracy.
//!
//! [`solve_lp`] reports the optimal basis alongside the solution (when it is
//! free of artificial columns), and [`resume_from_basis`] re-enters the
//! simplex from such a basis: the basis is re-installed by direct pivoting
//! and, when only the right-hand side changed since the basis was optimal
//! (the delta-solve case — a demand count moved between two re-plans), a
//! dual-simplex pass restores feasibility in a handful of pivots instead of
//! a cold two-phase solve. The warm path is *certified*: it either returns
//! an outcome with exactly `solve_lp`'s meaning or reports `NotCertified`,
//! in which case the caller must solve cold.

use crate::error::{Error, Result};

/// Constraint sense.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Le,
    Ge,
    Eq,
}

/// A sparse row: Σ coeffs · x (op) rhs.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub op: Op,
    pub rhs: f64,
}

impl Constraint {
    pub fn new(coeffs: Vec<(usize, f64)>, op: Op, rhs: f64) -> Self {
        Constraint { coeffs, op, rhs }
    }
}

/// `min objective·x` subject to `constraints`, `x ≥ 0`.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    pub num_vars: usize,
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

impl Lp {
    pub fn new(num_vars: usize) -> Self {
        Lp { num_vars, objective: vec![0.0; num_vars], constraints: Vec::new() }
    }

    pub fn set_objective(&mut self, var: usize, coeff: f64) {
        self.objective[var] = coeff;
    }

    pub fn add_constraint(&mut self, coeffs: Vec<(usize, f64)>, op: Op, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(i, _)| i < self.num_vars));
        self.constraints.push(Constraint::new(coeffs, op, rhs));
    }
}

/// A primal-feasible optimum.
#[derive(Clone, Debug)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    /// Optimal basis over the `[structural | slack]` column space (one
    /// column per row, row-aligned), or `None` when an artificial variable
    /// remained basic — such a basis cannot be re-installed by
    /// [`resume_from_basis`].
    pub basis: Option<Vec<usize>>,
}

/// Solve outcome.
#[derive(Clone, Debug)]
pub enum LpOutcome {
    Optimal(LpSolution),
    Infeasible,
    Unbounded,
}

/// Outcome of a warm re-entry attempt (see [`resume_from_basis`]).
#[derive(Clone, Debug)]
pub enum Resume {
    /// Certified result — identical in meaning to [`solve_lp`]'s.
    Solved(LpOutcome),
    /// The basis could not be installed or certified; solve cold instead.
    NotCertified,
}

const EPS: f64 = 1e-9;
/// Pivot-magnitude floor when re-installing a cached basis.
const PIVOT_EPS: f64 = 1e-7;
/// Feasibility tolerance for the warm path's primal/dual checks.
const FEAS_EPS: f64 = 1e-7;
/// Iterations of Dantzig pricing before switching to Bland's rule.
const BLAND_AFTER: usize = 5_000;
const MAX_ITERS: usize = 200_000;
/// Iteration budget for the warm-path dual repair. A genuine RHS-only delta
/// repairs in a handful of pivots; a degenerate stall must fail fast to
/// `NotCertified` (cold solve) instead of burning the full primal budget.
const DUAL_MAX_ITERS: usize = 2_000;

struct Tableau {
    /// (m+1) x (n+1): rows 0..m constraints, last row objective (reduced costs);
    /// column n is the RHS.
    a: Vec<Vec<f64>>,
    m: usize,
    n: usize,
    basis: Vec<usize>,
}

impl Tableau {
    fn pivot(&mut self, row: usize, col: usize) {
        let piv = self.a[row][col];
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for v in self.a[row].iter_mut() {
            *v *= inv;
        }
        for r in 0..=self.m {
            if r == row {
                continue;
            }
            let factor = self.a[r][col];
            if factor.abs() < EPS {
                continue;
            }
            // Row operation: a[r] -= factor * a[row]. Manual split-borrow.
            let (pivot_row, target_row) = if r < row {
                let (lo, hi) = self.a.split_at_mut(row);
                (&hi[0], &mut lo[r])
            } else {
                let (lo, hi) = self.a.split_at_mut(r);
                (&lo[row], &mut hi[0])
            };
            for (tv, pv) in target_row.iter_mut().zip(pivot_row.iter()) {
                *tv -= factor * pv;
            }
        }
        self.basis[row] = col;
    }

    /// Run simplex iterations on the current objective row. Returns false if
    /// unbounded.
    fn optimize(&mut self) -> Result<bool> {
        for iter in 0..MAX_ITERS {
            let bland = iter >= BLAND_AFTER;
            // Entering column: most negative reduced cost (Dantzig) or first
            // negative (Bland).
            let mut col = None;
            let mut best = -EPS;
            for j in 0..self.n {
                let rc = self.a[self.m][j];
                if rc < -EPS {
                    if bland {
                        col = Some(j);
                        break;
                    }
                    if rc < best {
                        best = rc;
                        col = Some(j);
                    }
                }
            }
            let col = match col {
                Some(c) => c,
                None => return Ok(true), // optimal
            };
            // Leaving row: min ratio test (Bland tie-break on basis index).
            let mut row = None;
            let mut best_ratio = f64::INFINITY;
            for r in 0..self.m {
                let a = self.a[r][col];
                if a > EPS {
                    let ratio = self.a[r][self.n] / a;
                    let better = ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && row.is_some_and(|pr: usize| self.basis[r] < self.basis[pr]));
                    if better {
                        best_ratio = ratio;
                        row = Some(r);
                    }
                }
            }
            match row {
                Some(r) => self.pivot(r, col),
                None => return Ok(false), // unbounded
            }
        }
        Err(Error::solver("simplex iteration limit exceeded"))
    }

    /// Load `objective` into the objective row (remaining columns zero) and
    /// price out the basic variables so reduced costs are consistent.
    fn install_objective(&mut self, objective: &[f64]) {
        for v in self.a[self.m].iter_mut() {
            *v = 0.0;
        }
        for (j, &c) in objective.iter().enumerate() {
            self.a[self.m][j] = c;
        }
        for r in 0..self.m {
            let b = self.basis[r];
            let factor = self.a[self.m][b];
            if factor.abs() > EPS {
                let row_vals: Vec<f64> = self.a[r].clone();
                for (obj_v, row_v) in self.a[self.m].iter_mut().zip(row_vals.iter()) {
                    *obj_v -= factor * row_v;
                }
            }
        }
    }

    /// Dual simplex: starting from a dual-feasible basis (reduced costs
    /// ≥ 0), restore primal feasibility. Returns `Ok(true)` when a
    /// primal-feasible (hence optimal) basis is reached, `Ok(false)` when
    /// primal infeasibility is certified (a row with negative RHS and no
    /// negative coefficient). Deliberately budgeted at `DUAL_MAX_ITERS`:
    /// degenerate stalls surface as an `Err`, which the warm path maps to
    /// `NotCertified` — never wrong, just cold.
    fn dual_optimize(&mut self) -> Result<bool> {
        for _ in 0..DUAL_MAX_ITERS {
            // Leaving row: most negative RHS.
            let mut row = None;
            let mut most = -EPS;
            for r in 0..self.m {
                let b = self.a[r][self.n];
                if b < most {
                    most = b;
                    row = Some(r);
                }
            }
            let Some(r) = row else { return Ok(true) };
            // Entering column: dual ratio test over negative row entries
            // (first minimum kept — deterministic).
            let mut col = None;
            let mut best = f64::INFINITY;
            for j in 0..self.n {
                let arj = self.a[r][j];
                if arj < -EPS {
                    let ratio = self.a[self.m][j].max(0.0) / -arj;
                    if ratio < best {
                        best = ratio;
                        col = Some(j);
                    }
                }
            }
            match col {
                Some(c) => self.pivot(r, c),
                None => return Ok(false), // certified primal infeasible
            }
        }
        Err(Error::solver("dual simplex iteration limit exceeded"))
    }
}

/// Normalize constraint rows to nonnegative RHS (shared by the cold and warm
/// paths so their augmented column layouts agree).
fn normalized_rows(lp: &Lp) -> Vec<(Vec<(usize, f64)>, Op, f64)> {
    let mut rows: Vec<(Vec<(usize, f64)>, Op, f64)> = Vec::with_capacity(lp.constraints.len());
    for c in &lp.constraints {
        let mut coeffs = c.coeffs.clone();
        let mut op = c.op;
        let mut rhs = c.rhs;
        if rhs < 0.0 {
            for (_, v) in coeffs.iter_mut() {
                *v = -*v;
            }
            rhs = -rhs;
            op = match op {
                Op::Le => Op::Ge,
                Op::Ge => Op::Le,
                Op::Eq => Op::Eq,
            };
        }
        rows.push((coeffs, op, rhs));
    }
    rows
}

/// Solve the LP; returns `Optimal`, `Infeasible`, or `Unbounded`.
pub fn solve_lp(lp: &Lp) -> Result<LpOutcome> {
    let n0 = lp.num_vars;
    let m = lp.constraints.len();

    // Normalize rows to rhs >= 0 and count auxiliary columns.
    let rows = normalized_rows(lp);

    let num_slack = rows.iter().filter(|r| r.1 != Op::Eq).count();
    let num_art = rows.iter().filter(|r| r.1 != Op::Le).count();
    let n = n0 + num_slack + num_art;

    let mut a = vec![vec![0.0; n + 1]; m + 1];
    let mut basis = vec![0usize; m];
    let mut slack_idx = n0;
    let mut art_idx = n0 + num_slack;
    let mut art_cols = Vec::with_capacity(num_art);

    for (r, (coeffs, op, rhs)) in rows.iter().enumerate() {
        for &(j, v) in coeffs {
            a[r][j] += v;
        }
        a[r][n] = *rhs;
        match op {
            Op::Le => {
                a[r][slack_idx] = 1.0;
                basis[r] = slack_idx;
                slack_idx += 1;
            }
            Op::Ge => {
                a[r][slack_idx] = -1.0;
                slack_idx += 1;
                a[r][art_idx] = 1.0;
                basis[r] = art_idx;
                art_cols.push(art_idx);
                art_idx += 1;
            }
            Op::Eq => {
                a[r][art_idx] = 1.0;
                basis[r] = art_idx;
                art_cols.push(art_idx);
                art_idx += 1;
            }
        }
    }

    let mut t = Tableau { a, m, n, basis };

    // Phase 1: minimize sum of artificials.
    if num_art > 0 {
        for &c in &art_cols {
            t.a[m][c] = 1.0;
        }
        // Make reduced costs consistent with the starting basis (price out
        // basic artificials).
        for r in 0..m {
            if art_cols.contains(&t.basis[r]) {
                let factor = t.a[m][t.basis[r]];
                if factor.abs() > EPS {
                    let row_vals: Vec<f64> = t.a[r].clone();
                    for (obj_v, row_v) in t.a[m].iter_mut().zip(row_vals.iter()) {
                        *obj_v -= factor * row_v;
                    }
                }
            }
        }
        if !t.optimize()? {
            return Err(Error::solver("phase-1 unbounded (internal error)"));
        }
        if t.a[m][n] < -1e-7 {
            return Ok(LpOutcome::Infeasible);
        }
        // Drive remaining artificials out of the basis where possible.
        for r in 0..m {
            if art_cols.contains(&t.basis[r]) {
                if let Some(col) = (0..n0 + num_slack).find(|&j| t.a[r][j].abs() > 1e-7) {
                    t.pivot(r, col);
                }
                // If no pivot exists the row is redundant (all-zero); leave it.
            }
        }
        // Forbid artificials from re-entering: zero their columns.
        for &c in &art_cols {
            for r in 0..=m {
                t.a[r][c] = 0.0;
            }
        }
    }

    // Phase 2: original objective (priced out against the current basis).
    t.install_objective(&lp.objective);

    if !t.optimize()? {
        return Ok(LpOutcome::Unbounded);
    }

    let mut x = vec![0.0; n0];
    for r in 0..m {
        if t.basis[r] < n0 {
            x[t.basis[r]] = t.a[r][n];
        }
    }
    let objective = lp.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
    // Report the basis only when artificial-free (re-installable later).
    let basis = t.basis.iter().all(|&b| b < n0 + num_slack).then(|| t.basis.clone());
    Ok(LpOutcome::Optimal(LpSolution { x, objective, basis }))
}

/// Re-enter the simplex from a previously optimal basis of a structurally
/// identical LP (same variables, same rows in the same order — typically
/// only the RHS changed). Either certifies an outcome with exactly
/// [`solve_lp`]'s meaning or returns [`Resume::NotCertified`], in which case
/// the caller must fall back to a cold solve. Never less exact than the cold
/// path: the installed basis is re-optimized (dual then primal simplex) to a
/// fully certified optimum.
pub fn resume_from_basis(lp: &Lp, basis: &[usize]) -> Result<Resume> {
    let n0 = lp.num_vars;
    let rows = normalized_rows(lp);
    let m = rows.len();
    if basis.len() != m {
        return Ok(Resume::NotCertified);
    }
    let num_slack = rows.iter().filter(|r| r.1 != Op::Eq).count();
    let n = n0 + num_slack;
    // Reject artificial or duplicate columns outright.
    let mut seen = vec![false; n];
    for &c in basis {
        if c >= n || seen[c] {
            return Ok(Resume::NotCertified);
        }
        seen[c] = true;
    }

    // Artificial-free tableau: structural + slack columns only.
    let mut a = vec![vec![0.0; n + 1]; m + 1];
    let mut slack_idx = n0;
    for (r, (coeffs, op, rhs)) in rows.iter().enumerate() {
        for &(j, v) in coeffs {
            a[r][j] += v;
        }
        a[r][n] = *rhs;
        match op {
            Op::Le => {
                a[r][slack_idx] = 1.0;
                slack_idx += 1;
            }
            Op::Ge => {
                a[r][slack_idx] = -1.0;
                slack_idx += 1;
            }
            Op::Eq => {}
        }
    }
    let mut t = Tableau { a, m, n, basis: vec![0; m] };

    // Install the basis by direct pivoting (partial pivoting over the rows
    // not yet claimed). A cached basis of the same coefficient matrix is
    // nonsingular, so this succeeds unless the matrix actually changed.
    let mut row_free = vec![true; m];
    for &col in basis {
        let mut best_r = None;
        let mut best_v = PIVOT_EPS;
        for (r, free) in row_free.iter().enumerate() {
            if *free {
                let v = t.a[r][col].abs();
                if v > best_v {
                    best_v = v;
                    best_r = Some(r);
                }
            }
        }
        let Some(r) = best_r else {
            return Ok(Resume::NotCertified); // singular w.r.t. this matrix
        };
        t.pivot(r, col);
        row_free[r] = false;
    }

    t.install_objective(&lp.objective);

    let primal_feasible = (0..m).all(|r| t.a[r][n] >= -FEAS_EPS);
    if !primal_feasible {
        // Only the RHS moved: the basis stays dual feasible and a dual
        // simplex pass repairs it. Anything else is not certifiable here.
        if (0..n).any(|j| t.a[m][j] < -FEAS_EPS) {
            return Ok(Resume::NotCertified);
        }
        match t.dual_optimize() {
            Ok(true) => {}
            Ok(false) => return Ok(Resume::Solved(LpOutcome::Infeasible)),
            Err(_) => return Ok(Resume::NotCertified),
        }
    }
    match t.optimize() {
        Ok(true) => {}
        Ok(false) => return Ok(Resume::Solved(LpOutcome::Unbounded)),
        Err(_) => return Ok(Resume::NotCertified),
    }

    let mut x = vec![0.0; n0];
    for r in 0..m {
        if t.basis[r] < n0 {
            x[t.basis[r]] = t.a[r][n];
        }
    }
    let objective = lp.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
    let out_basis = Some(t.basis.clone());
    Ok(Resume::Solved(LpOutcome::Optimal(LpSolution { x, objective, basis: out_basis })))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimal(lp: &Lp) -> LpSolution {
        match solve_lp(lp).unwrap() {
            LpOutcome::Optimal(s) => s,
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn simple_2d_max_as_min() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 => min -3x-5y; opt (2,6)=36.
        let mut lp = Lp::new(2);
        lp.set_objective(0, -3.0);
        lp.set_objective(1, -5.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 4.0);
        lp.add_constraint(vec![(1, 2.0)], Op::Le, 12.0);
        lp.add_constraint(vec![(0, 3.0), (1, 2.0)], Op::Le, 18.0);
        let s = optimal(&lp);
        assert!((s.x[0] - 2.0).abs() < 1e-6);
        assert!((s.x[1] - 6.0).abs() < 1e-6);
        assert!((s.objective + 36.0).abs() < 1e-6);
    }

    #[test]
    fn ge_and_eq_constraints() {
        // min x + 2y s.t. x + y = 10, x >= 3, y >= 2 -> x=8, y=2, obj=12.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 2.0);
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Eq, 10.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Ge, 3.0);
        lp.add_constraint(vec![(1, 1.0)], Op::Ge, 2.0);
        let s = optimal(&lp);
        assert!((s.x[0] - 8.0).abs() < 1e-6);
        assert!((s.x[1] - 2.0).abs() < 1e-6);
        assert!((s.objective - 12.0).abs() < 1e-6);
    }

    #[test]
    fn detects_infeasible() {
        // x >= 5 and x <= 3.
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Ge, 5.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 3.0);
        assert!(matches!(solve_lp(&lp).unwrap(), LpOutcome::Infeasible));
    }

    #[test]
    fn detects_unbounded() {
        // min -x, x >= 0 unconstrained above.
        let mut lp = Lp::new(1);
        lp.set_objective(0, -1.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Ge, 0.0);
        assert!(matches!(solve_lp(&lp).unwrap(), LpOutcome::Unbounded));
    }

    #[test]
    fn negative_rhs_normalization() {
        // min x s.t. -x <= -4  (i.e. x >= 4).
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add_constraint(vec![(0, -1.0)], Op::Le, -4.0);
        let s = optimal(&lp);
        assert!((s.x[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Classic degeneracy: multiple constraints active at the optimum.
        let mut lp = Lp::new(2);
        lp.set_objective(0, -1.0);
        lp.set_objective(1, -1.0);
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Le, 1.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 1.0);
        lp.add_constraint(vec![(1, 1.0)], Op::Le, 1.0);
        lp.add_constraint(vec![(0, 1.0), (1, 2.0)], Op::Le, 2.0);
        let s = optimal(&lp);
        assert!((s.objective + 1.0).abs() < 1e-6);
    }

    #[test]
    fn covering_lp_fractional() {
        // min z1 + z2 s.t. z1 + z2 >= 1.5 -> obj 1.5 (fractional; B&B fixes).
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 1.0);
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Ge, 1.5);
        let s = optimal(&lp);
        assert!((s.objective - 1.5).abs() < 1e-6);
    }

    #[test]
    fn bin_packing_relaxation() {
        // 2 bin types: cost 1 holds 2 units, cost 1.8 holds 5 units; need 10
        // units. LP picks the 1.8 bin: 2 of them = 3.6.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 1.8);
        lp.add_constraint(vec![(0, 2.0), (1, 5.0)], Op::Ge, 10.0);
        let s = optimal(&lp);
        assert!((s.objective - 3.6).abs() < 1e-6);
        assert!((s.x[1] - 2.0).abs() < 1e-6);
    }

    fn resumed(lp: &Lp, basis: &[usize]) -> LpOutcome {
        match resume_from_basis(lp, basis).unwrap() {
            Resume::Solved(o) => o,
            Resume::NotCertified => panic!("expected certified warm resume"),
        }
    }

    #[test]
    fn cold_solve_reports_reinstallable_basis() {
        let mut lp = Lp::new(2);
        lp.set_objective(0, -3.0);
        lp.set_objective(1, -5.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 4.0);
        lp.add_constraint(vec![(1, 2.0)], Op::Le, 12.0);
        lp.add_constraint(vec![(0, 3.0), (1, 2.0)], Op::Le, 18.0);
        let s = optimal(&lp);
        let basis = s.basis.expect("Le-only LP must expose its basis");
        // Re-entering from the optimal basis certifies the same optimum.
        match resumed(&lp, &basis) {
            LpOutcome::Optimal(w) => {
                assert!((w.objective - s.objective).abs() < 1e-9);
                assert!(w.basis.is_some());
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn resume_absorbs_rhs_change_via_dual_simplex() {
        // Covering LP whose RHS moves between re-plans (the delta-solve
        // case): the warm result must match a cold solve of the new LP.
        let build = |rhs: f64| {
            let mut lp = Lp::new(2);
            lp.set_objective(0, 1.0);
            lp.set_objective(1, 1.8);
            lp.add_constraint(vec![(0, 2.0), (1, 5.0)], Op::Ge, rhs);
            lp.add_constraint(vec![(0, 1.0)], Op::Le, 6.0);
            lp
        };
        let s1 = optimal(&build(10.0));
        let basis = s1.basis.expect("artificial-free optimum expected");
        for rhs in [7.0, 10.0, 14.0, 23.0] {
            let lp2 = build(rhs);
            let cold = optimal(&lp2);
            match resumed(&lp2, &basis) {
                LpOutcome::Optimal(w) => assert!(
                    (w.objective - cold.objective).abs() < 1e-9,
                    "rhs={rhs}: warm {} != cold {}",
                    w.objective,
                    cold.objective
                ),
                other => panic!("rhs={rhs}: expected optimal, got {other:?}"),
            }
        }
    }

    #[test]
    fn resume_certifies_infeasibility_after_rhs_change() {
        // min x, x >= 1, x <= 3 is feasible; raising the lower bound past
        // the upper one must surface as a *certified* Infeasible, never a
        // bogus optimum.
        let build = |lo: f64| {
            let mut lp = Lp::new(1);
            lp.set_objective(0, 1.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Le, 3.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Ge, lo);
            lp
        };
        let s = optimal(&build(1.0));
        let basis = s.basis.expect("artificial-free optimum expected");
        match resume_from_basis(&build(5.0), &basis).unwrap() {
            Resume::Solved(LpOutcome::Infeasible) | Resume::NotCertified => {}
            other => panic!("expected infeasible/not-certified, got {other:?}"),
        }
        // A certified outcome must agree with the cold solve.
        assert!(matches!(solve_lp(&build(5.0)).unwrap(), LpOutcome::Infeasible));
    }

    #[test]
    fn resume_rejects_garbage_bases() {
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Ge, 2.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 5.0);
        // Wrong length.
        assert!(matches!(resume_from_basis(&lp, &[0]).unwrap(), Resume::NotCertified));
        // Duplicate column (singular).
        assert!(matches!(resume_from_basis(&lp, &[0, 0]).unwrap(), Resume::NotCertified));
        // Out-of-range column.
        assert!(matches!(resume_from_basis(&lp, &[0, 99]).unwrap(), Resume::NotCertified));
    }

    #[test]
    fn property_resume_matches_cold_on_rhs_perturbations() {
        use crate::util::Rng;
        let mut rng = Rng::new(2024);
        let mut certified = 0usize;
        for round in 0..30 {
            let n = 3 + rng.index(4);
            let m = 2 + rng.index(3);
            let mk = |rhs: &[f64]| {
                let mut lp = Lp::new(n);
                let mut r2 = Rng::new(9000 + round as u64);
                for j in 0..n {
                    lp.set_objective(j, r2.range_f64(0.5, 2.0));
                }
                for &b in rhs.iter().take(m) {
                    let coeffs: Vec<(usize, f64)> =
                        (0..n).map(|j| (j, r2.range_f64(0.1, 1.5))).collect();
                    lp.add_constraint(coeffs, Op::Ge, b);
                }
                lp
            };
            let rhs1: Vec<f64> = (0..m).map(|_| rng.range_f64(1.0, 5.0)).collect();
            let rhs2: Vec<f64> = rhs1.iter().map(|&b| b + rng.range_f64(-0.8, 0.8)).collect();
            let LpOutcome::Optimal(s1) = solve_lp(&mk(&rhs1)).unwrap() else {
                continue;
            };
            let Some(basis) = s1.basis else { continue };
            let lp2 = mk(&rhs2);
            let cold = match solve_lp(&lp2).unwrap() {
                LpOutcome::Optimal(s) => s.objective,
                _ => continue,
            };
            match resume_from_basis(&lp2, &basis).unwrap() {
                Resume::Solved(LpOutcome::Optimal(w)) => {
                    certified += 1;
                    assert!(
                        (w.objective - cold).abs() < 1e-7,
                        "round {round}: warm {} != cold {cold}",
                        w.objective
                    );
                }
                Resume::Solved(other) => panic!("round {round}: warm {other:?}, cold optimal"),
                Resume::NotCertified => {} // falling back cold is always legal
            }
        }
        assert!(certified >= 10, "warm path certified only {certified}/30 rounds");
    }

    #[test]
    fn larger_random_lp_sane() {
        // Random feasible covering LP: objective stays finite & nonnegative.
        use crate::util::Rng;
        let mut rng = Rng::new(123);
        let n = 40;
        let m = 25;
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_objective(j, rng.range_f64(0.5, 2.0));
        }
        for _ in 0..m {
            let mut coeffs: Vec<(usize, f64)> = Vec::new();
            for j in 0..n {
                if rng.bool(0.3) {
                    coeffs.push((j, rng.range_f64(0.1, 1.0)));
                }
            }
            if coeffs.is_empty() {
                continue;
            }
            lp.add_constraint(coeffs, Op::Ge, rng.range_f64(0.5, 3.0));
        }
        let s = optimal(&lp);
        assert!(s.objective >= 0.0 && s.objective.is_finite());
        assert!(s.x.iter().all(|&v| v >= -1e-9));
    }
}
