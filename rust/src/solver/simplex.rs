//! Revised simplex on a factorized basis, plus a warm re-entry path.
//!
//! Solves `min c·x  s.t.  A x (≤|≥|=) b,  x ≥ 0`. The production solver
//! ([`solve_lp`]) is a two-phase *revised* simplex: the basis inverse is kept
//! as a product-form eta factorization ([`super::factor`]), the entering
//! column is reconstructed by FTRAN and the pricing row by BTRAN, so each
//! iteration costs `O(nnz(A) + m + |eta file|)` instead of the dense
//! tableau's `O(m·n)` row sweep. The dense tableau survives as
//! [`solve_lp_dense`] — the reference implementation the property suite
//! holds the revised path to, bit for bit.
//!
//! Both paths share the pivot rules (two-tier Dantzig with a degenerate-band
//! skip and a Bland fallback, EPS-windowed ratio tests tie-broken on basic
//! variable ids) and a canonical finalization that recomputes the solution
//! from the final basis by one deterministic dense solve. Equal bases thus
//! yield bit-identical objectives and solutions, which is what makes the
//! revised==dense parity property in `tests/properties.rs` checkable with
//! `==` rather than tolerances.
//!
//! ## Pricing modes
//!
//! The revised path prices entering columns in one of two modes
//! ([`Pricing`]):
//!
//! * [`Pricing::Dantzig`] — the default and the *reference* mode: every
//!   iteration BTRANs the basic costs and prices **all** non-basic columns.
//!   This is the mode the bit-for-bit revised==dense property is pinned on
//!   ([`solve_lp`] uses it).
//! * [`Pricing::PartialCandidates`] — candidate-list partial pricing
//!   ([`solve_lp_partial`]): a bounded list of attractive columns is built
//!   by a full sweep, then most iterations reprice *only the list* against
//!   fresh multipliers, falling back to a full sweep when the list runs
//!   dry. Per-iteration pricing cost drops from `O(n·nnz)` to the candidate
//!   budget. Optimality is only ever declared by a full sweep that prices
//!   every column — the final sweep is the optimality certificate — so the
//!   mode returns exact optima (same objective as dense to ≤ 1e-9; the
//!   pivot *path* may differ, so bit-parity is not promised).
//!
//! Pricing work is observable through [`LpStats`]: `pricing_iterations`,
//! `priced_columns` (their ratio is the priced-columns-per-iteration metric
//! `bench_solver` reports) and `full_sweeps`, alongside the eta-file fill
//! watermark/cap exported from the factorization.
//!
//! [`solve_lp`] reports the optimal basis alongside the solution (when it is
//! free of artificial columns), and [`resume_from_basis`] re-enters the
//! simplex from such a basis by *crash-factorizing* it directly — no
//! pivot-by-pivot re-installation — then repairing RHS drift by dual simplex
//! (the delta-solve case: demand counts moved between two re-plans). The
//! warm path is *certified*: it either returns an outcome with exactly
//! [`solve_lp`]'s meaning or reports `NotCertified`, in which case the
//! caller must solve cold. [`complete_basis`] extends a partial basis (the
//! shared sub-block of a memoized basis after a bounded structural delta)
//! into a full crash candidate for the same machinery.

use crate::error::{Error, Result};
use crate::solver::factor::{Builder, Factorization};

/// Constraint sense.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Le,
    Ge,
    Eq,
}

/// A sparse row: Σ coeffs · x (op) rhs.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub op: Op,
    pub rhs: f64,
}

impl Constraint {
    pub fn new(coeffs: Vec<(usize, f64)>, op: Op, rhs: f64) -> Self {
        Constraint { coeffs, op, rhs }
    }
}

/// `min objective·x` subject to `constraints`, `x ≥ 0`.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    pub num_vars: usize,
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

impl Lp {
    pub fn new(num_vars: usize) -> Self {
        Lp { num_vars, objective: vec![0.0; num_vars], constraints: Vec::new() }
    }

    pub fn set_objective(&mut self, var: usize, coeff: f64) {
        self.objective[var] = coeff;
    }

    pub fn add_constraint(&mut self, coeffs: Vec<(usize, f64)>, op: Op, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(i, _)| i < self.num_vars));
        self.constraints.push(Constraint::new(coeffs, op, rhs));
    }
}

/// A primal-feasible optimum.
#[derive(Clone, Debug)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    /// Optimal basis over the `[structural | slack]` column space (one
    /// column per row, row-aligned), or `None` when an artificial variable
    /// remained basic — such a basis cannot be re-installed by
    /// [`resume_from_basis`].
    pub basis: Option<Vec<usize>>,
}

/// Solve outcome.
#[derive(Clone, Debug)]
pub enum LpOutcome {
    Optimal(LpSolution),
    Infeasible,
    Unbounded,
}

/// Outcome of a warm re-entry attempt (see [`resume_from_basis`]).
#[derive(Clone, Debug)]
pub enum Resume {
    /// Certified result — identical in meaning to [`solve_lp`]'s.
    Solved(LpOutcome),
    /// The basis could not be installed or certified; solve cold instead.
    NotCertified,
}

/// Per-solve counters surfaced up through `SolveStats` and the pipeline
/// metrics. All zero-cost to maintain; the `_with_stats` entry points
/// accumulate into a caller-owned instance.
#[derive(Clone, Copy, Debug, Default)]
pub struct LpStats {
    /// Simplex pivots executed (both phases, primal and dual).
    pub iterations: u64,
    /// Pivots whose min-ratio was ~0: the basis changed but the point did
    /// not move (the degeneracy the two-tier pricing works to avoid).
    pub degenerate_pivots: u64,
    /// Column solves against the factorization (revised path only).
    pub ftran_ops: u64,
    /// Row/multiplier solves against the factorization (revised path only).
    pub btran_ops: u64,
    /// Eta-file rebuilds triggered mid-solve (revised path only).
    pub refactorizations: u64,
    /// Pricing rounds executed (one per simplex iteration on the revised
    /// path — full sweeps and candidate-list repricings both count).
    pub pricing_iterations: u64,
    /// Columns actually priced, summed over all pricing rounds. Divided by
    /// `pricing_iterations` this is the priced-columns-per-iteration metric:
    /// ~`n` under full Dantzig, far below `n` under partial pricing.
    pub priced_columns: u64,
    /// Pricing rounds that swept every non-basic column (every round under
    /// full Dantzig; candidate-list refreshes and the final optimality
    /// certificate under partial pricing).
    pub full_sweeps: u64,
    /// High-water mark of the eta file's nonzero count (max-merged on
    /// [`absorb`](Self::absorb), since it is a watermark, not a flow).
    pub eta_fill_watermark: u64,
    /// Measured-fill refactorization cap in force at the end of the solve
    /// (max-merged on absorb). `eta_fill_watermark` staying within
    /// `eta_fill_cap + m + 1` is the bounded-fill guarantee.
    pub eta_fill_cap: u64,
}

impl LpStats {
    pub fn absorb(&mut self, other: &LpStats) {
        self.iterations += other.iterations;
        self.degenerate_pivots += other.degenerate_pivots;
        self.ftran_ops += other.ftran_ops;
        self.btran_ops += other.btran_ops;
        self.refactorizations += other.refactorizations;
        self.pricing_iterations += other.pricing_iterations;
        self.priced_columns += other.priced_columns;
        self.full_sweeps += other.full_sweeps;
        self.eta_fill_watermark = self.eta_fill_watermark.max(other.eta_fill_watermark);
        self.eta_fill_cap = self.eta_fill_cap.max(other.eta_fill_cap);
    }
}

/// Entering-column pricing strategy for the revised simplex (see the
/// module docs' *Pricing modes* section).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Pricing {
    /// Full Dantzig pricing: every iteration prices all non-basic columns.
    /// The reference mode the bit-for-bit revised==dense property pins.
    #[default]
    Dantzig,
    /// Candidate-list partial pricing: reprice a bounded list most
    /// iterations, refresh it (and certify optimality) with full sweeps.
    PartialCandidates,
}

const EPS: f64 = 1e-9;
/// Pivot-magnitude floor when installing a cached basis or driving out a
/// basic artificial.
const PIVOT_EPS: f64 = 1e-7;
/// Feasibility tolerance for the warm path's primal/dual checks.
const FEAS_EPS: f64 = 1e-7;
/// Reduced costs in `(-RC_DEGEN_BAND, 0)` are treated as degenerate noise:
/// two-tier Dantzig pricing only falls back to them when no strongly
/// negative column exists.
const RC_DEGEN_BAND: f64 = 1e-7;
/// Iterations of Dantzig pricing before switching to Bland's rule.
const BLAND_AFTER: usize = 5_000;

/// Candidate-list size for partial pricing: an eighth of the columns,
/// clamped to `[16, 128]`. Small LPs keep enough candidates to certify
/// cheaply; huge LPs bound the per-refill sort and the list's repricing
/// cost.
pub(crate) fn partial_candidate_cap(n: usize) -> usize {
    (n / 8).clamp(16, 128)
}
const MAX_ITERS: usize = 200_000;
/// Iteration budget for the warm-path dual repair. A genuine RHS-only delta
/// repairs in a handful of pivots; a degenerate stall must fail fast to
/// `NotCertified` (cold solve) instead of burning the full primal budget.
const DUAL_MAX_ITERS: usize = 2_000;

/// Entering-column rule shared by the dense and revised paths: two-tier
/// Dantzig (most negative reduced cost, skipping the degenerate near-zero
/// band unless nothing else qualifies) with an EPS window so that only a
/// decisively more negative column displaces an earlier one — ulp-level
/// noise between the two paths cannot flip the choice. Bland's rule (first
/// negative column) takes over after `BLAND_AFTER` iterations.
fn choose_entering(n: usize, bland: bool, rc: impl Fn(usize) -> f64) -> Option<usize> {
    if bland {
        return (0..n).find(|&j| rc(j) < -EPS);
    }
    let mut col = None;
    let mut best = f64::INFINITY;
    for j in 0..n {
        let r = rc(j);
        if r < -RC_DEGEN_BAND && r < best - EPS {
            best = r;
            col = Some(j);
        }
    }
    if col.is_some() {
        return col;
    }
    let mut best = f64::INFINITY;
    for j in 0..n {
        let r = rc(j);
        if r < -EPS && r < best - EPS {
            best = r;
            col = Some(j);
        }
    }
    col
}

/// Leaving-row rule shared by both paths: min-ratio test with an EPS window,
/// ties broken toward the smallest basic *variable id* (not row index, so
/// the choice is independent of internal row permutations). Returns the
/// winning position and its ratio, or `None` (unbounded direction).
fn choose_leaving(
    m: usize,
    basis: &[usize],
    entry: impl Fn(usize) -> f64,
    rhs: impl Fn(usize) -> f64,
) -> Option<(usize, f64)> {
    let mut row: Option<usize> = None;
    let mut best_ratio = f64::INFINITY;
    for r in 0..m {
        let a = entry(r);
        if a > EPS {
            let ratio = rhs(r) / a;
            let better = ratio < best_ratio - EPS
                || (ratio < best_ratio + EPS
                    && row.is_some_and(|pr: usize| basis[r] < basis[pr]));
            if better {
                best_ratio = ratio;
                row = Some(r);
            }
        }
    }
    row.map(|r| (r, best_ratio))
}

/// Normalize constraint rows to nonnegative RHS (shared by the cold and warm
/// paths so their augmented column layouts agree).
fn normalized_rows(lp: &Lp) -> Vec<(Vec<(usize, f64)>, Op, f64)> {
    let mut rows: Vec<(Vec<(usize, f64)>, Op, f64)> = Vec::with_capacity(lp.constraints.len());
    for c in &lp.constraints {
        let mut coeffs = c.coeffs.clone();
        let mut op = c.op;
        let mut rhs = c.rhs;
        if rhs < 0.0 {
            for (_, v) in coeffs.iter_mut() {
                *v = -*v;
            }
            rhs = -rhs;
            op = match op {
                Op::Le => Op::Ge,
                Op::Ge => Op::Le,
                Op::Eq => Op::Eq,
            };
        }
        rows.push((coeffs, op, rhs));
    }
    rows
}

/// Column-major view of the normalized rows in the canonical augmented
/// layout `[structural | slack | artificial]` (artificials optional). The
/// slack/artificial index assignment matches the dense tableau's exactly.
struct ColumnLayout {
    cols: Vec<Vec<(usize, f64)>>,
    b: Vec<f64>,
    /// Structural + slack column count (the artificial-free prefix).
    n_real: usize,
    slack_of_row: Vec<Option<usize>>,
    art_of_row: Vec<Option<usize>>,
}

fn column_layout(n0: usize, rows: &[(Vec<(usize, f64)>, Op, f64)], with_art: bool) -> ColumnLayout {
    let m = rows.len();
    let num_slack = rows.iter().filter(|r| r.1 != Op::Eq).count();
    let num_art = if with_art { rows.iter().filter(|r| r.1 != Op::Le).count() } else { 0 };
    let n_real = n0 + num_slack;
    let mut cols = vec![Vec::new(); n_real + num_art];
    let mut b = vec![0.0; m];
    let mut slack_of_row = vec![None; m];
    let mut art_of_row = vec![None; m];
    let mut slack_idx = n0;
    let mut art_idx = n_real;
    for (r, (coeffs, op, rhs)) in rows.iter().enumerate() {
        b[r] = *rhs;
        for &(j, v) in coeffs {
            cols[j].push((r, v));
        }
        match op {
            Op::Le => {
                cols[slack_idx].push((r, 1.0));
                slack_of_row[r] = Some(slack_idx);
                slack_idx += 1;
            }
            Op::Ge => {
                cols[slack_idx].push((r, -1.0));
                slack_of_row[r] = Some(slack_idx);
                slack_idx += 1;
                if with_art {
                    cols[art_idx].push((r, 1.0));
                    art_of_row[r] = Some(art_idx);
                    art_idx += 1;
                }
            }
            Op::Eq => {
                if with_art {
                    cols[art_idx].push((r, 1.0));
                    art_of_row[r] = Some(art_idx);
                    art_idx += 1;
                }
            }
        }
    }
    ColumnLayout { cols, b, n_real, slack_of_row, art_of_row }
}

/// Canonical solution extraction shared by every solve path: recompute the
/// basic values from the final basis with one deterministic dense solve
/// (partial pivoting on max magnitude, first row winning ties, fixed
/// elimination and summation order). Two paths that agree on the final
/// basis therefore return bit-identical `x` and `objective`, regardless of
/// how their iteration arithmetic drifted apart along the way.
fn finalize_solution(
    lp: &Lp,
    cols: &[Vec<(usize, f64)>],
    b: &[f64],
    basis: &[usize],
    n_real: usize,
) -> LpSolution {
    let m = b.len();
    let mut a = vec![vec![0.0; m + 1]; m];
    for (p, &c) in basis.iter().enumerate() {
        for &(i, v) in &cols[c] {
            a[i][p] += v;
        }
    }
    for (r, &rhs) in b.iter().enumerate() {
        a[r][m] = rhs;
    }
    for k in 0..m {
        let mut pr = k;
        let mut pv = a[k][k].abs();
        for (r, row) in a.iter().enumerate().skip(k + 1) {
            let v = row[k].abs();
            if v > pv {
                pv = v;
                pr = r;
            }
        }
        if pv <= 1e-12 {
            continue; // numerically singular column; its value stays zero
        }
        a.swap(k, pr);
        let inv = 1.0 / a[k][k];
        let (pivot_row, rest) = a[k..].split_first_mut().expect("k < m");
        for row in rest {
            let f = row[k] * inv;
            for (tv, pv) in row.iter_mut().zip(pivot_row.iter()).skip(k) {
                *tv -= f * pv;
            }
        }
    }
    let mut xb = vec![0.0; m];
    for k in (0..m).rev() {
        let mut s = a[k][m];
        for j in (k + 1)..m {
            s -= a[k][j] * xb[j];
        }
        let d = a[k][k];
        xb[k] = if d.abs() > 1e-12 { s / d } else { 0.0 };
    }
    let mut x = vec![0.0; lp.num_vars];
    for (p, &c) in basis.iter().enumerate() {
        if c < lp.num_vars {
            x[c] = xb[p];
        }
    }
    let objective = lp.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
    let out = basis.iter().all(|&c| c < n_real).then(|| basis.to_vec());
    LpSolution { x, objective, basis: out }
}

// ---------------------------------------------------------------------------
// Revised simplex (production path)
// ---------------------------------------------------------------------------

/// Revised-simplex state: column-major constraint matrix, a factorized
/// basis, and the basic values — everything indexed by *position* (the slot
/// in the row-aligned basis vector), with the factorization's internal row
/// permutation hidden behind [`Factorization::row`].
struct Revised {
    m: usize,
    n: usize,
    n_real: usize,
    num_art: usize,
    cols: Vec<Vec<(usize, f64)>>,
    b: Vec<f64>,
    costs: Vec<f64>,
    basis: Vec<usize>,
    x: Vec<f64>,
    fact: Factorization,
    in_basis: Vec<bool>,
    barred: Vec<bool>,
    stats: LpStats,
    pricing: Pricing,
    /// Scratch: simplex multipliers (BTRAN output), reused every round.
    y: Vec<f64>,
    /// Scratch: reduced costs per column, reused every round.
    rc: Vec<f64>,
    /// Scratch: FTRAN column / unit-row BTRAN vector, reused every round.
    zcol: Vec<f64>,
    /// Candidate list of attractive non-basic columns (partial pricing).
    candidates: Vec<usize>,
    /// Iterations before falling back to Bland's rule ([`BLAND_AFTER`]
    /// everywhere except tests, which lower it to pin the fallback path).
    bland_after: usize,
}

impl Revised {
    fn build_cold(lp: &Lp) -> Revised {
        let rows = normalized_rows(lp);
        let m = rows.len();
        let lay = column_layout(lp.num_vars, &rows, true);
        let n = lay.cols.len();
        let mut basis = Vec::with_capacity(m);
        for (r, row) in rows.iter().enumerate() {
            let col = match row.1 {
                Op::Le => lay.slack_of_row[r],
                Op::Ge | Op::Eq => lay.art_of_row[r],
            };
            basis.push(col.expect("starting column exists for every row"));
        }
        let mut in_basis = vec![false; n];
        for &c in &basis {
            in_basis[c] = true;
        }
        let x = lay.b.clone();
        Revised {
            m,
            n,
            n_real: lay.n_real,
            num_art: n - lay.n_real,
            cols: lay.cols,
            b: lay.b,
            costs: vec![0.0; n],
            basis,
            x,
            fact: Factorization::identity(m),
            in_basis,
            barred: vec![false; n],
            stats: LpStats::default(),
            pricing: Pricing::Dantzig,
            y: vec![0.0; m],
            rc: vec![0.0; n],
            zcol: vec![0.0; m],
            candidates: Vec::new(),
            bland_after: BLAND_AFTER,
        }
    }

    /// Crash-factorize a cached basis directly — the warm path's whole point
    /// is that no pivot-by-pivot re-installation happens. `None` when the
    /// basis is malformed or numerically singular for this matrix.
    fn build_resume(lp: &Lp, basis_in: &[usize]) -> Option<Revised> {
        let rows = normalized_rows(lp);
        let m = rows.len();
        if basis_in.len() != m {
            return None;
        }
        let lay = column_layout(lp.num_vars, &rows, false);
        let n = lay.cols.len();
        let mut seen = vec![false; n];
        for &c in basis_in {
            if c >= n || seen[c] {
                return None;
            }
            seen[c] = true;
        }
        let bcols: Vec<Vec<(usize, f64)>> = basis_in.iter().map(|&c| lay.cols[c].clone()).collect();
        let mut fact = Factorization::factorize(m, &bcols)?;
        let mut z = lay.b.clone();
        fact.ftran(&mut z);
        let x: Vec<f64> = (0..m).map(|p| z[fact.row(p)]).collect();
        let mut costs = vec![0.0; n];
        costs[..lp.num_vars].copy_from_slice(&lp.objective);
        Some(Revised {
            m,
            n,
            n_real: n,
            num_art: 0,
            cols: lay.cols,
            b: lay.b,
            costs,
            basis: basis_in.to_vec(),
            x,
            fact,
            in_basis: seen,
            barred: vec![false; n],
            stats: LpStats::default(),
            pricing: Pricing::Dantzig,
            y: vec![0.0; m],
            rc: vec![0.0; n],
            zcol: vec![0.0; m],
            candidates: Vec::new(),
            bland_after: BLAND_AFTER,
        })
    }

    /// Scatter column `j` and FTRAN it: the tableau column, indexed by
    /// internal row (read position `p` at `fact.row(p)`). The returned
    /// buffer is the `zcol` scratch; [`pivot_update`](Self::pivot_update)
    /// hands it back, so the steady-state loop allocates nothing.
    fn ftran_col(&mut self, j: usize) -> Vec<f64> {
        let mut z = std::mem::take(&mut self.zcol);
        z.clear();
        z.resize(self.m, 0.0);
        for &(i, v) in &self.cols[j] {
            z[i] += v;
        }
        self.fact.ftran(&mut z);
        z
    }

    /// BTRAN the basic costs into simplex multipliers (the `y` scratch).
    /// Recomputed fresh each pricing round, so reduced costs never
    /// accumulate drift across pivots.
    fn compute_multipliers(&mut self) {
        self.y.iter_mut().for_each(|v| *v = 0.0);
        for p in 0..self.m {
            self.y[self.fact.row(p)] = self.costs[self.basis[p]];
        }
        self.fact.btran(&mut self.y);
    }

    /// Reduced cost of column `j` against the current multipliers.
    #[inline]
    fn price(&self, j: usize) -> f64 {
        let dot: f64 = self.cols[j].iter().map(|&(i, v)| self.y[i] * v).sum();
        self.costs[j] - dot
    }

    /// Fresh multipliers plus a full pricing sweep into the `rc` scratch —
    /// the only pricing that can certify optimality, and the one the
    /// Dantzig mode runs every iteration.
    fn full_price(&mut self) {
        self.compute_multipliers();
        self.stats.pricing_iterations += 1;
        self.stats.full_sweeps += 1;
        let mut priced = 0u64;
        let mut rc = std::mem::take(&mut self.rc);
        for (j, out) in rc.iter_mut().enumerate() {
            *out = 0.0;
            if self.in_basis[j] || self.barred[j] {
                continue;
            }
            *out = self.price(j);
            priced += 1;
        }
        self.rc = rc;
        self.stats.priced_columns += priced;
    }

    /// One partial-pricing round: reprice the surviving candidates against
    /// fresh multipliers, dropping columns that entered the basis or are no
    /// longer improving; when the list runs dry, run a full sweep and refill
    /// it with the `cap` most attractive strictly improving columns (kept in
    /// ascending column order for determinism). Returns `false` when the
    /// full sweep found no improving column — the optimality certificate.
    fn prime_candidates(&mut self, cap: usize) -> bool {
        self.compute_multipliers();
        self.stats.pricing_iterations += 1;
        let mut cands = std::mem::take(&mut self.candidates);
        let mut rc = std::mem::take(&mut self.rc);
        let mut priced = 0u64;
        cands.retain(|&j| {
            if self.in_basis[j] || self.barred[j] {
                return false;
            }
            let r = self.price(j);
            rc[j] = r;
            priced += 1;
            r < -EPS
        });
        if cands.is_empty() {
            self.stats.full_sweeps += 1;
            for (j, out) in rc.iter_mut().enumerate() {
                *out = 0.0;
                if self.in_basis[j] || self.barred[j] {
                    continue;
                }
                let r = self.price(j);
                *out = r;
                priced += 1;
                if r < -EPS {
                    cands.push(j);
                }
            }
            if cands.len() > cap {
                cands.sort_by(|&a, &b| {
                    rc[a]
                        .partial_cmp(&rc[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                cands.truncate(cap);
                cands.sort_unstable();
            }
        }
        self.stats.priced_columns += priced;
        let have = !cands.is_empty();
        self.candidates = cands;
        self.rc = rc;
        have
    }

    /// Execute the basis exchange: update the basic values with exactly the
    /// dense tableau's RHS arithmetic (scale by the pivot reciprocal, then
    /// subtract, skipping sub-EPS factors), absorb the pivot as an eta
    /// update, and refactorize if the eta file has grown past its threshold.
    fn pivot_update(&mut self, p: usize, col: usize, z: Vec<f64>) -> Result<()> {
        let r = self.fact.row(p);
        let piv = z[r];
        if piv.abs() <= EPS {
            return Err(Error::solver("vanishing pivot in factorized update"));
        }
        let inv = 1.0 / piv;
        let xr = self.x[p] * inv;
        for q in 0..self.m {
            if q == p {
                continue;
            }
            let zq = z[self.fact.row(q)];
            if zq.abs() >= EPS {
                self.x[q] -= zq * xr;
            }
        }
        self.x[p] = xr;
        if !self.fact.update(p, &z) {
            return Err(Error::solver("vanishing pivot in factorized update"));
        }
        self.in_basis[self.basis[p]] = false;
        self.in_basis[col] = true;
        self.basis[p] = col;
        // Hand the FTRAN scratch back before a possible refactorization
        // (which borrows it to re-derive the basic values).
        self.zcol = z;
        if self.fact.should_refactorize() {
            self.refresh_factorization();
        }
        Ok(())
    }

    /// Rebuild the eta file from the current basis columns and refresh the
    /// basic values from the fresh factorization (the drift repair).
    fn refresh_factorization(&mut self) {
        let bcols: Vec<Vec<(usize, f64)>> =
            self.basis.iter().map(|&c| self.cols[c].clone()).collect();
        if self.fact.refactorize(&bcols) {
            let mut z = std::mem::take(&mut self.zcol);
            z.clear();
            z.extend_from_slice(&self.b);
            self.fact.ftran(&mut z);
            for p in 0..self.m {
                self.x[p] = z[self.fact.row(p)];
            }
            self.zcol = z;
        }
    }

    /// Primal simplex on the current costs under the configured pricing
    /// mode. `Ok(true)` at optimality, `Ok(false)` on an unbounded
    /// direction.
    fn optimize(&mut self, max_iters: usize) -> Result<bool> {
        match self.pricing {
            Pricing::Dantzig => self.optimize_dantzig(max_iters),
            Pricing::PartialCandidates => self.optimize_partial(max_iters),
        }
    }

    /// Full-Dantzig loop: every iteration is a full pricing sweep. The
    /// reference mode — its pivot sequence is what the dense tableau
    /// reproduces bit for bit.
    fn optimize_dantzig(&mut self, max_iters: usize) -> Result<bool> {
        for iter in 0..max_iters {
            let bland = iter >= self.bland_after;
            self.full_price();
            let Some(col) = choose_entering(self.n, bland, |j| self.rc[j]) else {
                return Ok(true);
            };
            let z = self.ftran_col(col);
            let leave =
                choose_leaving(self.m, &self.basis, |p| z[self.fact.row(p)], |p| self.x[p]);
            match leave {
                Some((p, ratio)) => {
                    if ratio <= EPS {
                        self.stats.degenerate_pivots += 1;
                    }
                    self.stats.iterations += 1;
                    self.pivot_update(p, col, z)?;
                }
                None => return Ok(false),
            }
        }
        Err(Error::solver("simplex iteration limit exceeded"))
    }

    /// Candidate-list loop: cheap repricing of a bounded list most
    /// iterations, full sweeps only to refresh it — and any claim of
    /// optimality comes from a full sweep inside
    /// [`prime_candidates`](Self::prime_candidates), never from the list
    /// alone. A degenerate stall falls back to the Dantzig loop (which
    /// itself escalates to Bland's rule), so termination matches the
    /// reference mode's guarantee.
    fn optimize_partial(&mut self, max_iters: usize) -> Result<bool> {
        let cap = partial_candidate_cap(self.n);
        self.candidates.clear();
        for iter in 0..max_iters {
            if iter >= self.bland_after {
                return self.optimize_dantzig(max_iters - iter);
            }
            if !self.prime_candidates(cap) {
                return Ok(true);
            }
            let pick =
                choose_entering(self.candidates.len(), false, |k| self.rc[self.candidates[k]]);
            let Some(k) = pick else {
                // Every candidate sits inside the EPS window's blind spot;
                // drop the list and let the next round's full sweep decide.
                self.candidates.clear();
                continue;
            };
            let col = self.candidates[k];
            let z = self.ftran_col(col);
            let leave =
                choose_leaving(self.m, &self.basis, |p| z[self.fact.row(p)], |p| self.x[p]);
            match leave {
                Some((p, ratio)) => {
                    if ratio <= EPS {
                        self.stats.degenerate_pivots += 1;
                    }
                    self.stats.iterations += 1;
                    self.pivot_update(p, col, z)?;
                }
                None => return Ok(false),
            }
        }
        Err(Error::solver("simplex iteration limit exceeded"))
    }

    /// Dual simplex: from a dual-feasible basis, restore primal feasibility.
    /// `Ok(true)` when primal-feasible (hence optimal), `Ok(false)` when
    /// primal infeasibility is certified. Budgeted at `DUAL_MAX_ITERS`:
    /// degenerate stalls surface as an `Err`, which the warm path maps to
    /// `NotCertified` — never wrong, just cold.
    fn dual_optimize(&mut self) -> Result<bool> {
        for _ in 0..DUAL_MAX_ITERS {
            // Leaving position: most negative basic value (first minimum).
            let mut leave = None;
            let mut most = -EPS;
            for (p, &v) in self.x.iter().enumerate() {
                if v < most {
                    most = v;
                    leave = Some(p);
                }
            }
            let Some(p) = leave else { return Ok(true) };
            // Pricing row for the leaving position, via BTRAN of its unit
            // vector (in the reused scratch); entering column by the dual
            // ratio test over negative row entries (first minimum kept —
            // deterministic).
            let r = self.fact.row(p);
            let mut rho = std::mem::take(&mut self.zcol);
            rho.clear();
            rho.resize(self.m, 0.0);
            rho[r] = 1.0;
            self.fact.btran(&mut rho);
            self.full_price();
            let mut col = None;
            let mut best = f64::INFINITY;
            for j in 0..self.n {
                if self.in_basis[j] || self.barred[j] {
                    continue;
                }
                let arj: f64 = self.cols[j].iter().map(|&(i, v)| rho[i] * v).sum();
                if arj < -EPS {
                    let ratio = self.rc[j].max(0.0) / -arj;
                    if ratio < best {
                        best = ratio;
                        col = Some(j);
                    }
                }
            }
            self.zcol = rho;
            match col {
                Some(c) => {
                    let z = self.ftran_col(c);
                    if z[r].abs() <= EPS {
                        return Err(Error::solver("dual pivot vanished under factorization"));
                    }
                    self.stats.iterations += 1;
                    self.pivot_update(p, c, z)?;
                }
                None => return Ok(false), // certified primal infeasible
            }
        }
        Err(Error::solver("dual simplex iteration limit exceeded"))
    }

    /// Drive basic artificials out after phase 1 where a real pivot exists
    /// (mirrors the dense drive-out scan: first structural/slack column with
    /// a usable pivot row entry; redundant rows keep their artificial).
    fn drive_out_artificials(&mut self) -> Result<()> {
        for p in 0..self.m {
            if self.basis[p] < self.n_real {
                continue;
            }
            let r = self.fact.row(p);
            let mut rho = vec![0.0; self.m];
            rho[r] = 1.0;
            self.fact.btran(&mut rho);
            for j in 0..self.n_real {
                if self.in_basis[j] {
                    continue;
                }
                let alpha: f64 = self.cols[j].iter().map(|&(i, v)| rho[i] * v).sum();
                if alpha.abs() > PIVOT_EPS {
                    let z = self.ftran_col(j);
                    if z[r].abs() > EPS {
                        self.pivot_update(p, j, z)?;
                        break;
                    }
                    self.zcol = z;
                }
            }
            // No usable column: the row is redundant; the artificial stays
            // basic at (numerical) zero and the basis reports as
            // non-reinstallable.
        }
        Ok(())
    }

    fn run_cold(&mut self, lp: &Lp) -> Result<LpOutcome> {
        if self.num_art > 0 {
            // Phase 1: minimize the artificial sum.
            for j in self.n_real..self.n {
                self.costs[j] = 1.0;
            }
            if !self.optimize(MAX_ITERS)? {
                return Err(Error::solver("phase-1 unbounded (internal error)"));
            }
            let infeas: f64 = (0..self.m)
                .filter(|&p| self.basis[p] >= self.n_real)
                .map(|p| self.x[p])
                .sum();
            if infeas > 1e-7 {
                return Ok(LpOutcome::Infeasible);
            }
            self.drive_out_artificials()?;
            for j in self.n_real..self.n {
                self.costs[j] = 0.0;
                self.barred[j] = true;
            }
        }
        // Phase 2: the original objective.
        self.costs[..lp.num_vars].copy_from_slice(&lp.objective);
        if !self.optimize(MAX_ITERS)? {
            return Ok(LpOutcome::Unbounded);
        }
        Ok(LpOutcome::Optimal(self.finalize(lp)))
    }

    fn run_resume(&mut self, lp: &Lp) -> Result<Resume> {
        let primal_feasible = self.x.iter().all(|&v| v >= -FEAS_EPS);
        if !primal_feasible {
            // Only the RHS moved: the basis stays dual feasible and a dual
            // simplex pass repairs it. Anything else is not certifiable.
            self.full_price();
            if self.rc.iter().any(|&v| v < -FEAS_EPS) {
                return Ok(Resume::NotCertified);
            }
            match self.dual_optimize() {
                Ok(true) => {}
                Ok(false) => return Ok(Resume::Solved(LpOutcome::Infeasible)),
                Err(_) => return Ok(Resume::NotCertified),
            }
        }
        match self.optimize(MAX_ITERS) {
            Ok(true) => {}
            Ok(false) => return Ok(Resume::Solved(LpOutcome::Unbounded)),
            Err(_) => return Ok(Resume::NotCertified),
        }
        Ok(Resume::Solved(LpOutcome::Optimal(self.finalize(lp))))
    }

    fn finalize(&self, lp: &Lp) -> LpSolution {
        finalize_solution(lp, &self.cols, &self.b, &self.basis, self.n_real)
    }

    /// Fold the factorization's operation counters and fill telemetry into
    /// the solve stats.
    fn merge_fact_stats(&mut self) {
        self.stats.ftran_ops += self.fact.ftran_count;
        self.stats.btran_ops += self.fact.btran_count;
        self.stats.refactorizations += self.fact.refactorizations;
        self.stats.eta_fill_watermark =
            self.stats.eta_fill_watermark.max(self.fact.fill_watermark() as u64);
        self.stats.eta_fill_cap = self.stats.eta_fill_cap.max(self.fact.fill_cap() as u64);
    }
}

/// Solve the LP with the revised simplex; returns `Optimal`, `Infeasible`,
/// or `Unbounded`. Uses full-Dantzig pricing — the reference mode the
/// bit-for-bit revised==dense property pins.
pub fn solve_lp(lp: &Lp) -> Result<LpOutcome> {
    solve_lp_with_stats(lp, &mut LpStats::default())
}

/// [`solve_lp`], accumulating iteration/pricing/FTRAN/BTRAN/refactorization
/// counts into `stats`.
pub fn solve_lp_with_stats(lp: &Lp, stats: &mut LpStats) -> Result<LpOutcome> {
    solve_lp_with_pricing(lp, Pricing::Dantzig, stats)
}

/// Solve the LP with the revised simplex under candidate-list partial
/// pricing — the production hot-path mode: exact optima (certified by a
/// final full pricing sweep), much less pricing work per iteration, but no
/// bit-for-bit pivot-path guarantee against the dense reference.
pub fn solve_lp_partial(lp: &Lp) -> Result<LpOutcome> {
    solve_lp_partial_with_stats(lp, &mut LpStats::default())
}

/// [`solve_lp_partial`] with counter accumulation into `stats`.
pub fn solve_lp_partial_with_stats(lp: &Lp, stats: &mut LpStats) -> Result<LpOutcome> {
    solve_lp_with_pricing(lp, Pricing::PartialCandidates, stats)
}

/// Solve the LP with the revised simplex under an explicit [`Pricing`]
/// mode, accumulating counters into `stats`.
pub fn solve_lp_with_pricing(lp: &Lp, pricing: Pricing, stats: &mut LpStats) -> Result<LpOutcome> {
    let mut rv = Revised::build_cold(lp);
    rv.pricing = pricing;
    let out = rv.run_cold(lp);
    rv.merge_fact_stats();
    stats.absorb(&rv.stats);
    out
}

/// Re-enter the simplex from a previously optimal basis of a structurally
/// identical LP (same variables, same rows in the same order — typically
/// only the RHS changed). The basis is installed as a *crash
/// factorization* — one sparsity-ordered refactorization of its columns, no
/// pivot-by-pivot re-installation — then certified: either an outcome with
/// exactly [`solve_lp`]'s meaning is returned, or [`Resume::NotCertified`],
/// in which case the caller must fall back to a cold solve. Never less
/// exact than the cold path: the installed basis is re-optimized (dual then
/// primal simplex) to a fully certified optimum.
pub fn resume_from_basis(lp: &Lp, basis: &[usize]) -> Result<Resume> {
    resume_from_basis_with_stats(lp, basis, &mut LpStats::default())
}

/// [`resume_from_basis`] with counter accumulation into `stats`.
pub fn resume_from_basis_with_stats(
    lp: &Lp,
    basis: &[usize],
    stats: &mut LpStats,
) -> Result<Resume> {
    let Some(mut rv) = Revised::build_resume(lp, basis) else {
        return Ok(Resume::NotCertified);
    };
    let out = rv.run_resume(lp);
    rv.merge_fact_stats();
    stats.absorb(&rv.stats);
    out
}

/// Extend a partial basis (columns carried over from a structurally related
/// solve — the shared sub-block of a memoized basis) into a full basis
/// candidate for [`resume_from_basis`]. Dependent or out-of-range columns
/// are dropped; unclaimed rows are filled by their own slack when possible,
/// then by a scan for any independent column. Returns `None` when the
/// partial set covers less than half the rows (a crash from so little is
/// not worth attempting) or no completion exists — callers then solve cold.
pub fn complete_basis(lp: &Lp, partial: &[usize]) -> Option<Vec<usize>> {
    let rows = normalized_rows(lp);
    let m = rows.len();
    if m == 0 {
        return Some(Vec::new());
    }
    let lay = column_layout(lp.num_vars, &rows, false);
    let n = lay.cols.len();
    let mut seen = vec![false; n];
    let mut builder = Builder::new(m);
    let mut out: Vec<usize> = Vec::with_capacity(m);
    for &c in partial {
        if c >= n || seen[c] {
            continue;
        }
        seen[c] = true;
        let z = builder.transformed(&lay.cols[c]);
        if builder.pivot_best_row(out.len(), z).is_some() {
            out.push(c);
        }
    }
    if out.len() * 2 < m {
        return None;
    }
    for r in builder.unclaimed() {
        // Prefer the row's own slack — the cheapest independent column.
        let mut filled = false;
        if let Some(s) = lay.slack_of_row[r] {
            if !seen[s] {
                let z = builder.transformed(&lay.cols[s]);
                if builder.pivot_at(out.len(), r, z) {
                    seen[s] = true;
                    out.push(s);
                    filled = true;
                }
            }
        }
        if !filled {
            for j in 0..n {
                if seen[j] {
                    continue;
                }
                let z = builder.transformed(&lay.cols[j]);
                if z[r].abs() > PIVOT_EPS && builder.pivot_at(out.len(), r, z) {
                    seen[j] = true;
                    out.push(j);
                    filled = true;
                    break;
                }
            }
        }
        if !filled {
            return None;
        }
    }
    (out.len() == m).then_some(out)
}

// ---------------------------------------------------------------------------
// Dense tableau (reference path)
// ---------------------------------------------------------------------------

struct Tableau {
    /// (m+1) x (n+1): rows 0..m constraints, last row objective (reduced
    /// costs); column n is the RHS.
    a: Vec<Vec<f64>>,
    m: usize,
    n: usize,
    basis: Vec<usize>,
}

impl Tableau {
    fn pivot(&mut self, row: usize, col: usize) {
        let piv = self.a[row][col];
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for v in self.a[row].iter_mut() {
            *v *= inv;
        }
        for r in 0..=self.m {
            if r == row {
                continue;
            }
            let factor = self.a[r][col];
            if factor.abs() < EPS {
                continue;
            }
            // Row operation: a[r] -= factor * a[row]. Manual split-borrow.
            let (pivot_row, target_row) = if r < row {
                let (lo, hi) = self.a.split_at_mut(row);
                (&hi[0], &mut lo[r])
            } else {
                let (lo, hi) = self.a.split_at_mut(r);
                (&lo[row], &mut hi[0])
            };
            for (tv, pv) in target_row.iter_mut().zip(pivot_row.iter()) {
                *tv -= factor * pv;
            }
        }
        self.basis[row] = col;
    }

    /// Run simplex iterations on the current objective row (same entering /
    /// leaving rules as the revised path). Returns false if unbounded.
    fn optimize(&mut self, stats: &mut LpStats) -> Result<bool> {
        for iter in 0..MAX_ITERS {
            let bland = iter >= BLAND_AFTER;
            let obj = &self.a[self.m];
            let col = match choose_entering(self.n, bland, |j| obj[j]) {
                Some(c) => c,
                None => return Ok(true), // optimal
            };
            let leave =
                choose_leaving(self.m, &self.basis, |r| self.a[r][col], |r| self.a[r][self.n]);
            match leave {
                Some((r, ratio)) => {
                    if ratio <= EPS {
                        stats.degenerate_pivots += 1;
                    }
                    stats.iterations += 1;
                    self.pivot(r, col);
                }
                None => return Ok(false), // unbounded
            }
        }
        Err(Error::solver("simplex iteration limit exceeded"))
    }

    /// Load `objective` into the objective row (remaining columns zero) and
    /// price out the basic variables so reduced costs are consistent.
    fn install_objective(&mut self, objective: &[f64]) {
        for v in self.a[self.m].iter_mut() {
            *v = 0.0;
        }
        for (j, &c) in objective.iter().enumerate() {
            self.a[self.m][j] = c;
        }
        for r in 0..self.m {
            let b = self.basis[r];
            let factor = self.a[self.m][b];
            if factor.abs() > EPS {
                // Split-borrow the objective row from the constraint rows
                // instead of cloning the row (same subtraction order).
                let (rows, obj) = self.a.split_at_mut(self.m);
                for (obj_v, row_v) in obj[0].iter_mut().zip(rows[r].iter()) {
                    *obj_v -= factor * row_v;
                }
            }
        }
    }
}

/// Dense two-phase tableau solve — the reference implementation the revised
/// path is held to bit-for-bit (see `tests/properties.rs`), kept for the
/// parity property and the `bench_solver` dense-vs-revised comparison.
pub fn solve_lp_dense(lp: &Lp) -> Result<LpOutcome> {
    solve_lp_dense_with_stats(lp, &mut LpStats::default())
}

/// [`solve_lp_dense`] with iteration counting into `stats` (FTRAN/BTRAN
/// counters stay zero — there is no factorization to consult).
pub fn solve_lp_dense_with_stats(lp: &Lp, stats: &mut LpStats) -> Result<LpOutcome> {
    let n0 = lp.num_vars;
    let m = lp.constraints.len();

    // Normalize rows to rhs >= 0 and count auxiliary columns.
    let rows = normalized_rows(lp);

    let num_slack = rows.iter().filter(|r| r.1 != Op::Eq).count();
    let num_art = rows.iter().filter(|r| r.1 != Op::Le).count();
    let n = n0 + num_slack + num_art;

    let mut a = vec![vec![0.0; n + 1]; m + 1];
    let mut basis = vec![0usize; m];
    let mut slack_idx = n0;
    let mut art_idx = n0 + num_slack;
    let mut art_cols = Vec::with_capacity(num_art);

    for (r, (coeffs, op, rhs)) in rows.iter().enumerate() {
        for &(j, v) in coeffs {
            a[r][j] += v;
        }
        a[r][n] = *rhs;
        match op {
            Op::Le => {
                a[r][slack_idx] = 1.0;
                basis[r] = slack_idx;
                slack_idx += 1;
            }
            Op::Ge => {
                a[r][slack_idx] = -1.0;
                slack_idx += 1;
                a[r][art_idx] = 1.0;
                basis[r] = art_idx;
                art_cols.push(art_idx);
                art_idx += 1;
            }
            Op::Eq => {
                a[r][art_idx] = 1.0;
                basis[r] = art_idx;
                art_cols.push(art_idx);
                art_idx += 1;
            }
        }
    }

    let mut t = Tableau { a, m, n, basis };

    // Phase 1: minimize sum of artificials.
    if num_art > 0 {
        for &c in &art_cols {
            t.a[m][c] = 1.0;
        }
        // Make reduced costs consistent with the starting basis (price out
        // basic artificials).
        for r in 0..m {
            if art_cols.contains(&t.basis[r]) {
                let factor = t.a[m][t.basis[r]];
                if factor.abs() > EPS {
                    let (rows, obj) = t.a.split_at_mut(m);
                    for (obj_v, row_v) in obj[0].iter_mut().zip(rows[r].iter()) {
                        *obj_v -= factor * row_v;
                    }
                }
            }
        }
        if !t.optimize(stats)? {
            return Err(Error::solver("phase-1 unbounded (internal error)"));
        }
        if t.a[m][n] < -1e-7 {
            return Ok(LpOutcome::Infeasible);
        }
        // Drive remaining artificials out of the basis where possible.
        for r in 0..m {
            if art_cols.contains(&t.basis[r]) {
                if let Some(col) = (0..n0 + num_slack).find(|&j| t.a[r][j].abs() > 1e-7) {
                    t.pivot(r, col);
                }
                // If no pivot exists the row is redundant (all-zero); leave it.
            }
        }
        // Forbid artificials from re-entering: zero their columns.
        for &c in &art_cols {
            for r in 0..=m {
                t.a[r][c] = 0.0;
            }
        }
    }

    // Phase 2: original objective (priced out against the current basis).
    t.install_objective(&lp.objective);

    if !t.optimize(stats)? {
        return Ok(LpOutcome::Unbounded);
    }

    let lay = column_layout(n0, &rows, true);
    Ok(LpOutcome::Optimal(finalize_solution(lp, &lay.cols, &lay.b, &t.basis, lay.n_real)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimal(lp: &Lp) -> LpSolution {
        match solve_lp(lp).unwrap() {
            LpOutcome::Optimal(s) => s,
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn simple_2d_max_as_min() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 => min -3x-5y; opt (2,6)=36.
        let mut lp = Lp::new(2);
        lp.set_objective(0, -3.0);
        lp.set_objective(1, -5.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 4.0);
        lp.add_constraint(vec![(1, 2.0)], Op::Le, 12.0);
        lp.add_constraint(vec![(0, 3.0), (1, 2.0)], Op::Le, 18.0);
        let s = optimal(&lp);
        assert!((s.x[0] - 2.0).abs() < 1e-6);
        assert!((s.x[1] - 6.0).abs() < 1e-6);
        assert!((s.objective + 36.0).abs() < 1e-6);
    }

    #[test]
    fn ge_and_eq_constraints() {
        // min x + 2y s.t. x + y = 10, x >= 3, y >= 2 -> x=8, y=2, obj=12.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 2.0);
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Eq, 10.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Ge, 3.0);
        lp.add_constraint(vec![(1, 1.0)], Op::Ge, 2.0);
        let s = optimal(&lp);
        assert!((s.x[0] - 8.0).abs() < 1e-6);
        assert!((s.x[1] - 2.0).abs() < 1e-6);
        assert!((s.objective - 12.0).abs() < 1e-6);
    }

    #[test]
    fn detects_infeasible() {
        // x >= 5 and x <= 3.
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Ge, 5.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 3.0);
        assert!(matches!(solve_lp(&lp).unwrap(), LpOutcome::Infeasible));
    }

    #[test]
    fn detects_unbounded() {
        // min -x, x >= 0 unconstrained above.
        let mut lp = Lp::new(1);
        lp.set_objective(0, -1.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Ge, 0.0);
        assert!(matches!(solve_lp(&lp).unwrap(), LpOutcome::Unbounded));
    }

    #[test]
    fn negative_rhs_normalization() {
        // min x s.t. -x <= -4  (i.e. x >= 4).
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add_constraint(vec![(0, -1.0)], Op::Le, -4.0);
        let s = optimal(&lp);
        assert!((s.x[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Classic degeneracy: multiple constraints active at the optimum.
        let mut lp = Lp::new(2);
        lp.set_objective(0, -1.0);
        lp.set_objective(1, -1.0);
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Le, 1.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 1.0);
        lp.add_constraint(vec![(1, 1.0)], Op::Le, 1.0);
        lp.add_constraint(vec![(0, 1.0), (1, 2.0)], Op::Le, 2.0);
        let s = optimal(&lp);
        assert!((s.objective + 1.0).abs() < 1e-6);
    }

    #[test]
    fn covering_lp_fractional() {
        // min z1 + z2 s.t. z1 + z2 >= 1.5 -> obj 1.5 (fractional; B&B fixes).
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 1.0);
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Ge, 1.5);
        let s = optimal(&lp);
        assert!((s.objective - 1.5).abs() < 1e-6);
    }

    #[test]
    fn bin_packing_relaxation() {
        // 2 bin types: cost 1 holds 2 units, cost 1.8 holds 5 units; need 10
        // units. LP picks the 1.8 bin: 2 of them = 3.6.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 1.8);
        lp.add_constraint(vec![(0, 2.0), (1, 5.0)], Op::Ge, 10.0);
        let s = optimal(&lp);
        assert!((s.objective - 3.6).abs() < 1e-6);
        assert!((s.x[1] - 2.0).abs() < 1e-6);
    }

    fn resumed(lp: &Lp, basis: &[usize]) -> LpOutcome {
        match resume_from_basis(lp, basis).unwrap() {
            Resume::Solved(o) => o,
            Resume::NotCertified => panic!("expected certified warm resume"),
        }
    }

    #[test]
    fn cold_solve_reports_reinstallable_basis() {
        let mut lp = Lp::new(2);
        lp.set_objective(0, -3.0);
        lp.set_objective(1, -5.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 4.0);
        lp.add_constraint(vec![(1, 2.0)], Op::Le, 12.0);
        lp.add_constraint(vec![(0, 3.0), (1, 2.0)], Op::Le, 18.0);
        let s = optimal(&lp);
        let basis = s.basis.expect("Le-only LP must expose its basis");
        // Re-entering from the optimal basis certifies the same optimum.
        match resumed(&lp, &basis) {
            LpOutcome::Optimal(w) => {
                assert!((w.objective - s.objective).abs() < 1e-9);
                assert!(w.basis.is_some());
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn resume_absorbs_rhs_change_via_dual_simplex() {
        // Covering LP whose RHS moves between re-plans (the delta-solve
        // case): the warm result must match a cold solve of the new LP.
        let build = |rhs: f64| {
            let mut lp = Lp::new(2);
            lp.set_objective(0, 1.0);
            lp.set_objective(1, 1.8);
            lp.add_constraint(vec![(0, 2.0), (1, 5.0)], Op::Ge, rhs);
            lp.add_constraint(vec![(0, 1.0)], Op::Le, 6.0);
            lp
        };
        let s1 = optimal(&build(10.0));
        let basis = s1.basis.expect("artificial-free optimum expected");
        for rhs in [7.0, 10.0, 14.0, 23.0] {
            let lp2 = build(rhs);
            let cold = optimal(&lp2);
            match resumed(&lp2, &basis) {
                LpOutcome::Optimal(w) => assert!(
                    (w.objective - cold.objective).abs() < 1e-9,
                    "rhs={rhs}: warm {} != cold {}",
                    w.objective,
                    cold.objective
                ),
                other => panic!("rhs={rhs}: expected optimal, got {other:?}"),
            }
        }
    }

    #[test]
    fn resume_certifies_infeasibility_after_rhs_change() {
        // min x, x >= 1, x <= 3 is feasible; raising the lower bound past
        // the upper one must surface as a *certified* Infeasible, never a
        // bogus optimum.
        let build = |lo: f64| {
            let mut lp = Lp::new(1);
            lp.set_objective(0, 1.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Le, 3.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Ge, lo);
            lp
        };
        let s = optimal(&build(1.0));
        let basis = s.basis.expect("artificial-free optimum expected");
        match resume_from_basis(&build(5.0), &basis).unwrap() {
            Resume::Solved(LpOutcome::Infeasible) | Resume::NotCertified => {}
            other => panic!("expected infeasible/not-certified, got {other:?}"),
        }
        // A certified outcome must agree with the cold solve.
        assert!(matches!(solve_lp(&build(5.0)).unwrap(), LpOutcome::Infeasible));
    }

    #[test]
    fn resume_rejects_garbage_bases() {
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Ge, 2.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 5.0);
        // Wrong length.
        assert!(matches!(resume_from_basis(&lp, &[0]).unwrap(), Resume::NotCertified));
        // Duplicate column (singular).
        assert!(matches!(resume_from_basis(&lp, &[0, 0]).unwrap(), Resume::NotCertified));
        // Out-of-range column.
        assert!(matches!(resume_from_basis(&lp, &[0, 99]).unwrap(), Resume::NotCertified));
    }

    #[test]
    fn property_resume_matches_cold_on_rhs_perturbations() {
        use crate::util::Rng;
        let mut rng = Rng::new(2024);
        let mut certified = 0usize;
        for round in 0..30 {
            let n = 3 + rng.index(4);
            let m = 2 + rng.index(3);
            let mk = |rhs: &[f64]| {
                let mut lp = Lp::new(n);
                let mut r2 = Rng::new(9000 + round as u64);
                for j in 0..n {
                    lp.set_objective(j, r2.range_f64(0.5, 2.0));
                }
                for &b in rhs.iter().take(m) {
                    let coeffs: Vec<(usize, f64)> =
                        (0..n).map(|j| (j, r2.range_f64(0.1, 1.5))).collect();
                    lp.add_constraint(coeffs, Op::Ge, b);
                }
                lp
            };
            let rhs1: Vec<f64> = (0..m).map(|_| rng.range_f64(1.0, 5.0)).collect();
            let rhs2: Vec<f64> = rhs1.iter().map(|&b| b + rng.range_f64(-0.8, 0.8)).collect();
            let LpOutcome::Optimal(s1) = solve_lp(&mk(&rhs1)).unwrap() else {
                continue;
            };
            let Some(basis) = s1.basis else { continue };
            let lp2 = mk(&rhs2);
            let cold = match solve_lp(&lp2).unwrap() {
                LpOutcome::Optimal(s) => s.objective,
                _ => continue,
            };
            match resume_from_basis(&lp2, &basis).unwrap() {
                Resume::Solved(LpOutcome::Optimal(w)) => {
                    certified += 1;
                    assert!(
                        (w.objective - cold).abs() < 1e-7,
                        "round {round}: warm {} != cold {cold}",
                        w.objective
                    );
                }
                Resume::Solved(other) => panic!("round {round}: warm {other:?}, cold optimal"),
                Resume::NotCertified => {} // falling back cold is always legal
            }
        }
        assert!(certified >= 10, "warm path certified only {certified}/30 rounds");
    }

    #[test]
    fn larger_random_lp_sane() {
        // Random feasible covering LP: objective stays finite & nonnegative.
        use crate::util::Rng;
        let mut rng = Rng::new(123);
        let n = 40;
        let m = 25;
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_objective(j, rng.range_f64(0.5, 2.0));
        }
        for _ in 0..m {
            let mut coeffs: Vec<(usize, f64)> = Vec::new();
            for j in 0..n {
                if rng.bool(0.3) {
                    coeffs.push((j, rng.range_f64(0.1, 1.0)));
                }
            }
            if coeffs.is_empty() {
                continue;
            }
            lp.add_constraint(coeffs, Op::Ge, rng.range_f64(0.5, 3.0));
        }
        let s = optimal(&lp);
        assert!(s.objective >= 0.0 && s.objective.is_finite());
        assert!(s.x.iter().all(|&v| v >= -1e-9));
    }

    #[test]
    fn revised_matches_dense_bitwise_on_fixture_lps() {
        // Every deterministic fixture above, revised vs dense: identical
        // outcome variants, bit-identical objectives/solutions, equal bases.
        let mut fixtures: Vec<Lp> = Vec::new();
        {
            let mut lp = Lp::new(2);
            lp.set_objective(0, -3.0);
            lp.set_objective(1, -5.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Le, 4.0);
            lp.add_constraint(vec![(1, 2.0)], Op::Le, 12.0);
            lp.add_constraint(vec![(0, 3.0), (1, 2.0)], Op::Le, 18.0);
            fixtures.push(lp);
        }
        {
            let mut lp = Lp::new(2);
            lp.set_objective(0, 1.0);
            lp.set_objective(1, 2.0);
            lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Op::Eq, 10.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Ge, 3.0);
            lp.add_constraint(vec![(1, 1.0)], Op::Ge, 2.0);
            fixtures.push(lp);
        }
        {
            let mut lp = Lp::new(1);
            lp.set_objective(0, 1.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Ge, 5.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Le, 3.0);
            fixtures.push(lp); // infeasible
        }
        {
            let mut lp = Lp::new(1);
            lp.set_objective(0, -1.0);
            lp.add_constraint(vec![(0, 1.0)], Op::Ge, 0.0);
            fixtures.push(lp); // unbounded
        }
        {
            let mut lp = Lp::new(2);
            lp.set_objective(0, 1.0);
            lp.set_objective(1, 1.8);
            lp.add_constraint(vec![(0, 2.0), (1, 5.0)], Op::Ge, 10.0);
            fixtures.push(lp);
        }
        for (k, lp) in fixtures.iter().enumerate() {
            let r = solve_lp(lp).unwrap();
            let d = solve_lp_dense(lp).unwrap();
            match (r, d) {
                (LpOutcome::Optimal(a), LpOutcome::Optimal(b)) => {
                    assert_eq!(
                        a.objective.to_bits(),
                        b.objective.to_bits(),
                        "fixture {k}: objective {} vs {}",
                        a.objective,
                        b.objective
                    );
                    assert_eq!(a.basis, b.basis, "fixture {k}: bases differ");
                    let ax: Vec<u64> = a.x.iter().map(|v| v.to_bits()).collect();
                    let bx: Vec<u64> = b.x.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(ax, bx, "fixture {k}: solutions differ");
                }
                (LpOutcome::Infeasible, LpOutcome::Infeasible) => {}
                (LpOutcome::Unbounded, LpOutcome::Unbounded) => {}
                (r, d) => panic!("fixture {k}: revised {r:?} vs dense {d:?}"),
            }
        }
    }

    #[test]
    fn degenerate_pivot_is_counted() {
        // min -x s.t. x <= 0: the single pivot moves the basis but not the
        // point — counted as degenerate on both paths.
        let mut lp = Lp::new(1);
        lp.set_objective(0, -1.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 0.0);
        let mut rs = LpStats::default();
        assert!(matches!(solve_lp_with_stats(&lp, &mut rs).unwrap(), LpOutcome::Optimal(_)));
        assert_eq!(rs.degenerate_pivots, 1, "revised: {rs:?}");
        assert!(rs.ftran_ops > 0 && rs.btran_ops > 0, "revised: {rs:?}");
        let mut ds = LpStats::default();
        assert!(matches!(solve_lp_dense_with_stats(&lp, &mut ds).unwrap(), LpOutcome::Optimal(_)));
        assert_eq!(ds.degenerate_pivots, 1, "dense: {ds:?}");
    }

    #[test]
    fn complete_basis_fills_a_partial_basis() {
        let mut lp = Lp::new(2);
        lp.set_objective(0, -3.0);
        lp.set_objective(1, -5.0);
        lp.add_constraint(vec![(0, 1.0)], Op::Le, 4.0);
        lp.add_constraint(vec![(1, 2.0)], Op::Le, 12.0);
        lp.add_constraint(vec![(0, 3.0), (1, 2.0)], Op::Le, 18.0);
        let s = optimal(&lp);
        let basis = s.basis.expect("Le-only LP must expose its basis");
        // Drop one column; completion must rebuild a full, resumable basis.
        let partial: Vec<usize> = basis[..basis.len() - 1].to_vec();
        let full = complete_basis(&lp, &partial).expect("completion exists");
        assert_eq!(full.len(), lp.constraints.len());
        match resumed(&lp, &full) {
            LpOutcome::Optimal(w) => {
                assert!((w.objective - s.objective).abs() < 1e-9);
            }
            other => panic!("expected optimal, got {other:?}"),
        }
        // A hopeless partial (under half the rows) is refused outright.
        assert!(complete_basis(&lp, &[]).is_none());
    }

    #[test]
    fn partial_pricing_matches_dense_objectives() {
        // Partial pricing promises exact optima (certified by a final full
        // sweep), not bit-identical pivot paths: outcomes must match the
        // dense reference variant-for-variant, objectives to 1e-9.
        use crate::util::Rng;
        let mut rng = Rng::new(0xCA11D);
        for round in 0..40 {
            let n = 3 + rng.index(12);
            let m = 2 + rng.index(6);
            let mut lp = Lp::new(n);
            for j in 0..n {
                lp.set_objective(j, rng.range_f64(0.5, 2.0));
            }
            for _ in 0..m {
                let mut coeffs: Vec<(usize, f64)> = Vec::new();
                for j in 0..n {
                    if rng.bool(0.5) {
                        coeffs.push((j, rng.range_f64(0.1, 1.5)));
                    }
                }
                if coeffs.is_empty() {
                    continue;
                }
                let op = if rng.bool(0.5) { Op::Ge } else { Op::Le };
                lp.add_constraint(coeffs, op, rng.range_f64(0.5, 4.0));
            }
            let p = solve_lp_partial(&lp).unwrap();
            let d = solve_lp_dense(&lp).unwrap();
            match (p, d) {
                (LpOutcome::Optimal(a), LpOutcome::Optimal(b)) => {
                    assert!(
                        (a.objective - b.objective).abs() <= 1e-9,
                        "round {round}: partial {} vs dense {}",
                        a.objective,
                        b.objective
                    );
                }
                (LpOutcome::Infeasible, LpOutcome::Infeasible) => {}
                (LpOutcome::Unbounded, LpOutcome::Unbounded) => {}
                (p, d) => panic!("round {round}: partial {p:?} vs dense {d:?}"),
            }
        }
    }

    #[test]
    fn partial_pricing_prices_fewer_columns() {
        // A wide covering LP: full Dantzig prices ~n columns per round,
        // the candidate list far fewer on average.
        use crate::util::Rng;
        let mut rng = Rng::new(77);
        let n = 400;
        let m = 12;
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_objective(j, rng.range_f64(0.5, 2.0));
        }
        for _ in 0..m {
            let mut coeffs: Vec<(usize, f64)> = Vec::new();
            for j in 0..n {
                if rng.bool(0.2) {
                    coeffs.push((j, rng.range_f64(0.1, 1.0)));
                }
            }
            lp.add_constraint(coeffs, Op::Ge, rng.range_f64(1.0, 4.0));
        }
        let mut full = LpStats::default();
        assert!(matches!(solve_lp_with_stats(&lp, &mut full).unwrap(), LpOutcome::Optimal(_)));
        let mut part = LpStats::default();
        assert!(matches!(
            solve_lp_partial_with_stats(&lp, &mut part).unwrap(),
            LpOutcome::Optimal(_)
        ));
        assert!(full.pricing_iterations > 0 && part.pricing_iterations > 0);
        let full_per_iter = full.priced_columns as f64 / full.pricing_iterations as f64;
        let part_per_iter = part.priced_columns as f64 / part.pricing_iterations as f64;
        assert!(
            part_per_iter < full_per_iter,
            "partial {part_per_iter:.1} cols/iter !< full {full_per_iter:.1}"
        );
        assert!(part.full_sweeps < part.pricing_iterations || part.pricing_iterations <= 2);
        // Fill telemetry flows through on both modes.
        assert!(full.eta_fill_cap > 0 && part.eta_fill_cap > 0);
    }

    /// Random feasible covering LP: positive costs, `Ge` rows only — so the
    /// cold layout carries one surplus and one artificial per row and the
    /// total column count is exactly `cols + 2 * rows`.
    fn covering_lp_sized(cols: usize, rows: usize, seed: u64) -> Lp {
        use crate::util::Rng;
        let mut rng = Rng::new(seed);
        let mut lp = Lp::new(cols);
        for j in 0..cols {
            lp.set_objective(j, rng.range_f64(1.0, 2.0));
        }
        for r in 0..rows {
            let mut coeffs: Vec<(usize, f64)> = Vec::new();
            for j in 0..cols {
                if rng.bool(0.25) {
                    coeffs.push((j, rng.range_f64(0.5, 1.5)));
                }
            }
            if coeffs.is_empty() {
                coeffs.push((r % cols, 1.0));
            }
            lp.add_constraint(coeffs, Op::Ge, rng.range_f64(1.0, 4.0));
        }
        lp
    }

    #[test]
    fn partial_candidate_cap_clamps_at_the_documented_edges() {
        // Lower clamp: any n up to 16*8 prices at least 16 candidates.
        assert_eq!(partial_candidate_cap(0), 16);
        assert_eq!(partial_candidate_cap(127), 16);
        assert_eq!(partial_candidate_cap(128), 16);
        assert_eq!(partial_candidate_cap(135), 16);
        // First value past the lower clamp.
        assert_eq!(partial_candidate_cap(136), 17);
        // Upper clamp: n/8 saturates at 128 from n = 1024 on.
        assert_eq!(partial_candidate_cap(1023), 127);
        assert_eq!(partial_candidate_cap(1024), 128);
        assert_eq!(partial_candidate_cap(1025), 128);
        assert_eq!(partial_candidate_cap(1 << 20), 128);
    }

    #[test]
    fn partial_pricing_is_exact_at_both_cap_clamp_edges() {
        // Column counts landing exactly on the clamp edges: 96 + 2*16 = 128
        // (last LP still floored to 16 candidates) and 896 + 2*64 = 1024
        // (first LP ceilinged to 128).
        for &(cols, rows) in &[(96usize, 16usize), (896, 64)] {
            let lp = covering_lp_sized(cols, rows, 42);
            let n = cols + 2 * rows;
            let cap = partial_candidate_cap(n);
            let mut ds = LpStats::default();
            let dantzig = match solve_lp_with_stats(&lp, &mut ds).unwrap() {
                LpOutcome::Optimal(sol) => sol.objective,
                other => panic!("reference not optimal: {other:?}"),
            };
            let mut ps = LpStats::default();
            let partial = match solve_lp_partial_with_stats(&lp, &mut ps).unwrap() {
                LpOutcome::Optimal(sol) => sol.objective,
                other => panic!("partial not optimal: {other:?}"),
            };
            assert!(
                (partial - dantzig).abs() < 1e-6,
                "objective drift at n={n}: {partial} vs {dantzig}"
            );
            // Optimality was certified by at least one full sweep, and no
            // pricing round ever priced more than a sweep plus a full
            // candidate list.
            assert!(ps.full_sweeps >= 1, "no certificate sweep at n={n}");
            let bound = ps.full_sweeps * n as u64 + ps.pricing_iterations * cap as u64;
            assert!(
                ps.priced_columns <= bound,
                "n={n}: priced {} > bound {bound} (cap {cap})",
                ps.priced_columns
            );
        }
    }

    #[test]
    fn partial_pricing_stall_falls_back_through_dantzig_to_bland() {
        // Force the stall escape hatch on from iteration zero: the partial
        // loop must hand over to the Dantzig loop, which itself starts in
        // Bland mode — and the chained fallback must still certify the same
        // optimum the reference mode finds.
        let lp = covering_lp_sized(32, 8, 7);
        let want = match solve_lp(&lp).unwrap() {
            LpOutcome::Optimal(sol) => sol.objective,
            other => panic!("reference not optimal: {other:?}"),
        };
        let mut rv = Revised::build_cold(&lp);
        rv.pricing = Pricing::PartialCandidates;
        rv.bland_after = 0;
        match rv.run_cold(&lp).unwrap() {
            LpOutcome::Optimal(sol) => {
                assert!(
                    (sol.objective - want).abs() < 1e-6,
                    "fallback chain lost the optimum: {} vs {want}",
                    sol.objective
                );
            }
            other => panic!("fallback chain must stay exact, got {other:?}"),
        }
        assert!(rv.stats.iterations > 0, "the fallback path did no work");
    }
}
