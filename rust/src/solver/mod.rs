//! Linear and integer programming substrate (the Gurobi 5.0 stand-in).
//!
//! The paper solves the arc-flow formulation of multiple-choice vector bin
//! packing with a Gurobi branch-and-cut solver. Gurobi is proprietary and not
//! available offline, so this module implements:
//!
//! * [`factor`] — product-form (eta) basis factorization: sparsity-ordered
//!   crash factorization, FTRAN/BTRAN transforms, rank-1 pivot updates, and
//!   threshold-driven refactorization,
//! * [`simplex`] — a two-phase *revised* primal simplex over that
//!   factorization (per-iteration cost scales with basis size and column
//!   sparsity, not tableau width), with a certified warm re-entry path
//!   ([`simplex::resume_from_basis`]: crash-factorize a cached optimal
//!   basis, repair RHS drift by dual simplex) and partial-basis completion
//!   ([`simplex::complete_basis`]) for bounded structural deltas. The dense
//!   tableau survives as [`simplex::solve_lp_dense`], the bit-for-bit
//!   reference the property suite holds the revised path to,
//! * [`bnb`] — best-first branch-and-bound over fractional integer variables
//!   with warm-start incumbents (heuristic upper bounds, exactly the role the
//!   paper's FFD-style warm starts play in branch-and-cut), per-node warm LP
//!   resumes from the parent basis, and delta-solve replay of a previous
//!   structurally identical solve's root basis + branching order.
//!
//! Paper-scale instances (tens of stream groups × a dozen instance choices)
//! solve in milliseconds; see `benches/bench_packing.rs` for scaling curves
//! and `benches/bench_solver.rs` for the dense-vs-revised comparison.

pub mod bnb;
pub mod factor;
pub mod simplex;

pub use bnb::{solve_milp, Milp, MilpOptions, MilpSolution};
pub use factor::{Eta, Factorization};
pub use simplex::{
    complete_basis, resume_from_basis, resume_from_basis_with_stats, solve_lp, solve_lp_dense,
    solve_lp_dense_with_stats, solve_lp_partial, solve_lp_partial_with_stats, solve_lp_with_pricing,
    solve_lp_with_stats, Constraint, Lp, LpOutcome, LpSolution, LpStats, Op, Pricing, Resume,
};
