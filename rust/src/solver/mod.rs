//! Linear and integer programming substrate (the Gurobi 5.0 stand-in).
//!
//! The paper solves the arc-flow formulation of multiple-choice vector bin
//! packing with a Gurobi branch-and-cut solver. Gurobi is proprietary and not
//! available offline, so this module implements:
//!
//! * [`simplex`] — a dense two-phase primal simplex for LP relaxations,
//!   with a certified warm re-entry path ([`simplex::resume_from_basis`]:
//!   re-install a cached optimal basis, repair RHS drift by dual simplex),
//! * [`bnb`] — best-first branch-and-bound over fractional integer variables
//!   with warm-start incumbents (heuristic upper bounds, exactly the role the
//!   paper's FFD-style warm starts play in branch-and-cut), per-node warm LP
//!   resumes from the parent basis, and delta-solve replay of a previous
//!   structurally identical solve's root basis + branching order.
//!
//! Paper-scale instances (tens of stream groups × a dozen instance choices)
//! solve in milliseconds; see `benches/bench_packing.rs` for scaling curves.

pub mod bnb;
pub mod simplex;

pub use bnb::{solve_milp, Milp, MilpOptions, MilpSolution};
pub use simplex::{resume_from_basis, solve_lp, Constraint, Lp, LpOutcome, LpSolution, Op, Resume};
