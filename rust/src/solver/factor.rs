//! Basis factorization for the revised simplex: product-form (eta) inverse
//! with a sparsity-ordered crash factorization and rank-1 pivot updates.
//!
//! The dense tableau maintains `B⁻¹A` explicitly and pays `O(m·n)` per pivot.
//! The revised simplex keeps only a factorization of the basis matrix `B` and
//! reconstructs tableau columns/rows on demand:
//!
//! * **FTRAN** — apply the eta file to a column `v`, yielding `P·B⁻¹v` (the
//!   tableau column, up to the internal row permutation `P`),
//! * **BTRAN** — apply the transposed etas in reverse, yielding `B⁻ᵀPᵀy`
//!   (simplex multipliers / a tableau row),
//! * **update** — absorb a basis exchange as one more eta factor built from
//!   the already-FTRANed entering column (a rank-1 product-form update),
//! * **refactorize** — rebuild the eta file from the current basis columns
//!   when the update count or eta fill crosses a threshold, bounding both
//!   work per FTRAN and accumulated drift.
//!
//! Each eta replays *exactly* the row operations the dense tableau's `pivot`
//! performs on a single column (same multiply/subtract order, same `EPS`
//! skip of negligible factors), so until the first refactorization an
//! FTRANed column is bit-for-bit the dense tableau column. This is what lets
//! the revised solver in [`super::simplex`] mirror the dense path's pivot
//! choices and certify bit-identical results (see the parity property in
//! `tests/properties.rs`).
//!
//! ## Eta compaction and the measured-fill trigger
//!
//! Update etas used to be boxed one `Vec` per pivot and retired only by a
//! fixed `16·m + 256` nonzero cap. The file is now *compacted*: every eta's
//! off-row entries live in one flat arena (`EtaFile`), and exact-identity
//! steps (unit pivot, no off-row entries — a bit-exact no-op, since
//! `x · 1.0` preserves bits) are elided on push, so unit-column pivots add
//! zero fill. Both changes are storage-only: the per-entry arithmetic is the
//! same multiply/subtract sequence in the same order, keeping the bit-parity
//! guarantee intact (pinned by `prop_compacted_eta_matches_reference` in
//! `tests/properties.rs`). A true Forrest–Tomlin column merge would break
//! that guarantee, which is why compaction stops at layout + elision.
//!
//! The refactorization trigger is tuned from *measured* fill instead of the
//! fixed cap: the factorization remembers the nonzero count of its last
//! rebuilt base file (`base_nnz`) and retires the file once update fill
//! exceeds `2·base_nnz + 8·m + 256` — a dense base earns proportionally more
//! update headroom, a near-identity base refactorizes sooner. The high-water
//! mark of the file ([`Factorization::fill_watermark`]) is exported through
//! `LpStats` so benches can assert fill stays bounded between rebuilds.
//!
//! Positions vs rows: callers index the basis by *position* `p` (the slot in
//! the row-aligned basis vector, identical to the dense tableau's row). A
//! crash factorization or refactorization is free to pivot position `p` in
//! any internal row; [`Factorization::row`] maps positions to rows so all
//! caller-visible state (basic values, ratio tests, the reported basis) stays
//! in position space with dense-identical semantics.

/// Drop tolerance for eta entries; mirrors the dense pivot's skip of
/// `|factor| < EPS` row operations.
pub(crate) const EPS: f64 = 1e-9;
/// Minimum acceptable pivot magnitude when factorizing a cached basis.
pub(crate) const PIVOT_EPS: f64 = 1e-7;
/// Refactorize after this many product-form updates.
const REFACTOR_UPDATES: usize = 64;
/// ... or when measured fill exceeds `2·base_nnz + 8·m + 256` nonzeros
/// (see [`Factorization::fill_cap`]).
const FILL_SLACK_PER_ROW: usize = 8;
const FILL_SLACK_BASE: usize = 256;

/// One Gauss-Jordan elimination step: pivot in `row`, eliminating the pivot
/// column from every other row. `entries` holds the pre-elimination column
/// values outside the pivot row (negligible ones dropped), `inv` the pivot
/// reciprocal.
#[derive(Clone, Debug)]
pub struct Eta {
    /// Pivot row of this elimination step.
    pub row: usize,
    /// Reciprocal of the pivot entry.
    pub inv: f64,
    /// `(row, value)` column entries outside the pivot row.
    pub entries: Vec<(usize, f64)>,
}

impl Eta {
    /// FTRAN step: the dense `pivot`'s column arithmetic, verbatim —
    /// `x[row] *= inv`, then `x[i] -= v·x[row]` for each recorded entry.
    #[inline]
    pub fn apply(&self, x: &mut [f64]) {
        let xr = x[self.row] * self.inv;
        for &(i, v) in &self.entries {
            x[i] -= v * xr;
        }
        x[self.row] = xr;
    }

    /// BTRAN step: the transposed elimination.
    #[inline]
    pub fn apply_transposed(&self, y: &mut [f64]) {
        let mut s = y[self.row];
        for &(i, v) in &self.entries {
            s -= v * y[i];
        }
        y[self.row] = s * self.inv;
    }

    /// Build the eta for a pivot at `row` from an FTRANed column `z`,
    /// dropping entries the dense pivot would skip. `None` if the pivot
    /// entry is numerically unusable.
    fn from_column(z: &[f64], row: usize) -> Option<Eta> {
        let piv = z[row];
        if piv.abs() <= EPS {
            return None;
        }
        let mut entries = Vec::new();
        for (i, &v) in z.iter().enumerate() {
            if i != row && v.abs() >= EPS {
                entries.push((i, v));
            }
        }
        Some(Eta { row, inv: 1.0 / piv, entries })
    }

    /// Whether applying this eta is a bit-exact no-op: unit pivot (so
    /// `x[row] · 1.0` preserves bits) and no off-row entries.
    fn is_identity(&self) -> bool {
        self.entries.is_empty() && self.inv.to_bits() == 1.0f64.to_bits()
    }
}

/// Compacted eta file: every eta's off-row entries live in one flat arena,
/// each eta head holding only `(pivot row, pivot reciprocal, arena offset)`.
/// Exact-identity etas are elided on push. Both are storage-only changes —
/// the applied arithmetic is [`Eta::apply`]'s loop, entry for entry, in the
/// same order, so transforms stay bit-for-bit equal to a boxed
/// `Vec<Eta>` replay of the same pivots.
#[derive(Clone, Debug, Default)]
struct EtaFile {
    /// Per eta: pivot row, pivot reciprocal, start offset into `entries`.
    heads: Vec<(u32, f64, u32)>,
    /// Off-row elimination entries of every eta, concatenated in push order.
    entries: Vec<(u32, f64)>,
}

impl EtaFile {
    fn clear(&mut self) {
        self.heads.clear();
        self.entries.clear();
    }

    /// Nonzeros held: one pivot reciprocal per eta plus all off-row entries.
    fn nnz(&self) -> usize {
        self.heads.len() + self.entries.len()
    }

    /// Append an eta, eliding exact identities; returns the nonzeros added.
    fn push(&mut self, eta: &Eta) -> usize {
        if eta.is_identity() {
            return 0;
        }
        self.heads.push((eta.row as u32, eta.inv, self.entries.len() as u32));
        self.entries.extend(eta.entries.iter().map(|&(i, v)| (i as u32, v)));
        eta.entries.len() + 1
    }

    /// Arena span of eta `k`.
    #[inline]
    fn span(&self, k: usize) -> (usize, usize) {
        let lo = self.heads[k].2 as usize;
        let hi = self.heads.get(k + 1).map_or(self.entries.len(), |h| h.2 as usize);
        (lo, hi)
    }

    /// FTRAN over the whole file: each eta in push order.
    fn apply_all(&self, x: &mut [f64]) {
        for k in 0..self.heads.len() {
            let (row, inv, _) = self.heads[k];
            let (lo, hi) = self.span(k);
            let xr = x[row as usize] * inv;
            for &(i, v) in &self.entries[lo..hi] {
                x[i as usize] -= v * xr;
            }
            x[row as usize] = xr;
        }
    }

    /// BTRAN over the whole file: each transposed eta in reverse order.
    fn apply_all_transposed(&self, y: &mut [f64]) {
        for k in (0..self.heads.len()).rev() {
            let (row, inv, _) = self.heads[k];
            let (lo, hi) = self.span(k);
            let mut s = y[row as usize];
            for &(i, v) in &self.entries[lo..hi] {
                s -= v * y[i as usize];
            }
            y[row as usize] = s * inv;
        }
    }
}

/// Product-form factorization of an `m × m` basis matrix, plus the
/// position → internal-row permutation and operation counters.
#[derive(Clone, Debug)]
pub struct Factorization {
    m: usize,
    /// Base etas (from the last crash/refactorization) followed by update
    /// etas, applied in order for FTRAN and in reverse for BTRAN.
    etas: EtaFile,
    /// Nonzeros of the base file alone, measured at the last successful
    /// (re)factorization; sets the fill headroom for update etas.
    base_nnz: usize,
    /// High-water mark of the file's nonzero count over the whole solve.
    fill_watermark: usize,
    /// Updates appended since the last (re)factorization.
    updates: usize,
    row_of_pos: Vec<usize>,
    /// FTRAN invocations (column solves against the factorization).
    pub ftran_count: u64,
    /// BTRAN invocations (row/multiplier solves).
    pub btran_count: u64,
    /// Times the eta file was rebuilt from scratch mid-solve.
    pub refactorizations: u64,
}

impl Factorization {
    /// The identity factorization: the basis IS the identity (the all-slack /
    /// all-artificial starting basis of a cold solve), position `p` in row
    /// `p`, no etas.
    pub fn identity(m: usize) -> Self {
        Factorization {
            m,
            etas: EtaFile::default(),
            base_nnz: 0,
            fill_watermark: 0,
            updates: 0,
            row_of_pos: (0..m).collect(),
            ftran_count: 0,
            btran_count: 0,
            refactorizations: 0,
        }
    }

    /// Crash-factorize the basis whose position-`p` column is `cols[p]`
    /// (sparse `(row, value)` entries). Columns are eliminated sparsest
    /// first (a static Markowitz ordering) with partial pivoting over the
    /// unclaimed rows. Returns `None` when the columns are numerically
    /// singular — the caller must fall back to a cold solve.
    pub fn factorize(m: usize, cols: &[Vec<(usize, f64)>]) -> Option<Self> {
        debug_assert_eq!(cols.len(), m);
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by_key(|&p| (cols[p].len(), p));
        let mut b = Builder::new(m);
        for &p in &order {
            let z = b.transformed(&cols[p]);
            b.pivot_best_row(p, z)?;
        }
        b.finish()
    }

    /// Internal row holding position `p`'s basic variable: FTRAN output
    /// index `row(p)` is the tableau-column entry for position `p`.
    #[inline]
    pub fn row(&self, p: usize) -> usize {
        self.row_of_pos[p]
    }

    /// Current nonzero count of the eta file (base + update etas).
    pub fn eta_nnz(&self) -> usize {
        self.etas.nnz()
    }

    /// High-water mark of the eta file's nonzero count over the solve so
    /// far. Bounded by [`fill_cap`](Self::fill_cap)` + m + 1`: the trigger
    /// is consulted after every pivot and one update eta adds at most
    /// `m + 1` nonzeros.
    pub fn fill_watermark(&self) -> usize {
        self.fill_watermark
    }

    /// Measured-fill retirement threshold: `2·base_nnz + 8·m + 256`. A
    /// dense base file earns proportionally more update headroom; a
    /// near-identity base (compaction elides its unit etas) refactorizes
    /// as soon as update fill alone passes the slack term.
    pub fn fill_cap(&self) -> usize {
        2 * self.base_nnz + FILL_SLACK_PER_ROW * self.m + FILL_SLACK_BASE
    }

    /// Apply the eta file to `x` in place (forward transform): `x` becomes
    /// the tableau column of the original column scattered into `x`, indexed
    /// by internal row (read position `p` at [`row`](Self::row)`(p)`).
    pub fn ftran(&mut self, x: &mut [f64]) {
        self.ftran_count += 1;
        self.etas.apply_all(x);
    }

    /// Apply the transposed eta file in reverse (backward transform): for
    /// `y` scattered by internal row, yields the simplex multipliers whose
    /// dot product with an original column prices that column.
    pub fn btran(&mut self, y: &mut [f64]) {
        self.btran_count += 1;
        self.etas.apply_all_transposed(y);
    }

    /// Absorb a basis exchange at position `p`: the entering column's FTRAN
    /// result `z` becomes one more eta factor pivoted in `row(p)` (elided
    /// when it is an exact identity). Returns `false` (leaving the
    /// factorization unchanged) when the pivot entry is numerically
    /// unusable.
    pub fn update(&mut self, p: usize, z: &[f64]) -> bool {
        let Some(eta) = Eta::from_column(z, self.row_of_pos[p]) else {
            return false;
        };
        self.etas.push(&eta);
        self.fill_watermark = self.fill_watermark.max(self.etas.nnz());
        self.updates += 1;
        true
    }

    /// Whether the eta file has grown past the update-count threshold or
    /// the measured-fill cap and should be rebuilt from the current basis
    /// columns.
    pub fn should_refactorize(&self) -> bool {
        self.updates >= REFACTOR_UPDATES || self.etas.nnz() > self.fill_cap()
    }

    /// Rebuild the eta file from the current basis columns, carrying the
    /// operation counters and fill watermark over. Returns `false` (keeping
    /// the existing — still valid — eta file and deferring the next rebuild)
    /// if the fresh factorization fails numerically.
    pub fn refactorize(&mut self, cols: &[Vec<(usize, f64)>]) -> bool {
        match Self::factorize(self.m, cols) {
            Some(fresh) => {
                self.etas = fresh.etas;
                self.base_nnz = self.etas.nnz();
                self.fill_watermark = self.fill_watermark.max(self.etas.nnz());
                self.updates = 0;
                self.row_of_pos = fresh.row_of_pos;
                self.refactorizations += 1;
                true
            }
            None => {
                // Defer: pretend we just refactorized so the solve makes
                // progress instead of re-attempting every pivot. The kept
                // file becomes the new fill base, so the fill trigger also
                // re-arms instead of re-firing immediately.
                self.updates = 0;
                self.base_nnz = self.etas.nnz();
                false
            }
        }
    }
}

/// Incremental crash-factorization builder: pivot columns one at a time,
/// each claiming an internal row. Used both by [`Factorization::factorize`]
/// and by the partial-basis completion in [`super::simplex`] (crash from the
/// shared sub-block of a memoized basis, then fill the unclaimed rows).
pub struct Builder {
    m: usize,
    etas: EtaFile,
    claimed: Vec<bool>,
    /// `(position, row)` pairs in pivot order; positions must form
    /// `0..m` (in any order) by `finish` time.
    assigned: Vec<(usize, usize)>,
}

impl Builder {
    pub fn new(m: usize) -> Self {
        Builder { m, etas: EtaFile::default(), claimed: vec![false; m], assigned: Vec::new() }
    }

    /// Scatter a sparse column and apply the etas accumulated so far —
    /// the column as the partially built factorization sees it.
    pub fn transformed(&self, col: &[(usize, f64)]) -> Vec<f64> {
        let mut x = vec![0.0; self.m];
        for &(i, v) in col {
            x[i] += v;
        }
        self.etas.apply_all(&mut x);
        x
    }

    /// Whether internal row `r` has already been claimed by a pivot.
    pub fn is_claimed(&self, r: usize) -> bool {
        self.claimed[r]
    }

    /// Rows still unclaimed (ascending).
    pub fn unclaimed(&self) -> Vec<usize> {
        (0..self.m).filter(|&r| !self.claimed[r]).collect()
    }

    /// Pivot position `p` in the unclaimed row where its transformed column
    /// `z` is largest in magnitude (partial pivoting; ties keep the smallest
    /// row). `None` when no unclaimed entry clears `PIVOT_EPS` — the column
    /// is dependent on those already pivoted.
    pub fn pivot_best_row(&mut self, p: usize, z: Vec<f64>) -> Option<usize> {
        let mut best_r = None;
        let mut best_v = PIVOT_EPS;
        for (r, &claimed) in self.claimed.iter().enumerate() {
            if !claimed {
                let v = z[r].abs();
                if v > best_v {
                    best_v = v;
                    best_r = Some(r);
                }
            }
        }
        let r = best_r?;
        self.pivot_at(p, r, z).then_some(r)
    }

    /// Pivot position `p` in a specific unclaimed row `r`. Returns `false`
    /// (no state change) if `r` is claimed or the pivot entry is unusable.
    pub fn pivot_at(&mut self, p: usize, r: usize, z: Vec<f64>) -> bool {
        if self.claimed[r] || z[r].abs() <= PIVOT_EPS {
            return false;
        }
        let Some(eta) = Eta::from_column(&z, r) else {
            return false;
        };
        self.etas.push(&eta);
        self.claimed[r] = true;
        self.assigned.push((p, r));
        true
    }

    /// Finish into a [`Factorization`]. `None` unless every row was claimed
    /// and the pivoted positions are exactly `0..m`.
    pub fn finish(self) -> Option<Factorization> {
        if self.assigned.len() != self.m {
            return None;
        }
        let mut row_of_pos = vec![usize::MAX; self.m];
        for &(p, r) in &self.assigned {
            if p >= self.m || row_of_pos[p] != usize::MAX {
                return None;
            }
            row_of_pos[p] = r;
        }
        let base_nnz = self.etas.nnz();
        Some(Factorization {
            m: self.m,
            etas: self.etas,
            base_nnz,
            fill_watermark: base_nnz,
            updates: 0,
            row_of_pos,
            ftran_count: 0,
            btran_count: 0,
            refactorizations: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 3×3 basis with known inverse: columns of
    /// B = [[2,0,1],[0,1,0],[4,0,3]] (column-major below).
    fn cols3() -> Vec<Vec<(usize, f64)>> {
        vec![
            vec![(0, 2.0), (2, 4.0)],
            vec![(1, 1.0)],
            vec![(0, 1.0), (2, 3.0)],
        ]
    }

    fn ftran_pos(f: &mut Factorization, rhs: &[f64]) -> Vec<f64> {
        let mut x = rhs.to_vec();
        f.ftran(&mut x);
        (0..rhs.len()).map(|p| x[f.row(p)]).collect()
    }

    #[test]
    fn factorize_solves_against_the_basis() {
        let cols = cols3();
        let mut f = Factorization::factorize(3, &cols).expect("nonsingular");
        // Solve B·w = [3, 5, 7]: det=2, w = (1, 5, 1).
        let w = ftran_pos(&mut f, &[3.0, 5.0, 7.0]);
        assert!((w[0] - 1.0).abs() < 1e-12, "{w:?}");
        assert!((w[1] - 5.0).abs() < 1e-12, "{w:?}");
        assert!((w[2] - 1.0).abs() < 1e-12, "{w:?}");
        assert_eq!(f.ftran_count, 1);
    }

    #[test]
    fn btran_matches_transposed_solve() {
        let cols = cols3();
        let mut f = Factorization::factorize(3, &cols).expect("nonsingular");
        // y with y[row(p)] = c_B[p]; after BTRAN, y·A_j prices column j.
        // Take c_B = (1, 2, 3) over positions: solve Bᵀ·y = c_B.
        let mut y = vec![0.0; 3];
        for (p, &c) in [1.0, 2.0, 3.0].iter().enumerate() {
            y[f.row(p)] = c;
        }
        f.btran(&mut y);
        // Check yᵀ·B(col p) == c_B[p].
        for (p, &c) in [1.0, 2.0, 3.0].iter().enumerate() {
            let dot: f64 = cols3()[p].iter().map(|&(i, v)| y[i] * v).sum();
            assert!((dot - c).abs() < 1e-12, "p={p}: {dot} != {c}");
        }
        assert_eq!(f.btran_count, 1);
    }

    #[test]
    fn update_replaces_one_column() {
        let cols = cols3();
        let mut f = Factorization::factorize(3, &cols).expect("nonsingular");
        // Replace position 2's column with [1, 1, 1].
        let newcol = vec![(0, 1.0), (1, 1.0), (2, 1.0)];
        let mut z = vec![0.0; 3];
        for &(i, v) in &newcol {
            z[i] += v;
        }
        f.ftran(&mut z);
        assert!(f.update(2, &z));
        // New basis B' = [[2,0,1],[0,1,1],[4,0,1]]; solve B'·w = [4, 3, 6]:
        // det = 2·1 - 1·(-4)... check by substitution: w = (1, 1, 2).
        let w = ftran_pos(&mut f, &[4.0, 3.0, 6.0]);
        assert!((2.0 * w[0] + w[2] - 4.0).abs() < 1e-12, "{w:?}");
        assert!((w[1] + w[2] - 3.0).abs() < 1e-12, "{w:?}");
        assert!((4.0 * w[0] + w[2] - 6.0).abs() < 1e-12, "{w:?}");
    }

    #[test]
    fn singular_columns_are_rejected() {
        // Two identical columns.
        let cols = vec![vec![(0, 1.0), (1, 2.0)], vec![(0, 1.0), (1, 2.0)]];
        assert!(Factorization::factorize(2, &cols).is_none());
    }

    #[test]
    fn refactorize_resets_the_eta_file() {
        let cols = cols3();
        let mut f = Factorization::factorize(3, &cols).expect("nonsingular");
        let mut z = vec![1.0, 1.0, 1.0];
        f.ftran(&mut z);
        // Reconstruct the raw (row-space) column before permutation tricks:
        // just update with the FTRANed column directly.
        assert!(f.update(0, &z));
        assert!(f.refactorize(&cols));
        assert_eq!(f.refactorizations, 1);
        // Back to the original basis: the solve from the first test holds.
        let w = ftran_pos(&mut f, &[3.0, 5.0, 7.0]);
        assert!((w[0] - 1.0).abs() < 1e-12, "{w:?}");
    }

    #[test]
    fn identity_etas_are_elided() {
        // Factorizing the identity basis produces only unit-pivot etas,
        // all elided: zero fill, and transforms stay exact no-ops.
        let unit: Vec<Vec<(usize, f64)>> = (0..4).map(|r| vec![(r, 1.0)]).collect();
        let mut f = Factorization::factorize(4, &unit).expect("nonsingular");
        assert_eq!(f.eta_nnz(), 0);
        let mut x = vec![0.25, -0.0, 3.5, 7.125];
        let before = x.clone();
        f.ftran(&mut x);
        let same = x
            .iter()
            .zip(&before)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "{x:?} != {before:?}");
        // An update with a unit column at the pivot row is likewise elided.
        let mut z = vec![0.0; 4];
        z[f.row(1)] = 1.0;
        assert!(f.update(1, &z));
        assert_eq!(f.eta_nnz(), 0);
    }

    #[test]
    fn fill_trigger_fires_at_the_measured_boundary() {
        // m = 8, identity base: cap = 2·0 + 8·8 + 256 = 320 nonzeros.
        // Each fully dense update eta (pivot 2.0, seven off-row entries)
        // adds exactly 8 nonzeros.
        let mut f = Factorization::identity(8);
        assert_eq!(f.fill_cap(), 320);
        let z = vec![2.0; 8];
        for k in 1..=40 {
            assert!(f.update(k % 8, &z));
            assert_eq!(f.eta_nnz(), 8 * k);
        }
        // 320 nonzeros == cap exactly: at the boundary, no trigger yet
        // (and the update-count trigger is far off at 40 < 64).
        assert!(!f.should_refactorize());
        assert!(f.update(0, &z));
        // 328 > 320 with only 41 updates: the fill term fires, not the
        // update count.
        assert!(f.should_refactorize());
        assert_eq!(f.fill_watermark(), 328);

        // A successful rebuild from unit columns drops fill to zero
        // (identity etas elided), re-arms the trigger, and keeps the
        // watermark as the recorded high-water mark.
        let unit: Vec<Vec<(usize, f64)>> = (0..8).map(|r| vec![(r, 1.0)]).collect();
        assert!(f.refactorize(&unit));
        assert_eq!(f.eta_nnz(), 0);
        assert!(!f.should_refactorize());
        assert_eq!(f.fill_watermark(), 328);

        // Refill past the cap, then fail the rebuild (singular columns):
        // the defer path keeps the file but re-bases the fill cap on it,
        // so the trigger re-arms instead of firing every pivot.
        for k in 1..=41 {
            assert!(f.update(k % 8, &z));
        }
        assert!(f.should_refactorize());
        let singular: Vec<Vec<(usize, f64)>> = (0..8).map(|_| vec![(0, 1.0)]).collect();
        assert!(!f.refactorize(&singular));
        assert_eq!(f.eta_nnz(), 328, "failed rebuild keeps the valid file");
        assert_eq!(f.fill_cap(), 2 * 328 + 320);
        assert!(!f.should_refactorize());
    }

    #[test]
    fn builder_completes_a_partial_basis() {
        let mut b = Builder::new(3);
        // Claim positions 0 and 1 from a partial column set.
        let z0 = b.transformed(&[(0, 2.0), (2, 4.0)]);
        assert!(b.pivot_best_row(0, z0).is_some());
        let z1 = b.transformed(&[(1, 1.0)]);
        assert!(b.pivot_best_row(1, z1).is_some());
        assert_eq!(b.unclaimed().len(), 1);
        // Fill the last row with a unit column there.
        let r = b.unclaimed()[0];
        let z2 = b.transformed(&[(r, 1.0)]);
        assert!(b.pivot_at(2, r, z2));
        assert!(b.finish().is_some());
    }
}
