//! End-to-end test: the full three-layer stack — plan (L3 coordinator) →
//! load AOT artifacts (L2 jax model containing the L1 Pallas kernel) →
//! serve synthetic camera streams through the dynamic batcher on the PJRT
//! CPU client — in one process, with assertions on throughput and routing.
//!
//! Requires `make artifacts` (skipped gracefully if missing so `cargo test`
//! stays runnable from a clean checkout).

use camflow::cameras::{camera_at, StreamRequest};
use camflow::catalog::Catalog;
use camflow::coordinator::{Planner, PlannerConfig};
use camflow::geo::cities;
use camflow::profiles::{Program, Resolution};
use camflow::server::{serve, ServeConfig};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn requests() -> Vec<StreamRequest> {
    vec![
        StreamRequest::new(
            camera_at(0, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
            Program::Zf,
            3.0,
        ),
        StreamRequest::new(
            camera_at(1, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0),
            Program::Zf,
            2.0,
        ),
        StreamRequest::new(
            camera_at(2, "New York", cities::NEW_YORK, Resolution::VGA, 30.0),
            Program::Vgg16,
            1.0,
        ),
    ]
}

#[test]
fn three_layer_stack_serves_planned_workload() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let requests = requests();
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    let plan = Planner::new(catalog, PlannerConfig::st3()).plan(&requests).unwrap();
    assert!(!plan.instances.is_empty());

    let cfg = ServeConfig {
        artifacts_dir: artifacts,
        duration_s: 8.0,
        time_scale: 10.0,
        batch_window_ms: 25,
        queue_capacity: 128,
        seed: 13,
    };
    let fps = plan.delivered_fps(&requests);
    let report = serve(&plan, &requests, &fps, &cfg).unwrap();

    // Expected ~ (3+2+1) fps x 8 s = 48 frames.
    let expected = (fps.iter().sum::<f64>() * cfg.duration_s) as u64;
    assert!(
        report.total_frames_analyzed >= expected * 7 / 10,
        "analyzed {} of ~{expected}",
        report.total_frames_analyzed
    );
    assert!(report.drop_rate() < 0.25, "drop rate {}", report.drop_rate());
    assert!(report.detections > 0, "detectors returned nothing");
    // Per-instance accounting adds up.
    let per_inst: u64 = report.instances.iter().map(|i| i.frames_analyzed).sum();
    assert_eq!(per_inst, report.total_frames_analyzed);
    // Latency is recorded and sane (sub-second p99 at this load).
    for i in &report.instances {
        if i.frames_analyzed > 0 {
            assert!(i.e2e_p99_ms > 0.0 && i.e2e_p99_ms < 5_000.0, "{i:?}");
        }
    }
}

#[test]
fn serving_respects_planned_routing() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    // Two streams, ST1 -> CPU-only plan; both streams on CPU instances.
    let requests = requests()[..2].to_vec();
    let catalog =
        Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]));
    let plan = Planner::new(catalog, PlannerConfig::st1()).plan(&requests).unwrap();
    assert!(plan.instances.iter().all(|i| !i.has_gpu));

    let cfg = ServeConfig {
        artifacts_dir: artifacts,
        duration_s: 4.0,
        time_scale: 10.0,
        batch_window_ms: 20,
        queue_capacity: 64,
        seed: 5,
    };
    let fps = plan.delivered_fps(&requests);
    let report = serve(&plan, &requests, &fps, &cfg).unwrap();
    assert!(report.total_frames_analyzed > 0);
    assert_eq!(report.instances.len(), plan.instances.len());
}
