//! Integration tests over the public API: planner ↔ cloud simulator ↔
//! runtime ↔ config, plus exact-solver cross-validation against brute force.

use camflow::cameras::{camera_at, scenarios, StreamRequest};
use camflow::catalog::{Catalog, Dims};
use camflow::cloudsim::CloudSim;
use camflow::config::{RunConfig, StrategyName};
use camflow::coordinator::{Planner, PlannerConfig};
use camflow::geo::cities;
use camflow::packing::heuristic::simple_problem;
use camflow::packing::mcvbp::{solve, SolveOptions};
use camflow::packing::{Packing, PackedBin};
use camflow::profiles::{Program, Resolution};
use camflow::util::Rng;

fn fig3_catalog() -> Catalog {
    Catalog::builtin().restrict(Some(&["c4.2xlarge", "g2.2xlarge"]), Some(&["us-east-2"]))
}

#[test]
fn config_drives_full_planning_pipeline() {
    for scenario in 1..=3usize {
        for strategy in [StrategyName::St1, StrategyName::St2, StrategyName::St3] {
            let cfg = RunConfig { scenario, strategy, ..Default::default() };
            let requests = cfg.requests().unwrap();
            let planner = Planner::new(cfg.catalog(), cfg.strategy.to_planner_config());
            match planner.plan(&requests) {
                Ok(plan) => {
                    assert!(plan.cost_per_hour > 0.0);
                    let assigned: usize = plan.instances.iter().map(|i| i.streams.len()).sum();
                    assert_eq!(assigned, requests.len());
                }
                Err(e) => {
                    // Only the paper's Fail cell may fail: S3 x ST1.
                    assert!(
                        scenario == 3 && strategy == StrategyName::St1,
                        "unexpected failure {scenario}/{strategy:?}: {e}"
                    );
                }
            }
        }
    }
}

#[test]
fn plan_to_cloudsim_billing_consistency() {
    let planner = Planner::new(fig3_catalog(), PlannerConfig::st3());
    let scn = scenarios::fig3_scenario1();
    let plan = planner.plan(&scn.requests).unwrap();

    let mut sim = CloudSim::new(fig3_catalog());
    let ids = sim.apply_plan(&plan).unwrap();
    assert_eq!(ids.len(), plan.instances.len());
    assert!((sim.hourly_rate() - plan.cost_per_hour).abs() < 1e-9);

    sim.advance(7200.0);
    assert!((sim.accrued_usd() - 2.0 * plan.cost_per_hour).abs() < 1e-9);

    // Utilization stays below the degradation threshold by construction.
    for id in ids {
        let inst = sim.get(id).unwrap();
        assert!(inst.utilization() <= 0.9 + 1e-9, "util {}", inst.utilization());
        assert_eq!(inst.degradation_factor(), 1.0);
    }
}

/// Brute-force optimal packing for tiny single-demand-vector instances.
fn brute_force_cost(items: &[(f64, f64, usize)], bins: &[(f64, f64, f64)]) -> Option<f64> {
    // Expand items into individual units.
    let mut units = Vec::new();
    for (i, &(c, m, n)) in items.iter().enumerate() {
        for _ in 0..n {
            units.push((i, c, m));
        }
    }
    let nu = units.len();
    assert!(nu <= 7, "brute force limited");
    // Assign each unit to a bin instance; bins open lazily. Search over
    // partitions via recursive assignment to at most nu bins x bin types.
    fn rec(
        u: usize,
        units: &[(usize, f64, f64)],
        bins: &[(f64, f64, f64)],
        open: &mut Vec<(usize, f64, f64)>, // (type, used cpu, used mem)
        best: &mut f64,
        cur: f64,
    ) {
        if cur >= *best {
            return;
        }
        if u == units.len() {
            *best = cur;
            return;
        }
        let (_, c, m) = units[u];
        for i in 0..open.len() {
            let (t, uc, um) = open[i];
            let (bc, bm, _) = bins[t];
            if uc + c <= 0.9 * bc + 1e-9 && um + m <= 0.9 * bm + 1e-9 {
                open[i] = (t, uc + c, um + m);
                rec(u + 1, units, bins, open, best, cur);
                open[i] = (t, uc, um);
            }
        }
        for (t, &(bc, bm, cost)) in bins.iter().enumerate() {
            if c <= 0.9 * bc + 1e-9 && m <= 0.9 * bm + 1e-9 {
                open.push((t, c, m));
                rec(u + 1, units, bins, open, best, cur + cost);
                open.pop();
            }
        }
    }
    let mut best = f64::INFINITY;
    rec(0, &units, bins, &mut Vec::new(), &mut best, 0.0);
    best.is_finite().then_some(best)
}

#[test]
fn exact_solver_matches_brute_force_on_random_instances() {
    let mut rng = Rng::new(555);
    let mut checked = 0;
    for round in 0..25 {
        let n_groups = 1 + rng.index(3);
        let mut items = Vec::new();
        let mut total = 0usize;
        for _ in 0..n_groups {
            let n = 1 + rng.index(3);
            if total + n > 6 {
                break;
            }
            total += n;
            items.push((rng.range_f64(0.5, 6.0), rng.range_f64(0.5, 8.0), n));
        }
        if items.is_empty() {
            continue;
        }
        let bins = [(8.0, 15.0, 1.0), (16.0, 30.0, 1.7), (4.0, 8.0, 0.55)];
        let p = simple_problem(&items, &bins);
        let Ok((packing, _)) = solve(&p, &SolveOptions::default()) else {
            continue;
        };
        let Some(opt) = brute_force_cost(&items, &bins) else {
            continue;
        };
        let got = packing.total_cost(&p);
        // Quantization may cost at most one grid cell per item per dim; allow
        // one small-bin step of slack, but never better than optimal.
        assert!(got >= opt - 1e-9, "round {round}: beat brute force?!");
        assert!(
            got <= opt + 0.56,
            "round {round}: exact {got} far above optimal {opt} (items {items:?})"
        );
        checked += 1;
    }
    assert!(checked >= 10, "too few instances exercised ({checked})");
}

#[test]
fn location_strategies_cost_ordering_holds_across_seeds() {
    let catalog = Catalog::builtin();
    for seed in [2, 9, 33] {
        let requests = scenarios::fig6_workload(18, 2.0, seed);
        let nl = Planner::new(catalog.clone(), PlannerConfig::nl()).plan(&requests).unwrap();
        let armvac =
            Planner::new(catalog.clone(), PlannerConfig::armvac()).plan(&requests).unwrap();
        let gcl = Planner::new(catalog.clone(), PlannerConfig::gcl()).plan(&requests).unwrap();
        assert!(gcl.cost_per_hour <= armvac.cost_per_hour + 1e-9, "seed {seed}");
        assert!(gcl.cost_per_hour <= nl.cost_per_hour + 1e-9, "seed {seed}");
    }
}

#[test]
fn degraded_streams_get_capped_fps() {
    // A camera far from every region demanding a very high rate.
    let requests = vec![StreamRequest::new(
        camera_at(0, "Mexico City", cities::MEXICO_CITY, Resolution::VGA, 60.0),
        Program::Zf,
        60.0,
    )];
    let planner = Planner::new(Catalog::builtin(), PlannerConfig::gcl());
    let plan = planner.plan(&requests).unwrap();
    assert_eq!(plan.degraded, vec![0]);
    let fps = plan.delivered_fps(&requests);
    assert!(fps[0] < 60.0, "delivered fps must be capped, got {}", fps[0]);
    assert!(fps[0] > 0.0);
}

#[test]
fn packing_validation_rejects_corrupted_plans() {
    let p = simple_problem(&[(2.0, 1.0, 2)], &[(8.0, 15.0, 1.0)]);
    // Overfull bin.
    let bad = Packing {
        bins: vec![PackedBin { bin_type: 0, counts: vec![9] }],
    };
    assert!(bad.validate(&p).is_err());
    // Wrong counts length.
    let bad = Packing {
        bins: vec![PackedBin { bin_type: 0, counts: vec![1, 1] }],
    };
    assert!(bad.validate(&p).is_err());
}

#[test]
fn adaptive_manager_full_cycle_with_sim() {
    let planner = Planner::new(fig3_catalog(), PlannerConfig::st3());
    let mut mgr = camflow::coordinator::adaptive::AdaptiveManager::new(planner);
    let mut sim = CloudSim::new(fig3_catalog());

    let mk = |fps: f64| -> Vec<StreamRequest> {
        (0..4)
            .map(|i| {
                StreamRequest::new(
                    camera_at(i, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                    Program::Zf,
                    fps,
                )
            })
            .collect()
    };

    let mut total_by_hour = Vec::new();
    for (hour, fps) in [(0, 0.5), (1, 8.0), (2, 8.0), (3, 0.5)] {
        let _ = hour;
        mgr.replan(mk(fps)).unwrap();
        sim.apply_plan(mgr.current_plan().unwrap()).unwrap();
        sim.advance(3600.0);
        total_by_hour.push(sim.accrued_usd());
    }
    // Rush hours cost more than calm hours.
    let calm1 = total_by_hour[0];
    let rush = total_by_hour[2] - total_by_hour[1];
    let calm2 = total_by_hour[3] - total_by_hour[2];
    assert!(rush > calm1, "rush {rush} calm {calm1}");
    assert!((calm2 - calm1).abs() < 1e-6, "calm hours should cost the same");
}

#[test]
fn sticky_replan_moves_only_the_diff_and_fleet_stays_consistent() {
    let planner = Planner::new(fig3_catalog(), PlannerConfig::st3());
    let mut mgr = camflow::coordinator::adaptive::AdaptiveManager::new(planner);
    let mut sim = CloudSim::new(fig3_catalog());

    let mk = |ids: std::ops::Range<u64>| -> Vec<StreamRequest> {
        ids.map(|i| {
            StreamRequest::new(
                camera_at(i, "Chicago", cities::CHICAGO, Resolution::HD720, 30.0),
                Program::Zf,
                1.0,
            )
        })
        .collect()
    };

    mgr.replan(mk(0..6)).unwrap();
    sim.apply_plan(mgr.current_plan().unwrap()).unwrap();

    // One camera leaves, a new one arrives: five streams survive, and the
    // sticky Expand must not re-deal all of them.
    let mut requests = mk(1..6);
    requests.extend(mk(10..11));
    let report = mgr.replan(requests.clone()).unwrap();
    assert_eq!(report.streams_surviving, 5);
    assert!(report.streams_moved < 5, "sticky expand re-dealt the survivors: {report:?}");
    assert!(report.churn_ratio() < 1.0);

    // The plan still covers every stream exactly once, and the reconciled
    // fleet bills exactly the plan's rate.
    let plan = mgr.current_plan().unwrap();
    let mut seen = vec![0usize; requests.len()];
    for inst in &plan.instances {
        for &s in &inst.streams {
            seen[s] += 1;
        }
    }
    assert!(seen.iter().all(|&c| c == 1), "assignments: {seen:?}");
    sim.apply_plan(plan).unwrap();
    assert!((sim.hourly_rate() - plan.cost_per_hour).abs() < 1e-9);
}

#[test]
fn occurrence_shift_across_distinct_camera_objects_stays_correct() {
    // ROADMAP open item (PR 4): the dirty-tracking index keys on StreamKey,
    // whose `occurrence` field is slice-order dependent. Two requests can
    // share the whole (camera id, program, fps) tuple while their *camera
    // objects* differ in location — two physical cameras misconfigured onto
    // one id. When the first departs, the survivor's occurrence shifts from
    // 1 to 0, and its stream key now points at the other camera's previous
    // fingerprint. The fingerprint mismatch must force a conservative
    // re-run of that request's front-end — never a silent reuse of the
    // wrong camera's group. The re-run is the documented cost of the
    // slice-order-dependent occurrence: it is memoized (eligibility memo +
    // group arena), so only per-request key work repeats, and the outcome
    // stays bit-identical to a cold rebuild.
    use camflow::coordinator::pipeline::{plan_with_context, PlanContext};
    let catalog = Catalog::builtin();
    let cfg = PlannerConfig::gcl();
    // 20 fps keeps the coverage circles regional, so the two same-id
    // cameras genuinely group apart.
    let cam_a = camera_at(7, "Chicago", cities::CHICAGO, Resolution::VGA, 30.0);
    let cam_b = camera_at(7, "Tokyo", cities::TOKYO, Resolution::VGA, 30.0); // same id!
    let req = |cam: &camflow::cameras::Camera| StreamRequest::new(cam.clone(), Program::Zf, 20.0);

    let mut warm = PlanContext::new();
    let both = vec![req(&cam_a), req(&cam_b)];
    let first = plan_with_context(&catalog, &cfg, &both, &mut warm).unwrap();
    assert_eq!(first.problem.items.len(), 2, "distinct locations must group apart");

    // Camera A departs: the Tokyo request shifts from occurrence 1 to 0.
    let shifted = vec![req(&cam_b)];
    let warm_plan = plan_with_context(&catalog, &cfg, &shifted, &mut warm).unwrap();
    assert_eq!(
        (warm.stats.front_unchanged, warm.stats.front_changed),
        (0, 1),
        "the shifted request must conservatively re-run, not reuse the \
         departed camera's group: {:?}",
        warm.stats
    );
    let cold_plan =
        plan_with_context(&catalog, &cfg, &shifted, &mut PlanContext::new()).unwrap();
    assert_eq!(warm_plan.problem, cold_plan.problem, "shift must match a cold rebuild");
    assert!((warm_plan.cost_per_hour - cold_plan.cost_per_hour).abs() < 1e-9);
    let region = warm_plan.instances[0].region_idx;
    assert!(
        cities::TOKYO.distance_km(&warm_plan.region_locations[region]) < 4000.0,
        "survivor must plan near Tokyo, not near the departed Chicago camera"
    );
}

#[test]
fn bench_adaptive_portfolio_fields_are_populated_and_schema_checked() {
    // `bench_adaptive`'s portfolio section and this test call the same
    // library scenario (`camflow::bench::portfolio::run`), so the
    // BENCH_adaptive.json fields cannot drift from what is checked here.
    // Round-trip through util::json to pin the serialized schema.
    use camflow::util::json;
    let outcome = camflow::bench::portfolio::run();
    let doc = outcome.to_json();
    let parsed = json::parse(&json::to_string_pretty(&doc)).unwrap();
    for key in [
        "pool_shared_jobs",
        "budget_pooled_donated",
        "flip_churn_ratio",
        "sticky_churn_ratio",
        "winner_flips",
        "flip_provisioned",
        "flip_terminated",
    ] {
        let v = parsed
            .get_f64(key)
            .unwrap_or_else(|e| panic!("BENCH_adaptive portfolio field {key} missing: {e}"));
        assert!(v.is_finite() && v >= 0.0, "{key} = {v}");
    }
    // Populated, not just present: the shared pool ran jobs, the
    // cross-candidate budget pool engaged, and the forced flip stayed
    // churn-free relative to the sticky control.
    assert!(parsed.get_f64("pool_shared_jobs").unwrap() > 0.0);
    assert!(parsed.get_f64("budget_pooled_donated").unwrap() > 0.0);
    assert!(parsed.get_f64("winner_flips").unwrap() >= 1.0);
    assert_eq!(parsed.get_f64("flip_provisioned").unwrap(), 0.0);
    assert_eq!(parsed.get_f64("flip_terminated").unwrap(), 0.0);
    assert!(
        parsed.get_f64("flip_churn_ratio").unwrap()
            <= parsed.get_f64("sticky_churn_ratio").unwrap() + 0.05
    );
}

#[test]
fn dims_catalog_geo_contract() {
    // Capacity vectors in the catalog are internally consistent with the
    // 4-dimensional packing space.
    let c = Catalog::builtin();
    for t in &c.types {
        assert!(t.capacity.vcpus > 0.0);
        assert!(t.capacity.mem_gib > 0.0);
        assert_eq!(t.has_gpu(), t.capacity.gpus > 0.0);
        if t.has_gpu() {
            assert!(t.capacity.gpu_mem_gib > 0.0);
            assert!(t.gpu_speed >= 1.0);
        }
        let arr = t.capacity.as_array();
        assert_eq!(Dims::from_array(arr), t.capacity);
    }
    // All regions at plausible coordinates.
    for r in &c.regions {
        assert!((-60.0..=65.0).contains(&r.location.lat), "{}", r.id);
        assert!((-180.0..=180.0).contains(&r.location.lon));
    }
}

#[test]
fn bench_closedloop_fields_are_populated_and_schema_checked() {
    // `bench_closedloop` and this test call the same library scenarios
    // (`camflow::bench::closedloop::run`), so the BENCH_closedloop.json
    // fields cannot drift from what is checked here. Round-trip through
    // util::json to pin the serialized schema.
    use camflow::util::json;
    let outcome = camflow::bench::closedloop::run();
    let doc = outcome.to_json();
    let parsed = json::parse(&json::to_string_pretty(&doc)).unwrap();
    for key in [
        "over_declared_usd_per_hour",
        "over_closedloop_usd_per_hour",
        "over_final_drop_rate",
        "over_fleet_util_declared",
        "over_fleet_util_closed",
        "over_feedback_streams",
        "under_declared_usd_per_hour",
        "under_corrected_usd_per_hour",
        "under_epoch0_drop_rate",
        "under_final_drop_rate",
        "under_nofeedback_drop_rate",
        "under_max_shed_tier",
        "under_peak_streams_shed",
        "under_degraded_tier_streams",
    ] {
        let v = parsed
            .get_f64(key)
            .unwrap_or_else(|e| panic!("BENCH_closedloop field {key} missing: {e}"));
        assert!(v.is_finite() && v >= 0.0, "{key} = {v}");
    }
    // The acceptance bars, re-checked on the parsed document: an
    // over-declared fleet gets no costlier, an under-declared fleet's drop
    // rate stays bounded while the open-loop control keeps dropping, and
    // the new solver counters actually counted.
    assert!(
        parsed.get_f64("over_closedloop_usd_per_hour").unwrap()
            <= parsed.get_f64("over_declared_usd_per_hour").unwrap() + 1e-9
    );
    assert!(parsed.get_f64("under_final_drop_rate").unwrap() <= 0.01);
    assert!(parsed.get_f64("under_nofeedback_drop_rate").unwrap() > 0.1);
    assert!(parsed.get_f64("under_max_shed_tier").unwrap() >= 1.0);
    assert!(parsed.get_f64("over_feedback_streams").unwrap() > 0.0);
    assert!(parsed.get_f64("under_degraded_tier_streams").unwrap() > 0.0);
}

#[test]
fn bench_spot_fields_are_populated_and_schema_checked() {
    // `bench_spot` and this test call the same library replay
    // (`camflow::bench::spot::run`), so the BENCH_spot.json fields cannot
    // drift from what is checked here. The binary-shaped document is also
    // validated against the canonical schema the binary itself gates on.
    use camflow::bench::schema;
    use camflow::util::json::{self, Value};
    let outcome = camflow::bench::spot::run();
    let doc = Value::obj(vec![
        ("bench", Value::str("spot")),
        ("spot", outcome.to_json()),
        ("loop_ms", Value::num(1.0)),
    ]);
    schema::validate(&doc, &schema::SPOT).unwrap();
    let parsed = json::parse(&json::to_string_pretty(&doc)).unwrap();
    let spot = parsed.get("spot").unwrap();
    for key in [
        "queries",
        "total_units",
        "spot_backfill_usd",
        "spot_live_usd",
        "spot_revocations",
        "spot_rehomed_items",
        "spot_deadline_misses",
        "spot_completed_units",
        "spot_rounds_adopted",
        "od_backfill_usd",
        "od_deadline_misses",
        "od_completed_units",
        "savings_frac",
        "miss_rate",
    ] {
        let v = spot
            .get_f64(key)
            .unwrap_or_else(|e| panic!("BENCH_spot field {key} missing: {e}"));
        assert!(v.is_finite() && v >= 0.0, "{key} = {v}");
    }
    // The acceptance bars, re-checked on the parsed document: spot backfill
    // strictly cheaper than the on-demand-only control, the storm actually
    // revoked capacity, the certified gate adopted spot schedules, and the
    // deadline-miss rate held under the storms.
    assert!(spot.get_f64("spot_backfill_usd").unwrap() < spot.get_f64("od_backfill_usd").unwrap());
    assert!(spot.get_f64("savings_frac").unwrap() > 0.0);
    assert!(spot.get_f64("miss_rate").unwrap() <= 0.01);
    assert!(spot.get_f64("spot_revocations").unwrap() > 0.0);
    assert!(spot.get_f64("spot_rounds_adopted").unwrap() > 0.0);
}

#[test]
fn bench_schemas_are_documented_field_by_field() {
    // Every field each artifact schema declares must be documented in the
    // artifact's own section of docs/BENCH_SCHEMAS.md (the conventions
    // preamble covers page-wide fields like `bench`). Renaming a bench
    // output without updating the docs page fails here, not in review.
    use camflow::bench::schema::{self, PLANET, SOLVER, SPOT};
    let md = include_str!("../../docs/BENCH_SCHEMAS.md");
    let preamble = &md[..md.find("\n## ").expect("BENCH_SCHEMAS.md has sections")];
    for s in [&SOLVER, &PLANET, &SPOT] {
        let section = schema::doc_section(md, s.artifact)
            .unwrap_or_else(|| panic!("{} has no section in BENCH_SCHEMAS.md", s.artifact));
        for name in s.field_names() {
            let documented = section.contains(&format!("`{name}`"))
                || section.contains(&format!("`{name}[]`"))
                || preamble.contains(&format!("`{name}`"));
            assert!(documented, "{}: `{name}` undocumented in BENCH_SCHEMAS.md", s.artifact);
        }
    }
}
